"""Per-arch smoke tests: REDUCED variant, one forward + one train step on CPU.

Required by the brief: each assigned architecture instantiates a reduced
config of the same family (<=2-ish layers, d_model <= 512, <= 4 experts) and
runs a forward + a train step, asserting output shapes and finiteness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data.pipeline import add_modality_stubs
from repro.models.model import build_model
from repro.optim import adam

ARCH_IDS = [a for a in ARCHS if a != "gpt2"]

B, T = 2, 32


def _batch(cfg, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, T)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    batch = add_modality_stubs(
        batch, cfg.family, audio_frames=cfg.audio_frames,
        num_patches=cfg.num_patches, d_model=cfg.d_model, seed=seed)
    return {k: jnp.asarray(v) for k, v in batch.items()}


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_config(arch, "reduced")
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_limits(arch):
    cfg = get_config(arch, "reduced")
    assert cfg.d_model <= 512
    assert cfg.num_layers <= 4
    if cfg.num_experts:
        assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_finite(arch, built):
    cfg, model, params = built(arch)
    batch = _batch(cfg)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, built):
    cfg, model, params = built(arch)
    batch = _batch(cfg)

    acfg = adam.AdamConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    ost = adam.init(params, acfg)

    @jax.jit
    def step(params, ost, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: model.loss_fn(p, batch), has_aux=True)(params)
        params, ost, mets = adam.update(params, grads, ost, acfg)
        return params, ost, loss, mets

    p1, ost, loss, mets = step(params, ost, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(mets["grad_norm"]) > 0
    # params actually changed
    delta = max(
        float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1))
    )
    assert delta > 0
    # second step still finite
    _, _, loss2, _ = step(p1, ost, batch)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stage_pinning_non_block_params(arch, built):
    """Boundary pins in ``_layer_stage``: embeddings live on stage 0,
    lm_head / final-norm on stage S-1 — explicitly, for every family,
    rather than whatever a layer-index regex falls through to."""
    from repro.core import classify_leaves

    cfg, model, params = built(arch)
    S = 3
    leaves = classify_leaves(params, cfg.num_layers, S)
    assert leaves, arch
    saw_embed = saw_head = False
    for leaf in leaves:
        in_stage = "stages" in leaf.path
        if not in_stage and "embed" in leaf.path:
            assert leaf.stage == 0, f"{arch}: {leaf.path} -> {leaf.stage}"
            saw_embed = True
        if not in_stage and ("lm_head" in leaf.path
                             or "final_norm" in leaf.path):
            assert leaf.stage == S - 1, \
                f"{arch}: {leaf.path} -> {leaf.stage}"
            saw_head = True
        assert 0 <= leaf.stage < S, f"{arch}: {leaf.path} -> {leaf.stage}"
    assert saw_embed and saw_head, f"{arch}: pins not exercised"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_block_params_follow_stage_index(arch, built):
    """Block leaves land on their own ``['stages'][i]`` group (rescaled when
    the layout granularity differs from the requested S)."""
    from repro.core import classify_leaves

    cfg, model, params = built(arch)
    n_groups = max(1, min(cfg.num_stages, cfg.num_layers))
    leaves = classify_leaves(params, cfg.num_layers, n_groups)
    import re
    for leaf in leaves:
        m = re.search(r"\['stages'\]\[(\d+)\]", leaf.path)
        if m is not None:
            i = int(m.group(1))
            assert leaf.stage == min(i, n_groups - 1), \
                f"{arch}: {leaf.path} -> {leaf.stage}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_stage_adapter_partition_roundtrip(arch, built):
    """Every assigned arch's family has a stage adapter whose
    partition/merge is lossless on the REDUCED config (the flat<->stacked
    relayout the pipelined trainer rides on)."""
    from repro.pipeline.partition import make_partition, pipeline_supported

    cfg, model, params = built(arch)
    S = max(1, cfg.num_stages)
    reason = pipeline_supported(cfg, S)
    assert reason is None, f"{arch}: {reason}"
    part = make_partition(model, S)
    stage_p, shared_p = part.partition_params(params)
    for leaf in jax.tree_util.tree_leaves(stage_p):
        assert leaf.shape[0] == S
    merged = part.merge_params(stage_p, shared_p)
    ref, out = jax.tree_util.tree_flatten(params), \
        jax.tree_util.tree_flatten(merged)
    assert ref[1] == out[1], arch
    for a, b in zip(ref[0], out[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch, built):
    cfg, model, params = built(arch)
    if cfg.family == "whisper":
        from repro.models import encdec
        batch = _batch(cfg)
        cache = encdec.init_cache(cfg, B, 64, frames=batch["frames"], params=params)
    else:
        cache = model.init_cache(B, 64)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, cache, tok)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache len advanced
    assert int(cache2["len"]) == 1
