"""Marchenko–Pastur law + g-table: correctness vs real SVD, properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.mp_law import g_table, mp_cdf, mp_support, sample_eigenvalues


def test_mp_cdf_monotone_and_normalized():
    m, n = 128, 512
    a, b = mp_support(m, n)
    lam = np.linspace(a, b, 1000)
    cdf = mp_cdf(lam, m, n)
    assert cdf[0] == pytest.approx(0.0, abs=1e-6)
    assert cdf[-1] == pytest.approx(1.0, abs=1e-6)
    assert np.all(np.diff(cdf) >= -1e-12)


@pytest.mark.parametrize("m,n", [(64, 256), (128, 128), (256, 1024)])
def test_gtable_matches_svd(m, n):
    tbl = g_table(m, n)
    rng = np.random.default_rng(0)
    A = rng.standard_normal((m, n))
    s = np.linalg.svd(A, compute_uv=False)
    # r = m-1 is excluded: the extreme spectral edge is high-variance in a
    # single draw (and rank ~ m is never a useful compression operating point)
    for r in (0, m // 8, m // 2, 7 * m // 8):
        actual = np.sqrt((s[r:] ** 2).sum())
        assert tbl(r) == pytest.approx(actual, rel=0.05)


def test_gtable_monotone_decreasing():
    tbl = g_table(64, 256)
    g = tbl.g
    assert np.all(np.diff(g) <= 1e-9)
    assert g[-1] == pytest.approx(0.0, abs=1e-9)


@given(r=st.integers(0, 64))
@settings(max_examples=30, deadline=None)
def test_inverse_consistency(r):
    tbl = g_table(64, 256)
    assert tbl.rank_for_error(tbl(r)) <= r  # conservative inverse


@given(h_drop=st.floats(0.0, 0.5))
@settings(max_examples=30, deadline=None)
def test_theorem3_monotone_in_entropy(h_drop):
    """Entropy decrease never increases the rank (Theorem 3 direction)."""
    tbl = g_table(64, 256)
    r0 = 32
    r1 = tbl.theorem3_rank(r0, 3.0, 3.0 - h_drop)
    assert r1 <= r0


def test_sample_eigenvalues_mass():
    """Total eigenvalue mass ~ E||A||_F^2 = m*n for unit variance."""
    m, n = 128, 512
    lam = sample_eigenvalues(m, n)
    assert lam.sum() == pytest.approx(m * n, rel=0.02)


def test_randomized_variant_agrees():
    m, n = 128, 512
    det = sample_eigenvalues(m, n, stratified=True)
    rnd = sample_eigenvalues(m, n, stratified=False,
                             rng=np.random.default_rng(7))
    assert rnd.sum() == pytest.approx(det.sum(), rel=0.1)
