"""Telemetry subsystem: registry semantics, sink round-trips, trainer
series reconciling with the wire-byte/DAC ledgers, tick-trace span oracle,
and the fault-event log."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.obs import (
    JsonlSink, MemorySink, MetricsRegistry, expected_span_count, load_trace,
    read_jsonl, tick_trace_events, validate_trace, write_csv,
    write_chrome_trace,
)
from repro.obs.trace import EXTRA_CATS, SCHEDULED_CATS
from repro.optim.adam import AdamConfig
from repro.pipeline.schedule import OverlapPlan, slot_table
from repro.train.faults import RecoveryConfig, parse_inject
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="obs", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)


def _trainer(policy="edgc", steps=22, window=8, log_every=2, metrics=None,
             faults=None, recovery=None, ckpt_every=0, ckpt_path="ckpt/obs",
             seed=0):
    model = build_model(TINY)
    edgc = EDGCConfig(policy=policy, fixed_rank=16,
                      num_stages=TINY.num_stages, total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=window, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=log_every,
                         metrics=metrics, faults=faults, recovery=recovery,
                         ckpt_every=ckpt_every, ckpt_path=ckpt_path,
                         adam=AdamConfig(lr=1e-3, warmup_steps=10,
                                         total_steps=steps))
    return Trainer(model, make_host_mesh(), edgc, tcfg, seed=seed)


def _data(seed=0):
    return SyntheticLM(vocab_size=TINY.vocab_size, seq_len=64, batch_size=4,
                       seed=seed).batches()


# --------------------------------------------------------------- registry
def test_registry_kinds_tags_and_cursor():
    sink = MemorySink()
    reg = MetricsRegistry([sink])
    reg.scalar("loss", 1.5, step=0)
    reg.series("ranks", [8, 16], step=0)
    reg.counter("resets", step=3)
    reg.counter("resets", step=4)
    reg.event("boom", step=5, kind_detail="nan")
    reg.scalar("loss", 1.25)           # no step -> cursor (5)
    reg.flush()

    assert reg.last_step == 5 and reg.n_emitted == 6
    assert sink.scalars("loss") == [(0, 1.5), (5, 1.25)]
    assert sink.series("ranks") == [(0, [8, 16])]
    assert sink.counters("resets") == [(3, 1), (4, 2)]
    (ev,) = sink.events("boom")
    assert ev["data"]["kind_detail"] == "nan"

    view = reg.with_tags(pod=1)
    view.scalar("loss", 9.0, step=6)
    view.with_tags(shard=2).event("nested", step=6)
    reg.flush()
    tagged = [r for r in sink.records if r.get("pod") == 1]
    assert len(tagged) == 2
    assert tagged[1]["shard"] == 2 and "shard" not in tagged[0]
    assert reg.last_step == 6        # views share the base cursor


def test_flush_defers_device_fetch(monkeypatch):
    """Device values stay device values until flush; flush does exactly one
    batched block_until_ready for everything pending."""
    calls = []
    real = jax.block_until_ready
    monkeypatch.setattr(jax, "block_until_ready",
                        lambda x: calls.append(1) or real(x))
    reg = MetricsRegistry([sink := MemorySink()])
    for i in range(4):
        reg.scalar("x", jnp.float32(i) * 2, step=i)
    reg.series("v", jnp.arange(3, dtype=jnp.float32), step=4)
    assert calls == []               # nothing fetched yet
    reg.flush()
    assert len(calls) == 1           # one sync for all five records
    assert sink.scalars("x") == [(0, 0.0), (1, 2.0), (2, 4.0), (3, 6.0)]
    (sv,) = sink.series("v")
    assert sv[1] == [0.0, 1.0, 2.0]
    assert all(isinstance(v, float) for v in sv[1])


def test_jsonl_roundtrip_and_csv(tmp_path):
    path = str(tmp_path / "m" / "metrics.jsonl")
    reg = MetricsRegistry([JsonlSink(path)])
    reg.scalar("loss", 2.0, step=0)
    reg.series("ranks", [4, 8], step=1)
    reg.event("plan_change", step=1, window=1)
    reg.close()

    records = read_jsonl(path)
    assert [r["kind"] for r in records] == ["scalar", "series", "event"]
    assert json.loads(open(path).readline())["value"] == 2.0

    # append mode: a second registry continues the same file
    reg2 = MetricsRegistry([JsonlSink(path)])
    reg2.scalar("loss", 1.0, step=2)
    reg2.close()
    assert len(read_jsonl(path)) == 4

    csv_path = str(tmp_path / "out.csv")
    write_csv(records, csv_path)
    rows = open(csv_path).read().strip().splitlines()
    assert rows[0] == "step,name,kind,value"
    assert rows[1] == "0,loss,scalar,2.0"
    assert rows[2] == "1,ranks,series,4;8"
    assert len(rows) == 3            # events are not tabular -> skipped


def test_state_dict_cursor_roundtrip():
    reg = MetricsRegistry([MemorySink()])
    reg.scalar("loss", 1.0, step=7)
    reg.counter("resets")
    reg.flush()
    sd = reg.state_dict()
    assert sd["step"] == 7 and sd["emitted"] == 2

    sink2 = MemorySink()
    reg2 = MetricsRegistry([sink2])
    reg2.load_state_dict(sd)
    reg2.flush()
    assert reg2.last_step == 7 and reg2.n_emitted >= 2
    (ev,) = sink2.events("telemetry_resume")
    assert ev["step"] == 7
    assert reg2.counter("resets") == 2   # counter totals carried over


# ------------------------------------------------- trainer reconciliation
def test_trainer_series_reconcile_with_ledgers():
    """The acceptance check: JSONL-visible series must equal the trainer's
    own wire-byte ledger and the DAC's applied ranks, exactly."""
    sink = MemorySink()
    tr = _trainer("edgc", steps=22, window=8, log_every=2,
                  metrics=MetricsRegistry([sink]))
    tr.run(_data())

    ledger = tr.stage_bytes()
    step, last_swb = sink.series("stage_wire_bytes")[-1]
    assert last_swb == [int(c) for c, _ in ledger]
    _, last_full = sink.series("stage_wire_bytes_full")[-1]
    assert last_full == [int(f) for _, f in ledger]
    assert step == 21

    assert sink.scalars("bytes_synced")[-1][1] == tr.bytes_synced
    assert sink.scalars("bytes_full")[-1][1] == tr.bytes_full

    ranks = sink.series("dac_applied_ranks")
    assert ranks and ranks[-1][1] == [
        int(r) for r in tr.controller.dac.current_ranks()]

    # history and telemetry describe the same logged steps
    hist_steps = [h["step"] for h in tr.history]
    assert [s for s, _ in sink.scalars("loss")] == hist_steps
    for h, (s, v) in zip(tr.history, sink.scalars("loss")):
        assert h["loss"] == pytest.approx(v)

    names = {e["name"] for e in sink.events()}
    assert {"run_meta", "plan_change"} <= names


# ------------------------------------------------------------ tick traces
@pytest.mark.parametrize("schedule", ["gpipe", "1f1b"])
@pytest.mark.parametrize("S,M", [(2, 4), (4, 8)])
def test_tick_trace_matches_slot_table_oracle(schedule, S, M):
    events = tick_trace_events(schedule, S, M, n_units=4)
    spans = [e for e in events if e["ph"] == "X"]
    assert all(e["cat"] in SCHEDULED_CATS + EXTRA_CATS for e in spans)
    scheduled = [e for e in spans if e["cat"] in SCHEDULED_CATS]

    # one span per tick-table entry
    table = slot_table(schedule, S, M)
    n_oracle = sum(len(row[t]) for row in table for t in range(len(row)))
    assert len(scheduled) == n_oracle == expected_span_count(schedule, S, M)
    assert n_oracle == 2 * S * M     # F and B for every (stage, microbatch)

    # every span matches its table entry's (kind, microbatch) at its tick
    for e in scheduled:
        s, t, mb = e["tid"], e["args"]["tick"], e["args"]["microbatch"]
        kind = "F" if e["cat"] == "forward" else "B"
        assert (kind, mb) in table[s][t]

    # nesting: scheduled spans on one track never overlap
    for s in range(S):
        iv = sorted((e["ts"], e["ts"] + e["dur"])
                    for e in scheduled if e["tid"] == s)
        for (a0, a1), (b0, _) in zip(iv, iv[1:]):
            assert a1 <= b0 + 1e-6

    stats = validate_trace({"traceEvents": events})
    assert stats["tracks"] == S
    assert stats["by_cat"].get("bubble", 0) > 0   # filler spans present
    f_args = next(e["args"] for e in scheduled if e["cat"] == "forward")
    assert f_args["stash_policy"] == "replay"

    # stash annotations ride on the spans for stashing policies
    ev_full = tick_trace_events(schedule, S, M, n_units=4,
                                stash_policy="full")
    f_full = next(e["args"] for e in ev_full
                  if e.get("cat") == "forward")
    assert f_full["stash_points"] == [1, 2, 3]
    b_full = next(e["args"] for e in ev_full
                  if e.get("cat") == "backward")
    assert b_full["replay_segments"]


def test_tick_trace_sync_spans_from_overlap_plan():
    S, M = 2, 4
    plan = OverlapPlan(schedule="1f1b", num_stages=S, num_microbatches=M,
                       launches=(((4, (0, 1)),), ((3, (0,)),)),
                       residual=((2,), ()),
                       slack_seconds=(0.0, 1.0),
                       est_sync_seconds=(1.0, 1.0),
                       feasible=(False, True))
    events = tick_trace_events("1f1b", S, M, sync_plan=plan)
    sync = [e for e in events if e.get("cat") == "sync"]
    resid = [e for e in events if e.get("cat") == "sync-residual"]
    assert len(sync) == 3 and len(resid) == 1
    assert expected_span_count("1f1b", S, M, plan) == 2 * S * M + 3
    assert {e["tid"] for e in sync} == {0, 1}
    assert resid[0]["tid"] == 0 and resid[0]["args"]["residual"] is True
    # in-loop chunks start after the stage's last backward
    last_b = max(e["ts"] + e["dur"] for e in events
                 if e.get("cat") == "backward" and e["tid"] == 0)
    assert all(e["ts"] >= last_b - 1e-6 for e in sync if e["tid"] == 0)
    validate_trace({"traceEvents": events})


def test_trace_file_roundtrip_and_validation_errors(tmp_path):
    events = tick_trace_events("1f1b", 2, 4)
    path = write_chrome_trace(str(tmp_path / "t" / "trace.json"), events,
                              metadata={"schedule": "1f1b"})
    obj = load_trace(path)
    assert obj["otherData"]["schedule"] == "1f1b"
    assert validate_trace(obj)["spans"] == len(
        [e for e in events if e["ph"] == "X"])

    with pytest.raises(ValueError, match="traceEvents"):
        validate_trace({"events": []})
    with pytest.raises(ValueError, match="phase"):
        validate_trace({"traceEvents": [{"ph": "Q", "name": "x"}]})
    with pytest.raises(ValueError, match="negative"):
        validate_trace({"traceEvents": [
            {"ph": "X", "name": "x", "cat": "forward", "ts": 0.0,
             "dur": -1.0, "pid": 0, "tid": 0}]})


# ------------------------------------------------------------- fault log
def test_fault_run_event_log_sequence():
    """nan_grad -> guard skip + EF reset -> recovered, in order, in the
    structured event log."""
    sink = MemorySink()
    tr = _trainer("fixed", steps=24, window=8, log_every=24,
                  metrics=MetricsRegistry([sink]),
                  faults=parse_inject("nan_grad@12"),
                  recovery=RecoveryConfig(rollback=False))
    tr.run(_data())
    assert tr.recovery.skipped_steps == 1 and tr.recovery.ef_resets == 1

    seq = [(e["name"], e["step"]) for e in sink.events()
           if e["name"] in ("fault_injected", "guard_skip", "ef_reset",
                            "recovered")]
    assert [n for n, _ in seq] == ["fault_injected", "guard_skip",
                                   "ef_reset", "recovered"]
    assert seq[0][1] == 12 and seq[1][1] == 12 and seq[2][1] == 12
    assert seq[3][1] == 13
    (fault,) = sink.events("fault_injected")
    assert fault["data"]["kind"] == "nan_grad"
    assert sink.counters("ef_resets")[-1][1] == 1


def test_checkpoint_carries_metrics_cursor(tmp_path):
    sink = MemorySink()
    tr = _trainer("fixed", steps=12, window=6, log_every=4,
                  metrics=MetricsRegistry([sink]), ckpt_every=6,
                  ckpt_path=str(tmp_path / "st"))
    tr.run(_data())
    saved_cursor = tr.metrics.last_step

    sink2 = MemorySink()
    tr2 = _trainer("fixed", steps=12, window=6, log_every=4,
                   metrics=MetricsRegistry([sink2]), ckpt_every=6,
                   ckpt_path=str(tmp_path / "st"))
    step = tr2.restore_checkpoint(str(tmp_path / "st_12"))
    assert step == 12
    tr2.metrics.flush()
    assert tr2.metrics.last_step >= step - 1
    assert tr2.metrics.last_step <= saved_cursor
    (ev,) = sink2.events("telemetry_resume")
    assert ev["data"]["emitted"] > 0   # resumed run appends, not restarts


# ----------------------------------------------------------------- dryrun
def test_dryrun_record_summary():
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch.dryrun import record_summary
    finally:                    # dryrun import mutates XLA_FLAGS
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved

    ok = record_summary({
        "arch": "a", "shape": "s", "flops_per_chip": 1.0,
        "bytes_per_chip": 2.0, "collective_total": 3, "compile_s": 4.5,
        "policy": "edgc", "compressed_leaves": 7, "guarded": True,
        "memory": {"argument_bytes": 10, "temp_bytes": 5},
        "pipeline": {"num_stages": 2, "schedule": "1f1b",
                     "stash_policy": "replay", "stage_bytes": [[1, 2]],
                     "peak_activation_bytes": 99, "family": "dense",
                     "overlap": {"in_loop_chunks": 3, "residual_chunks": 1}},
        "outer_sync": {"wire_bytes_compressed": 6, "wire_bytes_full": 8,
                       "outer_k": 20, "outer_rank": 32},
    })
    assert ok["status"] == "ok" and ok["per_chip_bytes"] == 15
    assert ok["pipeline"]["overlap"]["in_loop_chunks"] == 3
    assert ok["outer_sync"]["outer_k"] == 20
    assert "traceback" not in json.dumps(ok)

    skip = record_summary({"arch": "a", "shape": "s", "skipped": True,
                           "reason": "too big"})
    assert skip == {"arch": "a", "shape": "s", "status": "skipped",
                    "reason": "too big"}
    fail = record_summary({"arch": "a", "shape": "s", "error": "boom",
                           "traceback": "..."})
    assert fail["status"] == "failed" and fail["error"] == "boom"
    assert "traceback" not in fail


def test_registry_series_handles_numpy_and_scalars():
    sink = MemorySink()
    reg = MetricsRegistry([sink])
    reg.series("v", np.array([1, 2], dtype=np.int64), step=0)
    reg.scalar("s", np.float32(0.5), step=0)
    reg.flush()
    assert sink.series("v") == [(0, [1, 2])]
    assert sink.scalars("s") == [(0, 0.5)]
    assert isinstance(sink.scalars("s")[0][1], float)
