"""HLO cost walker: loop scaling, dot FLOPs, collective bytes vs analytic."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo, parse_hlo, xla_cost_analysis


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_plain_matmul_flops():
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((256, 64), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_trip_count_scaling():
    def f(x, w):
        def body(h, wi):
            return jnp.dot(h, wi, preferred_element_type=jnp.float32), None
        return jax.lax.scan(body, x, w)[0]
    c = _compile(f, jax.ShapeDtypeStruct((128, 256), jnp.float32),
                 jax.ShapeDtypeStruct((21, 256, 256), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 128 * 256 * 256 * 21, rel=0.01)
    # XLA's own analysis counts the body once — the walker must beat it
    assert r["flops"] > xla_cost_analysis(c).get("flops", 0) * 10


def test_nested_scan():
    def f(x, ws):
        def outer(h, w2):
            def inner(hh, wi):
                return jnp.dot(hh, wi, preferred_element_type=jnp.float32), None
            return jax.lax.scan(inner, h, w2)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    c = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 64 * 64 * 64 * 12, rel=0.01)


def test_batched_dot_contraction():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 jax.ShapeDtypeStruct((4, 32, 64), jnp.float32),
                 jax.ShapeDtypeStruct((4, 64, 16), jnp.float32))
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_bytes_nonzero_and_scaled():
    def f(x, w):
        def body(h, wi):
            return jnp.dot(h, wi, preferred_element_type=jnp.float32), None
        return jax.lax.scan(body, x, w)[0]
    c1 = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((2, 64, 64), jnp.float32))
    c2 = _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((20, 64, 64), jnp.float32))
    b1 = analyze_hlo(c1.as_text())["bytes"]
    b2 = analyze_hlo(c2.as_text())["bytes"]
    assert b1 > 0
    assert b2 > 5 * b1          # ~10x trips -> ~10x traffic


def test_parse_structure():
    def f(x, w):
        return jax.lax.scan(lambda h, wi: (h @ wi, None), x, w)[0]
    c = _compile(f, jax.ShapeDtypeStruct((8, 8), jnp.float32),
                 jax.ShapeDtypeStruct((5, 8, 8), jnp.float32))
    comps, entry = parse_hlo(c.as_text())
    assert entry in comps
    assert any(op.opcode == "while" for op in comps[entry].ops)
