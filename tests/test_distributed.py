"""Distributed-step correctness, run in a subprocess with fake devices.

jax locks the device count at first init, so multi-device tests spawn a
fresh interpreter with XLA_FLAGS=--xla_force_host_platform_device_count=8.
Covers: (1) EDGC 2-way-DP train step == single-device step (compressed
all-reduce linearity), (2) TP sharding doesn't change the math, (3) the
multi-pod mesh axes compose.
"""
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import classify_leaves, make_plan
    from repro.core.compressor import init_compressor_state
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim import adam
    from repro.train.step import (TrainStepConfig, batch_shardings,
                                  make_train_step, replicate_comp_state,
                                  state_shardings)

    cfg = ModelConfig(name="d", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, 2, 2, min_dim=64)
    plan = make_plan("fixed", leaves, fixed_rank=8)
    batch_np = next(SyntheticLM(512, 64, 8, seed=0).batches())
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

    def mk_state(world):
        ost = adam.init(params, adam.AdamConfig())
        comp = init_compressor_state(params, plan, jax.random.PRNGKey(1))
        return {"params": params, "opt_m": ost.m, "opt_v": ost.v,
                "opt_step": ost.step,
                "comp": replicate_comp_state(comp, world)}

    scfg = TrainStepConfig(mode="dp_tp", policy_plan=plan)
    results = {}
    for tag, (d, m, w) in {"1x1": (1, 1, 1), "4x1": (4, 1, 4),
                           "2x2": (2, 2, 2), "2x4": (2, 4, 2)}.items():
        mesh = make_host_mesh(data=d, model=m)
        step = make_train_step(model, mesh, scfg)
        state = mk_state(w)
        sshard = state_shardings(state, model, mesh)
        bshard = batch_shardings(batch, mesh, 8)
        st, mets = jax.jit(
            step, in_shardings=(sshard, bshard),
            out_shardings=(sshard, NamedSharding(mesh, P())),
        )(jax.device_put(state, sshard), jax.device_put(batch, bshard))
        results[tag] = (float(mets["loss"]),
                        np.asarray(jax.tree_util.tree_leaves(st["params"])[0]))

    base_loss, base_leaf = results["1x1"]
    for tag, (loss, leaf) in results.items():
        assert abs(loss - base_loss) < 1e-4, (tag, loss, base_loss)
        np.testing.assert_allclose(leaf, base_leaf, rtol=2e-3, atol=3e-4,
                                   err_msg=tag)
    print("DISTRIBUTED_PARITY_OK")
""")


@pytest.mark.slow
def test_dp_tp_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "DISTRIBUTED_PARITY_OK" in proc.stdout, proc.stderr[-3000:]
