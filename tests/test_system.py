"""End-to-end system behaviour: training convergence, policy equivalence,
plan transitions, distributed-step parity, checkpointing, serving.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EDGCConfig, GDSConfig, classify_leaves, init_compressor_state, make_plan,
    plan_wire_bytes, sync_grads,
)
from repro.core.dac import DACConfig
from repro.data.pipeline import ByteCorpus, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim.adam import AdamConfig
from repro.serve.engine import Engine, ServeConfig
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="sys", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)


def _trainer(policy, steps=60, window=20, cfg=TINY, seed=0):
    model = build_model(cfg)
    edgc = EDGCConfig(policy=policy, fixed_rank=16, num_stages=cfg.num_stages,
                      total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=window, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=10,
                         adam=AdamConfig(lr=1e-3, warmup_steps=10,
                                         total_steps=steps))
    return Trainer(model, make_host_mesh(), edgc, tcfg, seed=seed)


def _data(cfg=TINY, seed=0):
    return SyntheticLM(vocab_size=cfg.vocab_size, seq_len=64, batch_size=4,
                       seed=seed)


@pytest.mark.parametrize("policy", ["none", "fixed", "optimus", "edgc"])
def test_all_policies_converge(policy):
    tr = _trainer(policy)
    hist = tr.run(_data().batches())
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]          # learning happened
    if policy in ("fixed", "optimus"):
        assert tr.bytes_synced < tr.bytes_full
    if policy == "none":
        assert tr.bytes_synced == tr.bytes_full


def test_edgc_adapts_and_saves_bytes():
    tr = _trainer("edgc", steps=120, window=20)
    tr.run(_data().batches())
    assert not tr.controller.in_warmup       # warm-up ended
    assert tr.controller.rank_history        # DAC produced rank vectors
    assert tr.comm_savings() > 0.0
    # plan recompiles happened but stayed bounded
    assert 1 <= len(tr._step_cache) <= 12


def test_edgc_loss_parity_with_baseline():
    t_none = _trainer("none", steps=120)
    h_none = t_none.run(_data(seed=3).batches())
    t_edgc = _trainer("edgc", steps=120, window=20, seed=0)
    h_edgc = t_edgc.run(_data(seed=3).batches())
    gap = h_edgc[-1]["loss"] - h_none[-1]["loss"]
    assert abs(gap) < 0.35                   # fidelity-scale parity band


def test_sync_grads_compressed_vs_plain_bytes():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    plan = make_plan("fixed", leaves, fixed_rank=8)
    comp_b, full_b = plan_wire_bytes(leaves, plan)
    assert comp_b < full_b / 2               # rank 8 is a big cut
    comp = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    grads = jax.tree_util.tree_map(lambda p: jnp.ones_like(p), params)
    synced, comp2 = sync_grads(grads, comp, plan, lambda x: x)
    assert jax.tree_util.tree_structure(synced) == jax.tree_util.tree_structure(grads)


def test_checkpoint_roundtrip(tmp_path):
    tr = _trainer("fixed", steps=5)
    tr.run(_data().batches())
    path = str(tmp_path / "state")
    ckpt.save(path, tr.state, extra={"step": 5})
    restored, extra = ckpt.restore(path, tr.state)
    assert extra["step"] == 5
    a = jax.tree_util.tree_leaves(tr.state)[0]
    b = jax.tree_util.tree_leaves(restored)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_byte_corpus_pipeline(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_text("hello world, this is a tiny corpus for byte-level lm " * 50)
    bc = ByteCorpus(str(p), seq_len=32, batch_size=4)
    b = next(bc.batches())
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 256


def test_synthetic_data_deterministic():
    a = next(SyntheticLM(256, 32, 4, seed=7).batches())
    b = next(SyntheticLM(256, 32, 4, seed=7).batches())
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_engine_generate():
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_new_tokens=8))
    prompts = np.random.default_rng(0).integers(0, 512, (2, 4)).astype(np.int32)
    out = eng.generate(prompts)
    assert out.shape == (2, 8)
    assert out.dtype == np.int32
    # greedy decoding is deterministic
    out2 = eng.generate(prompts)
    np.testing.assert_array_equal(out, out2)


def test_distributed_step_matches_single_device():
    """(data=2, model=1) EDGC step == single-device step (same global batch)."""
    if jax.device_count() < 2:
        pytest.skip("needs >= 2 devices (run under XLA_FLAGS host device count)")
    from repro.core.compressor import init_compressor_state
    from repro.optim import adam
    from repro.train.step import (
        TrainStepConfig, batch_shardings, make_train_step,
        replicate_comp_state, state_shardings,
    )
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = TINY
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, cfg.num_layers, 2, min_dim=64)
    plan = make_plan("fixed", leaves, fixed_rank=8)
    batch_np = next(_data().batches())
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}

    def mk_state(world):
        ost = adam.init(params, adam.AdamConfig())
        comp = init_compressor_state(params, plan, jax.random.PRNGKey(1))
        return {"params": params, "opt_m": ost.m, "opt_v": ost.v,
                "opt_step": ost.step,
                "comp": replicate_comp_state(comp, world)}

    # single device
    mesh1 = make_host_mesh(data=1, model=1)
    scfg = TrainStepConfig(mode="dp_tp", policy_plan=plan)
    s1 = make_train_step(model, mesh1, scfg)
    st1, m1 = jax.jit(s1)(mk_state(1), batch)

    # two-way data parallel
    mesh2 = make_host_mesh(data=2, model=1)
    s2 = make_train_step(model, mesh2, scfg)
    state2 = mk_state(2)
    sshard = state_shardings(state2, model, mesh2)
    bshard = batch_shardings(batch, mesh2, 4)
    st2, m2 = jax.jit(
        s2, in_shardings=(sshard, bshard),
        out_shardings=(sshard, NamedSharding(mesh2, P())),
    )(jax.device_put(state2, sshard), jax.device_put(batch, bshard))

    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-4)
    pa = jax.tree_util.tree_leaves(st1["params"])
    pb = jax.tree_util.tree_leaves(st2["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-3, atol=2e-4)
