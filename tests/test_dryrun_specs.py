"""Dry-run plumbing units (no 512-device init needed): input_specs shapes,
arch registry completeness, INPUT_SHAPES contract."""
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, get_config, sharding_mode
from repro.launch.dryrun import input_specs

ARCH_IDS = [a for a in ARCHS if a != "gpt2"]


def test_registry_complete():
    assert len(ARCH_IDS) == 10
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    for a in ARCH_IDS:
        assert get_config(a, "full") is not None
        assert get_config(a, "reduced") is not None
        assert sharding_mode(a) in ("dp_tp", "auto")


def test_exact_assigned_shapes():
    """The FULL configs match the assigned table exactly."""
    c = get_config("kimi-k2-1t-a32b", "full")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size, c.num_experts, c.experts_per_token) == \
        (61, 7168, 64, 8, 2048, 163840, 384, 8)
    c = get_config("qwen3-32b", "full")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.head_dim, c.qk_norm) == \
        (64, 5120, 64, 8, 25600, 151936, 128, True)
    c = get_config("llama3-405b", "full")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (126, 16384, 128, 8, 53248, 128256)
    c = get_config("zamba2-7b", "full")
    assert (c.num_layers, c.d_model, c.ssm_state) == (81, 3584, 64)
    c = get_config("xlstm-125m", "full")
    assert (c.num_layers, c.d_model, c.vocab_size) == (12, 768, 50304)
    c = get_config("whisper-base", "full")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.vocab_size) == \
        (6, 6, 512, 51865)
    c = get_config("qwen3-moe-235b-a22b", "full")
    assert (c.num_layers, c.num_experts, c.experts_per_token, c.d_ff) == \
        (94, 128, 8, 1536)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg_var = "long" if shape == "long_500k" else "full"
    cfg = get_config(arch, cfg_var)
    if cfg is None:
        assert arch == "whisper-base" and shape == "long_500k"
        return
    spec = INPUT_SHAPES[shape]
    B, T = spec["global_batch"], spec["seq_len"]
    specs = input_specs(cfg, shape)
    assert specs["tokens"].dtype == jnp.int32
    if spec["kind"] == "decode":
        assert specs["tokens"].shape == (B,)        # ONE new token
    else:
        assert specs["tokens"].shape == (B, T)
    if spec["kind"] == "train":
        assert specs["labels"].shape == (B, T)
    if cfg.family == "whisper" and spec["kind"] != "decode":
        assert specs["frames"].shape == (B, cfg.audio_frames, cfg.d_model)
    if cfg.family == "vlm" and spec["kind"] != "decode":
        assert specs["patches"].shape == (B, cfg.num_patches, cfg.d_model)


def test_long_500k_requires_subquadratic():
    """Dense archs must select a bounded-memory attention for long_500k."""
    for arch in ARCH_IDS:
        cfg = get_config(arch, "long")
        if cfg is None:
            continue
        if cfg.family in ("dense", "moe", "vlm"):
            assert cfg.sliding_window > 0, f"{arch} long_500k needs a window"
        # ssm/zamba: recurrent state, inherently O(1) per token
