"""PowerSGD compressor: exactness, error feedback, rank moves, batching."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.powersgd import (
    LowRankState, compress_leaf, compressed_bytes, gram_schmidt,
    init_leaf_state, resize_rank,
)


def test_exact_recovery_of_lowrank_matrix():
    """A rank-r matrix is recovered exactly (to fp) within 2 iterations."""
    rng = np.random.default_rng(0)
    U = rng.standard_normal((128, 8))
    V = rng.standard_normal((256, 8))
    g = jnp.asarray(U @ V.T, jnp.float32)
    st_ = init_leaf_state((128, 256), 8, jax.random.PRNGKey(0))
    for _ in range(2):
        ghat, st_ = compress_leaf(g, st_)
    assert float(jnp.linalg.norm(ghat - g) / jnp.linalg.norm(g)) < 1e-3


def test_error_feedback_unbiased_over_time():
    """sum of outputs telescopes: mean output -> g as EF accumulates."""
    rng = np.random.default_rng(1)
    U = rng.standard_normal((64, 4)); V = rng.standard_normal((96, 4))
    g = jnp.asarray(U @ V.T + 0.3 * rng.standard_normal((64, 96)), jnp.float32)
    st_ = init_leaf_state((64, 96), 4, jax.random.PRNGKey(1))
    acc = jnp.zeros_like(g)
    n = 30
    for _ in range(n):
        ghat, st_ = compress_leaf(g, st_)
        acc = acc + ghat
    # telescoping: acc = n*g + E_0 - E_n  =>  ||acc/n - g|| = ||E_n||/n
    rel = float(jnp.linalg.norm(acc / n - g) / jnp.linalg.norm(g))
    assert rel < 0.15


def test_ef_residual_bounded():
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
    st_ = init_leaf_state((128, 256), 16, jax.random.PRNGKey(2))
    norms = []
    for _ in range(40):
        _, st_ = compress_leaf(g, st_)
        norms.append(float(jnp.linalg.norm(st_.err)))
    # plateaus rather than diverging
    assert norms[-1] < 1.2 * max(norms[20:30])


def test_batched_3d_equals_per_matrix():
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.standard_normal((3, 64, 96)), jnp.float32)
    st3 = init_leaf_state((3, 64, 96), 8, jax.random.PRNGKey(3))
    out3, st3b = compress_leaf(g, st3)
    for e in range(3):
        st1 = LowRankState(q=st3.q[e], err=st3.err[e])
        out1, _ = compress_leaf(g[e], st1)
        np.testing.assert_allclose(np.asarray(out3[e]), np.asarray(out1),
                                   rtol=1e-4, atol=1e-4)


def test_4d_leaf_roundtrip():
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal((2, 3, 64, 96)), jnp.float32)
    st4 = init_leaf_state((2, 3, 64, 96), 8, jax.random.PRNGKey(4))
    out, st4b = compress_leaf(g, st4)
    assert out.shape == g.shape
    assert st4b.q.shape == (2, 3, 96, 8)
    assert st4b.err.shape == g.shape


@given(r0=st.integers(4, 32), r1=st.integers(4, 32))
@settings(max_examples=20, deadline=None)
def test_resize_rank_shapes(r0, r1):
    st_ = init_leaf_state((64, 96), r0, jax.random.PRNGKey(5))
    st2 = resize_rank(st_, r1, jax.random.PRNGKey(6))
    assert st2.q.shape == (96, r1)
    assert st2.err.shape == (64, 96)
    if r1 <= r0:  # leading columns preserved
        np.testing.assert_array_equal(np.asarray(st2.q),
                                      np.asarray(st_.q[:, :r1]))


def test_gram_schmidt_orthonormal():
    rng = np.random.default_rng(7)
    p = jnp.asarray(rng.standard_normal((128, 16)), jnp.float32)
    q = gram_schmidt(p)
    eye = np.asarray(q.T @ q)
    np.testing.assert_allclose(eye, np.eye(16), atol=1e-4)


def test_compressed_bytes_accounting():
    assert compressed_bytes((128, 256), 8, 2) == (128 + 256) * 8 * 2
    assert compressed_bytes((4, 128, 256), 8, 2) == 4 * (128 + 256) * 8 * 2


def test_psum_injection_called():
    calls = []

    def spy(x):
        calls.append(x.shape)
        return x

    g = jnp.ones((64, 96), jnp.float32)
    st_ = init_leaf_state((64, 96), 4, jax.random.PRNGKey(8))
    compress_leaf(g, st_, psum_mean=spy)
    assert calls == [(64, 4), (96, 4)]  # P then Q factors, nothing else
