"""Schedule-interleaved DP sync + the unified PipelineConfig/SyncConfig
surface: planner invariants (SYNC ticks never precede a stage's last
backward), chunked-bucket reassembly parity vs the monolithic schedule,
config shims / embedded-identity regressions, DAC overlap feedback, and
— in a fake-device subprocess — overlapped-1F1B loss parity with the
flat trainer plus the wire ledger implied by the DAC ranks."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CommModel, CompressionPlan, EDGCConfig, LeafInfo, NO_COMPRESSION,
    SyncConfig, classify_leaves, init_compressor_state, make_plan,
    sync_grads,
)
from repro.core import bucketing
from repro.core.bucketing import make_bucket_layout, sync_chunks
from repro.core.cqm import CQM
from repro.core.dac import DAC, DACConfig, stage_aligned_ranks
from repro.core.sync_executor import SyncExecutor
from repro.models.model import ModelConfig, build_model
from repro.pipeline import PipelineConfig
from repro.pipeline.schedule import (
    last_backward_tick, plan_overlap, simulate_schedule, slot_table,
    sync_slack_ticks, sync_ticks, tick_count,
)
from repro.pipeline.sync import make_stage_plans, stage_wire_bytes
from repro.train.step import TrainStepConfig
from repro.train.trainer import TrainerConfig

TINY = ModelConfig(name="ovl", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)

PLANS = {
    "none": {},
    "fixed": dict(fixed_rank=8),
    "optimus": dict(fixed_rank=8, num_stages=2),
    "edgc": dict(stage_ranks=[4, 16], num_stages=2),
}


def _setup(policy="fixed", **overrides):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    kw = dict(PLANS[policy]); kw.update(overrides)
    return params, leaves, make_plan(policy, leaves, **kw)


def _stage_world(num_stages=2, chunk_bytes=0, ranks=(4, 16)):
    """Synthetic uniform-stage world with the ``['stages'][i]`` paths the
    adapters emit: per-stage local template [w, u, b, t], no shared
    leaves, stage s compressed at ``ranks[s]``."""
    local = [("['w']", (64, 128)), ("['u']", (64, 128)),
             ("['b']", (128,)), ("['t']", (8192,))]
    g_ranks, infos = [], []
    for s in range(num_stages):
        for lp, shape in local:
            path = f"['stages'][{s}]{lp}"
            infos.append(LeafInfo(path=path, shape=shape, stage=s,
                                  eligible=len(shape) == 2))
            if len(shape) == 2:
                g_ranks.append((path, ranks[s % len(ranks)]))
    plan = CompressionPlan(ranks=tuple(g_ranks))
    splans = make_stage_plans(plan, num_stages, local,
                              chunk_bytes=chunk_bytes)
    return splans, infos, plan


# ------------------------------------------------------------- the planner
@pytest.mark.parametrize("name", ["gpipe", "1f1b"])
@pytest.mark.parametrize("S,M", [(2, 2), (2, 8), (4, 4), (4, 16)])
def test_sync_ticks_strictly_after_last_backward(name, S, M):
    last_b = last_backward_tick(name, S, M)
    ticks = sync_ticks(name, S, M)
    n = tick_count(name, S, M)
    table = slot_table(name, S, M)
    for s in range(S):
        assert all(last_b[s] < t < n for t in ticks[s])
        # the stage really is done at its recorded last backward
        assert any(k == "B" for k, _ in table[s][last_b[s]])
        assert all(k != "B" for t in range(last_b[s] + 1, n)
                   for k, _ in table[s][t])
        # the drain window IS the Alg-2 slack
        assert len(ticks[s]) == sync_slack_ticks(name, S, M)[s]


@pytest.mark.parametrize("name", ["gpipe", "1f1b"])
def test_plan_overlap_partitions_chunks_in_drain(name):
    S, M = 4, 8
    splans, _, _ = _stage_world(num_stages=S, chunk_bytes=4 << 10)
    plan = plan_overlap(name, S, M, splans)
    last_b = last_backward_tick(name, S, M)
    for s in range(S):
        n_chunks = len(sync_chunks(splans.layouts[splans.d_of_stage[s]]))
        launched = [ci for _, ids in plan.launches[s] for ci in ids]
        # every chunk launches exactly once: in the drain or post-loop
        assert sorted(launched + list(plan.residual[s])) == list(
            range(n_chunks))
        # SYNC ticks never precede the stage's last backward
        assert all(t > last_b[s] for t in plan.launch_ticks(s))
        assert set(plan.launch_ticks(s)) <= set(sync_ticks(name, S, M)[s])
    # stage 0 has zero slack: its whole schedule is post-loop residual
    assert plan.launches[0] == ()
    assert plan.slack_seconds[0] == 0.0
    # unit model, identical layouts: est[s] <= est[0] + slack[s] trivially
    assert plan.feasible == (True,) * S
    # declared switch budgets conserve the per-stage collective bill: the
    # in-loop tick counts plus the residual sum to exactly one launch per
    # transfer chunk — and chunking a bucket only ever adds launches over
    # the monolithic per-layout count
    from repro.pipeline.schedule import overlap_branch_psums
    in_loop, residual = overlap_branch_psums(plan, splans)
    totals = list(residual)
    for _, counts in in_loop:
        totals = [a + b for a, b in zip(totals, counts)]
    chunk_bill = tuple(
        sum(c.num_collectives
            for c in sync_chunks(splans.layouts[splans.d_of_stage[s]]))
        for s in range(S))
    assert tuple(totals) == chunk_bill
    assert all(c >= p for c, p in
               zip(chunk_bill, splans.predicted_collectives()))


def test_plan_overlap_feasibility_with_comm_model():
    S, M = 4, 8
    splans, _, _ = _stage_world(num_stages=S)
    comm = CommModel.from_shapes([(128, 256)] * 8, world=4)
    plan = plan_overlap("1f1b", S, M, splans, comm=comm)
    sim = simulate_schedule("1f1b", S, M)
    assert plan.slack_seconds == tuple(float(t) for t in
                                       sim["slack_seconds"])
    for s in range(S):
        assert plan.est_sync_seconds[s] > 0
        assert plan.feasible[s] == (
            plan.est_sync_seconds[s]
            <= plan.est_sync_seconds[0] + plan.slack_seconds[s] + 1e-9)


def test_slot_table_carries_sync_entries():
    S, M = 4, 8
    splans, _, _ = _stage_world(num_stages=S, chunk_bytes=4 << 10)
    plan = plan_overlap("1f1b", S, M, splans)
    table = slot_table("1f1b", S, M, sync_plan=plan)
    last_b = last_backward_tick("1f1b", S, M)
    for s in range(S):
        seen = sorted(ci for acts in table[s] for k, ci in acts if k == "S")
        launched = sorted(ci for _, ids in plan.launches[s] for ci in ids)
        assert seen == launched
        for t, acts in enumerate(table[s]):
            if any(k == "S" for k, _ in acts):
                assert t > last_b[s]


# --------------------------------------------------- chunked sync parity
@pytest.mark.parametrize("policy", ["none", "fixed", "optimus", "edgc"])
def test_chunked_reassembly_matches_monolithic(policy):
    """Running every chunk reproduces the monolithic bucketed sync bit for
    bit — grads, EF residual and warm-start Q — for all four policies."""
    params, leaves, plan = _setup(policy)
    mono_layout = make_bucket_layout(leaves, plan)
    chunked = make_bucket_layout(leaves, plan, chunk_bytes=16 << 10)
    chunks = sync_chunks(chunked)
    # the tiny cap really splits the flat buckets
    assert len(chunks) > len(mono_layout.groups) + len(mono_layout.buckets)

    rng = np.random.default_rng(0)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    state = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                  layout=mono_layout)
    s_ref, st_ref = sync_grads(grads, dict(state), plan, lambda x: x,
                               bucketed=True)

    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    by_path = {jax.tree_util.keystr(kp): g for kp, g in flat}
    upd_all, st_new = {}, dict(state)
    for chunk in chunks:
        gb = {p: by_path[p] for p in chunk.member_paths}
        upd, st_d = bucketing.sync_chunk_grads(gb, state, chunk,
                                               lambda x: x)
        upd_all.update(upd)
        st_new.update(st_d)

    ref_flat = jax.tree_util.tree_flatten_with_path(s_ref)[0]
    assert set(upd_all) == {jax.tree_util.keystr(kp) for kp, _ in ref_flat}
    for kp, ref in ref_flat:
        np.testing.assert_array_equal(
            np.asarray(ref), np.asarray(upd_all[jax.tree_util.keystr(kp)]),
            err_msg=jax.tree_util.keystr(kp))
    assert set(st_new) == set(st_ref)
    for key in st_ref:
        np.testing.assert_array_equal(np.asarray(st_ref[key].q),
                                      np.asarray(st_new[key].q), err_msg=key)
        np.testing.assert_array_equal(np.asarray(st_ref[key].err),
                                      np.asarray(st_new[key].err),
                                      err_msg=key)


def test_chunk_wire_ledger_matches_plan_ranks():
    """Per-stage chunk wire bytes == the Algorithm-2 ledger's compressed
    bytes, and every group chunk carries exactly its plan rank."""
    splans, leaves, plan = _stage_world(num_stages=2)
    ledger = stage_wire_bytes(leaves, plan, 2, bytes_per_elem=4)
    for s in range(2):
        sp = splans.stage_plans[s]
        chunks = sync_chunks(splans.layouts[splans.d_of_stage[s]])
        for c in chunks:
            if c.kind == "group":
                for p in c.member_paths:
                    assert sp.rank_of(p) == c.group.rank
        assert sum(c.wire_bytes() for c in chunks) == ledger[s][0]


# ----------------------------------------------------- the config surface
def _adam(steps=4):
    from repro.optim.adam import AdamConfig
    return AdamConfig(lr=1e-3, warmup_steps=1, total_steps=steps)


def test_step_config_legacy_shim():
    cfg = TrainStepConfig(mode="dp_tp", policy_plan=NO_COMPRESSION,
                          num_stages=2, schedule="gpipe",
                          num_microbatches=4, use_kernels=True)
    assert cfg.pipeline == PipelineConfig(num_stages=2, schedule="gpipe",
                                          num_microbatches=4)
    assert cfg.sync == SyncConfig(use_kernels=True)
    # flat aliases read through to the embedded configs
    assert cfg.num_stages == 2 and cfg.schedule == "gpipe"
    assert cfg.use_kernels is True and cfg.overlap_sync is False
    hash(cfg)                                    # still a static jit arg
    r = dataclasses.replace(cfg, pipeline=PipelineConfig(num_stages=3))
    assert r.num_stages == 3 and r.sync is cfg.sync
    with pytest.raises(TypeError):
        TrainStepConfig(mode="dp_tp", policy_plan=NO_COMPRESSION,
                        not_a_knob=1)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.remat = False


def test_embedded_configs_pass_by_identity():
    pcfg = PipelineConfig(num_stages=3, overlap_sync=True, chunk_bytes=256)
    scfg = SyncConfig(use_kernels=True, bucket_bytes=1 << 20)
    step = TrainStepConfig(mode="dp_tp", policy_plan=NO_COMPRESSION,
                           pipeline=pcfg, sync=scfg)
    assert step.pipeline is pcfg and step.sync is scfg
    edgc = EDGCConfig(policy="fixed", fixed_rank=8, pipeline=pcfg, sync=scfg)
    assert edgc.pipeline is pcfg and edgc.num_stages == 3
    tcfg = TrainerConfig(total_steps=2, pipeline=pcfg, sync=scfg,
                         adam=_adam())
    assert tcfg.pipeline is pcfg and tcfg.sync is scfg
    # a legacy override forces a (documented) copy, never a mutation
    step2 = TrainStepConfig(mode="dp_tp", policy_plan=NO_COMPRESSION,
                            pipeline=pcfg, num_stages=5)
    assert step2.pipeline is not pcfg and step2.num_stages == 5
    assert pcfg.num_stages == 3


def test_trainer_config_aliases_are_settable():
    tcfg = TrainerConfig(total_steps=2, adam=_adam())
    assert tcfg.pipeline == PipelineConfig() and tcfg.sync == SyncConfig()
    tcfg.schedule = "gpipe"
    tcfg.overlap_sync = True
    tcfg.bucket_bytes = 1 << 16
    assert tcfg.pipeline.schedule == "gpipe"
    assert tcfg.pipeline.overlap_sync is True
    assert tcfg.sync.bucket_bytes == 1 << 16
    with pytest.raises(TypeError):
        TrainerConfig(total_steps=2, adam=_adam(), bogus=3)


def test_trainer_and_step_builder_share_one_pipeline_config():
    """Regression: the Trainer hands the step builder the IDENTICAL
    PipelineConfig/SyncConfig objects it resolved, not copied fields."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import Trainer

    model = build_model(TINY)
    pcfg = PipelineConfig(num_stages=1)
    edgc = EDGCConfig(policy="fixed", fixed_rank=8, total_iterations=4,
                      pipeline=pcfg)
    tcfg = TrainerConfig(total_steps=4, pipeline=pcfg, adam=_adam())
    tr = Trainer(model, make_host_mesh(data=1, model=1), edgc, tcfg, seed=0)
    assert tr.pipeline_cfg is pcfg
    tr._get_step(False)
    assert tr.step_configs, "step builds must record their configs"
    for scfg in tr.step_configs.values():
        assert scfg.pipeline is tr.pipeline_cfg
        assert scfg.sync is tr.sync_cfg


def test_sync_executor_validates_mode_and_plans():
    splans, _, plan = _stage_world()
    with pytest.raises(ValueError):
        SyncExecutor(SyncConfig(), mode="carrier-pigeon")
    with pytest.raises(ValueError):
        SyncExecutor(SyncConfig(), mode="flat")            # needs a plan
    with pytest.raises(ValueError):
        SyncExecutor(SyncConfig(), mode="per-stage")       # needs splans
    SyncExecutor(SyncConfig(), mode="flat", plan=plan)
    SyncExecutor(SyncConfig(), mode="per-stage-overlapped", splans=splans)


# ------------------------------------------------------- DAC overlap hook
def _dac(num_stages=4):
    comm = CommModel.from_shapes([(1024, 4096)] * 24, world=16)
    return DAC(cqm=CQM(m=256, n=1024), comm=comm,
               cfg=DACConfig(window=100, adjust_limit=4),
               r_min=8, r_max=64, num_stages=num_stages,
               t_micro_back=comm.t_com(4), total_iterations=1000)


def test_stage_aligned_ranks_slack_degenerates_to_analytic():
    comm = CommModel.from_shapes([(1024, 4096)] * 24, world=16)
    t_mb = comm.t_com(4)
    base = stage_aligned_ranks(16, 4, comm, t_mb, 8, 64)
    unit = stage_aligned_ranks(16, 4, comm, t_mb, 8, 64,
                               slack_seconds=[s * t_mb for s in range(4)])
    assert base == unit


def test_dac_set_overlap_validates():
    dac = _dac()
    with pytest.raises(ValueError):
        dac.set_overlap([0.0, 1.0])                 # wrong stage count
    with pytest.raises(ValueError):
        dac.set_overlap([0.0, -1.0, 1.0, 2.0])      # negative slack
    dac.set_overlap([0.0, 1e-4, 2e-4, 3e-4])
    assert dac.slack_seconds == [0.0, 1e-4, 2e-4, 3e-4]


def test_dac_feasibility_clamp_trades_rank_for_overlap():
    free = _dac()
    tight = _dac()
    tight.set_overlap([0.0] * 4)        # no drain to hide behind at all
    r_free = free.current_ranks()
    r_tight = tight.current_ranks()
    assert all(a <= b for a, b in zip(r_tight, r_free))
    # zero slack leaves no room for a larger late-stage rank: every
    # stage's comm must fit stage 1's window
    t1 = tight.comm.t_com(r_tight[0])
    assert all(tight.comm.t_com(r) <= t1 + 1e-12 or r == tight.r_min
               for r in r_tight)
    # generous slack changes nothing vs the analytic head start
    loose = _dac()
    loose.set_overlap([0.0, 1.0, 2.0, 3.0])
    assert loose.current_ranks() == r_free


# --------------------- overlapped executor vs flat trainer (fake devices)
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax

    from repro.core import EDGCConfig, GDSConfig, bucketing
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.pipeline import PipelineConfig
    from repro.pipeline.sync import stage_wire_bytes
    from repro.train.trainer import Trainer, TrainerConfig

    S = 2
    CFG = ModelConfig(name="ovl4", family="dense", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=512, num_stages=S)

    def trainer(mesh, overlap=False, stages=S):
        model = build_model(CFG)
        pcfg = PipelineConfig(num_stages=stages, schedule="1f1b",
                              num_microbatches=4, overlap_sync=overlap,
                              chunk_bytes=1 << 16)
        edgc = EDGCConfig(policy="optimus", fixed_rank=8,
                          total_iterations=6,
                          gds=GDSConfig(alpha=1.0, beta=0.25),
                          dac=DACConfig(window=5, adjust_limit=4),
                          pipeline=pcfg)
        tcfg = TrainerConfig(total_steps=6, log_every=1, pipeline=pcfg,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=6))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = lambda: SyntheticLM(512, 32, 8, seed=3).batches()
    to = trainer(make_host_mesh(pipe=S, data=2, model=1), overlap=True)
    tf = trainer(make_host_mesh(data=2, model=1), stages=1)
    lo = [h["loss"] for h in to.run(data())]
    lf = [h["loss"] for h in tf.run(data())]
    gap = max(abs(a - b) for a, b in zip(lo, lf))
    print(f"overlap-vs-flat gap {gap:.2e}")
    assert gap < 5e-3, (lo, lf)

    # the executor really planned in-loop launches, and the DAC got the
    # planner's slack
    op = to.overlap_plan
    assert op is not None and all(op.feasible), op
    assert sum(len(ids) for s in range(S)
               for _, ids in op.launches[s]) > 0, op
    assert to.controller.dac.slack_seconds is not None

    # wire ledger: the chunks the overlapped executor moves per stage,
    # plus the shared leaves charged to that stage (embed/head move via
    # sync_shared_grads, uncompressed), sum to the Algorithm-2 ledger's
    # compressed bytes for the DAC's ranks — and each group chunk carries
    # exactly its plan rank.
    from repro.pipeline.partition import local_leaf_path
    plan = to.controller.plan
    ledger = stage_wire_bytes(to.leaves, plan, S, bytes_per_elem=4)
    shared_b = [0] * S
    for info in to.leaves:
        if local_leaf_path(info.path) is None:
            n = 1
            for d in info.shape:
                n *= d
            shared_b[min(info.stage, S - 1)] += n * 4
    for s in range(S):
        sp = to._splans.stage_plans[s]
        chunks = bucketing.sync_chunks(
            to._splans.layouts[to._splans.d_of_stage[s]])
        for c in chunks:
            if c.kind == "group":
                assert all(sp.rank_of(p) == c.group.rank
                           for p in c.member_paths)
        moved = sum(c.wire_bytes() for c in chunks)
        assert moved + shared_b[s] == ledger[s][0], \
            (s, moved, shared_b[s], ledger[s])
    print("OVERLAP_4DEV_OK")
""")


@pytest.mark.slow
def test_overlapped_1f1b_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "OVERLAP_4DEV_OK" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-3000:]
