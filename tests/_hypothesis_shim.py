"""Fallback property-testing shim for environments without `hypothesis`.

CI installs the real thing (the `dev` extra in pyproject.toml) and this
module is never imported. Hermetic environments that cannot pip-install get
a deterministic stand-in covering exactly the surface the test suite uses:
``given`` / ``settings`` / ``strategies.{integers,floats,sampled_from}``.

Semantics: each ``@given`` test runs ``max_examples`` times; the first
examples are the strategy boundaries (min/max or every element of a
``sampled_from``), the rest are drawn from a PRNG seeded by the test's
qualified name — stable across runs, no shrinking, no database.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self._boundary = tuple(boundary)

    def example_at(self, i: int, rng: random.Random):
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda rng: rng.randint(min_value, max_value),
                     boundary=(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                     boundary=(min_value, max_value))


def sampled_from(elements) -> _Strategy:
    elements = tuple(elements)
    return _Strategy(lambda rng: rng.choice(elements), boundary=elements)


def booleans() -> _Strategy:
    return _Strategy(lambda rng: rng.random() < 0.5, boundary=(False, True))


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._shim_settings = {"max_examples": max_examples}
        return fn
    return deco


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = data_too_large = filter_too_much = None


def given(*arg_strats, **kw_strats):
    if arg_strats:
        raise TypeError("shim @given supports keyword strategies only")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_shim_settings", None)
                   or getattr(fn, "_shim_settings", None) or {})
            n = cfg.get("max_examples", 20)
            seed = zlib.crc32(fn.__qualname__.encode("utf-8"))
            for i in range(n):
                rng = random.Random(seed ^ (i * 0x9E3779B9))
                drawn = {k: s.example_at(i, rng) for k, s in kw_strats.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _Unsatisfied:
                    continue
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # Hide the strategy params from pytest's fixture resolution: the
        # drawn values arrive via **kwargs, not fixtures.
        wrapper.__dict__.pop("__wrapped__", None)
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in kw_strats
        ])
        return wrapper
    return deco


def install() -> None:
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.sampled_from = sampled_from
    st.booleans = booleans
    hyp.strategies = st
    hyp.__version__ = "0.0-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
