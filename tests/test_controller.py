"""EDGCController invariants under arbitrary entropy trajectories."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import EDGCConfig, EDGCController, GDSConfig, LeafInfo
from repro.core.dac import DACConfig


def _leaves():
    return [
        LeafInfo(path=f"['stages'][{s}]['blocks']['mlp']['up']",
                 shape=(4, 512, 2048), stage=s, eligible=True)
        for s in range(4)
    ] + [
        LeafInfo(path="['embed']['tok']", shape=(50257, 512), stage=0,
                 eligible=False),
    ]


def _controller(policy="edgc", window=50, total=2000):
    cfg = EDGCConfig(policy=policy, num_stages=4, total_iterations=total,
                     gds=GDSConfig(alpha=0.5, beta=0.25),
                     dac=DACConfig(window=window, adjust_limit=4))
    return EDGCController(cfg, _leaves(), world=16)


@given(seed=st.integers(0, 2**31), drift=st.floats(-0.02, 0.02))
@settings(max_examples=25, deadline=None)
def test_ranks_always_in_bounds(seed, drift):
    """Whatever entropy does, applied ranks stay in [r_min, r_max]."""
    ctrl = _controller()
    rng = np.random.default_rng(seed)
    h = -5.0
    step = 0
    for w in range(20):
        for _ in range(10):
            if ctrl.wants_entropy(step):
                ctrl.on_entropy(step, h + rng.normal() * 0.05)
            h += drift
            step += 5
        ctrl.on_window_end(step)
        for _, rank in ctrl.plan.ranks:
            assert ctrl.r_min <= rank <= ctrl.r_max or rank <= ctrl.r_max


@given(seed=st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_warmup_never_compresses_before_10pct(seed):
    ctrl = _controller(total=1000)
    rng = np.random.default_rng(seed)
    step = 0
    while step < 99:  # below the 10% floor
        if ctrl.wants_entropy(step):
            ctrl.on_entropy(step, -5.0 - step * 0.01)  # falling fast
        step += 1
        if step % 50 == 0:
            ctrl.on_window_end(step)
        assert ctrl.plan.ranks == (), "compressed during the warm-up floor"


def test_rank_moves_bounded_per_window():
    ctrl = _controller(window=50)
    # warm up past the floor with stable entropy, then crash entropy
    step = 0
    for w in range(8):
        for _ in range(25):
            if ctrl.wants_entropy(step):
                ctrl.on_entropy(step, -5.0)
            step += 2
        ctrl.on_window_end(step)
    prev = None
    for w in range(6):
        for _ in range(25):
            if ctrl.wants_entropy(step):
                ctrl.on_entropy(step, -5.0 - (w + 1) * 0.3)  # crash
            step += 2
        ctrl.on_window_end(step)
        if ctrl.rank_history:
            r1 = ctrl.rank_history[-1][1][0]
            if prev is not None:
                limit = ctrl.cfg.dac.adjust_limit + ctrl.cfg.dac.quantize_to
                assert prev - r1 <= limit, "moved faster than Alg.1 allows"
            prev = r1


def test_baseline_policies_have_static_plans():
    for policy in ("fixed", "optimus"):
        ctrl = _controller(policy=policy)
        plan0 = ctrl.plan
        for step in (50, 100, 150):
            ctrl.on_window_end(step)
        assert ctrl.plan == plan0


def test_plan_cache_boundedness():
    """Quantization bounds the number of distinct plans (compile cache)."""
    ctrl = _controller()
    q = ctrl.cfg.dac.quantize_to
    possible = (ctrl.r_max - ctrl.r_min) // q + 2
    assert possible < 300  # sane compile-cache bound for this population
