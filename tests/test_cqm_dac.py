"""CQM control law + DAC algorithms 1 & 2 + controller transitions."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.comm_model import CommModel, rank_bounds
from repro.core.cqm import CQM, rank_from_entropy_delta
from repro.core.dac import (
    DAC, DACConfig, stage_aligned_ranks, window_rank_adjust,
)


def _comm(world=16):
    return CommModel.from_shapes([(1024, 4096)] * 24, world=world)


def test_cqm_anchor_and_direction():
    c = CQM(m=256, n=1024)
    c.anchor(64, h0=-3.0)
    assert c.rank_for_entropy(-3.0) == 64          # no entropy change
    assert c.rank_for_entropy(-3.5) < 64           # entropy down -> rank down
    assert c.rank_for_entropy(-2.5) >= 64          # entropy up -> rank up


@given(h0=st.floats(-6, 0), dh=st.floats(0, 1))
@settings(max_examples=30, deadline=None)
def test_theorem3_never_increases_on_entropy_drop(h0, dh):
    r1 = rank_from_entropy_delta(48, h0, h0 - dh, 256, 1024)
    assert r1 <= 48


@given(r_prev=st.integers(8, 120), r_new=st.integers(0, 200),
       s=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_window_adjust_constraints(r_prev, r_new, s):
    """Algorithm 1: move <= s per window, always inside [r_min, r_max]."""
    out = window_rank_adjust(r_prev, r_new, 8, 128, s)
    assert 8 <= out <= 128
    if 8 <= r_prev <= 128:
        assert abs(out - r_prev) <= s


def test_stage_alignment_monotone():
    """Later stages have more slack -> rank non-decreasing in stage index."""
    comm = _comm()
    ranks = stage_aligned_ranks(32, 4, comm, t_micro_back=comm.t_com(8),
                                r_min=8, r_max=128)
    assert ranks[0] == 32
    assert all(b >= a for a, b in zip(ranks, ranks[1:]))


def test_stage_alignment_eq4_exact():
    comm = _comm()
    t_micro = comm.t_com(10)
    ranks = stage_aligned_ranks(32, 3, comm, t_micro, 1, 10_000)
    # Eq. 4: r_i = (T_com(r1) + (i-1) t_micro) / eta
    for i, r in enumerate(ranks[1:], start=2):
        expected = round((comm.t_com(32) + (i - 1) * t_micro) / comm.eta)
        assert r == pytest.approx(expected, abs=1)


def test_rank_bounds_sane():
    comm = _comm()
    r_min, r_max = rank_bounds(comm, max_possible=512)
    assert 1 <= r_min < r_max <= 512
    # Eq. 2 holds at r_max, fails just past it (or r_max hit the cap)
    assert comm.t_total(r_max) <= comm.t_uncompressed() * 1.001
    if r_max < 512:
        assert comm.t_total(r_max + 2) > comm.t_uncompressed() * 0.999


def _dac(total=1000):
    cqm = CQM(m=256, n=1024)
    comm = _comm()
    return DAC(cqm=cqm, comm=comm, cfg=DACConfig(window=100, adjust_limit=4),
               r_min=8, r_max=64, num_stages=4,
               t_micro_back=comm.t_com(4), total_iterations=total)


def test_warmup_respects_10pct_floor():
    dac = _dac(total=1000)
    # huge entropy drop, but before 10% of iterations
    assert not dac.maybe_end_warmup(-5.0, step=50)
    assert not dac.warmed_up


def test_warmup_ends_on_entropy_drop():
    dac = _dac(total=1000)
    dac.maybe_end_warmup(-3.0, step=150)   # anchors
    assert not dac.warmed_up
    dac.maybe_end_warmup(-3.4, step=250)   # entropy fell -> r_new < r_max
    assert dac.warmed_up


def test_dac_update_moves_slowly():
    dac = _dac()
    dac.maybe_end_warmup(-3.0, step=150)
    dac.maybe_end_warmup(-3.4, step=250)
    r_before = dac.r_stage1
    ranks = dac.update(-5.0)               # massive drop
    # quantization happens INSIDE the clamp: the applied move respects
    # Constraint 2 exactly (no +quantize_to/2 slop)
    assert r_before - dac.r_stage1 <= dac.cfg.adjust_limit
    assert all(dac.r_min <= r <= dac.r_max for r in ranks)
    assert len(ranks) == 4


def test_dac_quantized_move_respects_constraint2():
    """Regression (Constraint 2): clamp-then-round could move the applied
    stage-1 rank by adjust_limit + quantize_to/2 in one window — e.g.
    prev=10, target 20, s=3, q=2: clamp -> 13, round -> 14, a move of 4.
    Snapping inside the clamp yields 12 (move 2 <= 3). Every stage's
    applied rank obeys the same bound across a window walk."""
    cqm = CQM(m=256, n=1024)
    comm = _comm()
    dac = DAC(cqm=cqm, comm=comm,
              cfg=DACConfig(window=100, adjust_limit=3, quantize_to=2),
              r_min=8, r_max=64, num_stages=4,
              t_micro_back=comm.t_com(4), total_iterations=1000)
    assert dac._snap_limited(13, 10) == 12          # the old path gave 14
    assert abs(dac._snap_limited(13, 10) - 10) <= 3

    # degenerate grid (quantize_to > 2*adjust_limit): no multiple of q
    # inside the +-s window -> hold at prev rather than stepping q past it
    dac_q = DAC(cqm=CQM(m=256, n=1024), comm=comm,
                cfg=DACConfig(window=100, adjust_limit=1, quantize_to=4),
                r_min=8, r_max=64, num_stages=4,
                t_micro_back=comm.t_com(4), total_iterations=1000)
    assert dac_q._snap_limited(15, 14) == 14         # was 12 (move of 2 > 1)
    assert dac_q._snap_limited(13, 14) == 14

    dac.maybe_end_warmup(-3.0, step=150)            # anchors at r_max
    dac.maybe_end_warmup(-3.4, step=250)
    assert dac.warmed_up
    prev = [dac.r_max] * 4                           # warm-up exit vector
    # a window sequence with violent entropy swings: every applied move,
    # for every stage, stays within +-adjust_limit and the Algorithm-2
    # monotonicity (non-decreasing over stages) survives the clamping
    for h in (-5.0, -2.0, -6.0, -3.0, -3.0, -7.0):
        ranks = dac.update(h)
        assert len(ranks) == 4
        for i, (p, r) in enumerate(zip(prev, ranks)):
            assert abs(r - p) <= dac.cfg.adjust_limit, (h, i, p, r)
            assert dac.r_min <= r <= dac.r_max
        assert all(b >= a for a, b in zip(ranks, ranks[1:])), ranks
        assert ranks == dac.current_ranks()
        prev = ranks


def test_dac_old_quantization_overshoot_would_fail():
    """The sequence the fix targets: prev=10, Theorem-3 target 20, s=5,
    q=2 — the old clamp-then-round order produced 16 (round(15/2)*2), a
    one-window move of adjust_limit + 1; snapping inside the clamp stays
    within +-s."""
    old = round(window_rank_adjust(10, 20, 8, 128, 5) / 2) * 2
    assert abs(old - 10) == 6 == 5 + 1              # the former violation
    cqm = CQM(m=256, n=1024)
    comm = _comm()
    dac = DAC(cqm=cqm, comm=comm,
              cfg=DACConfig(window=100, adjust_limit=5, quantize_to=2),
              r_min=8, r_max=128, num_stages=1,
              t_micro_back=comm.t_com(4), total_iterations=1000)
    new = dac._snap_limited(window_rank_adjust(10, 20, 8, 128, 5), 10)
    assert abs(new - 10) <= 5 and new % 2 == 0
