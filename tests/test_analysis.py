"""Collective-safety auditor: parity, budgets, host-sync, and lint.

The in-process tests trace tiny programs with ``make_jaxpr(axis_env=...)``
(no mesh needed); the real overlapped executor — including the seeded
dropped-psum mutation the auditor exists to catch — runs in a fake-device
subprocess like the rest of the multi-device suite.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from repro import analysis
from repro.analysis.lint import lint_source

AXES = [("pipe", 2), ("data", 2)]


def _trace(fn, *args):
    return jax.make_jaxpr(fn, axis_env=AXES)(*args)


# ------------------------------------------------------------ jaxpr_walk
def test_walk_paths_and_signature_order():
    def fn(x):
        y = lax.psum(x, "data")

        def body(c, _):
            return lax.pmax(c, "data"), ()

        z, _ = lax.scan(body, y, None, length=3)
        return lax.psum(z, "data")

    traced = _trace(fn, jnp.ones(4))
    sig = analysis.collective_signature(traced.jaxpr)
    assert [c.primitive for c in sig] == ["psum", "pmax", "psum"]
    assert all(c.axes == ("data",) for c in sig)
    # the scan-body collective is path-qualified into the sub-jaxpr
    assert ".jaxpr/" in sig[1].path
    paths = [p for _, p in analysis.walk(traced)]
    assert any("/scan#" in p for p in paths)


def test_count_collectives_counts_equations_not_strings():
    def fn(psum_lookalike):                 # var NAME must not count
        return lax.psum(psum_lookalike, "data")

    traced = _trace(fn, jnp.ones(4))
    assert analysis.count_collectives(traced, "psum") == 1
    assert analysis.count_collectives(traced) == 1


# ---------------------------------------------------------------- parity
def test_parity_identical_branches_pass():
    def fn(x, p):
        b = lambda v: lax.psum(v, "data") * 2.0
        return lax.switch(p, [b, lambda v: lax.psum(v, "data") + 1.0], x)

    traced = _trace(fn, jnp.ones(4), jnp.int32(0))
    assert analysis.check_collective_parity(traced) == []


def test_parity_divergent_data_predicate_flagged():
    """A data-dependent predicate with branch-divergent collectives is the
    canonical SPMD deadlock; the diagnostic names the first divergence."""
    def fn(x, p):
        b0 = lambda v: lax.psum(v, "data")
        b1 = lambda v: v * 2.0
        return lax.switch(p, [b0, b1], x)

    traced = _trace(fn, jnp.ones(4), jnp.int32(0))
    (v,) = analysis.check_collective_parity(traced)
    assert v.rule == "collective-parity"
    assert "/cond#" in v.path
    assert "psum[data]" in v.message


def test_parity_axis_index_predicate_is_safe():
    """The overlapped executor's shape: switch on axis_index('pipe') with
    per-branch psums over the DP axes only. Every data-group peer shares
    the pipe index, so divergence is deadlock-free — must pass."""
    def fn(x):
        i = lax.axis_index("pipe")
        b0 = lambda v: lax.psum(v, "data")
        b1 = lambda v: lax.psum(lax.psum(v, "data"), "data")
        return lax.switch(i, [b0, b1], x)

    traced = _trace(fn, jnp.ones(4))
    assert analysis.check_collective_parity(traced) == []


def test_parity_collective_over_predicate_axis_flagged():
    """Same pipe-index predicate, but one branch launches a PIPE-axis
    collective: pipe peers disagree on the branch — deadlock."""
    def fn(x):
        i = lax.axis_index("pipe")
        b0 = lambda v: lax.psum(v, "pipe")
        b1 = lambda v: v * 2.0
        return lax.switch(i, [b0, b1], x)

    traced = _trace(fn, jnp.ones(4))
    (v,) = analysis.check_collective_parity(traced)
    assert v.rule == "collective-parity" and "'pipe'" in v.message


def test_parity_reduced_value_predicate_is_safe():
    """A predicate produced by a data-axis reduction is uniform over
    'data': divergent data-axis collectives behind it cannot deadlock."""
    def fn(x):
        p = (lax.psum(x.sum(), "data") > 0).astype(jnp.int32)
        b0 = lambda v: lax.psum(v, "data")
        b1 = lambda v: v * 2.0
        return lax.switch(p, [b0, b1], x)

    traced = _trace(fn, jnp.ones(4))
    assert analysis.check_collective_parity(traced) == []


def test_parity_recurses_into_scan_bodies():
    def fn(x, p):
        def body(c, _):
            b0 = lambda v: lax.psum(v, "data")
            b1 = lambda v: v * 2.0
            return lax.switch(p, [b0, b1], c), ()

        y, _ = lax.scan(body, x, None, length=2)
        return y

    traced = _trace(fn, jnp.ones(4), jnp.int32(0))
    (v,) = analysis.check_collective_parity(traced)
    assert "/scan#" in v.path and "/cond#" in v.path


# --------------------------------------------------------- switch budgets
def _switchy(x):
    i = lax.axis_index("pipe")
    b0 = lambda v: lax.psum(v, "data")
    b1 = lambda v: lax.psum(lax.psum(v, "data"), "data")
    return lax.switch(i, [b0, b1], x)


def test_switch_budgets_clean_and_dropped_psum_caught():
    traced = _trace(_switchy, jnp.ones(4))
    assert analysis.check_switch_budgets(traced, [(1, 2)]) == []
    # the seeded-mutation shape: branch 1 declared 3 psums, traced 2
    (v,) = analysis.check_switch_budgets(traced, [(1, 3)])
    assert v.rule == "psum-budget"
    assert v.path.endswith(".branch=1")
    assert "launches 2" in v.message and "expects 3" in v.message


def test_switch_budgets_switch_count_mismatch():
    traced = _trace(_switchy, jnp.ones(4))
    (v,) = analysis.check_switch_budgets(traced, [(1, 2), (9, 7)])
    assert v.rule == "psum-budget" and "declares 2" in v.message


# --------------------------------------------------------- CollectiveSpy
def test_collective_spy_against_real_layout():
    from repro.core import (
        classify_leaves, init_compressor_state, make_bucket_layout,
        make_plan, sync_grads,
    )

    rng = np.random.default_rng(0)
    params = {"a": jnp.asarray(rng.standard_normal((64, 96)), jnp.float32),
              "b": jnp.asarray(rng.standard_normal((64, 96)), jnp.float32),
              "small": jnp.asarray(rng.standard_normal((8,)), jnp.float32)}
    leaves = classify_leaves(params, num_layers=1, num_stages=1, min_dim=32)
    plan = make_plan("fixed", leaves, fixed_rank=4)
    layout = make_bucket_layout(leaves, plan)
    state = init_compressor_state(params, plan, jax.random.PRNGKey(0),
                                  layout=layout)
    spy = analysis.CollectiveSpy()
    sync_grads(params, state, plan, spy, bucketed=True)
    assert analysis.check_sync_spy(spy, layout) == []
    assert spy.factor_ranks() == [4]

    # a spy that saw one launch too few fails the budget with a reason
    short = analysis.CollectiveSpy()
    short.calls = spy.calls[:-1]
    bad = analysis.check_sync_spy(short, layout)
    assert bad and all(v.rule == "psum-budget" for v in bad)


def test_entropy_gate_negative():
    def two(x):
        return lax.psum(lax.psum(x, "data"), "data")

    def one(x):
        return lax.psum(x, "data")

    t2, t1 = _trace(two, jnp.ones(4)), _trace(one, jnp.ones(4))
    assert analysis.check_entropy_gate(t2, t1, expected_delta=1) == []
    (v,) = analysis.check_entropy_gate(t2, t1, expected_delta=3)
    assert v.rule == "entropy-gate" and "delta 1" in v.message


# ------------------------------------------------------------- hostcalls
def test_host_transfer_flagged_and_clean():
    def dirty(x):
        jax.debug.print("x = {}", x)
        return x * 2

    def clean(x):
        return x * 2

    (v,) = analysis.check_host_transfers(jax.make_jaxpr(dirty)(1.0))
    assert v.rule == "host-sync" and "round-trip" in v.message
    assert analysis.check_host_transfers(jax.make_jaxpr(clean)(1.0)) == []
    # an explicit allowlist admits intentional callbacks
    traced = jax.make_jaxpr(dirty)(1.0)
    name = next(eqn.primitive.name for eqn, _ in analysis.walk(traced)
                if eqn.primitive.name in analysis.HOST_CALLBACK_PRIMS)
    assert analysis.check_host_transfers(traced, allow=[name]) == []


def test_step_cache_window_bounds():
    keys = [(f"plan{i}", m, "sync") for i in range(2) for m in (True, False)]
    assert analysis.check_step_cache(keys, steps=6, window=3) == []
    # 4 distinct plans after 6 steps with window=3 exceeds the bound of 3
    keys = [(f"plan{i}", True, "sync") for i in range(4)]
    (v,) = analysis.check_step_cache(keys, steps=6, window=3)
    assert v.rule == "recompile" and "window boundaries" in v.message
    # unhashable keys are flagged before any counting
    (v,) = analysis.check_step_cache([(["unhashable"], True, "s")],
                                     steps=1, window=1)
    assert v.rule == "recompile" and "unhashable" in v.message


# ------------------------------------------------------------------ lint
def test_lint_dup_dict_key():
    (f,) = lint_source('D = {"s64": 8, "u64": 8, "s64": 8}')
    assert f.rule == "dup-dict-key" and "'s64'" in f.message
    assert lint_source('D = {"s64": 8, "u64": 8}') == []
    # non-constant keys never crash or false-positive
    assert lint_source("D = {k: 1, k: 2}") == []


def test_lint_hlo_cost_dtype_table_regression():
    """The table this rule was born from: hlo_cost.py's DTYPE_BYTES once
    carried a silent duplicate "s64" entry."""
    path = os.path.join("src", "repro", "launch", "hlo_cost.py")
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    assert [f for f in lint_source(src, path)
            if f.rule == "dup-dict-key"] == []
    from repro.launch.hlo_cost import DTYPE_BYTES
    assert len(DTYPE_BYTES) == 15


def test_lint_host_call_in_hot_path():
    hot = "src/repro/core/powersgd.py"
    assert lint_source("x = float(y)", hot)[0].rule == "host-call-in-hot-path"
    assert lint_source("import numpy as np\nz = np.sum(y)", hot)[0].rule == \
        "host-call-in-hot-path"
    assert lint_source("y.block_until_ready()", hot)[0].rule == \
        "host-call-in-hot-path"
    # same source outside the hot-path list is fine
    assert lint_source("x = float(y)", "src/repro/train/trainer.py") == []
    # the inline allowlist suppresses with a reason
    allowed = "x = float(y)  # lint: allow(host-call-in-hot-path) static"
    assert lint_source(allowed, hot) == []


def test_lint_collective_axis_name():
    src = "from jax import lax\nr = lax.psum(x)\nk = lax.psum(x, 'data')\n" \
          "g = lax.all_gather(x, axis_name='data')\n"
    found = lint_source(src)
    assert len(found) == 1 and found[0].rule == "collective-axis-name"
    assert found[0].line == 2


def test_lint_unhashable_cache_key():
    (f,) = lint_source("self._step_cache[[p, m]] = step")
    assert f.rule == "unhashable-cache-key"
    assert lint_source("self._step_cache[(p, m)] = step") == []
    assert lint_source("values[[1, 2]] = x") == []    # not a cache name


def test_lint_repo_clean():
    """The blocking-gate invariant: the shipped tree lints clean."""
    roots = [r for r in ("src/repro", "tests", "benchmarks", "examples")
             if os.path.isdir(r)]
    assert [str(f) for f in analysis.run_lint(roots)] == []


# --------------------------- real overlapped executor (fake devices, slow)
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp

    from repro import analysis
    from repro.core import SyncConfig, bucketing
    from repro.launch.audit import FAMILY_CFGS, _trace_pipelined
    from repro.launch.mesh import make_host_mesh
    from repro.pipeline.schedule import overlap_branch_psums, plan_overlap

    cfg = FAMILY_CFGS["dense"]
    mesh = make_host_mesh(pipe=2, data=2, model=1)
    traced, oplan, splans = _trace_pipelined(cfg, mesh, overlap=True)

    # clean step: parity, declared budgets, host-sync all pass
    assert analysis.check_collective_parity(traced) == []
    assert analysis.check_overlap_branches(traced, oplan, splans) == []
    assert analysis.check_host_transfers(traced) == []
    switches = analysis.switch_collective_counts(traced)
    assert len(switches) >= 2          # >=1 in-loop launch + the residual
    in_loop, residual = overlap_branch_psums(oplan, splans)
    assert switches[-1][1] == residual

    # every family adapter's overlapped step audits clean
    for fam in ("moe", "zamba"):
        fcfg = FAMILY_CFGS[fam]
        fmesh = make_host_mesh(pipe=fcfg.num_stages, data=2, model=1)
        ftr, fop, fsp = _trace_pipelined(fcfg, fmesh, overlap=True)
        assert analysis.check_collective_parity(ftr) == [], fam
        assert analysis.check_overlap_branches(ftr, fop, fsp) == [], fam

    # SEEDED MUTATION: drop the second factor psum of every stacked-group
    # chunk (deadlock-free — DP peers still agree — but silently leaves
    # the factors unsynced). The declared-budget diff must catch it with
    # a path-qualified, branch-qualified diagnostic.
    real = bucketing.sync_chunk_grads
    def mutated(grads_by_path, state, chunk, psum_mean, **kw):
        if chunk.kind == "group":
            seen = []
            def dropping(x):
                seen.append(x)
                return x if len(seen) >= 2 else psum_mean(x)
            return real(grads_by_path, state, chunk, dropping, **kw)
        return real(grads_by_path, state, chunk, psum_mean, **kw)
    bucketing.sync_chunk_grads = mutated
    try:
        bad, oplan2, splans2 = _trace_pipelined(cfg, mesh, overlap=True)
    finally:
        bucketing.sync_chunk_grads = real
    found = analysis.check_overlap_branches(bad, oplan2, splans2)
    assert found, "seeded dropped-psum mutation not caught"
    assert all(v.rule == "psum-budget" for v in found)
    assert any(".branch=" in v.path and "/cond#" in v.path for v in found), \\
        [str(v) for v in found]
    print("overlap-audit-ok", len(found), "violation(s) on mutant")
""")


@pytest.mark.slow
def test_overlapped_step_audit_and_seeded_mutation_subprocess():
    env = {**os.environ, "PYTHONPATH": "src"}
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "overlap-audit-ok" in proc.stdout
