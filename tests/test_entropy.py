"""GDS entropy estimators: Lemma 2, histogram, sampling, properties."""
import math

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.entropy import (
    GDSConfig, gaussian_entropy, grads_entropy, grads_entropy_per_leaf,
    histogram_entropy, strided_sample,
)

GAUSS_H1 = 0.5 * math.log(2 * math.pi * math.e)  # H of N(0,1) in nats


def test_lemma2_gaussian_entropy():
    rng = np.random.default_rng(0)
    for sigma in (1.0, 0.1, 3.0):
        x = jnp.asarray(rng.standard_normal(200_000) * sigma, jnp.float32)
        expected = math.log(sigma) + GAUSS_H1
        assert float(gaussian_entropy(x)) == pytest.approx(expected, abs=0.02)


def test_histogram_close_to_gaussian_on_normal_data():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(500_000), jnp.float32)
    assert float(histogram_entropy(x)) == pytest.approx(GAUSS_H1, abs=0.05)


def test_histogram_detects_nongaussian():
    """Uniform has LOWER entropy than a Gaussian of equal variance."""
    rng = np.random.default_rng(2)
    u = jnp.asarray(rng.uniform(-np.sqrt(3), np.sqrt(3), 500_000), jnp.float32)
    h_u = float(histogram_entropy(u))
    assert h_u < GAUSS_H1
    assert h_u == pytest.approx(math.log(2 * math.sqrt(3)), abs=0.05)


@given(beta=st.sampled_from([1.0, 0.5, 0.25, 0.1, 0.05]))
@settings(max_examples=10, deadline=None)
def test_sampled_entropy_tracks_full(beta):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal(400_000) * 0.37, jnp.float32)
    full = float(histogram_entropy(x))
    sampled = float(histogram_entropy(strided_sample(x, beta)))
    assert sampled == pytest.approx(full, abs=0.05)


def test_strided_sample_size_and_determinism():
    x = jnp.arange(1000, dtype=jnp.float32)
    s1 = strided_sample(x, 0.25)
    s2 = strided_sample(x, 0.25)
    assert s1.shape[0] == 250
    assert bool(jnp.all(s1 == s2))


@given(scale=st.floats(0.01, 10.0))
@settings(max_examples=20, deadline=None)
def test_entropy_monotone_in_scale(scale):
    """H(aX) = H(X) + log a — entropy must increase with spread."""
    rng = np.random.default_rng(4)
    base = rng.standard_normal(100_000).astype(np.float32)
    h1 = float(gaussian_entropy(jnp.asarray(base)))
    h2 = float(gaussian_entropy(jnp.asarray(base * scale)))
    assert h2 == pytest.approx(h1 + math.log(scale), abs=0.01)


def test_grads_entropy_per_leaf_weighted_mean():
    rng = np.random.default_rng(5)
    grads = {
        "a": jnp.asarray(rng.standard_normal((256, 256)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((256, 256)) * 0.1, jnp.float32),
    }
    h = float(grads_entropy_per_leaf(grads, GDSConfig(beta=1.0)))
    ha = GAUSS_H1
    hb = math.log(0.1) + GAUSS_H1
    assert h == pytest.approx((ha + hb) / 2, abs=0.05)


def test_grads_entropy_single_pass_pools_samples():
    """grads_entropy == entropy of the concatenated beta-samples."""
    rng = np.random.default_rng(6)
    grads = {
        "a": jnp.asarray(rng.standard_normal((256, 256)), jnp.float32),
        "b": jnp.asarray(rng.standard_normal((256, 256)) * 0.1, jnp.float32),
    }
    for beta in (1.0, 0.25):
        cfg = GDSConfig(beta=beta)
        pooled = jnp.concatenate(
            [strided_sample(grads["a"], beta), strided_sample(grads["b"], beta)]
        )
        want = float(gaussian_entropy(pooled))
        assert float(grads_entropy(grads, cfg)) == pytest.approx(want, abs=1e-5)
    # pooled sigma is the RMS of the two sigmas, not the per-leaf mean H
    sigma = math.sqrt((1.0 + 0.01) / 2)
    assert float(grads_entropy(grads, GDSConfig(beta=1.0))) == pytest.approx(
        math.log(sigma) + GAUSS_H1, abs=0.05)


def test_gds_alpha_gate():
    cfg = GDSConfig(alpha=0.1)
    measured = [s for s in range(100) if cfg.should_measure(s)]
    assert len(measured) == 10
