"""Suite-wide fixtures/config.

If the real `hypothesis` is importable (CI installs the `dev` extra) it is
used untouched; otherwise the deterministic shim in _hypothesis_shim.py is
registered so the five property-based modules still collect and run in
hermetic environments that cannot pip-install.
"""
import importlib.util
import os

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_shim",
        os.path.join(os.path.dirname(__file__), "_hypothesis_shim.py"),
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()
