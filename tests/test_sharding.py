"""Partition rules, batch/cache specs, FSDP application, HLO collective parser."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    apply_fsdp, batch_pspec, cache_pspecs, param_pspecs,
)
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh(data=1, model=1)


def _leaf_specs(params, mesh):
    specs = param_pspecs(params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    return {jax.tree_util.keystr(kp): s for kp, s in flat}


def test_dense_tp_rules(mesh):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = _leaf_specs(params, mesh)
    wq = next(s for p, s in specs.items() if "wq" in p)
    wo = next(s for p, s in specs.items() if "wo" in p)
    up = next(s for p, s in specs.items() if "'up'" in p)
    down = next(s for p, s in specs.items() if "'down'" in p)
    emb = next(s for p, s in specs.items() if "tok" in p)
    assert wq[-1] == "model" and wo[-2] == "model"       # column / row
    assert up[-1] == "model" and down[-2] == "model"
    assert emb[0] == "model"                              # vocab sharded
    for p, s in specs.items():
        if "norm" in p:
            assert "model" not in tuple(s)


def test_moe_expert_parallel(mesh):
    cfg = ModelConfig(name="m", family="moe", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
                      num_experts=4, experts_per_token=2)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = _leaf_specs(params, mesh)
    gate = next(s for p, s in specs.items() if "experts" in p and "gate" in p)
    # (layers, E, d, f): expert dim sharded
    assert gate[1] == "model"
    router = next(s for p, s in specs.items() if "router" in p)
    assert "model" not in tuple(router)


def test_divisibility_guard():
    """Dims not divisible by the model-axis size are never sharded."""
    mesh16 = make_host_mesh(data=1, model=1)  # size 1 divides everything
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=100,
                      num_heads=4, num_kv_heads=2, d_ff=130, vocab_size=500)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    # simulate a 16-way model axis by checking the rule path directly
    from repro.dist.sharding import _spec_for

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (1, 16)
    s = _spec_for("stages[0].blocks.attn.wq", (2, 100, 130), FakeMesh)
    assert "model" not in tuple(s)  # 130 % 16 != 0 -> dropped


def test_batch_pspec_divisibility(mesh):
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        class devices:
            shape = (2, 16, 16)
    assert batch_pspec(2, FakeMesh, batch_size=256)[0] == ("pod", "data")
    assert batch_pspec(2, FakeMesh, batch_size=16)[0] in ("pod", ("pod",))  # 16 % 32 != 0
    assert batch_pspec(2, FakeMesh, batch_size=1)[0] is None


def test_cache_pspecs(mesh):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512)
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(8, 64))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 2)
    specs = cache_pspecs(cache, FakeMesh, batch_size=8)
    kspec = specs["stages"][0]["k"]
    # (layers, B, C, Hkv, hd): batch over data, kv-heads over model
    assert kspec[1] in ("data", ("data",))
    assert kspec[3] == "model"
    assert specs["len"] == P()


def test_fsdp_application(mesh):
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=256,
                      num_heads=4, num_kv_heads=2, d_ff=4096, vocab_size=8192)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    specs = param_pspecs(params, FakeMesh)
    fsdp = apply_fsdp(specs, params, FakeMesh, "data")
    flat_f = jax.tree_util.tree_flatten_with_path(fsdp)[0]
    flat_p = {jax.tree_util.keystr(kp): l for kp, l
              in jax.tree_util.tree_flatten_with_path(params)[0]}
    got_data = 0
    for kp, s in flat_f:
        path = jax.tree_util.keystr(kp)
        leaf = flat_p[path]
        if leaf.size >= (1 << 20):
            if "data" in jax.tree_util.tree_leaves(tuple(s)):
                got_data += 1
    assert got_data > 0  # big leaves actually picked up the fsdp axis


def test_collective_parser():
    from repro.launch.dryrun import _shape_bytes, collective_bytes
    hlo = """
  %ar = bf16[128,256] all-reduce(bf16[128,256] %x), replica_groups={}
  %ag.1 = f32[512]{0} all-gather(f32[32] %y), dimensions={0}
  %t = (f32[16,16], f32[16,16]) all-to-all(f32[16,16] %a, f32[16,16] %b)
  %cp = u32[4] collective-permute(u32[4] %c)
  %noise = f32[8] add(f32[8] %p, f32[8] %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 128 * 256 * 2
    assert out["all-gather"] == 512 * 4
    assert out["all-to-all"] == 2 * 16 * 16 * 4
    assert out["collective-permute"] == 4 * 4
    assert _shape_bytes("bf16[2,3]") == 12


def test_stage_pspecs_per_family():
    """Stage-stacked trees keep the Megatron TP rules behind the leading
    'pipe' dim for every family: MoE expert stacks (S, L, E, d, f) shard
    E over 'model' (expert parallelism under TP), Mamba2 projections keep
    column/row rules, and replicated-by-path leaves stay replicated."""
    from repro.dist.sharding import stage_param_pspecs
    from repro.pipeline.partition import make_partition

    mesh = make_host_mesh(pipe=1, data=1, model=1)

    def stage_specs(cfg):
        model = build_model(cfg)
        part = make_partition(model, cfg.num_stages)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sp, _ = jax.eval_shape(lambda p: part.partition_params(p), shapes)
        flat = jax.tree_util.tree_flatten_with_path(
            stage_param_pspecs(sp, mesh))[0]
        return {jax.tree_util.keystr(kp): s for kp, s in flat}

    moe = stage_specs(ModelConfig(
        name="m", family="moe", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512, num_experts=4,
        experts_per_token=2, num_stages=1))
    gate = next(s for p, s in moe.items() if "experts" in p and "gate" in p)
    assert gate[0] == "pipe" and gate[2] == "model"      # (S, L, E, d, f)
    router = next(s for p, s in moe.items() if "router" in p)
    assert "model" not in tuple(router)

    zam = stage_specs(ModelConfig(
        name="z", family="zamba", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, ssm_state=16, chunk=16,
        attn_every=2, num_stages=1))
    in_proj = next(s for p, s in zam.items() if "in_proj" in p)
    out_proj = next(s for p, s in zam.items() if "out_proj" in p)
    assert in_proj[0] == "pipe" and in_proj[-1] == "model"
    assert out_proj[-2] == "model"
    conv = next(s for p, s in zam.items() if "conv" in p)
    assert "model" not in tuple(conv)

    wh = stage_specs(ModelConfig(
        name="w", family="whisper", num_layers=2, encoder_layers=2,
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
        audio_frames=16, max_position=512, num_stages=2))
    cross_wq = next(s for p, s in wh.items()
                    if "cross" in p and "wq" in p)
    assert cross_wq[0] == "pipe" and cross_wq[-1] == "model"
