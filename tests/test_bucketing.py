"""Bucketed gradient sync: layout derivation, parity with the per-leaf
oracle (grads, EF residual, warm-start Q), stacked-state rank resize, and —
in a fake-device subprocess — the collective-count collapse on a 4-way DP
mesh (acceptance: bucketed HLO holds <= 25% of the per-leaf collectives).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CompressionPlan, NO_COMPRESSION, classify_leaves, init_compressor_state,
    make_plan, resize_compressor_state, sync_grads,
)
from repro.core import bucketing
from repro.core.bucketing import make_bucket_layout
from repro.core.powersgd import resize_rank
from repro.models.model import ModelConfig, build_model

TINY = ModelConfig(name="bkt", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)

PLANS = {
    "none": {},
    "fixed": dict(fixed_rank=8),
    "optimus": dict(fixed_rank=8, num_stages=2),
    "edgc": dict(stage_ranks=[4, 16], num_stages=2),
}


def _setup(policy="fixed", **overrides):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    kw = dict(PLANS[policy]); kw.update(overrides)
    plan = make_plan(policy, leaves, **kw)
    return params, leaves, plan


def _rand_grads(params, seed=0):
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32), params)


# ------------------------------------------------------------------- layout
def test_layout_groups_by_shape_and_rank():
    params, leaves, plan = _setup("fixed")
    layout = make_bucket_layout(leaves, plan)
    assert layout.groups, "compressed leaves must form groups"
    # every compressed leaf is in exactly one group, at its plan rank
    in_groups = {p: g.rank for g in layout.groups for p, _ in g.members}
    assert in_groups == plan.as_dict()
    for g in layout.groups:
        for _, shape in g.members:
            assert tuple(shape[-2:]) == (g.m, g.n)
    # uncompressed leaves all land in buckets, none twice
    bucketed_paths = [p for b in layout.buckets for p, _ in b.members]
    assert sorted(bucketed_paths) == sorted(
        l.path for l in leaves if l.path not in in_groups)
    # layout is hashable & deterministic (static-arg / compile-cache safe)
    assert hash(layout) == hash(make_bucket_layout(leaves, plan))
    assert layout == make_bucket_layout(leaves, plan)


def test_layout_bucket_size_cap():
    params, leaves, plan = _setup("none")
    one = make_bucket_layout(leaves, plan)                  # default 32 MiB cap
    assert len(one.buckets) == 1
    small = make_bucket_layout(leaves, plan, bucket_bytes=64 << 10)
    assert len(small.buckets) > 1
    cap_elems = (64 << 10) // 4
    for b in small.buckets:
        # a bucket only exceeds the cap when a single oversize leaf forces it
        assert b.num_elements <= cap_elems or len(b.members) == 1
    # packing preserves every leaf exactly once, in tree order
    flat = [p for b in small.buckets for p, _ in b.members]
    assert flat == [l.path for l in leaves]


def test_layout_collective_count_math():
    params, leaves, plan = _setup("fixed")
    layout = make_bucket_layout(leaves, plan)
    assert layout.num_collectives() == 2 * len(layout.groups) + len(layout.buckets)
    per_leaf = 2 * len(plan.ranks) + sum(
        1 for l in leaves if l.path not in plan.as_dict())
    assert layout.num_collectives() < per_leaf


def test_rank_of_matches_dict_and_misses():
    _, leaves, plan = _setup("fixed")
    as_dict = dict(plan.ranks)
    for path, rank in plan.ranks:
        assert plan.rank_of(path) == rank == as_dict[path]
    assert plan.rank_of("['not']['a']['leaf']") is None


# ------------------------------------------------------------------- parity
@pytest.mark.parametrize("policy", ["none", "fixed", "optimus", "edgc"])
def test_bucketed_matches_per_leaf_oracle(policy):
    """Same synced grads, EF residual and warm-start Q as the per-leaf loop."""
    params, leaves, plan = _setup(policy)
    layout = make_bucket_layout(leaves, plan)
    per_leaf = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    stacked = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                    layout=layout)
    grads = _rand_grads(params)
    # two rounds so the EF residual is nonzero going into the second
    s_ref, st_ref = sync_grads(grads, per_leaf, plan, lambda x: x)
    s_ref, st_ref = sync_grads(grads, st_ref, plan, lambda x: x)
    s_bkt, st_bkt = sync_grads(grads, stacked, plan, lambda x: x, bucketed=True)
    s_bkt, st_bkt = sync_grads(grads, st_bkt, plan, lambda x: x, bucketed=True)
    for a, b in zip(jax.tree_util.tree_leaves(s_ref),
                    jax.tree_util.tree_leaves(s_bkt)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    unstacked = bucketing.unstack_state(st_bkt, layout)
    assert set(unstacked) == set(st_ref)
    for path, st in st_ref.items():
        np.testing.assert_allclose(np.asarray(st.q), np.asarray(unstacked[path].q),
                                   rtol=1e-4, atol=1e-5, err_msg=f"q {path}")
        np.testing.assert_allclose(np.asarray(st.err),
                                   np.asarray(unstacked[path].err),
                                   rtol=1e-4, atol=1e-5, err_msg=f"err {path}")


def test_bucketed_auto_detected_from_state_format():
    params, leaves, plan = _setup("fixed")
    layout = make_bucket_layout(leaves, plan)
    stacked = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                    layout=layout)
    grads = _rand_grads(params)
    auto, st_auto = sync_grads(grads, stacked, plan, lambda x: x)  # no flag
    explicit, st_exp = sync_grads(grads, stacked, plan, lambda x: x,
                                  bucketed=True)
    for a, b in zip(jax.tree_util.tree_leaves(auto),
                    jax.tree_util.tree_leaves(explicit)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert set(st_auto) == set(st_exp)


def test_bucketed_psum_count_and_wire_dtype():
    """Exactly 2 psums per group + 1 per bucket; buckets keep the members'
    native wire dtype (bf16 tree -> bf16 bucket, no fp32 upcast)."""
    params, leaves, plan = _setup("fixed")
    layout = make_bucket_layout(leaves, plan)
    stacked = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                    layout=layout)
    from repro.analysis import CollectiveSpy, check_sync_spy
    for dtype in (jnp.float32, jnp.bfloat16):
        grads = jax.tree_util.tree_map(
            lambda a: a.astype(dtype), _rand_grads(params))
        spy = CollectiveSpy()
        sync_grads(grads, stacked, plan, spy, bucketed=True)
        assert check_sync_spy(spy, layout) == []
        assert len(spy.flat_calls) == len(layout.buckets)
        for _, dt in spy.flat_calls:
            assert dt == dtype                            # no upcast on wire


def test_stack_unstack_roundtrip():
    params, leaves, plan = _setup("fixed")
    layout = make_bucket_layout(leaves, plan)
    per_leaf = init_compressor_state(params, plan, jax.random.PRNGKey(2))
    back = bucketing.unstack_state(bucketing.stack_state(per_leaf, layout),
                                   layout)
    for path, st in per_leaf.items():
        assert back[path].q.shape == st.q.shape
        assert back[path].err.shape == st.err.shape
        np.testing.assert_array_equal(np.asarray(st.q), np.asarray(back[path].q))
        np.testing.assert_array_equal(np.asarray(st.err),
                                      np.asarray(back[path].err))


# ------------------------------------------------------- rank resize (DAC)
def test_stacked_resize_across_window():
    """DAC window re-plan: shrink keeps leading Q columns + EF; grow appends."""
    params, leaves, _ = _setup("fixed")
    plan0 = make_plan("fixed", leaves, fixed_rank=8)
    # alternate shrink (8 -> 4) and grow (8 -> 16) across the leaves, as a
    # DAC window boundary would when stage ranks move in both directions
    plan1 = CompressionPlan(ranks=tuple(
        (path, 4 if i % 2 == 0 else 16)
        for i, (path, _) in enumerate(plan0.ranks)))
    lay0 = make_bucket_layout(leaves, plan0)
    lay1 = make_bucket_layout(leaves, plan1)
    state0 = init_compressor_state(params, plan0, jax.random.PRNGKey(3),
                                   layout=lay0)
    state1 = resize_compressor_state(state0, plan1, jax.random.PRNGKey(4),
                                     old_layout=lay0, new_layout=lay1)
    assert bucketing.is_stacked_state(state1)
    per0 = bucketing.unstack_state(state0, lay0)
    per1 = bucketing.unstack_state(state1, lay1)
    ranks1 = plan1.as_dict()
    assert set(per1) == set(ranks1)
    grew = shrank = 0
    for path, st1 in per1.items():
        r0, r1 = per0[path].q.shape[-1], ranks1[path]
        assert st1.q.shape[-1] == r1
        # EF residual survives the rank move untouched
        np.testing.assert_array_equal(np.asarray(per0[path].err),
                                      np.asarray(st1.err))
        if r1 <= r0:
            shrank += r1 < r0
            np.testing.assert_array_equal(np.asarray(per0[path].q[..., :r1]),
                                          np.asarray(st1.q))
        else:
            grew += 1
            np.testing.assert_array_equal(np.asarray(per0[path].q),
                                          np.asarray(st1.q[..., :r0]))
    assert grew and shrank, "plan change must exercise both directions"


def test_stacked_resize_matches_per_leaf_resize():
    params, leaves, _ = _setup("fixed")
    plan0 = make_plan("fixed", leaves, fixed_rank=8)
    plan1 = make_plan("fixed", leaves, fixed_rank=12)
    lay0, lay1 = (make_bucket_layout(leaves, p) for p in (plan0, plan1))
    state0 = init_compressor_state(params, plan0, jax.random.PRNGKey(5),
                                   layout=lay0)
    state1 = resize_compressor_state(state0, plan1, jax.random.PRNGKey(6),
                                     old_layout=lay0, new_layout=lay1)
    per0 = bucketing.unstack_state(state0, lay0)
    per1 = bucketing.unstack_state(state1, lay1)
    for path in per1:
        # the deterministic part (leading columns) must match a direct
        # per-leaf resize_rank; the appended tail is fresh randomness
        direct = resize_rank(per0[path], 12, jax.random.PRNGKey(7))
        np.testing.assert_array_equal(np.asarray(direct.q[..., :8]),
                                      np.asarray(per1[path].q[..., :8]))


def test_stacked_resize_from_no_compression():
    """EDGC warm-up exit: every compressed leaf enters with fresh state."""
    params, leaves, _ = _setup("fixed")
    plan1 = make_plan("fixed", leaves, fixed_rank=8)
    lay0 = make_bucket_layout(leaves, NO_COMPRESSION)
    lay1 = make_bucket_layout(leaves, plan1)
    state1 = resize_compressor_state({}, plan1, jax.random.PRNGKey(8),
                                     old_layout=lay0, new_layout=lay1)
    assert set(state1) == {g.key for g in lay1.groups}
    for g in lay1.groups:
        assert state1[g.key].q.shape == (g.stack_size, g.n, g.rank)
        assert state1[g.key].err.shape == (g.stack_size, g.m, g.n)
        assert not np.asarray(state1[g.key].err).any()   # EF starts at zero


# ------------------------------------------- 4-device mesh (fake devices)
_SCRIPT = textwrap.dedent("""
    # benchmarks.sync_bucketing forces the fake 4-device platform before jax
    # initializes and provides the shared harness (_setup/_build_sync/
    # _count_collectives) so the CI smoke gate and this test assert against
    # the very same lowering.
    from benchmarks.sync_bucketing import (
        WORLD, _build_sync, _count_collectives, _setup,
    )
    import jax
    import numpy as np

    from repro.core import bucketing, make_plan
    from repro.core.powersgd import LowRankState

    params, leaves, _, mesh, gstack = _setup()
    assert len(leaves) >= 32, len(leaves)

    def build(plan, bucketed):
        return _build_sync(params, leaves, plan, mesh, bucketed)

    def n_collectives(jfn, *args):
        return _count_collectives(jfn.lower(*args).as_text())

    PLANS = {
        "none": make_plan("none", leaves),
        "fixed": make_plan("fixed", leaves, fixed_rank=8),
        "optimus": make_plan("optimus", leaves, fixed_rank=8, num_stages=4),
        # two distinct stage ranks: exercises rank-keyed grouping while
        # keeping the group count low enough for the 25% acceptance bound
        "edgc": make_plan("edgc", leaves, stage_ranks=[4, 4, 16, 16],
                          num_stages=4),
    }
    for name, plan in PLANS.items():
        fn_ref, comp_ref, layout = build(plan, False)
        fn_bkt, comp_bkt, _ = build(plan, True)
        # acceptance: bucketed lowered HLO holds <= 25% of per-leaf collectives
        c_ref = n_collectives(fn_ref, gstack, comp_ref)
        c_bkt = n_collectives(fn_bkt, gstack, comp_bkt)
        assert c_bkt <= 0.25 * c_ref, (name, c_bkt, c_ref)
        assert c_bkt == layout.num_collectives(), (name, c_bkt, layout)
        # two rounds: EF residual + warm Q diverge per worker after round 1
        s_ref, st_ref = fn_ref(gstack, comp_ref)
        s_ref, st_ref = fn_ref(gstack, st_ref)
        s_bkt, st_bkt = fn_bkt(gstack, comp_bkt)
        s_bkt, st_bkt = fn_bkt(gstack, st_bkt)
        for a, b in zip(jax.tree_util.tree_leaves(s_ref),
                        jax.tree_util.tree_leaves(s_bkt)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)
        for w in range(WORLD):
            slc = {k: LowRankState(q=v.q[w], err=v.err[w])
                   for k, v in st_bkt.items()}
            un = bucketing.unstack_state(slc, layout)
            for path, st in st_ref.items():
                np.testing.assert_allclose(np.asarray(st.q[w]),
                                           np.asarray(un[path].q),
                                           rtol=2e-4, atol=2e-5)
                np.testing.assert_allclose(np.asarray(st.err[w]),
                                           np.asarray(un[path].err),
                                           rtol=2e-4, atol=2e-5)
        print(f"{name}: collectives {c_ref} -> {c_bkt} PARITY_OK")
    print("BUCKETED_MESH_OK")
""")


@pytest.mark.slow
def test_bucketed_sync_4dev_collectives_and_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "BUCKETED_MESH_OK" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-3000:]
