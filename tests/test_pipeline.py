"""Pipeline-parallel subsystem: partitioning, schedules, per-stage sync,
checkpoint resume of the control plane, and — in a fake-device subprocess —
1F1B/GPipe loss parity with the single-stage trainer under all four
policies with DAC Algorithm-2 ranks applied per stage.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EDGCConfig, GDSConfig, classify_leaves, init_compressor_state, make_plan,
    plan_wire_bytes, sync_grads,
)
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim.adam import AdamConfig
from repro.pipeline import partition as ppart
from repro.pipeline import schedule as psched
from repro.pipeline import sync as psync
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="pp", family="dense", num_layers=4, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)


def _setup(stage_ranks=(4, 16)):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=list(stage_ranks),
                     num_stages=2)
    return model, params, leaves, plan


# ---------------------------------------------------------------- partition
def test_partition_roundtrip():
    model, params, _, _ = _setup()
    stage_p, shared_p = ppart.partition_params(params, 2)
    for leaf in jax.tree_util.tree_leaves(stage_p):
        assert leaf.shape[0] == 2          # leading stage dim
    assert "embed" in shared_p and "stages" not in shared_p
    merged = ppart.merge_params(stage_p, shared_p, 2)
    ref, out = jax.tree_util.tree_flatten(params), \
        jax.tree_util.tree_flatten(merged)
    assert ref[1] == out[1]
    for a, b in zip(ref[0], out[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partition_unsupported():
    cfg = ModelConfig(name="x", family="dense", num_layers=3, num_stages=3)
    assert ppart.pipeline_supported(cfg, 2) is not None     # stage mismatch
    cfg = ModelConfig(name="x", family="moe", num_layers=4, num_stages=2,
                      num_experts=2, experts_per_token=1)
    assert ppart.pipeline_supported(cfg, 2) is not None     # family
    cfg = TINY
    assert ppart.pipeline_supported(cfg, 2) is None


def test_local_global_path_mapping():
    _, params, _, plan = _setup()
    for path, _ in plan.ranks:
        s, lp = ppart.local_leaf_path(path)
        assert ppart.global_leaf_path(s, lp) == path
    assert ppart.local_leaf_path("['embed']['tok']") is None


# ---------------------------------------------------------------- schedules
@pytest.mark.parametrize("name", psched.SCHEDULES)
@pytest.mark.parametrize("S,M", [(2, 2), (4, 4), (4, 8), (3, 7)])
def test_schedule_table_dependencies(name, S, M):
    """Every F/B obeys pipeline dataflow; every microbatch runs exactly once."""
    table = psched.slot_table(name, S, M)
    f_tick = {}
    b_tick = {}
    for s in range(S):
        for t, acts in enumerate(table[s]):
            for kind, j in acts:
                (f_tick if kind == "F" else b_tick)[(s, j)] = t
    assert set(f_tick) == {(s, j) for s in range(S) for j in range(M)}
    assert set(b_tick) == set(f_tick)
    for s in range(S):
        for j in range(M):
            if s > 0:       # F needs upstream F one tick earlier
                assert f_tick[(s, j)] > f_tick[(s - 1, j)]
            if s < S - 1:   # B needs downstream B one tick earlier
                assert b_tick[(s, j)] > b_tick[(s + 1, j)]
            assert b_tick[(s, j)] > f_tick[(s, j)]
    # in-flight activations never exceed the ring the executor allocates
    peaks = psched.peak_inflight(name, S, M)
    assert max(peaks) <= psched.ring_slots(name, S, M)


def test_schedule_analytics():
    S, M = 4, 16
    assert psched.bubble_fraction(S, M) == pytest.approx((S - 1) / (M + S - 1))
    # 1F1B bounds in-flight activations by min(M, 2S); GPipe holds all M
    assert max(psched.peak_inflight("gpipe", S, M)) == M
    assert max(psched.peak_inflight("1f1b", S, M)) <= min(M, 2 * S)
    # both schedules open s ticks of sync slack at stage s (Alg 2 / Eq. 4)
    for name in psched.SCHEDULES:
        assert psched.sync_slack_ticks(name, S, M) == list(range(S))


# -------------------------------------------------------------- stage plans
def test_make_stage_plans_distinct_grouping():
    model, params, leaves, plan = _setup(stage_ranks=(4, 16))
    stage_p, _ = ppart.partition_params(params, 2)
    local = psync.stage_local_leaves(stage_p)
    splans = psync.make_stage_plans(plan, 2, local)
    assert splans.num_stages == 2
    assert len(splans.distinct) == 2           # two distinct ranks
    assert splans.d_of_stage == (0, 1)
    for s, sp in enumerate(splans.stage_plans):
        assert sp.ranks, f"stage {s} must compress"
        for lp, r in sp.ranks:
            assert r == (4, 16)[s]
            assert plan.rank_of(ppart.global_leaf_path(s, lp)) == r
    # uniform plan -> one schedule, zero masked redundancy
    uni = make_plan("fixed", leaves, fixed_rank=8)
    su = psync.make_stage_plans(uni, 2, local)
    assert len(su.distinct) == 1
    assert su.d_of_stage == (0, 0)


def test_stage_wire_bytes_sums_to_plan():
    _, _, leaves, plan = _setup()
    per_stage = psync.stage_wire_bytes(leaves, plan, 2)
    comp, full = plan_wire_bytes(leaves, plan)
    assert sum(c for c, _ in per_stage) == comp
    assert sum(f for _, f in per_stage) == full
    # stage 1 runs rank 16 vs stage 0's rank 4 on identical block shapes:
    # its block bytes are strictly larger (Alg 2: later stages, bigger ranks)
    assert per_stage[1][0] > 0 and per_stage[0][0] > 0


# ---------------------------------------------------- per-stage sync parity
def test_stage_sync_matches_per_leaf_oracle_and_applies_stage_ranks():
    """Acceptance: DAC ranks are applied per stage — wire accounting via a
    psum spy — and the synced grads match the flat per-leaf oracle."""
    model, params, leaves, plan = _setup(stage_ranks=(4, 16))
    stage_p, shared_p = ppart.partition_params(params, 2)
    splans = psync.make_stage_plans(plan, 2,
                                    psync.stage_local_leaves(stage_p))
    comp = psync.init_pipeline_comp_state(params, plan, jax.random.PRNGKey(1),
                                          splans)

    rng = np.random.default_rng(0)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    g_stage, g_shared = ppart.partition_params(grads, 2)

    # flat per-leaf oracle on the full tree
    oracle_state = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    oracle, _ = sync_grads(grads, oracle_state, plan, lambda x: x)
    o_stage, o_shared = ppart.partition_params(oracle, 2)

    for s in range(2):
        local_g = jax.tree_util.tree_map(lambda a: a[s], g_stage)
        local_c = jax.tree_util.tree_map(lambda a: a[s], comp)
        calls = []

        def spy(x):
            calls.append((x.shape, x.dtype))
            return x

        synced_s, synced_sh, _ = psync.stage_sync_grads(
            local_g, g_shared, local_c, splans, spy, my_stage=s)

        # per-stage rank application: the schedule covering stage s psums
        # factors whose trailing dim is EXACTLY the DAC rank for stage s
        # (and the other schedule's rank also appears — masked SPMD pass)
        factor_ranks = sorted({shp[-1] for shp, _ in calls if len(shp) == 3})
        assert (4, 16)[s] in factor_ranks
        assert factor_ranks == [4, 16]   # both schedules execute (SPMD)

        # grads parity with the flat oracle, stage leaves + shared leaves
        want = jax.tree_util.tree_map(lambda a: a[s], o_stage)
        for a, b in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(synced_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(o_shared),
                        jax.tree_util.tree_leaves(synced_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_resize_pipeline_comp_state_across_replan():
    """DAC window re-plan: Q keeps leading columns / EF survives, per stage."""
    model, params, leaves, _ = _setup()
    stage_p, _ = ppart.partition_params(params, 2)
    local = psync.stage_local_leaves(stage_p)
    plan0 = make_plan("edgc", leaves, stage_ranks=[8, 8], num_stages=2)
    plan1 = make_plan("edgc", leaves, stage_ranks=[4, 16], num_stages=2)
    sp0 = psync.make_stage_plans(plan0, 2, local)
    sp1 = psync.make_stage_plans(plan1, 2, local)
    st0 = psync.replicate_pipeline_comp_state(
        psync.init_pipeline_comp_state(params, plan0, jax.random.PRNGKey(2),
                                       sp0), 1)
    st1 = psync.resize_pipeline_comp_state(st0, sp0, sp1,
                                           jax.random.PRNGKey(3))
    from repro.core import bucketing
    for s, r_new in [(0, 4), (1, 16)]:
        d0, d1 = sp0.d_of_stage[s], sp1.d_of_stage[s]
        old = {k[len(f"p{d0}:"):]:
               jax.tree_util.tree_map(lambda a: a[s, 0], v)
               for k, v in st0.items() if k.startswith(f"p{d0}:")}
        new = {k[len(f"p{d1}:"):]:
               jax.tree_util.tree_map(lambda a: a[s], v)
               for k, v in st1.items() if k.startswith(f"p{d1}:")}
        per0 = bucketing.unstack_state(old, sp0.layouts[d0])
        per1 = bucketing.unstack_state(new, sp1.layouts[d1])
        assert set(per0) == set(per1)
        for lp in per1:
            assert per1[lp].q.shape[-1] == r_new
            np.testing.assert_array_equal(np.asarray(per0[lp].err),
                                          np.asarray(per1[lp].err))
            keep = min(8, r_new)
            np.testing.assert_array_equal(
                np.asarray(per0[lp].q[..., :keep]),
                np.asarray(per1[lp].q[..., :keep]))


# --------------------------------------------- end-to-end (single device)
def _trainer(mesh, policy="fixed", num_stages=1, steps=6, schedule="1f1b",
             num_micro=2, seed=0):
    cfg = ModelConfig(name="pp1", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=num_stages)
    model = build_model(cfg)
    edgc = EDGCConfig(policy=policy, fixed_rank=8, num_stages=num_stages,
                      total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=3, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule=schedule,
                         num_microbatches=num_micro,
                         adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=steps))
    return Trainer(model, mesh, edgc, tcfg, seed=seed)


@pytest.mark.parametrize("schedule", psched.SCHEDULES)
def test_pipelined_trainer_single_device_parity(schedule):
    """pipe=1 mesh exercises the full pipelined executor (microbatching,
    ring buffer, manual VJP, per-stage sync) without fake devices; the loss
    trajectory must match the flat trainer's."""
    data = lambda: SyntheticLM(512, 32, 4, seed=3).batches()
    tp = _trainer(make_host_mesh(pipe=1, data=1, model=1), schedule=schedule)
    hp = tp.run(data())
    tf_ = _trainer(make_host_mesh(data=1, model=1))
    hf = tf_.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    assert max(abs(a - b) for a, b in zip(lp, lf)) < 5e-3, (lp, lf)
    assert tp.bytes_synced == tf_.bytes_synced


def test_pipelined_trainer_checkpoint_resume(tmp_path):
    """Satellite: the control plane survives save/restore — a resumed EDGC
    run must not restart warm-up and must keep the DAC plan."""
    steps = 24
    mesh = make_host_mesh()
    cfg = ModelConfig(name="ckpt", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=2)

    def mk():
        model = build_model(cfg)
        edgc = EDGCConfig(policy="edgc", fixed_rank=16, num_stages=2,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=0.5, beta=0.25),
                          dac=DACConfig(window=4, adjust_limit=4))
        tcfg = TrainerConfig(total_steps=steps, log_every=4,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = SyntheticLM(512, 32, 4, seed=3)
    t1 = mk()
    t1.run(data.batches(), num_steps=16)
    assert not t1.controller.in_warmup
    path = str(tmp_path / "state")
    t1.save_checkpoint(path)

    t2 = mk()
    assert t2.controller.in_warmup
    assert t2.restore_checkpoint(path) == 16
    assert not t2.controller.in_warmup, "resume restarted warm-up"
    assert t2.controller.plan == t1.controller.plan
    assert t2.controller.rank_history == t1.controller.rank_history
    for k in t1.state["comp"]:
        np.testing.assert_array_equal(
            np.asarray(t1.state["comp"][k].q), np.asarray(t2.state["comp"][k].q))
    h = t2.run(data.batches())
    assert h[-1]["step"] == steps - 1


def test_make_plan_rejects_short_stage_ranks():
    _, _, leaves, _ = _setup()
    with pytest.raises(ValueError, match="one rank per pipeline stage"):
        make_plan("edgc", leaves, stage_ranks=[4], num_stages=2)
    with pytest.raises(ValueError, match="one rank per pipeline stage"):
        make_plan("edgc", leaves, stage_ranks=[4, 8, 16], num_stages=2)
    with pytest.raises(ValueError):
        make_plan("edgc", leaves, stage_ranks=None, num_stages=2)


# ------------------------------------------- 4-device mesh (fake devices)
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np

    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.core.powersgd import compressed_bytes
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    S = 4
    CFG = ModelConfig(name="pp4", family="dense", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=S)

    def trainer(policy, mesh, steps, sched="1f1b"):
        model = build_model(CFG)
        edgc = EDGCConfig(policy=policy, fixed_rank=16, num_stages=S,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=0.5, beta=0.25),
                          dac=DACConfig(window=5, adjust_limit=4))
        tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule=sched,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = lambda: SyntheticLM(512, 32, 8, seed=3).batches()
    mesh_pipe = make_host_mesh(pipe=S, data=1, model=1)
    mesh_flat = make_host_mesh(data=1, model=1)

    # 1F1B loss parity with the single-stage trainer, all four policies;
    # GPipe spot-checked on the compressed baseline.
    runs = [(p, "1f1b", 30 if p == "edgc" else 8)
            for p in ("none", "fixed", "optimus", "edgc")]
    runs.append(("fixed", "gpipe", 8))
    tp_edgc = None
    for policy, sched, steps in runs:
        tp = trainer(policy, mesh_pipe, steps, sched)
        hp = tp.run(data())
        tf = trainer(policy, mesh_flat, steps)
        hf = tf.run(data())
        lp = [h["loss"] for h in hp]; lf = [h["loss"] for h in hf]
        gap = max(abs(a - b) for a, b in zip(lp, lf))
        tol = 5e-3 if policy != "edgc" else 2e-2   # edgc: resize RNG differs
        assert gap < tol, (policy, sched, gap, lp, lf)
        if policy == "edgc":
            tp_edgc = tp
        print(f"{policy}/{sched}: gap {gap:.2e} PARITY_OK")

    # Algorithm 2 applied per stage: the edgc run warmed up, emitted a
    # stage-aligned (non-decreasing) rank vector, and the per-stage wire
    # ledger reflects exactly those ranks.
    tp = tp_edgc
    assert not tp.controller.in_warmup
    ranks = tp.controller.rank_history[-1][1]   # the vector the plan used
    assert len(ranks) == S
    assert all(b >= a for a, b in zip(ranks, ranks[1:])), ranks
    per_stage = tp.stage_bytes()
    plan = tp.controller.plan.as_dict()
    for s in range(S):
        stage_leaves = [l for l in tp.leaves if l.stage == s]
        comp = sum(compressed_bytes(l.shape, plan[l.path]) if l.path in plan
                   else int(np.prod(l.shape)) * 2 for l in stage_leaves)
        assert comp == per_stage[s][0], (s, comp, per_stage)
        for l in stage_leaves:
            if l.path in plan:
                max_r = min(l.shape[-2:]) // 2
                assert plan[l.path] == max(1, min(ranks[s], max_r)), l.path
    print("stage ranks", ranks, "stage bytes", per_stage)
    print("PIPELINE_4DEV_OK")
""")


@pytest.mark.slow
def test_pipeline_4dev_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_4DEV_OK" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-3000:]
