"""Pipeline-parallel subsystem: partitioning, schedules, per-stage sync,
checkpoint resume of the control plane, and — in a fake-device subprocess —
1F1B/GPipe loss parity with the single-stage trainer under all four
policies with DAC Algorithm-2 ranks applied per stage.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.core import (
    EDGCConfig, GDSConfig, classify_leaves, init_compressor_state, make_plan,
    plan_wire_bytes, sync_grads,
)
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim.adam import AdamConfig
from repro.pipeline import partition as ppart
from repro.pipeline import schedule as psched
from repro.pipeline import sync as psync
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="pp", family="dense", num_layers=4, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)

# One tiny config per non-dense family with a stage adapter. zamba is
# deliberately RAGGED (3 layers, attn_every=2 -> groups [2, 1] -> stage
# layer counts [2, 1]); whisper splits 2 enc + 2 dec layers over 2 stages.
FAMILY_CFGS = {
    "moe": ModelConfig(
        name="pp-moe", family="moe", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512, num_experts=2,
        experts_per_token=1, capacity_factor=4.0, num_stages=2),
    "xlstm": ModelConfig(
        name="pp-xlstm", family="xlstm", num_layers=4, d_model=128,
        num_heads=2, num_kv_heads=2, vocab_size=512, chunk=16, num_stages=2),
    "zamba": ModelConfig(
        name="pp-zamba", family="zamba", num_layers=3, d_model=128,
        num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512, ssm_state=16,
        chunk=16, attn_every=2, num_stages=2),
    "whisper": ModelConfig(
        name="pp-whisper", family="whisper", num_layers=2, encoder_layers=2,
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512,
        audio_frames=16, max_position=512, num_stages=2),
    "vlm": ModelConfig(
        name="pp-vlm", family="vlm", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, d_ff=256, vocab_size=512, num_patches=4,
        num_stages=2),
}


def _family_batch(cfg, B=2, T=16, seed=0):
    from repro.data.pipeline import add_modality_stubs
    rng = np.random.default_rng(seed)
    toks = rng.integers(0, cfg.vocab_size, (B, T)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    batch = add_modality_stubs(batch, cfg.family,
                               audio_frames=cfg.audio_frames,
                               num_patches=cfg.num_patches,
                               d_model=cfg.d_model, seed=seed)
    return {k: jnp.asarray(v) for k, v in batch.items()}


def _setup(stage_ranks=(4, 16)):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=list(stage_ranks),
                     num_stages=2)
    return model, params, leaves, plan


# ---------------------------------------------------------------- partition
def test_partition_roundtrip():
    model, params, _, _ = _setup()
    stage_p, shared_p = ppart.partition_params(params, 2)
    for leaf in jax.tree_util.tree_leaves(stage_p):
        assert leaf.shape[0] == 2          # leading stage dim
    assert "embed" in shared_p and "stages" not in shared_p
    merged = ppart.merge_params(stage_p, shared_p, 2)
    ref, out = jax.tree_util.tree_flatten(params), \
        jax.tree_util.tree_flatten(merged)
    assert ref[1] == out[1]
    for a, b in zip(ref[0], out[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_partition_unsupported():
    """Satellite: the reason string is the ADAPTER's, not a generic one."""
    cfg = ModelConfig(name="x", family="dense", num_layers=3, num_stages=3)
    assert "num_stages" in ppart.pipeline_supported(cfg, 2)  # stage mismatch
    # unregistered family names the registry
    cfg = ModelConfig(name="x", family="nosuch")
    reason = ppart.pipeline_supported(cfg, 2)
    assert "no stage adapter" in reason and "dense" in reason
    # family-specific constraints come from the family's adapter
    cfg = ModelConfig(name="x", family="xlstm", num_layers=2, num_stages=2)
    assert "pair" in ppart.pipeline_supported(cfg, 2)        # 1 pair, 2 stages
    cfg = ModelConfig(name="x", family="zamba", num_layers=2, attn_every=2,
                      num_stages=2, ssm_state=16)
    assert "group" in ppart.pipeline_supported(cfg, 2)       # 1 group, 2 stages
    assert ppart.pipeline_supported(TINY, 2) is None
    # moe / vlm / whisper now have adapters
    cfg = ModelConfig(name="x", family="moe", num_layers=4, num_stages=2,
                      num_experts=2, experts_per_token=1)
    assert ppart.pipeline_supported(cfg, 2) is None
    cfg = ModelConfig(name="x", family="whisper", num_layers=2,
                      encoder_layers=2, num_stages=2)
    assert ppart.pipeline_supported(cfg, 2) is None


@pytest.mark.parametrize("fam", sorted(FAMILY_CFGS))
def test_family_partition_roundtrip(fam):
    """Satellite: every family's adapter partition/merge is lossless —
    including zero-padded ragged stage plans (zamba) and the enc/dec
    union tree (whisper)."""
    cfg = FAMILY_CFGS[fam]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    part = ppart.make_partition(model, cfg.num_stages)
    stage_p, shared_p = part.partition_params(params)
    for leaf in jax.tree_util.tree_leaves(stage_p):
        assert leaf.shape[0] == cfg.num_stages
    assert "stages" not in shared_p
    merged = part.merge_params(stage_p, shared_p)
    ref, out = jax.tree_util.tree_flatten(params), \
        jax.tree_util.tree_flatten(merged)
    assert ref[1] == out[1], fam
    for a, b in zip(ref[0], out[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("fam", sorted(FAMILY_CFGS))
def test_family_stagewise_forward_matches_flat(fam):
    """Chaining the adapter's embed -> per-stage blocks -> head (plus the
    per-stage aux losses) on concrete stage indices reproduces the flat
    model's loss — the forward half of pipeline parity, per family,
    without any mesh."""
    cfg = FAMILY_CFGS[fam]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    part = ppart.make_partition(model, cfg.num_stages)
    stage_p, shared_p = part.partition_params(params)
    batch = _family_batch(cfg)

    bnd = part.embed(shared_p, batch)
    aux_total = jnp.zeros((), jnp.float32)
    for s in range(cfg.num_stages):
        tree_s = jax.tree_util.tree_map(lambda a: a[s], stage_p)
        bnd, aux = part.blocks(tree_s, shared_p, bnd, jnp.int32(s))
        aux_total = aux_total + aux
    loss = part.head_loss(shared_p, bnd, batch) + aux_total

    flat_loss, _ = model.loss_fn(params, batch)
    np.testing.assert_allclose(np.asarray(loss), np.asarray(flat_loss),
                               rtol=2e-5, atol=2e-5)


def test_zamba_ragged_stage_plan_is_padded():
    """The hybrid adapter owns layer->stage assignment: whole attention
    groups per stage, ragged counts zero-padded to the widest stage."""
    cfg = FAMILY_CFGS["zamba"]
    model = build_model(cfg)
    part = ppart.make_partition(model, 2)
    assert part.unit_counts() == {"mamba": [2, 1]}
    params = model.init(jax.random.PRNGKey(0))
    stage_p, _ = part.partition_params(params)
    for leaf in jax.tree_util.tree_leaves(stage_p):
        assert leaf.shape[:2] == (2, 2)     # (S, Lmax) with stage 1 padded
    # padded slice is exactly zero
    pad = jax.tree_util.tree_map(lambda a: a[1, 1:], stage_p)
    assert all(float(jnp.max(jnp.abs(l))) == 0.0
               for l in jax.tree_util.tree_leaves(pad))


def test_local_global_path_mapping():
    _, params, _, plan = _setup()
    for path, _ in plan.ranks:
        s, lp = ppart.local_leaf_path(path)
        assert ppart.global_leaf_path(s, lp) == path
    assert ppart.local_leaf_path("['embed']['tok']") is None


# ---------------------------------------------------------------- schedules
@pytest.mark.parametrize("name", psched.SCHEDULES)
@pytest.mark.parametrize("S,M", [(2, 2), (4, 4), (4, 8), (3, 7)])
def test_schedule_table_dependencies(name, S, M):
    """Every F/B obeys pipeline dataflow; every microbatch runs exactly once."""
    table = psched.slot_table(name, S, M)
    f_tick = {}
    b_tick = {}
    for s in range(S):
        for t, acts in enumerate(table[s]):
            for kind, j in acts:
                (f_tick if kind == "F" else b_tick)[(s, j)] = t
    assert set(f_tick) == {(s, j) for s in range(S) for j in range(M)}
    assert set(b_tick) == set(f_tick)
    for s in range(S):
        for j in range(M):
            if s > 0:       # F needs upstream F one tick earlier
                assert f_tick[(s, j)] > f_tick[(s - 1, j)]
            if s < S - 1:   # B needs downstream B one tick earlier
                assert b_tick[(s, j)] > b_tick[(s + 1, j)]
            assert b_tick[(s, j)] > f_tick[(s, j)]
    # in-flight activations never exceed the ring the executor allocates
    peaks = psched.peak_inflight(name, S, M)
    assert max(peaks) <= psched.ring_slots(name, S, M)


def test_simulate_schedule_degenerates_and_weights():
    """Satellite: the weighted-tick simulator matches the unit analytics at
    t_f == t_b == 1 and scales the Eq. 4 slack by the BACKWARD tick cost."""
    S, M = 4, 16
    for name in psched.SCHEDULES:
        sim = psched.simulate_schedule(name, S, M, 1.0, 1.0)
        assert sim["bubble_fraction"] == pytest.approx(
            psched.bubble_fraction(S, M))
        assert sim["slack_seconds"] == [
            float(s) for s in psched.sync_slack_ticks(name, S, M)]
    # B-cost 2x F-cost: slack (in seconds) is s backward ticks
    sim = psched.simulate_schedule("1f1b", S, M, 1.0, 2.0)
    assert sim["slack_seconds"] == [0.0, 2.0, 4.0, 6.0]
    assert sim["makespan"] == (M + S - 1) * 3.0


def test_stash_points_and_segments():
    """Stash cuts are static interior unit boundaries; the segments tile
    [0, n_units) for every policy."""
    assert psched.stash_points("replay", 6) == ()
    assert psched.stash_points("full", 6) == (1, 2, 3, 4, 5)
    assert psched.stash_points("every_k", 6, 2) == (2, 4)
    assert psched.stash_points("every_k", 7, 3) == (3, 6)
    assert psched.stash_points("full", 1) == ()      # single unit: no cuts
    with pytest.raises(ValueError, match="stash policy"):
        psched.stash_points("nope", 4)
    for pol, n, k in [("replay", 5, 2), ("full", 5, 2), ("every_k", 5, 2),
                      ("every_k", 8, 3)]:
        segs = psched.stash_segments(pol, n, k)
        assert segs[0][0] == 0 and segs[-1][1] == n
        assert all(a[1] == b[0] for a, b in zip(segs, segs[1:]))
        assert all(hi > lo for lo, hi in segs)
        assert len(segs) == len(psched.stash_points(pol, n, k)) + 1


@pytest.mark.parametrize("name", psched.SCHEDULES)
@pytest.mark.parametrize("S,M", [(2, 4), (4, 8), (4, 16)])
def test_peak_activation_bytes_matches_tick_oracle(name, S, M):
    """Acceptance: the activation-memory ledger equals an independent
    tick-table walk — at each F the stage saves (1 + n_stash) boundary-
    sized entries for its microbatch, each B frees them — and orders
    full >= every_k >= replay per stage."""
    n_units, bbytes, k = 4, 1000, 2
    table = psched.slot_table(name, S, M)
    by_pol = {}
    for pol in psched.STASH_POLICIES:
        per_entry = bbytes * (1 + len(psched.stash_points(pol, n_units, k)))
        oracle = []
        for s in range(S):
            live = peak = 0
            for acts in table[s]:
                for kind, _ in acts:
                    live += per_entry if kind == "F" else -per_entry
                    peak = max(peak, live)
            oracle.append(peak)
        got = psched.peak_activation_bytes(name, S, M, pol,
                                           boundary_bytes=bbytes,
                                           n_units=n_units, stash_every=k)
        assert got == oracle, (name, pol, got, oracle)
        by_pol[pol] = got
    for s in range(S):
        assert (by_pol["full"][s] >= by_pol["every_k"][s]
                >= by_pol["replay"][s])
    assert max(by_pol["full"]) > max(by_pol["every_k"]) \
        > max(by_pol["replay"])


def test_policy_tick_cost_model():
    """Every policy's VJP replays the un-stashed spans once (+t_f); only
    replay-with-remat pays the per-unit recompute a second time."""
    t_f, t_b = 1.0, 2.5
    assert psched.policy_tick_cost(t_f, t_b, "replay") == t_b + t_f
    assert psched.policy_tick_cost(t_f, t_b, "full") == t_b + t_f
    assert psched.policy_tick_cost(t_f, t_b, "every_k") == t_b + t_f
    assert psched.policy_tick_cost(t_f, t_b, "replay", remat=True) \
        == t_b + 2 * t_f
    # stashed segments run un-remat'ed: remat never changes their cost
    assert psched.policy_tick_cost(t_f, t_b, "full", remat=True) == t_b + t_f
    with pytest.raises(ValueError, match="stash policy"):
        psched.policy_tick_cost(t_f, t_b, "nope")


def test_schedule_analytics():
    S, M = 4, 16
    assert psched.bubble_fraction(S, M) == pytest.approx((S - 1) / (M + S - 1))
    # 1F1B bounds in-flight activations by min(M, 2S); GPipe holds all M
    assert max(psched.peak_inflight("gpipe", S, M)) == M
    assert max(psched.peak_inflight("1f1b", S, M)) <= min(M, 2 * S)
    # both schedules open s ticks of sync slack at stage s (Alg 2 / Eq. 4)
    for name in psched.SCHEDULES:
        assert psched.sync_slack_ticks(name, S, M) == list(range(S))


# -------------------------------------------------------------- stage plans
def test_make_stage_plans_distinct_grouping():
    model, params, leaves, plan = _setup(stage_ranks=(4, 16))
    stage_p, _ = ppart.partition_params(params, 2)
    local = psync.stage_local_leaves(stage_p)
    splans = psync.make_stage_plans(plan, 2, local)
    assert splans.num_stages == 2
    assert len(splans.distinct) == 2           # two distinct ranks
    assert splans.d_of_stage == (0, 1)
    for s, sp in enumerate(splans.stage_plans):
        assert sp.ranks, f"stage {s} must compress"
        for lp, r in sp.ranks:
            assert r == (4, 16)[s]
            assert plan.rank_of(ppart.global_leaf_path(s, lp)) == r
    # uniform plan -> one schedule, zero masked redundancy
    uni = make_plan("fixed", leaves, fixed_rank=8)
    su = psync.make_stage_plans(uni, 2, local)
    assert len(su.distinct) == 1
    assert su.d_of_stage == (0, 0)


def test_stage_wire_bytes_sums_to_plan():
    _, _, leaves, plan = _setup()
    per_stage = psync.stage_wire_bytes(leaves, plan, 2)
    comp, full = plan_wire_bytes(leaves, plan)
    assert sum(c for c, _ in per_stage) == comp
    assert sum(f for _, f in per_stage) == full
    # stage 1 runs rank 16 vs stage 0's rank 4 on identical block shapes:
    # its block bytes are strictly larger (Alg 2: later stages, bigger ranks)
    assert per_stage[1][0] > 0 and per_stage[0][0] > 0


# ---------------------------------------------------- per-stage sync parity
def test_stage_sync_matches_per_leaf_oracle_and_applies_stage_ranks():
    """Acceptance: DAC ranks are applied per stage — wire accounting via a
    psum spy — and the synced grads match the flat per-leaf oracle."""
    model, params, leaves, plan = _setup(stage_ranks=(4, 16))
    stage_p, shared_p = ppart.partition_params(params, 2)
    splans = psync.make_stage_plans(plan, 2,
                                    psync.stage_local_leaves(stage_p))
    comp = psync.init_pipeline_comp_state(params, plan, jax.random.PRNGKey(1),
                                          splans)

    rng = np.random.default_rng(0)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    g_stage, g_shared = ppart.partition_params(grads, 2)

    # flat per-leaf oracle on the full tree
    oracle_state = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    oracle, _ = sync_grads(grads, oracle_state, plan, lambda x: x)
    o_stage, o_shared = ppart.partition_params(oracle, 2)

    for s in range(2):
        local_g = jax.tree_util.tree_map(lambda a: a[s], g_stage)
        local_c = jax.tree_util.tree_map(lambda a: a[s], comp)
        spy = analysis.CollectiveSpy()
        synced_s, synced_sh, _ = psync.stage_sync_grads(
            local_g, g_shared, local_c, splans, spy, my_stage=s)

        # per-stage rank application: the schedule covering stage s psums
        # factors whose trailing dim is EXACTLY the DAC rank for stage s
        # (and the other schedule's rank also appears — masked SPMD pass)
        assert (4, 16)[s] in spy.factor_ranks()
        assert spy.factor_ranks() == [4, 16]  # both schedules execute (SPMD)

        # grads parity with the flat oracle, stage leaves + shared leaves
        want = jax.tree_util.tree_map(lambda a: a[s], o_stage)
        for a, b in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(synced_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(o_shared),
                        jax.tree_util.tree_leaves(synced_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_moe_stage_sync_psum_spy_applies_stage_ranks():
    """Satellite: per-stage DAC ranks apply on a MoE tree — expert stacks
    compress through 3-D factor psums whose trailing dim is the stage's
    rank, and the result matches the flat per-leaf oracle."""
    cfg = FAMILY_CFGS["moe"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, cfg.num_layers, 2, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=[4, 16], num_stages=2)
    # expert stacks must be in the plan (router excluded)
    assert any("experts" in p for p, _ in plan.ranks)
    assert not any("router" in p for p, _ in plan.ranks)

    part = ppart.make_partition(model, 2)
    stage_p, shared_p = part.partition_params(params)
    splans = psync.make_stage_plans(plan, 2,
                                    psync.stage_local_leaves(stage_p),
                                    local_path=part.local_leaf_path)
    comp = psync.init_pipeline_comp_state(params, plan, jax.random.PRNGKey(1),
                                          splans)

    rng = np.random.default_rng(0)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    g_stage, g_shared = part.partition_params(grads)

    oracle_state = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    oracle, _ = sync_grads(grads, oracle_state, plan, lambda x: x)
    o_stage, o_shared = part.partition_params(oracle)

    for s in range(2):
        local_g = jax.tree_util.tree_map(lambda a: a[s], g_stage)
        local_c = jax.tree_util.tree_map(lambda a: a[s], comp)
        spy = analysis.CollectiveSpy()
        synced_s, synced_sh, _ = psync.stage_sync_grads(
            local_g, g_shared, local_c, splans, spy, my_stage=s)
        assert (4, 16)[s] in spy.factor_ranks()
        assert spy.factor_ranks() == [4, 16]  # both schedules execute (SPMD)

        want = jax.tree_util.tree_map(lambda a: a[s], o_stage)
        for a, b in zip(jax.tree_util.tree_leaves(want),
                        jax.tree_util.tree_leaves(synced_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
        for a, b in zip(jax.tree_util.tree_leaves(o_shared),
                        jax.tree_util.tree_leaves(synced_sh)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


def test_hybrid_ragged_nonuniform_stage_plans():
    """Acceptance: per-stage BucketLayout grouping on a NON-UNIFORM
    (ragged hybrid) stage plan — distinct per-stage layouts, padded local
    shapes, padded gradient slices stay exactly zero through the sync,
    and live slices match the leaf-level compressor run with the same
    warm-start state."""
    from repro.core import bucketing
    from repro.core.powersgd import compress_leaf

    cfg = FAMILY_CFGS["zamba"]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, cfg.num_layers, 2, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=[4, 16], num_stages=2)
    assert plan.ranks, "zamba mamba stacks must be compressible"
    # the shared attention block must NOT be in the plan (pipe-replicated)
    assert not any("shared" in p for p, _ in plan.ranks)

    part = ppart.make_partition(model, 2)
    stage_p, shared_p = part.partition_params(params)
    splans = psync.make_stage_plans(plan, 2,
                                    psync.stage_local_leaves(stage_p),
                                    local_path=part.local_leaf_path)
    assert len(splans.distinct) == 2           # two distinct rank plans
    r0 = {g.rank for g in splans.layouts[0].groups}
    r1 = {g.rank for g in splans.layouts[1].groups}
    assert r0 == {4} and r1 == {16}
    # local shapes are the PADDED per-rank shapes (Lmax = 2 everywhere)
    for lay in splans.layouts:
        for g in lay.groups:
            for _, shp in g.members:
                assert shp[0] == 2

    comp = psync.init_pipeline_comp_state(params, plan, jax.random.PRNGKey(1),
                                          splans)
    rng = np.random.default_rng(1)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    g_stage, g_shared = part.partition_params(grads)

    for s in range(2):
        local_g = jax.tree_util.tree_map(lambda a: a[s], g_stage)
        local_c = jax.tree_util.tree_map(lambda a: a[s], comp)
        synced_s, _, _ = psync.stage_sync_grads(
            local_g, g_shared, local_c, splans, lambda x: x, my_stage=s)
        # leaf-level oracle: same warm-start state, per-leaf compression
        d = splans.d_of_stage[s]
        per_leaf = bucketing.unstack_state(
            {k[len(f"p{d}:"):]: v for k, v in local_c.items()
             if k.startswith(f"p{d}:")},
            splans.layouts[d])
        by_path = {jax.tree_util.keystr(kp): g for kp, g
                   in jax.tree_util.tree_flatten_with_path(local_g)[0]}
        synced_by_path = {jax.tree_util.keystr(kp): g for kp, g
                          in jax.tree_util.tree_flatten_with_path(synced_s)[0]}
        for lp, _rank in splans.stage_plans[s].ranks:
            want, _ = compress_leaf(by_path[lp], per_leaf[lp], lambda x: x)
            np.testing.assert_allclose(np.asarray(synced_by_path[lp]),
                                       np.asarray(want),
                                       rtol=1e-4, atol=1e-5)
        if s == 1:   # stage 1's second (padded) slice: zero in, zero out
            for lp, _rank in splans.stage_plans[s].ranks:
                np.testing.assert_array_equal(
                    np.asarray(by_path[lp][1:]) * 0,
                    np.asarray(synced_by_path[lp][1:]))


def _family_trainer(cfg, mesh, steps, num_micro):
    model = build_model(cfg)
    edgc = EDGCConfig(policy="fixed", fixed_rank=8, num_stages=1,
                      total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=3, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule="1f1b",
                         num_microbatches=num_micro,
                         adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=steps))
    return Trainer(model, mesh, edgc, tcfg, seed=0)


def _family_data(cfg, seed=3):
    base = SyntheticLM(cfg.vocab_size, 32, 4, seed=seed)
    from repro.data.pipeline import add_modality_stubs
    for b in base.batches():
        yield add_modality_stubs(b, cfg.family,
                                 audio_frames=cfg.audio_frames,
                                 num_patches=cfg.num_patches,
                                 d_model=cfg.d_model, seed=seed)


@pytest.mark.parametrize("fam,num_micro", [
    ("moe", 1), ("zamba", 2), ("whisper", 2), ("xlstm", 2), ("vlm", 2),
])
def test_pipelined_trainer_families_pipe1_parity(fam, num_micro):
    """Acceptance: non-dense pipe=1 pipelined training (microbatching,
    boundary rings, manual VJP, per-stage sync) matches the flat trainer's
    loss trajectory. MoE runs M=1: with top-1 routing, the per-microbatch
    router-aux mean differs from the full-batch mean in a way that FLIPS
    discrete expert assignments after one update, so microbatch counts
    must agree for a strict parity statement (the flat trainer has no
    microbatching; see test_pipelined_moe_microbatched_envelope)."""
    cfg = dataclasses.replace(FAMILY_CFGS[fam], num_stages=1)
    steps = 4
    tp = _family_trainer(cfg, make_host_mesh(pipe=1, data=1, model=1),
                         steps, num_micro)
    hp = tp.run(_family_data(cfg))
    tf_ = _family_trainer(cfg, make_host_mesh(data=1, model=1), steps, 0)
    hf = tf_.run(_family_data(cfg))
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    assert max(abs(a - b) for a, b in zip(lp, lf)) < 5e-3, (fam, lp, lf)
    assert tp.bytes_synced == tf_.bytes_synced


def test_pipelined_moe_microbatched_envelope():
    """MoE with real microbatching (M=2) stays finite and inside a loose
    envelope of the flat trainer: per-microbatch router-aux gradients
    legitimately differ from the full-batch ones (exactly as per-DP-shard
    aux does), and top-1 routing makes that a discrete perturbation."""
    cfg = dataclasses.replace(FAMILY_CFGS["moe"], num_stages=1)
    steps = 4
    tp = _family_trainer(cfg, make_host_mesh(pipe=1, data=1, model=1),
                         steps, 2)
    hp = tp.run(_family_data(cfg))
    tf_ = _family_trainer(cfg, make_host_mesh(data=1, model=1), steps, 0)
    hf = tf_.run(_family_data(cfg))
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    assert all(np.isfinite(lp)), lp
    assert max(abs(a - b) for a, b in zip(lp, lf)) < 0.2, (lp, lf)


def test_resize_pipeline_comp_state_across_replan():
    """DAC window re-plan: Q keeps leading columns / EF survives, per stage."""
    model, params, leaves, _ = _setup()
    stage_p, _ = ppart.partition_params(params, 2)
    local = psync.stage_local_leaves(stage_p)
    plan0 = make_plan("edgc", leaves, stage_ranks=[8, 8], num_stages=2)
    plan1 = make_plan("edgc", leaves, stage_ranks=[4, 16], num_stages=2)
    sp0 = psync.make_stage_plans(plan0, 2, local)
    sp1 = psync.make_stage_plans(plan1, 2, local)
    st0 = psync.replicate_pipeline_comp_state(
        psync.init_pipeline_comp_state(params, plan0, jax.random.PRNGKey(2),
                                       sp0), 1)
    st1 = psync.resize_pipeline_comp_state(st0, sp0, sp1,
                                           jax.random.PRNGKey(3))
    from repro.core import bucketing
    for s, r_new in [(0, 4), (1, 16)]:
        d0, d1 = sp0.d_of_stage[s], sp1.d_of_stage[s]
        old = {k[len(f"p{d0}:"):]:
               jax.tree_util.tree_map(lambda a: a[s, 0], v)
               for k, v in st0.items() if k.startswith(f"p{d0}:")}
        new = {k[len(f"p{d1}:"):]:
               jax.tree_util.tree_map(lambda a: a[s], v)
               for k, v in st1.items() if k.startswith(f"p{d1}:")}
        per0 = bucketing.unstack_state(old, sp0.layouts[d0])
        per1 = bucketing.unstack_state(new, sp1.layouts[d1])
        assert set(per0) == set(per1)
        for lp in per1:
            assert per1[lp].q.shape[-1] == r_new
            np.testing.assert_array_equal(np.asarray(per0[lp].err),
                                          np.asarray(per1[lp].err))
            keep = min(8, r_new)
            np.testing.assert_array_equal(
                np.asarray(per0[lp].q[..., :keep]),
                np.asarray(per1[lp].q[..., :keep]))


# ------------------------------------- pipelined entropy vs flat (ragged)
@pytest.mark.parametrize("fam", ["zamba", "whisper"])
def test_pipelined_entropy_matches_flat_ragged(fam):
    """Acceptance/regression: ragged stage plans zero-pad each rank's
    stacks — pooling the PADDED leaves fed exact-zero pad slots into the
    Lemma-2 moments (sigma under-estimated, entropy biased low). With the
    live-unit masks the pipelined pooled entropy equals the flat
    ``grads_entropy`` to 1e-6 (the strided sample positions coincide)."""
    from repro.core.entropy import (
        entropy_from_moments, grads_entropy, sample_moments,
    )
    cfg = FAMILY_CFGS[fam]
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    part = ppart.make_partition(model, cfg.num_stages)
    rng = np.random.default_rng(0)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    g_stage, g_shared = part.partition_params(grads)
    gds = GDSConfig(alpha=0.5, beta=0.25)

    z = jnp.zeros((), jnp.float32)
    n = s1 = s2 = z
    n_old = o1 = o2 = z
    for s in range(cfg.num_stages):
        local = jax.tree_util.tree_map(lambda a: a[s], g_stage)
        for key in sorted(local):
            kn, k1, k2 = sample_moments(
                local[key], gds,
                lead_mask=part.stage_flags(key, jnp.int32(s)))
            n, s1, s2 = n + kn, s1 + k1, s2 + k2
        kn, k1, k2 = sample_moments(local, gds)     # the old padded pooling
        n_old, o1, o2 = n_old + kn, o1 + k1, o2 + k2
    n2, c1, c2 = sample_moments(g_shared, gds)
    masked = float(entropy_from_moments(n + n2, s1 + c1, s2 + c2))
    padded = float(entropy_from_moments(n_old + n2, o1 + c1, o2 + c2))
    flat = float(grads_entropy(grads, gds))
    assert abs(masked - flat) < 1e-6, (fam, masked, flat)
    # the bias this guards against was real and material
    assert padded < flat - 1e-3, (fam, padded, flat)


# --------------------------------------------- end-to-end (single device)
def _trainer(mesh, policy="fixed", num_stages=1, steps=6, schedule="1f1b",
             num_micro=2, seed=0, stash="replay", num_layers=2):
    cfg = ModelConfig(name="pp1", family="dense", num_layers=num_layers,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                      vocab_size=512, num_stages=num_stages)
    model = build_model(cfg)
    edgc = EDGCConfig(policy=policy, fixed_rank=8, num_stages=num_stages,
                      total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=3, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule=schedule,
                         num_microbatches=num_micro, stash_policy=stash,
                         adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=steps))
    return Trainer(model, mesh, edgc, tcfg, seed=seed)


@pytest.mark.parametrize("schedule", psched.SCHEDULES)
def test_pipelined_trainer_single_device_parity(schedule):
    """pipe=1 mesh exercises the full pipelined executor (microbatching,
    ring buffer, manual VJP, per-stage sync) without fake devices; the loss
    trajectory must match the flat trainer's."""
    data = lambda: SyntheticLM(512, 32, 4, seed=3).batches()
    tp = _trainer(make_host_mesh(pipe=1, data=1, model=1), schedule=schedule)
    hp = tp.run(data())
    tf_ = _trainer(make_host_mesh(data=1, model=1))
    hf = tf_.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    assert max(abs(a - b) for a, b in zip(lp, lf)) < 5e-3, (lp, lf)
    assert tp.bytes_synced == tf_.bytes_synced


@pytest.mark.parametrize("schedule", psched.SCHEDULES)
@pytest.mark.parametrize("stash", ["full", "every_k"])
def test_pipelined_trainer_stash_policies_parity(schedule, stash):
    """Acceptance: the stashed executors (segmented forward + stash ring +
    per-segment backward VJPs) hold the same loss parity replay does, for
    both schedules. 4 layers -> 4 units at pipe=1: full stashes 3 carries,
    every_k=2 one — both exercise a real second ring."""
    data = lambda: SyntheticLM(512, 32, 4, seed=3).batches()
    tp = _trainer(make_host_mesh(pipe=1, data=1, model=1), schedule=schedule,
                  stash=stash, num_layers=4)
    hp = tp.run(data())
    tf_ = _trainer(make_host_mesh(data=1, model=1), num_layers=4)
    hf = tf_.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    assert max(abs(a - b) for a, b in zip(lp, lf)) < 5e-3, \
        (schedule, stash, lp, lf)
    assert tp.bytes_synced == tf_.bytes_synced


def test_entropy_off_variant_lowers_no_moment_collectives():
    """Satellite: the GDS ISR (alpha) gate is real — the entropy-off step
    variant traces EXACTLY the three Lemma-2 moment psums fewer (n, s1,
    s2 over the pipe axis) and nothing else; dispatching on
    wants_entropy means off-gate iterations run the cheaper program.
    (Counted in the jaxpr: on a pipe=1 mesh the partitioned HLO elides
    size-1 collectives entirely.)"""
    from repro.train.step import TrainStepConfig, make_train_step
    data = lambda: SyntheticLM(512, 32, 4, seed=3).batches()
    tp = _trainer(make_host_mesh(pipe=1, data=1, model=1), num_layers=4)
    batch = {k: jnp.asarray(v) for k, v in next(data()).items()}
    state = jax.device_get(tp.state)
    traced = {}
    for measure in (True, False):
        scfg = TrainStepConfig(
            mode="dp_tp", policy_plan=tp.controller.plan,
            gds=tp.edgc_cfg.gds, measure_entropy=measure,
            num_stages=1, schedule="1f1b", num_microbatches=2,
            adam=tp.tcfg.adam)
        raw = make_train_step(tp.model, tp.mesh, scfg)
        traced[measure] = jax.make_jaxpr(raw)(state, batch)
    counts = {m: analysis.count_collectives(t, "psum")
              for m, t in traced.items()}
    assert counts[False] < counts[True], counts
    assert analysis.check_entropy_gate(traced[True], traced[False]) == []


def test_trainer_rejects_edgc_without_entropy():
    """Satellite: policy='edgc' with measure_entropy=False used to fill
    the DAC window with the step's 0.0 placeholder entropies — now an
    up-front error."""
    cfg = ModelConfig(name="pp1", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=1)
    model = build_model(cfg)
    edgc = EDGCConfig(policy="edgc", num_stages=1, total_iterations=8,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=3))
    tcfg = TrainerConfig(total_steps=8, measure_entropy=False)
    with pytest.raises(ValueError, match="measure_entropy"):
        Trainer(model, make_host_mesh(), edgc, tcfg, seed=0)


def test_alpha_gate_skips_and_holds_history():
    """Satellite: off-gate iterations dispatch the entropy-off variant
    (no on_entropy recording) and history zero-order-holds the last
    measured reading instead of logging a 0.0 placeholder."""
    data = lambda: SyntheticLM(512, 32, 4, seed=3).batches()
    tr = _trainer(make_host_mesh(data=1, model=1), steps=6)
    hist = tr.run(data())
    gds = tr.edgc_cfg.gds
    measured = {s for s in range(6)
                if gds.should_measure(s % tr.edgc_cfg.dac.window)}
    assert {s for s, _ in tr.controller.entropy_history} == measured
    by_step = {h["step"]: h["entropy"] for h in hist}
    ent = dict(tr.controller.entropy_history)
    last = 0.0
    for s in range(6):
        if s in ent:
            last = ent[s]
        assert by_step[s] == pytest.approx(last)
    assert any(v != 0.0 for v in by_step.values())


def test_pipelined_trainer_checkpoint_resume(tmp_path):
    """Satellite: the control plane survives save/restore — a resumed EDGC
    run must not restart warm-up and must keep the DAC plan."""
    steps = 24
    mesh = make_host_mesh()
    cfg = ModelConfig(name="ckpt", family="dense", num_layers=2, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=2)

    def mk():
        model = build_model(cfg)
        edgc = EDGCConfig(policy="edgc", fixed_rank=16, num_stages=2,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=0.5, beta=0.25),
                          dac=DACConfig(window=4, adjust_limit=4))
        tcfg = TrainerConfig(total_steps=steps, log_every=4,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = SyntheticLM(512, 32, 4, seed=3)
    t1 = mk()
    t1.run(data.batches(), num_steps=16)
    assert not t1.controller.in_warmup
    path = str(tmp_path / "state")
    t1.save_checkpoint(path)

    t2 = mk()
    assert t2.controller.in_warmup
    assert t2.restore_checkpoint(path) == 16
    assert not t2.controller.in_warmup, "resume restarted warm-up"
    assert t2.controller.plan == t1.controller.plan
    assert t2.controller.rank_history == t1.controller.rank_history
    for k in t1.state["comp"]:
        np.testing.assert_array_equal(
            np.asarray(t1.state["comp"][k].q), np.asarray(t2.state["comp"][k].q))
    h = t2.run(data.batches())
    assert h[-1]["step"] == steps - 1


def test_make_plan_rejects_short_stage_ranks():
    _, _, leaves, _ = _setup()
    with pytest.raises(ValueError, match="one rank per pipeline stage"):
        make_plan("edgc", leaves, stage_ranks=[4], num_stages=2)
    with pytest.raises(ValueError, match="one rank per pipeline stage"):
        make_plan("edgc", leaves, stage_ranks=[4, 8, 16], num_stages=2)
    with pytest.raises(ValueError):
        make_plan("edgc", leaves, stage_ranks=None, num_stages=2)


# ------------------------------------------- 4-device mesh (fake devices)
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np

    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.core.powersgd import compressed_bytes
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    S = 4
    CFG = ModelConfig(name="pp4", family="dense", num_layers=4, d_model=128,
                      num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                      num_stages=S)

    def trainer(policy, mesh, steps, sched="1f1b"):
        model = build_model(CFG)
        # alpha=1 keeps the ISR gate always-on: one compiled step
        # variant per (policy, plan) instead of two, which keeps this
        # 10-trainer subprocess inside its timeout
        edgc = EDGCConfig(policy=policy, fixed_rank=16, num_stages=S,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=1.0, beta=0.25),
                          dac=DACConfig(window=5, adjust_limit=4))
        tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule=sched,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = lambda: SyntheticLM(512, 32, 8, seed=3).batches()
    mesh_pipe = make_host_mesh(pipe=S, data=1, model=1)
    mesh_flat = make_host_mesh(data=1, model=1)

    # 1F1B loss parity with the single-stage trainer, all four policies;
    # GPipe spot-checked on the compressed baseline.
    runs = [(p, "1f1b", 30 if p == "edgc" else 8)
            for p in ("none", "fixed", "optimus", "edgc")]
    runs.append(("fixed", "gpipe", 8))
    tp_edgc = None
    for policy, sched, steps in runs:
        tp = trainer(policy, mesh_pipe, steps, sched)
        hp = tp.run(data())
        tf = trainer(policy, mesh_flat, steps)
        hf = tf.run(data())
        lp = [h["loss"] for h in hp]; lf = [h["loss"] for h in hf]
        gap = max(abs(a - b) for a, b in zip(lp, lf))
        tol = 5e-3 if policy != "edgc" else 2e-2   # edgc: resize RNG differs
        assert gap < tol, (policy, sched, gap, lp, lf)
        if policy == "edgc":
            tp_edgc = tp
        print(f"{policy}/{sched}: gap {gap:.2e} PARITY_OK")

    # Algorithm 2 applied per stage: the edgc run warmed up, emitted a
    # stage-aligned (non-decreasing) rank vector, and the per-stage wire
    # ledger reflects exactly those ranks.
    tp = tp_edgc
    assert not tp.controller.in_warmup
    ranks = tp.controller.rank_history[-1][1]   # the vector the plan used
    assert len(ranks) == S
    assert all(b >= a for a, b in zip(ranks, ranks[1:])), ranks
    per_stage = tp.stage_bytes()
    plan = tp.controller.plan.as_dict()
    for s in range(S):
        stage_leaves = [l for l in tp.leaves if l.stage == s]
        comp = sum(compressed_bytes(l.shape, plan[l.path]) if l.path in plan
                   else int(np.prod(l.shape)) * 2 for l in stage_leaves)
        assert comp == per_stage[s][0], (s, comp, per_stage)
        for l in stage_leaves:
            if l.path in plan:
                max_r = min(l.shape[-2:]) // 2
                assert plan[l.path] == max(1, min(ranks[s], max_r)), l.path
    print("stage ranks", ranks, "stage bytes", per_stage)
    print("PIPELINE_4DEV_OK")
""")


@pytest.mark.slow
def test_pipeline_4dev_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_4DEV_OK" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-3000:]


# ------------------------- 2-device mesh, non-dense families (fake devices)
_SCRIPT_FAMILIES = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax
    import numpy as np

    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    ZAMBA = ModelConfig(name="pp2-zamba", family="zamba", num_layers=3,
                        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                        vocab_size=512, ssm_state=16, chunk=16, attn_every=2,
                        num_stages=2)        # ragged: stage layers [2, 1]
    MOE = ModelConfig(name="pp2-moe", family="moe", num_layers=4,
                      d_model=128, num_heads=4, num_kv_heads=2, d_ff=128,
                      vocab_size=512, num_experts=2, experts_per_token=1,
                      capacity_factor=4.0, num_stages=2)

    def trainer(cfg, mesh, steps, stash="replay"):
        model = build_model(cfg)
        edgc = EDGCConfig(policy="fixed", fixed_rank=8, num_stages=2,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=1.0, beta=0.25),
                          dac=DACConfig(window=5, adjust_limit=4))
        tcfg = TrainerConfig(total_steps=steps, log_every=1, schedule="1f1b",
                             stash_policy=stash,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = lambda cfg: SyntheticLM(cfg.vocab_size, 32, 4, seed=3).batches()
    mesh_pipe = make_host_mesh(pipe=2, data=1, model=1)
    mesh_flat = make_host_mesh(data=1, model=1)

    # RAGGED hybrid: strict 1F1B parity on a real pipe axis (no discrete
    # routing in the family, so the padded executor must match the flat
    # trainer's virtual-stage run to fp tolerance).
    steps = 6
    tp = trainer(ZAMBA, mesh_pipe, steps)
    hp = tp.run(data(ZAMBA))
    tf = trainer(ZAMBA, mesh_flat, steps)
    hf = tf.run(data(ZAMBA))
    lp = [h["loss"] for h in hp]; lf = [h["loss"] for h in hf]
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    assert gap < 5e-3, ("zamba", gap, lp, lf)
    assert tp.bytes_synced == tf.bytes_synced
    print(f"zamba ragged pipe=2: gap {gap:.2e} PARITY_OK")

    # MoE on a real pipe axis: microbatching flips discrete top-1 routing
    # vs the unmicrobatched flat run, so assert a loose envelope + the
    # per-stage wire ledger (which must sum to the flat plan's bytes).
    tp = trainer(MOE, mesh_pipe, steps)
    hp = tp.run(data(MOE))
    tf = trainer(MOE, mesh_flat, steps)
    hf = tf.run(data(MOE))
    lp = [h["loss"] for h in hp]; lf = [h["loss"] for h in hf]
    assert all(np.isfinite(lp)), lp
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    assert gap < 0.25, ("moe", gap, lp, lf)
    per_stage = tp.stage_bytes()
    from repro.core import plan_wire_bytes
    comp, full = plan_wire_bytes(tp.leaves, tp.controller.plan)
    assert sum(c for c, _ in per_stage) == comp
    assert sum(f for _, f in per_stage) == full
    print(f"moe pipe=2: gap {gap:.2e} stage bytes {per_stage}")

    # Selective stashing on a REAL pipe axis with a RAGGED plan: 5 layers,
    # attn_every=2 -> groups [2,2,1] -> stage group slots [2, 1] (Gmax=2),
    # so stash="full" saves one inter-group carry per microbatch and the
    # backward replays single group slots instead of the whole stage.
    ZAMBA5 = ModelConfig(name="pp2-zamba5", family="zamba", num_layers=5,
                         d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                         vocab_size=512, ssm_state=16, chunk=16,
                         attn_every=2, num_stages=2)
    steps = 4
    tp = trainer(ZAMBA5, mesh_pipe, steps, stash="full")
    hp = tp.run(data(ZAMBA5))
    tf = trainer(ZAMBA5, mesh_flat, steps)
    hf = tf.run(data(ZAMBA5))
    lp = [h["loss"] for h in hp]; lf = [h["loss"] for h in hf]
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    assert gap < 5e-3, ("zamba5-stash-full", gap, lp, lf)
    print(f"zamba ragged pipe=2 stash=full: gap {gap:.2e} PARITY_OK")
    print("PIPELINE_FAMILIES_2DEV_OK")
""")


@pytest.mark.slow
def test_pipeline_families_2dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT_FAMILIES], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PIPELINE_FAMILIES_2DEV_OK" in proc.stdout, \
        proc.stdout[-2000:] + proc.stderr[-3000:]
