"""Pallas kernel validation: shape/dtype sweeps vs the ref.py oracles.

Required by the brief: for each kernel, sweep shapes & dtypes and
assert_allclose against the pure-jnp oracle (interpret=True on CPU).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels import lowrank as lr

SHAPES = [(128, 128), (256, 512), (512, 256), (384, 640), (1024, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]
RANKS = [4, 16, 64]


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.float32).astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rank", [4, 64])
def test_p_kernel_sweep(shape, dtype, rank):
    m, n = shape
    g, e = _rand(shape, dtype, 0), _rand(shape, dtype, 1)
    q = _rand((n, rank), jnp.float32, 2)
    got = lr.ef_lowrank_p(g, e, q, interpret=True)
    want = ref.ef_lowrank_p(g, e, q)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_q_kernel_sweep(shape, dtype):
    m, n = shape
    rank = 16
    g, e = _rand(shape, dtype, 3), _rand(shape, dtype, 4)
    p_hat = _rand((m, rank), jnp.float32, 5)
    got = lr.ef_lowrank_q(g, e, p_hat, interpret=True)
    want = ref.ef_lowrank_q(g, e, p_hat)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_decompress_kernel_sweep(shape, dtype):
    m, n = shape
    rank = 8
    g, e = _rand(shape, dtype, 6), _rand(shape, dtype, 7)
    p_hat = _rand((m, rank), jnp.float32, 8)
    q = _rand((n, rank), jnp.float32, 9)
    gh, ne = lr.decompress_residual(p_hat, q, g, e, interpret=True)
    ghr, ner = ref.decompress_residual(p_hat, q, g, e)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(gh, np.float32),
                               np.asarray(ghr, np.float32), rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(ne, np.float32),
                               np.asarray(ner, np.float32), rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m", [64, 256, 1024])
@pytest.mark.parametrize("r", RANKS)
def test_gram_schmidt_panel_sweep(m, r):
    p = _rand((m, r), jnp.float32, 10)
    got = lr.gram_schmidt_panel(p, interpret=True)
    # orthonormal + same span as the oracle
    eye = np.asarray(got.T @ got)
    np.testing.assert_allclose(eye, np.eye(r), atol=2e-4)
    want = ref.gram_schmidt(p)
    overlap = np.abs(np.asarray(got.T @ want))
    np.testing.assert_allclose(overlap, np.eye(r), atol=2e-3)


@pytest.mark.parametrize("n", [1000, 4096, 100_000])
@pytest.mark.parametrize("bins", [64, 256])
def test_entropy_hist_kernel_sweep(n, bins):
    x = _rand((n,), jnp.float32, 11) * 0.37
    got = float(ops.sampled_entropy_hist(x, num_bins=bins))
    want = float(ref.sampled_entropy_hist(x, num_bins=bins))
    assert got == pytest.approx(want, abs=1e-5)


@given(mexp=st.integers(1, 3), nexp=st.integers(1, 3),
       rank=st.sampled_from([4, 8, 32]))
@settings(max_examples=10, deadline=None)
def test_p_kernel_property(mexp, nexp, rank):
    m, n = 128 * mexp, 128 * nexp
    g, e = _rand((m, n), jnp.float32, 12), _rand((m, n), jnp.float32, 13)
    q = _rand((n, rank), jnp.float32, 14)
    got = lr.ef_lowrank_p(g, e, q, interpret=True)
    want = ref.ef_lowrank_p(g, e, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_ops_fallback_untileable():
    """Non-128-multiple shapes silently use the oracle — same numbers."""
    g, e = _rand((100, 300), jnp.float32, 15), _rand((100, 300), jnp.float32, 16)
    q = _rand((300, 8), jnp.float32, 17)
    np.testing.assert_allclose(np.asarray(ops.lowrank_p(g, e, q)),
                               np.asarray(ref.ef_lowrank_p(g, e, q)),
                               rtol=1e-5)


def test_hist_kernel_padding_correct():
    """Non-multiple-of-block sizes: the pad sentinel must not leak counts."""
    x = _rand((3001,), jnp.float32, 18)
    got = float(ops.sampled_entropy_hist(x))
    want = float(ref.sampled_entropy_hist(x))
    # f32 accumulation order differs between the blocked kernel and the
    # single-pass oracle; the histogram itself is exact (pad-count corrected)
    assert got == pytest.approx(want, abs=1e-4)


def test_hist_counts_padded_equals_unpadded():
    """Regression for the dead NaN-pad write: sentinel-padded counts must
    match the same call blocked without padding, bin for bin."""
    from repro.kernels.entropy_hist import hist_counts
    x = _rand((5000,), jnp.float32, 19)
    lo = jnp.float32(float(jnp.mean(x)) - 4.0)
    inv_w = jnp.float32(256 / 8.0)
    padded = hist_counts(x, lo, inv_w, bx=2048)    # pad = 1144
    exact = hist_counts(x, lo, inv_w, bx=1000)     # divides evenly, no pad
    np.testing.assert_array_equal(np.asarray(padded), np.asarray(exact))
    assert float(jnp.sum(padded)) == 5000.0        # no phantom pad counts


# ---------------------------------------------- batched (E, m, n) variants
@pytest.mark.parametrize("shape", [(3, 256, 512), (2, 128, 128)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_p_q_kernels_vs_vmapped_ref(shape, dtype):
    E, m, n = shape
    rank = 16
    g, e = _rand(shape, dtype, 20), _rand(shape, dtype, 21)
    q = _rand((E, n, rank), jnp.float32, 22)
    p_hat = _rand((E, m, rank), jnp.float32, 23)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(lr.ef_lowrank_p_batched(g, e, q, interpret=True)),
        np.asarray(jax.vmap(ref.ef_lowrank_p)(g, e, q)),
        rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(
        np.asarray(lr.ef_lowrank_q_batched(g, e, p_hat, interpret=True)),
        np.asarray(jax.vmap(ref.ef_lowrank_q)(g, e, p_hat)),
        rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("dtype", DTYPES)
def test_batched_decompress_kernel_vs_vmapped_ref(dtype):
    E, m, n, rank = 3, 256, 512, 8
    g, e = _rand((E, m, n), dtype, 24), _rand((E, m, n), dtype, 25)
    p_hat = _rand((E, m, rank), jnp.float32, 26)
    q = _rand((E, n, rank), jnp.float32, 27)
    gh, ne = lr.decompress_residual_batched(p_hat, q, g, e, interpret=True)
    ghr, ner = jax.vmap(ref.decompress_residual)(p_hat, q, g, e)
    tol = 1e-4 if dtype == jnp.float32 else 1e-1
    np.testing.assert_allclose(np.asarray(gh, np.float32),
                               np.asarray(ghr, np.float32), rtol=tol, atol=tol * 10)
    np.testing.assert_allclose(np.asarray(ne, np.float32),
                               np.asarray(ner, np.float32), rtol=tol, atol=tol * 10)


def test_batched_gram_schmidt_panel():
    E, m, r = 4, 256, 16
    p = _rand((E, m, r), jnp.float32, 28)
    got = lr.gram_schmidt_panel_batched(p, interpret=True)
    for i in range(E):
        eye = np.asarray(got[i].T @ got[i])
        np.testing.assert_allclose(eye, np.eye(r), atol=2e-4)
        want = ref.gram_schmidt(p[i])
        overlap = np.abs(np.asarray(got[i].T @ want))
        np.testing.assert_allclose(overlap, np.eye(r), atol=2e-3)


def test_batched_ops_fallback_untileable():
    """Non-128-multiple stacks route to the vmapped oracle — same numbers."""
    E, m, n, rank = 3, 100, 300, 8
    g, e = _rand((E, m, n), jnp.float32, 29), _rand((E, m, n), jnp.float32, 30)
    q = _rand((E, n, rank), jnp.float32, 37)
    np.testing.assert_allclose(np.asarray(ops.lowrank_p3(g, e, q)),
                               np.asarray(jax.vmap(ref.ef_lowrank_p)(g, e, q)),
                               rtol=1e-5)
    p = _rand((E, 252, rank), jnp.float32, 38)     # m % 8 != 0 -> QR fallback
    q3 = ops.orthonormalize3(p)
    for i in range(E):
        np.testing.assert_allclose(np.asarray(q3[i].T @ q3[i]), np.eye(rank),
                                   atol=2e-4)


FLASH_CASES = [
    # (B, Tq, Tk, H, Hkv, Dh, bq, bk)
    (2, 256, 256, 4, 2, 64, 64, 64),
    (1, 512, 512, 8, 8, 128, 128, 128),
    (2, 128, 384, 4, 1, 32, 64, 128),   # cross-attn-like, Tq != Tk
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_sweep(case, causal, dtype):
    from repro.kernels.flash_attention import flash_attention
    B, Tq, Tk, H, Hkv, Dh, bq, bk = case
    if causal and Tq != Tk:
        pytest.skip("causal requires aligned q/k positions here")
    q = _rand((B, Tq, H, Dh), dtype, 31)
    k = _rand((B, Tk, Hkv, Dh), dtype, 32)
    v = _rand((B, Tk, Hkv, Dh), dtype, 33)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_reference(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_flash_matches_model_blockwise():
    """The model's blockwise attention and the Pallas flash kernel agree."""
    from repro.kernels.flash_attention import flash_attention
    from repro.models.layers import blockwise_attention
    q = _rand((2, 256, 4, 64), jnp.float32, 34)
    k = _rand((2, 256, 2, 64), jnp.float32, 35)
    v = _rand((2, 256, 2, 64), jnp.float32, 36)
    got = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = blockwise_attention(q, k, v, causal=True, block_q=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 128, 4, 2, 32), (1, 256, 8, 8, 64)])
def test_flash_backward_matches_autodiff(causal, shape):
    """custom_vjp flash bwd vs jax.grad of the full-materialization oracle."""
    from repro.kernels.flash_attention_bwd import flash_attention_train
    B, T, H, Hkv, D = shape
    q = _rand((B, T, H, D), jnp.float32, 41)
    k = _rand((B, T, Hkv, D), jnp.float32, 42)
    v = _rand((B, T, Hkv, D), jnp.float32, 43)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention_train(q, k, v, causal, 64, 64)))

    def loss_ref(q, k, v):
        return jnp.sum(jnp.sin(ref.flash_reference(q, k, v, causal=causal)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
