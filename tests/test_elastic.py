"""Elastic outer loop, fault injection, and control-plane recovery.

Covers the PR-7 surface: atomic checkpoint writes + torn-pair detection,
checkpoint error quality (structure mismatches name leaf paths, dtype
coercion warns or raises), the non-finite step guard with error-feedback
reset, loss-spike rollback through the checkpoint ring, controller
compression fallback, DAC/CQM/EF state round-trips across plan changes,
and the DiLoCo outer optimizer (single-pod in-process; multi-pod drop/join
in a fake-device subprocess, marked slow).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import numpy as np
import pytest

from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim.adam import AdamConfig
from repro.train import checkpoint as ckpt
from repro.train.checkpoint import CheckpointError
from repro.train.faults import (
    FaultEvent, FaultPlan, RecoveryConfig, parse_inject, truncate_file,
)
from repro.train.trainer import Trainer, TrainerConfig

TINY = ModelConfig(name="el", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)


def _trainer(steps=40, policy="fixed", window=10, faults=None, recovery=None,
             ckpt_every=0, ckpt_path="ckpt/state", seed=0):
    model = build_model(TINY)
    edgc = EDGCConfig(policy=policy, fixed_rank=8, total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=window, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=steps,
                         ckpt_every=ckpt_every, ckpt_path=ckpt_path,
                         faults=faults, recovery=recovery,
                         adam=AdamConfig(lr=1e-3, warmup_steps=10,
                                         total_steps=steps))
    return Trainer(model, make_host_mesh(), edgc, tcfg, seed=seed)


def _data(seed=0):
    return SyntheticLM(vocab_size=TINY.vocab_size, seq_len=64, batch_size=4,
                       seed=seed).batches()


# ------------------------------------------------------------- fault specs
def test_parse_inject():
    plan = parse_inject("nan_grad@40, corrupt_payload@8,pod_drop:1@r3")
    assert plan.has("nan_grad") and plan.has("pod_drop")
    ev = {e.kind: e for e in plan.events}
    assert ev["nan_grad"].at == 40 and not ev["nan_grad"].on_round
    assert ev["pod_drop"].at == 3 and ev["pod_drop"].on_round
    assert ev["pod_drop"].arg == 1
    with pytest.raises(ValueError):
        parse_inject("nan_grad")            # no @step
    with pytest.raises(ValueError):
        parse_inject("explode@3")           # unknown kind
    with pytest.raises(ValueError):
        FaultPlan(events=(FaultEvent(kind="pod_drop", at=3,
                                     on_round=False),))  # pod event needs @r
    assert not FaultPlan()


# ------------------------------------------------- checkpoint crash safety
def _tiny_state():
    return {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": {"c": np.ones((4,), np.int32)}}


def test_checkpoint_atomic_no_partials(tmp_path):
    path = str(tmp_path / "st")
    ckpt.save(path, _tiny_state(), extra={"step": 3})
    names = sorted(os.listdir(tmp_path))
    assert names == ["st.json", "st.npz"], names   # no .tmp leftovers
    restored, extra = ckpt.restore(path, _tiny_state())
    assert extra["step"] == 3
    np.testing.assert_array_equal(restored["a"], _tiny_state()["a"])


def test_torn_checkpoint_fails_cleanly(tmp_path):
    path = str(tmp_path / "st")
    ckpt.save(path, _tiny_state())
    truncate_file(path + ".npz", keep_frac=0.3)
    with pytest.raises(CheckpointError, match="torn checkpoint"):
        ckpt.restore(path, _tiny_state())


def test_mixed_save_nonce_mismatch(tmp_path):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    ckpt.save(a, _tiny_state())
    ckpt.save(b, _tiny_state())
    # simulate a crash that left a's manifest paired with b's archive
    os.replace(b + ".npz", a + ".npz")
    with pytest.raises(CheckpointError,
                       match="nonce mismatch|torn checkpoint"):
        ckpt.restore(a, _tiny_state())


def test_structure_mismatch_names_leaves(tmp_path):
    path = str(tmp_path / "st")
    ckpt.save(path, _tiny_state())
    other = {"a": np.zeros((2, 3), np.float32),
             "b": {"d": np.ones((4,), np.int32)}}
    with pytest.raises(CheckpointError) as ei:
        ckpt.restore(path, other)
    msg = str(ei.value)
    assert "structure mismatch" in msg
    assert "'d'" in msg and "'c'" in msg      # names both sides of the diff


def test_dtype_mismatch_warn_raise_silent(tmp_path):
    path = str(tmp_path / "st")
    ckpt.save(path, _tiny_state())
    like = _tiny_state()
    like["a"] = like["a"].astype(np.float16)
    with pytest.warns(UserWarning, match="dtype mismatch.*'a'"):
        restored, _ = ckpt.restore(path, like)
    assert restored["a"].dtype == np.float16   # coerced to the template
    with pytest.raises(CheckpointError, match="dtype mismatch"):
        ckpt.restore(path, like, on_dtype_mismatch="raise")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ckpt.restore(path, like, on_dtype_mismatch="silent")
    with pytest.raises(ValueError):
        ckpt.restore(path, like, on_dtype_mismatch="ignore")


def test_read_extra_errors(tmp_path):
    with pytest.raises(CheckpointError, match="no checkpoint manifest"):
        ckpt.read_extra(str(tmp_path / "absent"))
    bad = tmp_path / "bad"
    (tmp_path / "bad.json").write_text("{not json")
    with pytest.raises(CheckpointError, match="corrupt checkpoint manifest"):
        ckpt.read_extra(str(bad))
    (tmp_path / "nokeys.json").write_text("{}")
    with pytest.raises(CheckpointError, match="missing required keys"):
        ckpt.read_extra(str(tmp_path / "nokeys"))


# --------------------------------------------------------- recovery in run
def test_nan_skip_ef_reset_and_convergence():
    faults = parse_inject("nan_grad@12")
    tr = _trainer(steps=40, faults=faults,
                  recovery=RecoveryConfig(rollback=False))
    hist = tr.run(_data())
    rs = tr.recovery
    assert rs.skipped_steps == 1 and rs.ef_resets == 1
    assert rs.anomalies >= 1 and not rs.fallback
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]              # still converges post-skip
    # the skipped step's update must not have landed in the params
    assert all(np.isfinite(x).all()
               for x in jax.tree_util.tree_leaves(
                   jax.device_get(tr.state["params"])))


def test_rollback_restores_step_and_window(tmp_path):
    # Guard OFF: the NaN lands in the params, the NaN loss on the next
    # step triggers rollback through the checkpoint ring.
    faults = parse_inject("nan_grad@15")
    tr = _trainer(steps=40, policy="edgc", window=10, faults=faults,
                  recovery=RecoveryConfig(guard_nonfinite=False),
                  ckpt_every=10, ckpt_path=str(tmp_path / "st"))
    hist = tr.run(_data())
    rs = tr.recovery
    assert rs.rollbacks == 1, rs.as_dict()
    assert tr._global_step == 40               # re-ran to completion
    assert np.isfinite(hist[-1]["loss"])
    # controller window state survived the rollback round-trip
    sd = tr.controller.state_dict()
    assert not sd["fallback"]
    tr2 = _trainer(steps=40, policy="edgc", window=10)
    tr2.controller.load_state_dict(sd)
    assert tr2.controller.state_dict() == sd


def test_fallback_pins_uncompressed():
    tr = _trainer(steps=20)
    ctrl = tr.controller
    assert not ctrl.in_fallback
    ctrl.force_fallback()
    assert ctrl.in_fallback
    assert ctrl.plan.ranks == ()             # NO_COMPRESSION pinned
    assert ctrl.on_window_end(19) is False     # windows become no-ops
    sd = ctrl.state_dict()
    assert sd["fallback"]
    tr2 = _trainer(steps=20)
    tr2.controller.load_state_dict(sd)
    assert tr2.controller.in_fallback
    assert tr2.controller.plan.ranks == ()


def test_control_plane_roundtrip_across_plan_resize(tmp_path):
    # DAC/CQM/EF state must survive save -> restore across an EDGC plan
    # change (warm-up ends mid-run, so the plan at step 30 != init plan).
    path = str(tmp_path / "st")
    tr = _trainer(steps=50, policy="edgc", window=10, seed=3)
    data = _data(seed=3)
    tr.run(data, num_steps=30)
    tr.save_checkpoint(path, step=30)
    tr2 = _trainer(steps=50, policy="edgc", window=10, seed=3)
    assert tr2.restore_checkpoint(path) == 30
    assert tr2.controller.state_dict() == tr.controller.state_dict()
    for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(tr.state)),
                    jax.tree_util.tree_leaves(jax.device_get(tr2.state))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    h1 = tr.run(data, num_steps=10)
    assert np.isfinite(h1[-1]["loss"])


# ---------------------------------------------------- outer loop (1 pod)
def _elastic(tmp_path, rounds=4, n_pods=1, faults=None, recovery=None):
    from repro.optim.outer import OuterConfig
    from repro.train.elastic import ElasticTrainer
    model = build_model(TINY)
    steps = 5 * rounds
    edgc = EDGCConfig(policy="fixed", fixed_rank=8, total_iterations=steps,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=10, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=steps, log_every=steps,
                         ckpt_path=str(tmp_path / "st"),
                         faults=faults, recovery=recovery,
                         adam=AdamConfig(lr=1e-3, warmup_steps=5,
                                         total_steps=steps))
    ocfg = OuterConfig(outer_k=5, policy="fixed", fixed_rank=8,
                       window=2, total_rounds=rounds)

    def batch_fn(pod):
        return SyntheticLM(TINY.vocab_size, 64, 4, seed=100 + pod).batches()

    return ElasticTrainer(model, edgc, tcfg, ocfg, n_pods, batch_fn)


def test_outer_loop_single_pod(tmp_path):
    et = _elastic(tmp_path, rounds=4)
    hist = et.run_rounds(4)
    assert len(hist) == 4 and et.round_index == 4
    assert all(np.isfinite(h["pod_losses"][0]) for h in hist)
    assert hist[-1]["pod_losses"][0] < hist[0]["pod_losses"][0]
    # the outer sync actually compressed (fixed rank 8 on TINY leaves)
    assert 0 < hist[0]["bytes_synced"] < hist[0]["bytes_full"]
    assert et.outer.comm_savings() > 0.1


def test_outer_checkpoint_roundtrip(tmp_path):
    et = _elastic(tmp_path, rounds=4)
    et.run_rounds(2)
    path = str(tmp_path / "el")
    et.save_checkpoint(path)
    et2 = _elastic(tmp_path, rounds=4)
    assert et2.restore_checkpoint(path) == 2
    assert et2.outer.round_index == et.outer.round_index
    for a, b in zip(jax.tree_util.tree_leaves(et.anchor),
                    jax.tree_util.tree_leaves(et2.anchor)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    hist = et2.run_rounds(2)
    assert np.isfinite(hist[-1]["pod_losses"][0])


# ----------------------------------------------- multi-pod (subprocess)
_MULTIPOD = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax
    import numpy as np
    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.optim.outer import OuterConfig
    from repro.train.elastic import ElasticTrainer
    from repro.train.faults import RecoveryConfig, parse_inject
    from repro.train.trainer import TrainerConfig

    TINY = ModelConfig(name="el", family="dense", num_layers=2, d_model=128,
                       num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512)
    model = build_model(TINY)
    rounds, k = 6, 5
    faults = parse_inject("nan_grad@7,pod_drop:1@r2,pod_join@r4")
    edgc = EDGCConfig(policy="fixed", fixed_rank=8,
                      total_iterations=rounds * k,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=10, adjust_limit=4))
    tcfg = TrainerConfig(total_steps=rounds * k, log_every=rounds * k,
                         ckpt_path="/tmp/el_sub/st", faults=faults,
                         recovery=RecoveryConfig(rollback=False),
                         adam=AdamConfig(lr=1e-3, warmup_steps=5,
                                         total_steps=rounds * k))
    ocfg = OuterConfig(outer_k=k, policy="fixed", fixed_rank=8,
                       window=2, total_rounds=rounds)

    def batch_fn(pod):
        return SyntheticLM(512, 64, 4, seed=100 + pod).batches()

    et = ElasticTrainer(model, edgc, tcfg, ocfg, 2, batch_fn)
    et.run_rounds(rounds - 1)
    # round-boundary composed checkpoint, BEFORE the inner step budget is
    # exhausted (a resume must have inner steps left to run)
    et.save_checkpoint("/tmp/el_sub/full")
    hist = et.run_rounds(1)
    pods = [h["n_pods"] for h in hist]
    assert pods == [2, 2, 1, 1, 2, 2], pods
    assert hist[2]["membership_events"] == ["pod_drop:1"]
    assert hist[4]["membership_events"] == ["pod_join"]
    # the injected NaN step was skipped with an EF reset, and the
    # counters survived two fleet rebuilds via the checkpoint round-trip
    rec = hist[-1]["recovery"]
    assert rec["skipped_steps"] >= 1 and rec["ef_resets"] >= 1, rec
    final = [l for l in hist[-1]["pod_losses"]]
    assert all(np.isfinite(l) for l in final), final
    assert max(final) < max(hist[0]["pod_losses"])
    assert et.outer.comm_savings() > 0.1
    # elastic resume rebuilds the fleet at the checkpoint's pod count
    et2 = ElasticTrainer(model, edgc, tcfg, ocfg, 1, batch_fn)
    et2.restore_checkpoint("/tmp/el_sub/full")
    assert et2.n_pods == 2 and et2.round_index == rounds - 1
    et2.run_rounds(1)
    assert all(np.isfinite(l) for l in et2.history[-1]["pod_losses"])
    print("ELASTIC_MULTIPOD_OK")
""")


@pytest.mark.slow
def test_multipod_drop_join_recovery_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _MULTIPOD], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_MULTIPOD_OK" in proc.stdout, proc.stderr[-3000:]
