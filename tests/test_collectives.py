"""repro.dist.collectives: DP pmean/psum semantics and their composition
with dp_axes/batch_pspec on pod-shaped meshes.

The multi-device half runs in a subprocess (jax locks the device count at
first init, same pattern as test_distributed.py) but stays un-`slow`: it is
one tiny shard_map, not a train step.
"""
import os
import subprocess
import sys
import textwrap

from repro.dist.collectives import dp_world_size, make_dp_pmean, make_dp_psum
from repro.dist.sharding import batch_pspec
from repro.launch.mesh import dp_axes


class PodMesh:
    axis_names = ("pod", "data", "model")

    class devices:
        shape = (2, 16, 16)


class FlatMesh:
    axis_names = ("data", "model")

    class devices:
        shape = (4, 2)


def test_empty_axes_is_identity():
    tree = {"a": 1.0, "b": [2.0, 3.0]}
    assert make_dp_pmean(())(tree) is tree
    assert make_dp_psum(())(tree) is tree


def test_dp_world_size():
    assert dp_world_size(PodMesh) == 32
    assert dp_world_size(FlatMesh) == 4


def test_dp_axes_batch_pspec_composition():
    """batch_pspec shards over a pod-major PREFIX of dp_axes, never more."""
    assert dp_axes(PodMesh) == ("pod", "data")
    assert dp_axes(FlatMesh) == ("data",)

    # divisible by the full dp product (32): both axes, pod-major
    full = batch_pspec(3, PodMesh, batch_size=64)
    assert full[0] == ("pod", "data")
    assert tuple(full)[1:] == (None, None)
    # divisible by pod (2) only: the prefix stops at pod
    assert batch_pspec(2, PodMesh, batch_size=6)[0] in ("pod", ("pod",))
    # divisible by nothing: replicated batch dim
    assert batch_pspec(2, PodMesh, batch_size=3)[0] is None
    # every sharded axis must come from dp_axes (never 'model')
    for b in (1, 2, 3, 6, 32, 64):
        entry = batch_pspec(4, PodMesh, batch_size=b)[0]
        used = entry if isinstance(entry, tuple) else (entry,)
        assert set(used) - {None} <= set(dp_axes(PodMesh))


_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import make_dp_pmean, make_dp_psum, shard_map_dp
    from repro.launch.mesh import dp_axes, make_host_mesh

    mesh = make_host_mesh(data=4, model=1, pod=2)   # (pod, data, model)
    axes = dp_axes(mesh)
    assert axes == ("pod", "data")

    pmean = make_dp_pmean(axes)
    psum = make_dp_psum(axes)

    def body(x, y):
        return pmean(x), pmean(x + y), psum(x)

    f = shard_map_dp(body, mesh,
                     in_specs=(P(axes), P(axes)),
                     out_specs=(P(), P(), P()),
                     manual_axes=axes)
    x = jnp.arange(16.0).reshape(8, 2)
    y = jnp.linspace(-1.0, 1.0, 16).reshape(8, 2)
    mx, mxy, sx = jax.jit(f)(x, y)

    # pmean over all 8 workers == column mean of the global batch
    np.testing.assert_allclose(np.asarray(mx), np.asarray(x).mean(0, keepdims=True),
                               rtol=1e-6)
    # linearity: pmean(x + y) == pmean(x) + pmean(y)
    my = jax.jit(shard_map_dp(pmean, mesh, in_specs=P(axes), out_specs=P(),
                              manual_axes=axes))(y)
    np.testing.assert_allclose(np.asarray(mxy), np.asarray(mx) + np.asarray(my),
                               rtol=1e-6)
    # psum == world_size * pmean
    np.testing.assert_allclose(np.asarray(sx), 8 * np.asarray(mx), rtol=1e-6)
    print("COLLECTIVES_OK")
""")


def test_dp_pmean_linearity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COLLECTIVES_OK" in proc.stdout, proc.stderr[-3000:]
