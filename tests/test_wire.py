"""Entropy-coded wire format (core/wire.py + kernels/pack.py).

Covers: pack/unpack bit-exactness (ops dispatcher vs the ref.py oracle,
small->ref and large->Pallas-interpret routing), quantizer error bounds,
entropy -> bit-width selection, coded-payload byte accounting vs the
sampled-entropy estimate (the Lemma-2 consistency property), chunked vs
monolithic coded-sync equality (the PR 6 invariant at the coded-payload
level), and EF absorption — a short coded training run must track the raw
run within the flat-vs-pipelined parity tolerance.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    classify_leaves,
    init_compressor_state,
    make_plan,
    plan_wire_bytes,
    sync_grads,
    wire,
)
from repro.core import bucketing
from repro.core.bucketing import EF_PREFIX, make_bucket_layout
from repro.core.entropy import histogram_entropy
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.models.model import ModelConfig, build_model

TINY = ModelConfig(name="wire", family="dense", num_layers=2, d_model=128,
                   num_heads=4, num_kv_heads=2, d_ff=256, vocab_size=512,
                   num_stages=2)


def _setup(policy="fixed", **kw):
    model = build_model(TINY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, TINY.num_layers, 2, min_dim=64)
    plan = make_plan(policy, leaves, **kw)
    return params, leaves, plan


def _rand_grads(params, seed=0):
    rng = np.random.default_rng(seed)
    return jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)


# ---------------------------------------------------------------- pack/unpack
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("n", [7, 100, 4096, 12301])
def test_pack_unpack_bit_exact(bits, n):
    """ops dispatcher (ref for small n, Pallas interpret for large) must
    round-trip bit-exactly and agree with the ref.py oracle."""
    rng = np.random.default_rng(n * bits)
    codes = jnp.asarray(rng.integers(0, 1 << bits, size=n), jnp.int32)
    words = kops.pack_bits(codes, bits)
    words_ref = kref.pack_bits(codes, bits)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(words_ref))
    back = kops.unpack_bits(words, bits, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    back_ref = kref.unpack_bits(words_ref, bits, n)
    np.testing.assert_array_equal(np.asarray(back_ref), np.asarray(codes))


def test_pack_density():
    """Packed words actually hold epw codes each — no byte is wasted."""
    for bits in (4, 8):
        n = 10_000
        epw = 32 // bits
        codes = jnp.zeros((n,), jnp.int32)
        words = kops.pack_bits(codes, bits)
        assert words.shape[0] == -(-n // epw)
        assert words.dtype == jnp.uint32


# ------------------------------------------------------------------ quantizer
def test_quantize_error_bound_and_roundtrip():
    codec = wire.ChunkCodec(bits=8, group=256)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(5000) * 3.0, jnp.float32)
    codes, scales = wire.quantize(x, codec)
    assert int(jnp.min(codes)) >= 0
    assert int(jnp.max(codes)) <= 2 * codec.qmax
    y = wire.dequantize(codes, scales, codec)
    # per-group error bound: half a quantization step
    step = np.repeat(np.asarray(scales), codec.group)[: x.shape[0]]
    assert np.all(np.abs(np.asarray(y - x)) <= step / 2 + 1e-7)
    # roundtrip == quantize∘pack∘unpack∘dequantize, bit-exact
    rt = wire.roundtrip(x, codec)
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(y))


def test_quantize_zero_payload():
    codec = wire.ChunkCodec(bits=4, group=64)
    x = jnp.zeros((300,), jnp.float32)
    out = wire.roundtrip(x, codec)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(300, np.float32))


# ----------------------------------------------------------- codec resolution
def test_resolve_codec_modes():
    assert wire.resolve_codec("raw") is None
    q8 = wire.resolve_codec("quant8")
    assert q8.bits == 8
    q4 = wire.resolve_codec("quant4")
    assert q4.bits == 4
    # entropy mode: quant8 fallback until a reading exists
    assert wire.resolve_codec("entropy").bits == 8
    assert wire.resolve_codec("entropy", entropy_nats=None,
                              ref_nats=1.0).bits == 8
    with pytest.raises(ValueError):
        wire.resolve_codec("zstd")


def test_select_bits_tracks_entropy():
    ln2 = float(np.log(2.0))
    h0 = 1.5
    assert wire.select_bits(h0, h0) == 8
    assert wire.select_bits(h0 - 1 * ln2, h0) == 8     # snaps up: b=7
    assert wire.select_bits(h0 - 3 * ln2, h0) == 4     # snaps down: b=5
    assert wire.select_bits(h0 - 10 * ln2, h0) == 4    # clipped low
    assert wire.select_bits(h0 + 5 * ln2, h0) == 8     # clipped high
    # every reachable width must construct a valid codec (regression:
    # intermediate widths like 7 used to escape and fail ChunkCodec)
    for dn in range(-12, 6):
        b = wire.select_bits(h0 + dn * ln2, h0)
        assert wire.ChunkCodec(bits=b).bits in (4, 8)
    # the resolved codec follows
    c = wire.resolve_codec("entropy", entropy_nats=h0 - 4 * ln2, ref_nats=h0)
    assert c.bits == 4 and c.group == 256


def test_coded_bytes_accounting():
    for bits, group in ((8, 1024), (4, 256)):
        codec = wire.ChunkCodec(bits=bits, group=group)
        n = 20_000
        epw = 32 // bits
        expect = (-(-n // epw)) * 4 + (-(-n // group)) * 4
        assert wire.coded_bytes(n, codec) == expect
    assert wire.coded_bytes(1000, None) == 4000
    q8, q4 = wire.resolve_codec("quant8"), wire.resolve_codec("quant4")
    assert wire.coded_bytes(20_000, q8) <= 0.5 * 20_000 * 4
    assert wire.coded_bytes(20_000, q4) < wire.coded_bytes(20_000, q8)


# -------------------------------------------- entropy-consistency (Lemma 2)
@pytest.mark.parametrize("sigma", [0.03, 1.0, 30.0])
def test_coded_size_consistent_with_sampled_entropy(sigma):
    """The achieved fixed-width coded size must sit at or above the
    sampled-entropy lower bound for the realized quantization step, and
    within a constant of it (scale side-channel + fixed-width slack)."""
    codec = wire.resolve_codec("quant8")
    rng = np.random.default_rng(42)
    n = 1 << 14
    x = jnp.asarray(rng.standard_normal(n) * sigma, jnp.float32)
    h = float(histogram_entropy(x))                     # nats
    _, scales = wire.quantize(x, codec)
    step = float(jnp.mean(scales))                      # realized step
    predicted = wire.predicted_code_bits(h, step)
    achieved = wire.coded_bytes(n, codec) * 8.0 / n     # bits/elem
    assert predicted <= achieved + 0.6, (predicted, achieved)
    assert achieved - predicted <= 3.5, (predicted, achieved)


# --------------------------------------- chunked vs monolithic (coded level)
def test_chunked_equals_monolithic_coded():
    """PR 6's chunk-invariance must hold for CODED payloads: running every
    chunk separately reproduces the monolithic bucketed sync bit-exactly —
    grads, group states, and per-member EF updates."""
    params, leaves, plan = _setup("fixed", fixed_rank=8)
    codec = wire.resolve_codec("quant8")
    layout = make_bucket_layout(leaves, plan, chunk_bytes=32 << 10)
    comp = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                 layout=layout, wire_ef=True)
    assert any(k.startswith(EF_PREFIX) for k in comp)
    grads = _rand_grads(params)
    psum = lambda x: x

    mono, mono_state = bucketing.bucketed_sync_grads(
        grads, comp, layout, psum, codec=codec)

    flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    by_path = {jax.tree_util.keystr(kp): g for kp, g in flat}
    chunks = bucketing.sync_chunks(layout)
    # the small chunk_bytes cap must actually split the flat buckets, or
    # this test degenerates to monolithic-vs-monolithic
    assert len(chunks) > len(layout.groups) + len(layout.buckets)
    upd: dict = {}
    state_upd: dict = {}
    for chunk in chunks:
        u, s = bucketing.sync_chunk_grads(by_path, comp, chunk, psum,
                                          codec=codec)
        upd.update(u)
        state_upd.update(s)

    mono_flat, _ = jax.tree_util.tree_flatten_with_path(mono)
    for kp, leaf in mono_flat:
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(upd[jax.tree_util.keystr(kp)]))
    for k, v in state_upd.items():
        if k.startswith(EF_PREFIX):
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(mono_state[k]))


def test_coded_sync_updates_flat_ef():
    """Flat-bucket EF: with an identity psum the residual after a coded
    sync is exactly ``grad - shipped`` (the coding error), and folding it
    into the next step keeps the two-step SUM of shipped values closer to
    the two-step sum of grads than coding without EF would."""
    params, leaves, plan = _setup("none")          # all leaves -> flat buckets
    codec = wire.resolve_codec("quant4")
    layout = make_bucket_layout(leaves, plan)
    comp = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                 layout=layout, wire_ef=True)
    grads = _rand_grads(params)
    psum = lambda x: x
    synced, state = bucketing.bucketed_sync_grads(grads, comp, layout, psum,
                                                  codec=codec)
    g_flat, _ = jax.tree_util.tree_flatten_with_path(grads)
    s_flat, _ = jax.tree_util.tree_flatten_with_path(synced)
    synced_by_path = {jax.tree_util.keystr(kp): v for kp, v in s_flat}
    checked = 0
    for kp, g in g_flat:
        path = jax.tree_util.keystr(kp)
        ef = state.get(EF_PREFIX + path)
        if ef is None:
            continue
        shipped = synced_by_path[path]
        np.testing.assert_allclose(
            np.asarray(ef),
            np.asarray(g, np.float32) - np.asarray(shipped, np.float32),
            rtol=0, atol=1e-6)
        checked += 1
    assert checked > 0
    # second step: EF folds the residual back in; over two steps the total
    # applied error must be below two independent (EF-less) coded steps
    synced2, state2 = bucketing.bucketed_sync_grads(grads, state, layout,
                                                    psum, codec=codec)
    no_ef = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                  layout=layout, wire_ef=False)
    base, _ = bucketing.bucketed_sync_grads(grads, no_ef, layout, psum,
                                            codec=codec)
    err_ef, err_base = 0.0, 0.0
    for (kp, g), s1, s2, b in zip(
            g_flat, jax.tree_util.tree_leaves(synced),
            jax.tree_util.tree_leaves(synced2),
            jax.tree_util.tree_leaves(base)):
        g2 = 2.0 * np.asarray(g, np.float32)
        err_ef += float(np.sum((g2 - np.asarray(s1) - np.asarray(s2)) ** 2))
        err_base += float(np.sum((g2 - 2.0 * np.asarray(b)) ** 2))
    assert err_ef < err_base


# ------------------------------------------------------------- executor gates
def test_per_leaf_codec_rejected():
    params, leaves, plan = _setup("fixed", fixed_rank=8)
    comp = init_compressor_state(params, plan, jax.random.PRNGKey(1))
    with pytest.raises(ValueError, match="bucketed"):
        sync_grads(_rand_grads(params), comp, plan, lambda x: x,
                   bucketed=False, codec=wire.resolve_codec("quant8"))


def test_sync_executor_wire_validation():
    from repro.core.config import SyncConfig
    from repro.core.sync_executor import SyncExecutor
    _, leaves, plan = _setup("fixed", fixed_rank=8)
    with pytest.raises(ValueError, match="wire"):
        SyncExecutor(SyncConfig(wire="gzip"), "flat", plan=plan)
    with pytest.raises(ValueError, match="bucketed"):
        SyncExecutor(SyncConfig(wire="quant8", bucketed=False), "flat",
                     plan=plan)
    ex = SyncExecutor(SyncConfig(wire="quant4"), "flat", plan=plan)
    assert ex.codec is not None and ex.codec.bits == 4


def test_plan_wire_bytes_codec_accounting():
    _, leaves, plan = _setup("fixed", fixed_rank=8)
    raw_c, raw_f = plan_wire_bytes(leaves, plan, 4)
    q8 = wire.resolve_codec("quant8")
    coded_c, coded_f = plan_wire_bytes(leaves, plan, 4, codec=q8)
    assert coded_f == raw_f                     # baseline stays raw
    assert coded_c < 0.5 * raw_c


# ------------------------------------------------- EF absorption (short run)
def test_coded_run_tracks_raw_run():
    """quant8 + EF must track the raw run: same model/data/seed, loss
    stays within the flat-vs-pipelined parity tolerance of PR 6 scaled to
    a short noisy run."""
    from repro.core import EDGCConfig, GDSConfig, SyncConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.train.trainer import Trainer, TrainerConfig

    def run(wire_mode):
        model = build_model(TINY)
        mesh = make_host_mesh()
        scfg = SyncConfig(wire=wire_mode)
        edgc = EDGCConfig(policy="fixed", fixed_rank=8, total_iterations=12,
                          gds=GDSConfig(alpha=1.0, beta=0.5),
                          dac=DACConfig(window=6), sync=scfg)
        tcfg = TrainerConfig(total_steps=12, log_every=3, sync=scfg,
                             min_compress_dim=64)
        tr = Trainer(model, mesh, edgc, tcfg, seed=0)
        data = SyntheticLM(vocab_size=TINY.vocab_size, seq_len=32,
                           batch_size=4, seed=0)
        hist = tr.run(data.batches())
        return tr, hist

    tr_raw, h_raw = run("raw")
    tr_q8, h_q8 = run("quant8")
    assert tr_q8.bytes_synced < 0.55 * tr_q8.bytes_wire_raw
    assert tr_raw.bytes_synced == tr_raw.bytes_wire_raw
    for a, b in zip(h_raw, h_q8):
        assert abs(a["loss"] - b["loss"]) <= 0.05 * max(1.0, a["loss"]), (
            a["step"], a["loss"], b["loss"])
    # telemetry carries the coded-vs-raw ledger
    assert "bytes_wire_raw" in h_q8[-1]
    assert "bytes_wire_raw" not in h_raw[-1]
