"""Probe gradient entropy estimators (Obs. 1 demo, Lemma 2 sanity).

  PYTHONPATH=src python examples/entropy_probe.py
"""
import math

import jax.numpy as jnp
import numpy as np

from repro.core.entropy import gaussian_entropy, histogram_entropy, strided_sample
from repro.kernels import ops

rng = np.random.default_rng(0)
for sigma in (1.0, 0.1, 0.01):
    x = jnp.asarray(rng.standard_normal(200_000).astype(np.float32) * sigma)
    h_theory = math.log(sigma) + 0.5 * math.log(2 * math.pi * math.e)
    print(f"sigma={sigma:6.3f}  gaussian={float(gaussian_entropy(x)):+.4f}  "
          f"hist={float(histogram_entropy(x)):+.4f}  "
          f"pallas={float(ops.sampled_entropy_hist(x)):+.4f}  "
          f"theory={h_theory:+.4f}")

x = jnp.asarray(rng.standard_normal(1_000_000).astype(np.float32))
for beta in (1.0, 0.25, 0.05):
    s = strided_sample(x, beta)
    print(f"beta={beta:4.2f}  sample={s.shape[0]:8d}  "
          f"H={float(histogram_entropy(s)):+.4f}")
