"""Serve a small model with batched requests (decode path), incl. whisper.

  PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serve.engine import Engine, ServeConfig

# --- decoder-only (qwen2 reduced) ---------------------------------------
cfg = get_config("qwen2-0.5b", "reduced")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
eng = Engine(model, params, ServeConfig(max_new_tokens=16, temperature=0.8))
prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 8)).astype(np.int32)
out = eng.generate(prompts)
print(f"qwen2 reduced: generated {out.shape}; row0={out[0].tolist()}")

# --- enc-dec (whisper reduced): audio frames -> tokens --------------------
wcfg = get_config("whisper-base", "reduced")
wmodel = build_model(wcfg)
wparams = wmodel.init(jax.random.PRNGKey(1))
from repro.models import encdec
frames = jnp.asarray(np.random.default_rng(1).standard_normal(
    (2, wcfg.audio_frames, wcfg.d_model)).astype(np.float32) * 0.1)
cache = encdec.init_cache(wcfg, 2, 32, frames=frames, params=wparams)
dec = jax.jit(wmodel.decode_step)
tok = jnp.zeros((2,), jnp.int32)
toks = []
for _ in range(12):
    logits, cache = dec(wparams, cache, tok)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks.append(np.asarray(tok))
print(f"whisper reduced: decoded {np.stack(toks,1).tolist()}")
