"""Quickstart: train a small GPT-2 with EDGC and watch ranks adapt.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.gpt2 import GPT2_FIDELITY
from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model, param_count
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig

STEPS = 200

model = build_model(GPT2_FIDELITY)
mesh = make_host_mesh()
edgc = EDGCConfig(policy="edgc", num_stages=4, total_iterations=STEPS,
                  gds=GDSConfig(alpha=0.5, beta=0.25),
                  dac=DACConfig(window=40, adjust_limit=4))
trainer = Trainer(model, mesh, edgc,
                  TrainerConfig(total_steps=STEPS, log_every=20,
                                adam=AdamConfig(lr=1e-3, warmup_steps=20,
                                                total_steps=STEPS)))
print(f"model: {param_count(trainer.state['params'])/1e6:.1f}M params")
print(f"EDGC: {trainer.controller.describe()}")

data = SyntheticLM(vocab_size=GPT2_FIDELITY.vocab_size, seq_len=128,
                   batch_size=8)
for h in trainer.run(data.batches()):
    print(f"step {h['step']:4d}  loss {h['loss']:.3f}  entropy {h['entropy']:+.3f}"
          f"  stage-ranks {h['ranks']}")
print(f"\nDP-sync bytes saved vs no compression: {trainer.comm_savings():.1%}")
