"""End-to-end driver: EDGC vs the no-compression baseline, same seed/data.

Reproduces Table III's core claim at fidelity scale: near-identical loss,
large DP-sync byte reduction.

  PYTHONPATH=src python examples/train_gpt2_edgc.py
"""

from repro.configs.gpt2 import GPT2_FIDELITY
from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig

STEPS = 300


def run(policy: str):
    model = build_model(GPT2_FIDELITY)
    edgc = EDGCConfig(policy=policy, num_stages=4, total_iterations=STEPS,
                      gds=GDSConfig(alpha=0.5, beta=0.25),
                      dac=DACConfig(window=50, adjust_limit=4))
    tr = Trainer(model, make_host_mesh(), edgc,
                 TrainerConfig(total_steps=STEPS, log_every=50,
                               adam=AdamConfig(lr=1e-3, warmup_steps=30,
                                               total_steps=STEPS)))
    data = SyntheticLM(vocab_size=GPT2_FIDELITY.vocab_size, seq_len=128,
                       batch_size=8, seed=0)
    hist = tr.run(data.batches())
    return hist[-1]["loss"], tr.comm_savings()


loss_none, _ = run("none")
loss_edgc, saved = run("edgc")
print(f"no-compression final loss : {loss_none:.4f}")
print(f"EDGC           final loss : {loss_edgc:.4f}  (gap {loss_edgc-loss_none:+.4f})")
print(f"EDGC DP-sync bytes saved  : {saved:.1%}")
