"""Batched serving engine: prefill + token-by-token decode over a KV cache.

The engine batches independent requests, prefills them with the full-seq
forward (teacher-forced logits give the first sampled token), then decodes
with the model's single-token ``decode_step``. Sampling is greedy or
temperature; everything jit-compiled once per (batch, prompt-length) bucket.

On a mesh the cache shards batch over (pod, data) and kv-heads over 'model'
(dist/sharding.cache_pspecs) — decode needs no hand-written collectives.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig = ServeConfig()):
        self.model = model
        self.params = params
        self.cfg = cfg
        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(model.forward)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.cfg.temperature, axis=-1).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, extra_batch: dict | None = None
                 ) -> np.ndarray:
        """prompts: (B, T_prompt) int32. Returns (B, max_new_tokens).

        The prompt is replayed through decode_step to build the KV cache
        (simple and exact; a fused bulk-prefill cache writer is the listed
        beyond-paper optimization for the serving path).
        """
        B, T = prompts.shape
        key = jax.random.PRNGKey(self.cfg.seed)
        cache = self.model.init_cache(B, T + self.cfg.max_new_tokens)
        tok = None
        for t in range(T):
            logits, cache = self._decode(self.params, cache, jnp.asarray(prompts[:, t]))
        key, sub = jax.random.split(key)
        tok = self._sample(logits, sub)
        out = [tok]
        for _ in range(self.cfg.max_new_tokens - 1):
            logits, cache = self._decode(self.params, cache, tok)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def decode_benchmark(self, batch_size: int, context: int, steps: int = 8
                         ) -> float:
        """Seconds per decode step at a given context length (Table-style)."""
        import time
        cache = self.model.init_cache(batch_size, context + steps + 1)
        tok = jnp.zeros((batch_size,), jnp.int32)
        logits, cache = self._decode(self.params, cache, tok)  # compile
        jax.block_until_ready(logits)
        t0 = time.time()
        for _ in range(steps):
            logits, cache = self._decode(self.params, cache, tok)
        jax.block_until_ready(logits)
        return (time.time() - t0) / steps
