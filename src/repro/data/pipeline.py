"""Data pipeline: deterministic synthetic LM streams + byte-corpus loading.

OpenWebText is not available offline (DESIGN §9); the fidelity experiments
use:

  * ``SyntheticLM`` — a Zipf-weighted order-2 Markov token stream. It has
    real sequential structure (so the loss falls, gradients evolve, and
    entropy *decreases* over training — the dynamics EDGC consumes) while
    being fully deterministic and infinitely long.
  * ``ByteCorpus`` — byte-level LM over any local text file (README, source
    tree, ...), for end-to-end runs on real text.

Both yield the same batch dict the models expect and shard the global batch
over the (pod, data) mesh axes via ``jax.device_put`` with a NamedSharding.
Multimodal stubs (audio frames / image patches) are generated here too —
deterministic pseudo-embeddings keyed by the token content, per the brief's
stub carve-out.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterator

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLM:
    """Order-2 Markov chain with Zipf marginals, deterministic by seed."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.3

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed)
        V = self.vocab_size
        # Zipf-ish marginal
        ranks = np.arange(1, V + 1, dtype=np.float64)
        base = 1.0 / ranks ** self.zipf_a
        base /= base.sum()
        # each (prev-token bucket) induces a different permutation of the
        # marginal — cheap stand-in for bigram structure
        self._n_buckets = 64
        self._perms = np.stack(
            [rng.permutation(V) for _ in range(self._n_buckets)])
        self._base = base
        self._rng = np.random.default_rng(self.seed + 1)

    def _sample_batch(self) -> np.ndarray:
        """Batch-vectorized sequential draw (loop over T, vector over B)."""
        B, T, V = self.batch_size, self.seq_len + 1, self.vocab_size
        cdf = np.cumsum(self._base)
        draws = self._rng.random((B, T))
        out = np.empty((B, T), np.int64)
        prev = np.zeros(B, np.int64)
        for t in range(T):
            buckets = (prev * 2654435761) % self._n_buckets
            idx = np.minimum(np.searchsorted(cdf, draws[:, t]), V - 1)
            prev = self._perms[buckets, idx]
            out[:, t] = prev
        return out

    def batches(self) -> Iterator[dict]:
        while True:
            seqs = self._sample_batch()
            yield {
                "tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32),
            }


@dataclasses.dataclass
class ByteCorpus:
    """Byte-level LM batches over a local file."""

    path: str
    seq_len: int
    batch_size: int
    seed: int = 0

    def __post_init__(self) -> None:
        with open(self.path, "rb") as f:
            self._data = np.frombuffer(f.read(), np.uint8).astype(np.int32)
        if len(self._data) < self.seq_len + 2:
            raise ValueError(f"{self.path} too small for seq_len={self.seq_len}")
        self._rng = np.random.default_rng(self.seed)

    @property
    def vocab_size(self) -> int:
        return 256

    def batches(self) -> Iterator[dict]:
        n = len(self._data) - self.seq_len - 1
        while True:
            starts = self._rng.integers(0, n, self.batch_size)
            toks = np.stack([self._data[s: s + self.seq_len + 1] for s in starts])
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def _stub_embedding(shape: tuple[int, ...], tag: str, seed: int) -> np.ndarray:
    """Deterministic pseudo-embedding for the stubbed modality frontends."""
    h = int.from_bytes(hashlib.sha256(f"{tag}:{seed}".encode()).digest()[:4], "little")
    rng = np.random.default_rng(h)
    return rng.standard_normal(shape).astype(np.float32) * 0.1


def add_modality_stubs(batch: dict, family: str, *, audio_frames: int = 0,
                       num_patches: int = 0, d_model: int = 0, seed: int = 0) -> dict:
    """Attach stub frames/patches as the brief's modality-frontend carve-out."""
    B = batch["tokens"].shape[0]
    if family == "whisper":
        batch = dict(batch)
        batch["frames"] = _stub_embedding((B, audio_frames, d_model), "audio", seed)
    elif family == "vlm":
        batch = dict(batch)
        batch["patches"] = _stub_embedding((B, num_patches, d_model), "vision", seed)
    return batch


def shard_batch(batch: dict, mesh, batch_axes=("pod", "data")) -> dict:
    """Device-put a host batch with the global batch dim sharded over DP axes."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    out = {}
    for k, v in batch.items():
        spec = P(axes, *([None] * (v.ndim - 1)))
        out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out
