"""DiLoCo-style outer optimizer: EDGC-compressed outer-delta sync.

EDGC's premise is that compression matters most where communication is
scarcest, and nothing is scarcer than the cross-pod links. This module
gives the ``pod`` mesh axis its algorithmic role (ROADMAP item 3): each pod
runs K inner Trainer steps on its own data shard, then the pods all-reduce
the OUTER DELTA (anchor params minus the pod's post-inner-loop params) over
the ``pod`` axis — through the same PowerSGD + error-feedback machinery the
inner loop uses — and a Nesterov-momentum outer update moves the shared
anchor.

The outer control plane is a second, independent EDGC stack: its own
``EDGCController`` (CQM law + DAC window) adapts the OUTER rank from
outer-delta entropy, with the window counted in outer rounds. Outer deltas
are far smoother than per-step gradients (K steps of Adam average a lot of
noise), so their entropy — and hence the DAC's rank — sits well below the
inner loop's: the L-GreCo observation that signal-adapted compression
tolerates much higher ratios on slowly-varying quantities.

Execution: the outer sync runs as a ``shard_map`` manual over ("pod",) on a
1-device-per-pod mesh (``make_pod_mesh``). Per-pod deltas are distinct
values under a replicated PartitionSpec — each pod's lead device holds its
own delta buffer — and the manual pmean inside the region averages them,
exactly like the inner DP sync but over the scarce axis. The Nesterov
update itself is host-side numpy: it runs once per K inner steps on
anchor-sized trees, so it is never on the critical path.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    EDGCConfig,
    EDGCController,
    classify_leaves,
    init_compressor_state,
    plan_wire_bytes,
    sync_grads,
)
from repro.core import wire
from repro.core.dac import DACConfig
from repro.core.entropy import GDSConfig, grads_entropy
from repro.core.powersgd import LowRankState, resize_rank
from repro.dist.collectives import make_dp_pmean, shard_map_dp

__all__ = ["OuterConfig", "OuterOptimizer", "make_outer_sync_step"]

#: outer deltas ship in fp32 (they are parameter-scale, not gradient-scale)
_OUTER_BYTES_PER_ELEM = 4


def make_outer_sync_step(mesh, plan, gds: GDSConfig, codec=None):
    """The compressed outer all-reduce, jitted for one plan.

    (delta, comp) -> (synced delta, new comp, entropy): per-leaf PowerSGD
    factor pmeans + error feedback over the ``pod`` axis (plain pmeans for
    uncompressed leaves), entropy measured on the synced delta — the
    reading the outer DAC window consumes. ``delta`` enters with a
    replicated spec whose per-pod shards hold each pod's OWN delta; ``comp``
    carries the per-pod leading dim. Also used standalone by the dryrun to
    lower the outer sync at frontier scale.

    ``codec`` (a :class:`~repro.core.wire.ChunkCodec`) wraps the pod-axis
    pmean so every payload crosses the scarce link quantized+bit-packed:
    PowerSGD factor error is EF-absorbed; uncompressed leaves see error
    bounded by half a quantization step per round.
    """
    axes = ("pod",) if "pod" in mesh.axis_names else ()

    def local(delta, comp):
        if axes:
            comp = jax.tree_util.tree_map(lambda a: a[0], comp)
        pmean = wire.coded_psum(make_dp_pmean(axes), codec)
        synced, comp = sync_grads(delta, comp, plan, pmean, bucketed=False)
        h = grads_entropy(synced, gds)
        if axes:
            comp = jax.tree_util.tree_map(lambda a: a[None], comp)
        return synced, comp, h

    if axes:
        fn = shard_map_dp(local, mesh,
                          in_specs=(P(), P(("pod",))),
                          out_specs=(P(), P(("pod",)), P()),
                          manual_axes=axes)
    else:
        fn = local
    return jax.jit(fn)


@dataclasses.dataclass(frozen=True)
class OuterConfig:
    """DiLoCo outer loop configuration.

    ``outer_k`` inner steps per round; the standard DiLoCo outer SGD uses
    Nesterov momentum with lr around 0.7 / momentum 0.9. ``policy`` picks
    the outer-delta compression: 'none' (plain fp32 all-reduce), 'fixed'
    (static rank), or 'edgc' (the dedicated outer DAC window, counted in
    rounds, adapting rank from outer-delta entropy).
    """

    outer_k: int = 30
    lr: float = 0.7
    momentum: float = 0.9
    policy: str = "edgc"            # none | fixed | edgc
    fixed_rank: int = 32
    # Wire coding of the outer all-reduce (repro.core.wire). Cross-pod
    # links are the scarcest, so deltas ship coded BY DEFAULT; 'entropy'
    # re-picks the bit width per window from outer-delta entropy.
    wire: str = "quant8"            # raw | quant8 | quant4 | entropy
    window: int = 2                 # outer DAC window, in ROUNDS
    adjust_limit: int = 8
    total_rounds: int = 100
    min_compress_dim: int = 64
    warmup_frac_min: float = 0.0    # rounds are scarce: allow early warm-up end


class OuterOptimizer:
    """Compressed outer-delta all-reduce + Nesterov outer update.

    Owns: the outer EDGC control plane (controller/DAC/CQM over outer
    rounds), the per-pod outer compressor state (warm-start Q + EF, leading
    pod dim), the outer momentum tree, and the plan-keyed compile cache for
    the outer sync step. Elastic membership changes go through
    ``resize_pods`` — surviving pods keep their EF rows, joiners start with
    the shared warm-start Q and zero EF.
    """

    def __init__(self, params: Any, cfg: OuterConfig, mesh,
                 num_layers: int, seed: int = 0) -> None:
        self.cfg = cfg
        self.leaves = classify_leaves(params, num_layers, 1,
                                      min_dim=cfg.min_compress_dim)
        self._edgc = EDGCConfig(
            policy=cfg.policy, fixed_rank=cfg.fixed_rank,
            total_iterations=cfg.total_rounds,
            gds=GDSConfig(alpha=1.0, beta=0.25),  # every round measured
            dac=DACConfig(window=cfg.window, adjust_limit=cfg.adjust_limit,
                          warmup_frac_min=cfg.warmup_frac_min),
        )
        self._key = jax.random.fold_in(jax.random.PRNGKey(seed), 777)
        self.momentum = jax.tree_util.tree_map(
            lambda a: np.zeros(a.shape, np.float32), jax.device_get(params))
        self.round_index = 0
        self.bytes_synced = 0
        self.bytes_wire_raw = 0      # same payloads priced uncoded
        self.bytes_full = 0
        self.entropy_log: list[tuple[int, float]] = []
        # entropy mode starts at its quant8 fallback until the first
        # round's reading sets the reference distribution
        self._codec = wire.resolve_codec(cfg.wire)
        self._sync_cache: dict[Any, Any] = {}
        self._host_shapes = jax.tree_util.tree_map(
            lambda a: tuple(a.shape), jax.device_get(params))
        self.set_mesh(mesh)
        self.controller = EDGCController(self._edgc, self.leaves,
                                         world=max(2, self.n_pods))
        self._comp_host = self._init_comp_host(params)
        self._put_comp()

    # ------------------------------------------------------------------ mesh
    def set_mesh(self, mesh) -> None:
        """(Re)bind to a pod mesh; invalidates the compiled sync cache."""
        self.mesh = mesh
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.n_pods = sizes.get("pod", 1)
        self._axes = ("pod",) if "pod" in mesh.axis_names else ()
        self._pod_devices = list(mesh.devices.flatten())
        self._sync_cache.clear()

    @property
    def plan(self):
        return self.controller.plan

    # ------------------------------------------------------- compressor state
    def _init_comp_host(self, params) -> dict[str, LowRankState]:
        """Per-leaf outer compressor state, host-side, leading pod dim."""
        per_leaf = jax.device_get(
            init_compressor_state(params, self.controller.plan, self._key))
        return {
            path: LowRankState(
                q=np.broadcast_to(np.asarray(st.q)[None],
                                  (self.n_pods,) + st.q.shape).copy(),
                err=np.zeros((self.n_pods,) + st.err.shape,
                             np.asarray(st.err).dtype),
            )
            for path, st in per_leaf.items()
        }

    def _put_comp(self) -> None:
        if self._axes:
            sh = NamedSharding(self.mesh, P("pod"))
            self._comp = jax.device_put(self._comp_host, sh)
        else:
            self._comp = jax.device_put(self._comp_host)

    def _apply_plan_change(self, params_like) -> None:
        """Re-shape the outer compressor state to the controller's new plan
        (same per-leaf migration as the inner trainer: resized warm Q + EF
        for surviving leaves, fresh state for newly-compressed ones)."""
        plan = self.controller.plan
        fresh = jax.device_get(
            init_compressor_state(params_like, plan, self._key))
        new_host: dict[str, LowRankState] = {}
        for path, st in fresh.items():
            if path in self._comp_host:
                old = self._comp_host[path]
                # the stored leading dim can lag n_pods (restore into a
                # resized fleet): extra pods reuse row 0's warm Q, their
                # EF rows start at zero (same rule as resize_pods joiners)
                old_n = np.asarray(old.q).shape[0]
                rows = [
                    jax.device_get(resize_rank(
                        LowRankState(
                            q=jnp.asarray(old.q[i if i < old_n else 0]),
                            err=jnp.asarray(old.err[i] if i < old_n
                                            else np.zeros_like(old.err[0]))),
                        plan.rank_of(path), self._key))
                    for i in range(self.n_pods)
                ]
                new_host[path] = LowRankState(
                    q=np.stack([np.asarray(r.q) for r in rows]),
                    err=np.stack([np.asarray(r.err) for r in rows]))
            else:
                new_host[path] = LowRankState(
                    q=np.broadcast_to(np.asarray(st.q)[None],
                                      (self.n_pods,) + st.q.shape).copy(),
                    err=np.zeros((self.n_pods,) + st.err.shape,
                                 np.asarray(st.err).dtype))
        self._comp_host = new_host
        self._put_comp()
        self._sync_cache.clear()

    def resize_pods(self, mesh, survivors: list[int]) -> None:
        """Elastic membership change: rebind to ``mesh`` (new pod count),
        migrating EF state — survivors keep their rows, joiners get the
        shared warm-start Q (row parity is a PowerSGD requirement) and a
        zero EF residual.
        """
        self._comp_host = jax.device_get(self._comp)
        old_n = self.n_pods
        for i in survivors:
            if not 0 <= i < old_n:
                raise ValueError(f"survivor index {i} out of range for "
                                 f"{old_n} pods")
        self.set_mesh(mesh)
        n_new = self.n_pods

        def migrate(st: LowRankState) -> LowRankState:
            q, err = np.asarray(st.q), np.asarray(st.err)
            q_rows = [q[i] for i in survivors]
            err_rows = [err[i] for i in survivors]
            while len(q_rows) < n_new:       # joiners
                q_rows.append(q_rows[0].copy())
                err_rows.append(np.zeros_like(err_rows[0]))
            return LowRankState(q=np.stack(q_rows[:n_new]),
                                err=np.stack(err_rows[:n_new]))

        self._comp_host = {p: migrate(st)
                           for p, st in self._comp_host.items()}
        self._put_comp()

    # ------------------------------------------------------------- sync step
    def _get_sync(self, plan):
        key = (plan, self._codec)
        if key not in self._sync_cache:
            self._sync_cache[key] = make_outer_sync_step(
                self.mesh, plan, self._edgc.gds, codec=self._codec)
        return self._sync_cache[key]

    def _refresh_codec(self) -> None:
        """Entropy-mode wire coding: bit width from the latest outer-delta
        reading vs the first round's reference. Window-boundary cadence,
        like the rank plan — the (plan, codec) sync cache re-specializes."""
        if self.cfg.wire != "entropy" or not self.entropy_log:
            return
        self._codec = wire.resolve_codec(
            "entropy", entropy_nats=self.entropy_log[-1][1],
            ref_nats=self.entropy_log[0][1])

    def _pod_array(self, per_pod: list[np.ndarray]):
        """One logical array whose per-pod shards hold DIFFERENT values.

        Replicated spec + explicit per-device buffers: inside the manual
        shard_map region each pod sees its own delta, and the pmean over
        'pod' averages them — the outer all-reduce.
        """
        a0 = np.asarray(per_pod[0], np.float32)
        if not self._axes:
            return jnp.asarray(a0)
        sharding = NamedSharding(self.mesh, P())
        bufs = [jax.device_put(np.asarray(a, np.float32), d)
                for a, d in zip(per_pod, self._pod_devices)]
        return jax.make_array_from_single_device_arrays(
            a0.shape, sharding, bufs)

    # ----------------------------------------------------------------- round
    def round(self, anchor: Any, pod_deltas: list[Any]) -> tuple[Any, dict]:
        """One outer round: compressed all-reduce of the per-pod deltas,
        then the Nesterov outer update.

        ``anchor``: host pytree of the shared params at the round start.
        ``pod_deltas``: one host pytree per pod, ``anchor - pod_params``
        (the outer pseudo-gradient). Returns (new anchor params as a host
        pytree, round info dict).
        """
        if len(pod_deltas) != self.n_pods:
            raise ValueError(f"{len(pod_deltas)} pod deltas for "
                             f"{self.n_pods} pods")
        plan = self.controller.plan
        leaves_list = [jax.tree_util.tree_leaves(d) for d in pod_deltas]
        treedef = jax.tree_util.tree_structure(pod_deltas[0])
        delta = jax.tree_util.tree_unflatten(
            treedef,
            [self._pod_array([ls[i] for ls in leaves_list])
             for i in range(len(leaves_list[0]))])

        synced, self._comp, h = self._get_sync(plan)(delta, self._comp)
        synced = jax.device_get(synced)
        h = float(h)
        self.entropy_log.append((self.round_index, h))
        self.controller.on_entropy(self.round_index, h)

        comp_b, full_b = plan_wire_bytes(self.leaves, plan,
                                         _OUTER_BYTES_PER_ELEM,
                                         codec=self._codec)
        raw_b = (plan_wire_bytes(self.leaves, plan,
                                 _OUTER_BYTES_PER_ELEM)[0]
                 if self._codec is not None else comp_b)
        self.bytes_synced += comp_b
        self.bytes_wire_raw += raw_b
        self.bytes_full += full_b

        # Nesterov outer SGD on the averaged pseudo-gradient.
        mu, lr = self.cfg.momentum, self.cfg.lr
        flat_a = jax.tree_util.tree_leaves(anchor)
        flat_d = jax.tree_util.tree_leaves(synced)
        flat_m = jax.tree_util.tree_leaves(self.momentum)
        tdef = jax.tree_util.tree_structure(anchor)
        new_p, new_m = [], []
        for a, d, m in zip(flat_a, flat_d, flat_m):
            a32 = np.asarray(a, np.float32)
            d32 = np.asarray(d, np.float32)
            m2 = mu * m + d32
            new_m.append(m2)
            new_p.append((a32 - lr * (d32 + mu * m2)).astype(
                np.asarray(a).dtype))
        self.momentum = jax.tree_util.tree_unflatten(tdef, new_m)
        new_params = jax.tree_util.tree_unflatten(tdef, new_p)

        self.round_index += 1
        plan_changed = False
        if self.round_index % self.cfg.window == 0:
            if self.controller.on_window_end(self.round_index - 1):
                self._apply_plan_change(anchor)
                plan_changed = True
            self._refresh_codec()
        info = {
            "round": self.round_index - 1,
            "entropy": h,
            "bytes_synced": comp_b,
            "bytes_full": full_b,
            "ranks": ([r for _, r in plan.ranks[:4]]),
            "plan_changed": plan_changed,
        }
        if self._codec is not None:
            info["bytes_wire_raw"] = raw_b
            info["wire_bits"] = int(self._codec.bits)
        return new_params, info

    # --------------------------------------------------------- checkpointing
    def state_dict(self) -> dict[str, Any]:
        """JSON control-plane state (arrays ride the checkpoint pytree)."""
        return {
            "controller": self.controller.state_dict(),
            "round_index": int(self.round_index),
            "n_pods": int(self.n_pods),
            "bytes_synced": int(self.bytes_synced),
            "bytes_wire_raw": int(self.bytes_wire_raw),
            "bytes_full": int(self.bytes_full),
            "entropy_log": [[int(r), float(h)] for r, h in self.entropy_log],
        }

    def load_state_dict(self, sd: dict[str, Any], params_like: Any) -> None:
        self.controller.load_state_dict(sd["controller"])
        self.round_index = int(sd["round_index"])
        self.bytes_synced = int(sd["bytes_synced"])
        self.bytes_wire_raw = int(sd.get("bytes_wire_raw", 0))
        self.bytes_full = int(sd["bytes_full"])
        self.entropy_log = [(int(r), float(h)) for r, h in sd["entropy_log"]]
        self._refresh_codec()   # entropy mode: codec from restored log
        # Re-shape the comp state to the restored plan (arrays get loaded
        # into it afterwards — same order contract as the inner trainer).
        self._apply_plan_change(params_like)
        saved_n = int(sd.get("n_pods", self.n_pods))
        if saved_n != self.n_pods:
            # checkpoint written at a different pod count: the arrays will
            # be loaded at saved_n rows, then migrated — handled by the
            # caller via resize_pods after array restore.
            pass

    @property
    def arrays(self) -> dict[str, Any]:
        """The outer device/host arrays for the checkpoint state pytree."""
        return {"outer_m": self.momentum,
                "outer_comp": jax.device_get(self._comp)}

    def load_arrays(self, arrs: dict[str, Any]) -> None:
        self.momentum = jax.tree_util.tree_map(np.asarray, arrs["outer_m"])
        self._comp_host = jax.tree_util.tree_map(np.asarray,
                                                 arrs["outer_comp"])
        self._put_comp()

    def comm_savings(self) -> float:
        if self.bytes_full == 0:
            return 0.0
        return 1.0 - self.bytes_synced / self.bytes_full
