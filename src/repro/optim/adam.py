"""AdamW + cosine LR schedule with linear warmup (the paper's training setup).

Pure-functional, pytree-shaped state (m, v mirror the params). Weight decay
is masked off 1-D leaves (norms, biases) per standard practice. The state is
float32 regardless of param dtype; ``opt_dtype='bfloat16'`` halves m/v for
the memory-bound monster configs (documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array          # scalar int32
    m: Any                   # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    opt_dtype: str = "float32"


def lr_at(cfg: AdamConfig, step) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac * lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / max(1, cfg.warmup_steps)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1.0 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: AdamConfig) -> AdamState:
    dt = getattr(jnp, cfg.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def update(params, grads, state: AdamState, cfg: AdamConfig, gnorm=None):
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``gnorm`` overrides the grad-clip norm: pipeline-parallel callers pass
    the cross-stage global norm (each pipe rank holds only its stage's
    grads, so the local norm would clip each stage differently and break
    parity with the single-program step).
    """
    b1, b2 = cfg.betas
    step = state.step + 1
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12)) if cfg.grad_clip > 0 else 1.0
    lr = lr_at(cfg, step)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = getattr(jnp, cfg.opt_dtype)

    def leaf(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        upd = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [leaf(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamState(step=step, m=new_m, v=new_v), {
        "lr": lr, "grad_norm": gnorm,
    }
