"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="zamba2-7b", family="zamba",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, ssm_state=64, conv_kernel=4,
    chunk=128, attn_every=7, num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="zamba2-smoke", family="zamba",
    num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, ssm_state=16, chunk=16, attn_every=2,
)
SHARDING_MODE = "dp_tp"
# Mamba2 state is O(1)/token; the shared-attn sites use a sliding window so
# the 500k decode KV stays bounded (DESIGN §5).
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
