"""Qwen3-MoE-235B-A22B — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
    d_ff=1536, vocab_size=151936,
    num_experts=128, experts_per_token=8,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512, num_experts=4, experts_per_token=2,
)
SHARDING_MODE = "auto"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
