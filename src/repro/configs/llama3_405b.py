"""Llama-3-405B — dense, GQA kv=8, 128k vocab [arXiv:2407.21783]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="llama3-405b", family="dense",
    num_layers=126, d_model=16384, num_heads=128, num_kv_heads=8,
    d_ff=53248, vocab_size=128256, head_dim=128,
    num_stages=6, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="llama3-smoke", family="dense",
    num_layers=2, d_model=512, num_heads=8, num_kv_heads=2,
    d_ff=1024, vocab_size=512, head_dim=64,
)
# 405B params exceed per-chip HBM under replicated-DP: 'auto' (FSDP+TP)
SHARDING_MODE = "auto"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
