"""Phi-3-vision — phi3-mini decoder + stubbed CLIP frontend
[hf:microsoft/Phi-3-vision-128k-instruct]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    num_layers=32, d_model=3072, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=32064, num_patches=576,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="phi3v-smoke", family="vlm",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512, num_patches=16,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
