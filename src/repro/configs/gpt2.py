"""GPT-2 family — the paper's own models (Table II) + fidelity reductions.

GPT2-345M/2.5B/12.1B as Megatron configured them (LayerNorm, plain GeLU,
learned positions, MHA). ``GPT2_FIDELITY`` is the CPU-scale reduction used
by the EXPERIMENTS.md paper-fidelity runs (entropy decay, CQM, Tables).
"""
from repro.models.model import ModelConfig

GPT2_345M = ModelConfig(
    name="gpt2-345m", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=50257, norm="layernorm", act="gelu_plain",
    pos="learned", tie_embeddings=True, max_position=1024,
    num_stages=4, dtype="bfloat16", remat=True,
)
GPT2_2_5B = ModelConfig(
    name="gpt2-2.5b", family="dense",
    num_layers=52, d_model=1920, num_heads=20, num_kv_heads=20,
    d_ff=7680, vocab_size=50257, norm="layernorm", act="gelu_plain",
    pos="learned", tie_embeddings=True, max_position=1024,
    num_stages=4, dtype="bfloat16", remat=True,   # paper: TP4/DP2/PP4
)
GPT2_12_1B = ModelConfig(
    name="gpt2-12.1b", family="dense",
    num_layers=76, d_model=3584, num_heads=28, num_kv_heads=28,
    d_ff=14336, vocab_size=50257, norm="layernorm", act="gelu_plain",
    pos="learned", tie_embeddings=True, max_position=1024,
    num_stages=4, dtype="bfloat16", remat=True,   # paper: TP4/DP4/PP4
)
GPT2_FIDELITY = ModelConfig(
    name="gpt2-fidelity", family="dense",
    num_layers=4, d_model=256, num_heads=8, num_kv_heads=8,
    d_ff=1024, vocab_size=2048, norm="layernorm", act="gelu_plain",
    pos="learned", tie_embeddings=True, max_position=512,
    num_stages=4,
)
FULL = GPT2_2_5B
REDUCED = GPT2_FIDELITY
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = None
