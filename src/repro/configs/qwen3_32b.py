"""Qwen3-32B — dense, GQA kv=8, qk-norm [hf:Qwen/Qwen3-8B]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    d_ff=25600, vocab_size=151936, head_dim=128, qk_norm=True,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="qwen3-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, head_dim=64, qk_norm=True,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
