"""Kimi K2 — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
    d_ff=2048, vocab_size=163840,
    num_experts=384, experts_per_token=8,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="kimi-k2-smoke", family="moe",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512,
    num_experts=4, experts_per_token=2, num_stages=2,
)
# 1T params cannot hold DP-replicated on a 256-chip v5e pod: FSDP/EP 'auto'
# sharding mode (DESIGN §5); EDGC applies on the cross-pod axis only.
SHARDING_MODE = "auto"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)  # long_500k variant
