"""Architecture registry: the 10 assigned archs + the paper's GPT-2 family.

``get_config(arch, variant)`` returns a ModelConfig; ``--arch <id>`` in the
launchers resolves through ARCHS. Variants: full | reduced | long (the
long_500k decode variant; None = skip, recorded in DESIGN §5).
"""
from __future__ import annotations

import importlib

ARCHS: dict[str, str] = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "xlstm-125m": "xlstm_125m",
    "qwen3-32b": "qwen3_32b",
    "qwen2-0.5b": "qwen2_0_5b",
    "zamba2-7b": "zamba2_7b",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama3-405b": "llama3_405b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "whisper-base": "whisper_base",
    "gpt2": "gpt2",
}

INPUT_SHAPES: dict[str, dict] = {
    "train_4k":    {"seq_len": 4096,    "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768,   "global_batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq_len": 32768,   "global_batch": 128, "kind": "decode"},
    "long_500k":   {"seq_len": 524288,  "global_batch": 1,   "kind": "decode"},
}


def arch_module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str, variant: str = "full"):
    mod = arch_module(arch)
    if variant == "full":
        return mod.FULL
    if variant == "reduced":
        return mod.REDUCED
    if variant == "long":
        return mod.LONG_CONTEXT
    raise ValueError(f"unknown variant {variant!r}")


def sharding_mode(arch: str) -> str:
    return arch_module(arch).SHARDING_MODE
