"""Qwen2-0.5B — dense, GQA kv=2, QKV bias, tied embeddings [arXiv:2407.10671]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen2-0.5b", family="dense",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="qwen2-smoke", family="dense",
    num_layers=2, d_model=224, num_heads=7, num_kv_heads=1,
    d_ff=512, vocab_size=512, qkv_bias=True, tie_embeddings=True,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
