"""xLSTM-125M — alternating sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    chunk=128, num_stages=2, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="xlstm-smoke", family="xlstm",
    num_layers=2, d_model=128, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=512, chunk=16,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = FULL  # recurrent state: long_500k runs natively
