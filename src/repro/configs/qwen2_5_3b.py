"""Qwen2.5-3B — dense, GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
import dataclasses
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="qwen2.5-3b", family="dense",
    num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
    d_ff=11008, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
    num_stages=4, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    num_layers=2, d_model=256, num_heads=4, num_kv_heads=2,
    d_ff=512, vocab_size=512, qkv_bias=True, tie_embeddings=True,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = dataclasses.replace(FULL, sliding_window=8192)
