"""Whisper-base — enc-dec, conv frontend stubbed [arXiv:2212.04356]."""
from repro.models.model import ModelConfig

FULL = ModelConfig(
    name="whisper-base", family="whisper",
    num_layers=6, encoder_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865, audio_frames=1500,
    max_position=1 << 16, num_stages=1, dtype="bfloat16", remat=True,
)
REDUCED = ModelConfig(
    name="whisper-smoke", family="whisper",
    num_layers=2, encoder_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, audio_frames=30, max_position=4096,
)
SHARDING_MODE = "dp_tp"
LONG_CONTEXT = None  # skipped: whisper's decoder context is architecturally bounded
