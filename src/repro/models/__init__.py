"""Model zoo: functional JAX implementations of the assigned architectures."""
from .model import Model, ModelConfig, build_model, param_count, active_param_count

__all__ = ["Model", "ModelConfig", "build_model", "param_count",
           "active_param_count"]
