"""Dense decoder-only transformer family.

Covers: qwen2-0.5b / qwen2.5-3b (GQA + QKV bias, tied embeddings),
qwen3-32b (GQA + qk-norm), llama3-405b (GQA, 128k vocab), phi3-class text
backbones, and the GPT-2 family used for the paper-fidelity experiments
(LayerNorm + plain GeLU + learned positions).

Layers are stacked per virtual pipeline stage and executed with
``jax.lax.scan`` — one HLO body per stage regardless of depth (126-layer
llama lowers in seconds), and the per-stage parameter leaves are exactly the
granularity EDGC's DAC assigns ranks to.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


# ----------------------------------------------------------------------- init
def _block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    dt = cfg.jdtype
    p: dict[str, Any] = {
        "attn_norm_scale": jnp.ones((cfg.d_model,), dt),
        "attn": L.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.hd, dt, cfg.qkv_bias, cfg.qk_norm),
        "mlp_norm_scale": jnp.ones((cfg.d_model,), dt),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt,
                          gated=cfg.act in ("silu", "gelu"),
                          bias=cfg.norm == "layernorm"),
    }
    if cfg.norm == "layernorm":
        p["attn_norm_bias"] = jnp.zeros((cfg.d_model,), dt)
        p["mlp_norm_bias"] = jnp.zeros((cfg.d_model,), dt)
    return p


def _stack_init(key, cfg: ModelConfig, n: int):
    """n stacked blocks: every leaf gains a leading layer dim."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, cfg))(keys)


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.num_stages + 3)
    dt = cfg.jdtype
    params: dict[str, Any] = {
        "embed": {"tok": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)},
        "stages": [
            {"blocks": _stack_init(ks[1 + s], cfg, sz)}
            for s, sz in enumerate(cfg.stage_sizes())
        ],
        "final_norm_scale": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.norm == "layernorm":
        params["final_norm_bias"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.pos == "learned":
        params["pos_embed"] = (jax.random.normal(ks[-2], (cfg.max_position, cfg.d_model), F32)
                               * 0.01).astype(dt)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[-1], cfg.d_model, cfg.vocab_size, dt)
    return params


# -------------------------------------------------------------------- forward
def _norm(x, p, prefix, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        return L.layer_norm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"], cfg.norm_eps)
    return L.rms_norm(x, p[f"{prefix}_scale"], cfg.norm_eps)


def _block_apply(bp, x, cfg: ModelConfig, positions, window: int):
    h = _norm(x, bp, "attn_norm", cfg)
    h = L.attn_apply(
        bp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=True, positions=positions,
        rope_theta=cfg.rope_theta, use_rope=(cfg.pos == "rope"),
        window=window, norm_eps=cfg.norm_eps, block_q=cfg.block_q,
    )
    x = x + h
    h = _norm(x, bp, "mlp_norm", cfg)
    h = L.mlp_apply(bp["mlp"], h, act="gelu" if "gelu" in cfg.act else "silu")
    return x + h


def apply_block_stack(blocks, x, cfg: ModelConfig, positions,
                      window: int | None = None, remat: bool | None = None):
    """Run one scanned stack of decoder blocks (one pipeline stage's worth).

    ``blocks`` is the stacked-params subtree (every leaf has a leading layer
    dim); this is the per-stage unit the pipeline subsystem executes on each
    pipe rank, and the loop body ``forward`` runs once per stage.
    """
    def body(h, bp):
        return _block_apply(bp, h, cfg, positions,
                            cfg.sliding_window if window is None else window), None
    if cfg.remat if remat is None else remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, blocks)
    return x


def _run_stages(params, x, cfg: ModelConfig, positions, window: int):
    for stage in params["stages"]:
        x = apply_block_stack(stage["blocks"], x, cfg, positions, window)
    return x


def embed_tokens(params, tokens, cfg: ModelConfig, offset=0):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    if cfg.pos == "learned":
        T = tokens.shape[-1]
        pos = jax.lax.dynamic_slice_in_dim(params["pos_embed"], offset, T, axis=0) \
            if isinstance(offset, int) else \
            jax.vmap(lambda o: jax.lax.dynamic_slice_in_dim(params["pos_embed"], o, T, 0))(offset)
        x = x + pos
    return x


def final_logits(params, x, cfg: ModelConfig):
    if cfg.norm == "layernorm":
        x = L.layer_norm(x, params["final_norm_scale"], params["final_norm_bias"], cfg.norm_eps)
    else:
        x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    w = params["embed"]["tok"] if cfg.tie_embeddings else params["lm_head"]
    return L.lm_logits(x, w, tie=cfg.tie_embeddings)


def forward(params, batch, cfg: ModelConfig, window: int | None = None):
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = embed_tokens(params, tokens, cfg)
    x = _run_stages(params, x, cfg, positions,
                    cfg.sliding_window if window is None else window)
    return final_logits(params, x, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


# --------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    """Stacked KV cache per stage + the absolute length counter."""
    C = cfg.sliding_window if cfg.sliding_window > 0 else max_len
    dt = cfg.jdtype
    caches = []
    for sz in cfg.stage_sizes():
        caches.append({
            "k": jnp.zeros((sz, batch_size, C, cfg.num_kv_heads, cfg.hd), dt),
            "v": jnp.zeros((sz, batch_size, C, cfg.num_kv_heads, cfg.hd), dt),
        })
    return {"stages": caches, "len": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One token for the whole batch. tokens: (B,) int32."""
    B = tokens.shape[0]
    cache_len = cache["len"]
    x = embed_tokens(params, tokens[:, None], cfg, offset=cache_len)
    new_stage_caches = []
    for stage, sc in zip(params["stages"], cache["stages"]):
        def body(h, inp):
            bp, ck, cv = inp
            hn = _norm(h, bp, "attn_norm", cfg)
            a, ck, cv = L.attn_decode(
                bp["attn"], hn, ck, cv, cache_len,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd, rope_theta=cfg.rope_theta,
                use_rope=(cfg.pos == "rope"), window=cfg.sliding_window,
                norm_eps=cfg.norm_eps,
            )
            h = h + a
            hn = _norm(h, bp, "mlp_norm", cfg)
            h = h + L.mlp_apply(bp["mlp"], hn, act="gelu" if "gelu" in cfg.act else "silu")
            return h, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, (stage["blocks"], sc["k"], sc["v"]))
        new_stage_caches.append({"k": ks, "v": vs})
    logits = final_logits(params, x, cfg)[:, 0]
    return logits, {"stages": new_stage_caches, "len": cache_len + 1}


# -------------------------------------------------------------------- registry
@register_family("dense")
def _build(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: init(key, cfg),
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        forward=lambda p, b: forward(p, b, cfg),
        init_cache=lambda bs, max_len=None: init_cache(
            cfg, bs, max_len if max_len else 32768),
        decode_step=lambda p, c, t: decode_step(p, c, t, cfg),
    )
