"""Mixture-of-Experts decoder family (kimi-k2-1t, qwen3-moe-235b).

GShard-style dispatch: tokens are flattened and re-grouped into fixed-size
groups; each group builds a (S, E, C) dispatch/combine pair via top-k routing
with a capacity factor. The dispatch tensors are the standard trade-off —
O(S * E * C) transient memory per group, chosen so a group's dispatch fits
VMEM-scale buffers — and the expert FFN is three batched einsums over the
(E, d, f) expert stacks, which shard cleanly over the 'model' mesh axis
(expert parallelism) under GSPMD.

Router aux loss: Switch-style load balancing E * sum_e f_e * p_e.
EDGC note: expert weights are 3-D (E, d, f) leaves -> compressed per-expert
by the batched PowerSGD path; the router itself is excluded (small + routing
noise sensitivity), matching DESIGN §4.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


# ----------------------------------------------------------------------- init
def moe_ffn_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    dt = cfg.jdtype
    scale = 1.0 / jnp.sqrt(d)
    return {
        "router": (jax.random.normal(ks[0], (d, E), F32) * 0.02).astype(F32),
        "experts": {
            "gate": (jax.random.normal(ks[1], (E, d, f), F32) * scale).astype(dt),
            "up": (jax.random.normal(ks[2], (E, d, f), F32) * scale).astype(dt),
            "down": (jax.random.normal(ks[3], (E, f, d), F32) * (1.0 / jnp.sqrt(f))).astype(dt),
        },
    }


def _block_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    dt = cfg.jdtype
    return {
        "attn_norm_scale": jnp.ones((cfg.d_model,), dt),
        "attn": L.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.hd, dt, cfg.qkv_bias, cfg.qk_norm),
        "mlp_norm_scale": jnp.ones((cfg.d_model,), dt),
        "moe": moe_ffn_init(ks[1], cfg),
    }


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, cfg.num_stages + 2)
    dt = cfg.jdtype
    return {
        "embed": {"tok": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)},
        "stages": [
            {"blocks": jax.vmap(lambda k: _block_init(k, cfg))(jax.random.split(ks[1 + s], sz))}
            for s, sz in enumerate(cfg.stage_sizes())
        ],
        "final_norm_scale": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(ks[-1], cfg.d_model, cfg.vocab_size, dt),
    }


# ------------------------------------------------------------------- routing
def route(x_flat, ffn, cfg: ModelConfig, group_size: int, capacity: int | None = None):
    """Top-k dispatch/combine for flattened tokens (N, d).

    Returns (grouped tokens (G,S,d), dispatch (G,S,E,C), combine (G,S,E,C),
    aux loss scalar). ``capacity`` overrides the capacity-factor rule
    (decode uses C = S so no token is ever dropped).
    """
    N, d = x_flat.shape
    E, k, cf = cfg.num_experts, cfg.experts_per_token, cfg.capacity_factor
    S = min(group_size, N)
    G = max(1, N // S)
    xg = x_flat[: G * S].reshape(G, S, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(F32), ffn["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,S,E)
    top_vals, top_idx = jax.lax.top_k(probs, k)                  # (G,S,k)
    top_vals = top_vals / jnp.sum(top_vals, axis=-1, keepdims=True)

    C = capacity if capacity is not None else max(k, int(S * k / E * cf))
    loc = jnp.zeros((G, E), jnp.int32)
    dispatch = jnp.zeros((G, S, E, C), jnp.bool_)
    combine = jnp.zeros((G, S, E, C), F32)
    for i in range(k):
        oh = jax.nn.one_hot(top_idx[..., i], E, dtype=jnp.int32)  # (G,S,E)
        pos = jnp.cumsum(oh, axis=1) - oh + loc[:, None, :]       # queue position
        loc = loc + jnp.sum(oh, axis=1)
        keep = (pos < C) & (oh > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=F32)
        d_i = keep[..., None] & (pos_oh > 0)
        dispatch = dispatch | d_i
        combine = combine + top_vals[..., i, None, None] * d_i.astype(F32)

    # Switch load-balance aux: E * sum_e fraction_e * mean_prob_e
    assign1 = jax.nn.one_hot(top_idx[..., 0], E, dtype=F32)
    f_e = jnp.mean(assign1, axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e)
    return xg, dispatch, combine, aux


def moe_ffn_apply(ffn, x, cfg: ModelConfig, group_size: int = 1024,
                  capacity: int | None = None):
    """x: (B, T, d) -> (B, T, d), plus the router aux loss."""
    B, T, d = x.shape
    x_flat = x.reshape(B * T, d)
    xg, dispatch, combine, aux = route(x_flat, ffn, cfg, group_size, capacity)
    G, S, E, C = combine.shape
    ein = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    w = ffn["experts"]
    gate = jnp.einsum("gecd,edf->gecf", ein, w["gate"], preferred_element_type=F32)
    up = jnp.einsum("gecd,edf->gecf", ein, w["up"], preferred_element_type=F32)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    eout = jnp.einsum("gecf,efd->gecd", h, w["down"], preferred_element_type=F32)
    yg = jnp.einsum("gsec,gecd->gsd", combine, eout.astype(F32))
    y = yg.reshape(G * S, d)
    if G * S < B * T:  # ragged tail (only when B*T is not a multiple of S)
        y = jnp.concatenate([y, jnp.zeros((B * T - G * S, d), y.dtype)], 0)
    return y.reshape(B, T, d).astype(x.dtype), aux


# -------------------------------------------------------------------- forward
def _block_apply(bp, x, cfg: ModelConfig, positions, window: int):
    h = L.rms_norm(x, bp["attn_norm_scale"], cfg.norm_eps)
    h = L.attn_apply(
        bp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=True, positions=positions,
        rope_theta=cfg.rope_theta, use_rope=True, window=window,
        norm_eps=cfg.norm_eps, block_q=cfg.block_q,
    )
    x = x + h
    h = L.rms_norm(x, bp["mlp_norm_scale"], cfg.norm_eps)
    h, aux = moe_ffn_apply(bp["moe"], h, cfg, group_size=cfg.moe_group)
    return x + h, aux


def forward(params, batch, cfg: ModelConfig, return_aux: bool = False):
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    aux_total = jnp.zeros((), F32)
    for stage in params["stages"]:
        def body(carry, bp):
            h, aux_acc = carry
            h, aux = _block_apply(bp, h, cfg, positions, cfg.sliding_window)
            return (h, aux_acc + aux), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stage["blocks"])
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = L.lm_logits(x, params["lm_head"], tie=False)
    if return_aux:
        return logits, aux_total / max(1, cfg.num_layers)
    return logits


def loss_fn(params, batch, cfg: ModelConfig):
    logits, aux = forward(params, batch, cfg, return_aux=True)
    ce = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"loss": ce, "aux": aux}


# --------------------------------------------------------------------- decode
def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    C = cfg.sliding_window if cfg.sliding_window > 0 else max_len
    dt = cfg.jdtype
    return {
        "stages": [
            {"k": jnp.zeros((sz, batch_size, C, cfg.num_kv_heads, cfg.hd), dt),
             "v": jnp.zeros((sz, batch_size, C, cfg.num_kv_heads, cfg.hd), dt)}
            for sz in cfg.stage_sizes()
        ],
        "len": jnp.zeros((), jnp.int32),
    }


def decode_step(params, cache, tokens, cfg: ModelConfig):
    B = tokens.shape[0]
    cache_len = cache["len"]
    x = jnp.take(params["embed"]["tok"], tokens[:, None], axis=0)
    new_caches = []
    for stage, sc in zip(params["stages"], cache["stages"]):
        def body(h, inp):
            bp, ck, cv = inp
            hn = L.rms_norm(h, bp["attn_norm_scale"], cfg.norm_eps)
            a, ck, cv = L.attn_decode(
                bp["attn"], hn, ck, cv, cache_len,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd, rope_theta=cfg.rope_theta, use_rope=True,
                window=cfg.sliding_window, norm_eps=cfg.norm_eps,
            )
            h = h + a
            hn = L.rms_norm(h, bp["mlp_norm_scale"], cfg.norm_eps)
            # decode: full capacity (C = B) so no token is ever dropped
            y, _ = moe_ffn_apply(bp["moe"], hn, cfg, group_size=B, capacity=B)
            return h + y, (ck, cv)
        x, (ks, vs) = jax.lax.scan(body, x, (stage["blocks"], sc["k"], sc["v"]))
        new_caches.append({"k": ks, "v": vs})
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = L.lm_logits(x, params["lm_head"], tie=False)[:, 0]
    return logits, {"stages": new_caches, "len": cache_len + 1}


@register_family("moe")
def _build(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: init(key, cfg),
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        forward=lambda p, b: forward(p, b, cfg),
        init_cache=lambda bs, max_len=32768: init_cache(cfg, bs, max_len),
        decode_step=lambda p, c, t: decode_step(p, c, t, cfg),
    )
