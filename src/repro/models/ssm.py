"""SSM / recurrent families: xLSTM (mLSTM + sLSTM blocks) and Mamba2 blocks.

The shared compute core is a *chunked linear recurrence*

    S_t = a_t * S_{t-1} + k_t (x) v_t          (matrix state per head)
    y_t = q_t . S_t

evaluated chunk-parallel: intra-chunk terms are an attention-like product
with a decay mask D_ts = exp(Lambda_t - Lambda_s) (Lambda = cumsum log a),
inter-chunk terms flow through a ``lax.scan`` over chunk states. This is the
TPU-native adaptation (DESIGN §3): the intra-chunk part is MXU matmuls over
(chunk x chunk) tiles; the sequential scan touches T/chunk steps instead
of T. mLSTM (xLSTM) and Mamba2 (SSD) both lower onto this helper —
mLSTM adds a normalizer channel, Mamba2 derives its decay from dt*A.

Numerics note (documented deviation): mLSTM's exponential input gate is run
through a sigmoid-stabilized form (i_t = sigmoid(i_raw)) in the chunked path;
the sLSTM path implements the paper's true exponential gating with the m_t
stabilizer state, which is well-defined in its sequential scan.

Decode: all blocks carry O(1)-per-token recurrent state (matrix state +
conv tail), which is why the SSM archs run ``long_500k`` natively.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


# ------------------------------------------------------------- linear recurrence
def chunked_linear_recurrence(q, k, v, log_a, chunk: int, s0=None):
    """y_t = q_t . S_t with S_t = a_t S_{t-1} + k_t (x) v_t, chunk-parallel.

    q, k: (B, T, H, Dk); v: (B, T, H, Dv); log_a: (B, T, H) (<= 0).
    Returns (y (B, T, H, Dv), S_final (B, H, Dk, Dv)).
    T must be a multiple of ``chunk`` (callers pad).
    """
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    assert T % chunk == 0, (T, chunk)
    N = T // chunk
    qc = q.reshape(B, N, chunk, H, Dk)
    kc = k.reshape(B, N, chunk, H, Dk)
    vc = v.reshape(B, N, chunk, H, Dv)
    la = log_a.reshape(B, N, chunk, H).astype(F32)
    La = jnp.cumsum(la, axis=2)                       # (B,N,C,H) inclusive

    # intra-chunk: D_ts = exp(La_t - La_s) for s <= t (t,s within chunk)
    scores = jnp.einsum("bnthk,bnshk->bnhts", qc.astype(F32), kc.astype(F32))
    ldiff = La[..., :, None, :] - La[..., None, :, :]  # (B,N,t,s,H)... fix axes
    ldiff = jnp.transpose(ldiff, (0, 1, 4, 2, 3))      # (B,N,H,t,s)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tri, jnp.exp(ldiff), 0.0)
    y_intra = jnp.einsum("bnhts,bnshv->bnthv", scores * decay, vc.astype(F32))

    # inter-chunk: scan over chunk-final states
    if s0 is None:
        s0 = jnp.zeros((B, H, Dk, Dv), F32)
    La_end = La[:, :, -1, :]                           # (B,N,H)
    # per-chunk input to the state: sum_s exp(La_end - La_s) k_s v_s
    w = jnp.exp(La_end[:, :, None, :] - La)            # (B,N,C,H)
    kw = kc.astype(F32) * w[..., None]
    chunk_in = jnp.einsum("bnshk,bnshv->bnhkv", kw, vc.astype(F32))
    chunk_decay = jnp.exp(La_end)                      # (B,N,H)

    def body(s, inp):
        cin, cdec = inp                                # (B,H,Dk,Dv), (B,H)
        s_prev = s
        s = cdec[..., None, None] * s + cin
        return s, s_prev

    # scan over the chunk axis: move N to the front
    s_final, s_prevs = jax.lax.scan(
        body, s0,
        (jnp.moveaxis(chunk_in, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)              # (B,N,H,Dk,Dv) state at chunk start
    qw = qc.astype(F32) * jnp.exp(La)[..., None]       # q_t scaled by decay from chunk start
    y_cross = jnp.einsum("bnthk,bnhkv->bnthv", qw, s_prevs)

    y = (y_intra + y_cross).reshape(B, T, H, Dv)
    return y, s_final


def recurrence_decode(q, k, v, log_a, s):
    """One-token update: q,k (B,H,Dk), v (B,H,Dv), log_a (B,H), s (B,H,Dk,Dv)."""
    a = jnp.exp(log_a.astype(F32))[..., None, None]
    s = a * s + jnp.einsum("bhk,bhv->bhkv", k.astype(F32), v.astype(F32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(F32), s)
    return y, s


# ---------------------------------------------------------------- causal conv
def causal_conv_init(key, channels: int, kernel: int, dtype):
    return {"w": (jax.random.normal(key, (kernel, channels), F32) / math.sqrt(kernel)).astype(dtype),
            "b": jnp.zeros((channels,), dtype)}


def causal_conv_apply(p, x):
    """Depthwise causal conv along T. x: (B, T, C)."""
    k = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1]] * p["w"][i].astype(F32) for i in range(k))
    return (out + p["b"].astype(F32)).astype(x.dtype)


def causal_conv_decode(p, x_t, tail):
    """x_t: (B, C) new input; tail: (B, k-1, C) previous inputs."""
    k = p["w"].shape[0]
    window = jnp.concatenate([tail, x_t[:, None]], axis=1)      # (B,k,C)
    out = jnp.einsum("bkc,kc->bc", window.astype(F32), p["w"].astype(F32))
    out = out + p["b"].astype(F32)
    return out.astype(x_t.dtype), window[:, 1:]


# ======================================================================= mLSTM
def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner = 2 * d
    H = cfg.num_heads
    dh = d_inner // H
    ks = jax.random.split(key, 8)
    dt = cfg.jdtype
    return {
        "norm_scale": jnp.ones((d,), dt),
        "up_x": L.dense_init(ks[0], d, d_inner, dt),
        "up_z": L.dense_init(ks[1], d, d_inner, dt),
        "conv": causal_conv_init(ks[2], d_inner, cfg.conv_kernel, dt),
        "wq": L.dense_init(ks[3], d_inner, d_inner, dt),
        "wk": L.dense_init(ks[4], d_inner, d_inner, dt),
        "wv": L.dense_init(ks[5], d_inner, d_inner, dt),
        "w_gates": L.dense_init(ks[6], d_inner, 2 * H, dt),  # i, f per head
        "gate_bias": jnp.concatenate([jnp.zeros((H,), F32), 3.0 * jnp.ones((H,), F32)]).astype(F32),
        "head_norm_scale": jnp.ones((d_inner,), dt),
        "down": L.dense_init(ks[7], d_inner, d, dt),
    }


def _mlstm_qkv_gates(p, xc, xz, H: int):
    """Shared by train and decode: q,k,v heads + per-head log decay/input gate."""
    d_inner = xc.shape[-1]
    dh = d_inner // H
    q = jnp.einsum("...d,de->...e", xc, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("...d,de->...e", xc, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("...d,de->...e", xz, p["wv"], preferred_element_type=F32)
    gates = jnp.einsum("...d,de->...e", xc, p["w_gates"], preferred_element_type=F32)
    gates = gates + p["gate_bias"]
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)        # (..., H)
    i_gate = jax.nn.sigmoid(i_raw)                     # stabilized input gate
    log_a = jax.nn.log_sigmoid(f_raw)                  # log forget/decay
    shape = xc.shape[:-1] + (H, dh)
    scale = 1.0 / math.sqrt(dh)
    return (q.reshape(shape) * scale, k.reshape(shape) * i_gate[..., None],
            v.reshape(shape), log_a)


def mlstm_apply(p, x, cfg: ModelConfig):
    """x: (B, T, d). Matrix-memory LSTM with normalizer channel."""
    B, T, d = x.shape
    H = cfg.num_heads
    h = L.rms_norm(x, p["norm_scale"], cfg.norm_eps)
    xz = jnp.einsum("btd,de->bte", h, p["up_z"], preferred_element_type=F32).astype(x.dtype)
    xc = jnp.einsum("btd,de->bte", h, p["up_x"], preferred_element_type=F32).astype(x.dtype)
    xc = jax.nn.silu(causal_conv_apply(p["conv"], xc).astype(F32)).astype(x.dtype)
    q, k, v, log_a = _mlstm_qkv_gates(p, xc, xz, H)
    # normalizer channel: append ones to v
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    pad = (-T) % cfg.chunk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v_aug, log_a = map(zpad, (q, k, v_aug, log_a))
    y_aug, _ = chunked_linear_recurrence(q, k, v_aug, log_a, cfg.chunk)
    y_aug = y_aug[:, :T]
    y, norm = y_aug[..., :-1], y_aug[..., -1:]
    y = y / jnp.maximum(jnp.abs(norm), 1.0)
    y = y.reshape(B, T, -1).astype(x.dtype)
    y = L.rms_norm(y, p["head_norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(xz.astype(F32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["down"], preferred_element_type=F32)
    return x + out.astype(x.dtype)


def mlstm_decode(p, x_t, state, cfg: ModelConfig):
    """x_t: (B, d); state: {'s': (B,H,Dk,Dv+1), 'conv': (B,k-1,d_inner)}."""
    B, d = x_t.shape
    H = cfg.num_heads
    h = L.rms_norm(x_t, p["norm_scale"], cfg.norm_eps)
    xz = jnp.einsum("bd,de->be", h, p["up_z"], preferred_element_type=F32).astype(x_t.dtype)
    xc = jnp.einsum("bd,de->be", h, p["up_x"], preferred_element_type=F32).astype(x_t.dtype)
    xc, conv_tail = causal_conv_decode(p["conv"], xc, state["conv"])
    xc = jax.nn.silu(xc.astype(F32)).astype(x_t.dtype)
    q, k, v, log_a = _mlstm_qkv_gates(p, xc, xz, H)
    v_aug = jnp.concatenate([v, jnp.ones(v.shape[:-1] + (1,), v.dtype)], axis=-1)
    y_aug, s = recurrence_decode(q, k, v_aug, log_a, state["s"])
    y, norm = y_aug[..., :-1], y_aug[..., -1:]
    y = (y / jnp.maximum(jnp.abs(norm), 1.0)).reshape(B, -1).astype(x_t.dtype)
    y = L.rms_norm(y, p["head_norm_scale"], cfg.norm_eps)
    y = y * jax.nn.silu(xz.astype(F32)).astype(x_t.dtype)
    out = jnp.einsum("be,ed->bd", y, p["down"], preferred_element_type=F32)
    return x_t + out.astype(x_t.dtype), {"s": s, "conv": conv_tail}


def mlstm_state_init(cfg: ModelConfig, batch: int):
    d_inner = 2 * cfg.d_model
    H = cfg.num_heads
    dh = d_inner // H
    return {
        "s": jnp.zeros((batch, H, dh, dh + 1), F32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner), cfg.jdtype),
    }


# ======================================================================= sLSTM
def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 7)
    dt = cfg.jdtype
    d_ff = int(d * 4 / 3 / 2) * 2  # xLSTM proj factor 4/3, even
    return {
        "norm_scale": jnp.ones((d,), dt),
        "w_in": L.dense_init(ks[0], d, 4 * d, dt),          # z, i, f, o pre-acts
        "r_blocks": (jax.random.normal(ks[1], (H, dh, 4 * dh), F32)
                     / math.sqrt(dh)).astype(dt),           # block-diag recurrence
        "gate_bias": jnp.concatenate(
            [jnp.zeros((2 * d,), F32), 3.0 * jnp.ones((d,), F32), jnp.zeros((d,), F32)]
        ).astype(F32),
        "head_norm_scale": jnp.ones((d,), dt),
        "ffn_norm_scale": jnp.ones((d,), dt),
        "ffn": L.mlp_init(ks[2], d, d_ff, dt, gated=True),
    }


def _slstm_cell(p, x_pre, h_prev, c_prev, n_prev, m_prev, H: int):
    """One sLSTM step with true exponential gating + m stabilizer.

    x_pre: (B, 4d) input pre-activations; h_prev/c_prev/n_prev: (B, d);
    m_prev: (B, d) stabilizer. Returns (h, c, n, m).
    """
    B, d4 = x_pre.shape
    d = d4 // 4
    dh = d // H
    hh = h_prev.reshape(B, H, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh.astype(F32), p["r_blocks"].astype(F32))
    pre = x_pre.astype(F32) + rec.reshape(B, 4 * d) + p["gate_bias"]
    z_raw, i_raw, f_raw, o_raw = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_raw)
    o = jax.nn.sigmoid(o_raw)
    log_f = jax.nn.log_sigmoid(f_raw)          # exp-gate via log-sigmoid form
    m = jnp.maximum(log_f + m_prev, i_raw)
    i_s = jnp.exp(i_raw - m)
    f_s = jnp.exp(log_f + m_prev - m)
    c = f_s * c_prev + i_s * z
    n = f_s * n_prev + i_s
    h = o * c / jnp.maximum(n, 1e-6)
    return h, c, n, m


def slstm_apply(p, x, cfg: ModelConfig):
    """x: (B, T, d) — sequential scan over T (sLSTM is inherently recurrent)."""
    B, T, d = x.shape
    H = cfg.num_heads
    hx = L.rms_norm(x, p["norm_scale"], cfg.norm_eps)
    x_pre = jnp.einsum("btd,de->bte", hx, p["w_in"], preferred_element_type=F32)

    def step(carry, xp):
        h_prev, c, n, m = carry
        h, c, n, m = _slstm_cell(p, xp, h_prev, c, n, m, H)
        return (h, c, n, m), h

    zeros = jnp.zeros((B, d), F32)
    init = (zeros, zeros, zeros, zeros - 10.0)
    _, hs = jax.lax.scan(step, init, jnp.moveaxis(x_pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)           # (B,T,d)
    y = L.rms_norm(y, p["head_norm_scale"], cfg.norm_eps)
    x = x + y
    h2 = L.rms_norm(x, p["ffn_norm_scale"], cfg.norm_eps)
    return x + L.mlp_apply(p["ffn"], h2, act="silu")


def slstm_decode(p, x_t, state, cfg: ModelConfig):
    """x_t: (B, d); state: dict h/c/n/m each (B, d)."""
    hx = L.rms_norm(x_t, p["norm_scale"], cfg.norm_eps)
    x_pre = jnp.einsum("bd,de->be", hx, p["w_in"], preferred_element_type=F32)
    h, c, n, m = _slstm_cell(p, x_pre, state["h"], state["c"], state["n"],
                             state["m"], cfg.num_heads)
    y = L.rms_norm(h.astype(x_t.dtype), p["head_norm_scale"], cfg.norm_eps)
    x = x_t + y
    h2 = L.rms_norm(x, p["ffn_norm_scale"], cfg.norm_eps)
    out = x + L.mlp_apply(p["ffn"], h2, act="silu")
    return out, {"h": h, "c": c, "n": n, "m": m}


def slstm_state_init(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), F32)
    return {"h": z, "c": z, "n": z, "m": z - 10.0}


# ================================================================ xLSTM model
def _pair_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"mlstm": mlstm_init(k1, cfg), "slstm": slstm_init(k2, cfg)}


def xlstm_stage_sizes(cfg: ModelConfig) -> list[int]:
    """(mLSTM, sLSTM) pairs per virtual pipeline stage, near-even split.

    The pair — not the layer — is the stage-assignable unit: splitting one
    would separate an mLSTM from its sLSTM partner.
    """
    from .model import near_even_split
    n_pairs = cfg.num_layers // 2
    return near_even_split(n_pairs, min(cfg.num_stages, n_pairs))


def xlstm_init(key, cfg: ModelConfig):
    assert cfg.num_layers % 2 == 0, "xlstm stacks (mLSTM, sLSTM) pairs"
    sizes = xlstm_stage_sizes(cfg)
    ks = jax.random.split(key, len(sizes) + 2)
    dt = cfg.jdtype
    return {
        "embed": {"tok": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)},
        "stages": [
            {"pairs": jax.vmap(lambda k: _pair_init(k, cfg))(
                jax.random.split(ks[1 + s], sz))}
            for s, sz in enumerate(sizes)
        ],
        "final_norm_scale": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(ks[-1], cfg.d_model, cfg.vocab_size, dt),
    }


def xlstm_all_pairs(params):
    """Concatenate the per-stage pair stacks back to one (n_pairs, ...) tree."""
    from .model import concat_stage_stacks
    return concat_stage_stacks([st["pairs"] for st in params["stages"]])


def xlstm_forward(params, batch, cfg: ModelConfig):
    x = jnp.take(params["embed"]["tok"], batch["tokens"], axis=0)

    def body(h, pair):
        h = mlstm_apply(pair["mlstm"], h, cfg)
        h = slstm_apply(pair["slstm"], h, cfg)
        return h, None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, xlstm_all_pairs(params))
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    return L.lm_logits(x, params["lm_head"], tie=False)


def xlstm_loss(params, batch, cfg: ModelConfig):
    logits = xlstm_forward(params, batch, cfg)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


def xlstm_cache_init(cfg: ModelConfig, batch: int):
    n_pairs = cfg.num_layers // 2
    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_pairs,) + a.shape), tree)
    return {
        "mlstm": stack(mlstm_state_init(cfg, batch)),
        "slstm": stack(slstm_state_init(cfg, batch)),
        "len": jnp.zeros((), jnp.int32),
    }


def xlstm_decode(params, cache, tokens, cfg: ModelConfig):
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)   # (B, d)

    def body(h, inp):
        pair, ms, ss = inp
        h2, ms = mlstm_decode(pair["mlstm"], h, ms, cfg)
        h3, ss = slstm_decode(pair["slstm"], h2, ss, cfg)
        return h3, (ms, ss)

    x, (ms, ss) = jax.lax.scan(
        body, x, (xlstm_all_pairs(params), cache["mlstm"], cache["slstm"]))
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"], preferred_element_type=F32)
    return logits, {"mlstm": ms, "slstm": ss, "len": cache["len"] + 1}


@register_family("xlstm")
def _build_xlstm(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: xlstm_init(key, cfg),
        loss_fn=lambda p, b: xlstm_loss(p, b, cfg),
        forward=lambda p, b: xlstm_forward(p, b, cfg),
        init_cache=lambda bs, max_len=0: xlstm_cache_init(cfg, bs),
        decode_step=lambda p, c, t: xlstm_decode(p, c, t, cfg),
    )


# ====================================================================== Mamba2
def mamba2_init(key, cfg: ModelConfig):
    d = cfg.d_model
    d_inner = 2 * d
    n = cfg.ssm_state
    H = d_inner // 64                     # headdim 64 (Mamba2 default)
    ks = jax.random.split(key, 6)
    dt = cfg.jdtype
    return {
        "norm_scale": jnp.ones((d,), dt),
        "in_proj": L.dense_init(ks[0], d, 2 * d_inner + 2 * n + H, dt),
        "conv": causal_conv_init(ks[1], d_inner + 2 * n, cfg.conv_kernel, dt),
        "a_log": jnp.zeros((H,), F32),                       # A = -exp(a_log)
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(F32),
        "d_skip": jnp.ones((H,), F32),
        "out_norm_scale": jnp.ones((d_inner,), dt),
        "out_proj": L.dense_init(ks[2], d_inner, d, dt),
    }


def _mamba2_project(p, h, cfg: ModelConfig):
    d = cfg.d_model
    d_inner = 2 * d
    n = cfg.ssm_state
    H = d_inner // 64
    zxbcdt = jnp.einsum("...d,de->...e", h, p["in_proj"], preferred_element_type=F32)
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner: 2 * d_inner + 2 * n].astype(h.dtype)
    dt_raw = zxbcdt[..., -H:]
    return z, xbc, dt_raw


def _mamba2_ssm_inputs(p, xbc, dt_raw, cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    n = cfg.ssm_state
    H = d_inner // 64
    x = xbc[..., :d_inner]
    b = xbc[..., d_inner: d_inner + n]
    c = xbc[..., d_inner + n:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])            # (..., H) > 0
    log_a = -dt * jnp.exp(p["a_log"])                      # (..., H) <= 0
    shape = x.shape[:-1] + (H, 64)
    xh = x.reshape(shape)
    # B/C shared across heads (n_groups=1); input scaled by dt per head
    k = jnp.broadcast_to(b[..., None, :], x.shape[:-1] + (H, n))
    q = jnp.broadcast_to(c[..., None, :], x.shape[:-1] + (H, n))
    v = xh * dt[..., None]
    return q, k, v, log_a, xh


def mamba2_apply(p, x, cfg: ModelConfig):
    B, T, d = x.shape
    h = L.rms_norm(x, p["norm_scale"], cfg.norm_eps)
    z, xbc, dt_raw = _mamba2_project(p, h, cfg)
    xbc = jax.nn.silu(causal_conv_apply(p["conv"], xbc).astype(F32)).astype(x.dtype)
    q, k, v, log_a, xh = _mamba2_ssm_inputs(p, xbc, dt_raw, cfg)
    pad = (-T) % cfg.chunk
    if pad:
        zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v, log_a = map(zp, (q, k, v, log_a))
    y, _ = chunked_linear_recurrence(q, k, v, log_a, cfg.chunk)
    y = y[:, :T] + p["d_skip"][:, None] * xh.astype(F32)   # D skip per head
    y = y.reshape(B, T, -1)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y.astype(x.dtype), p["out_norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"], preferred_element_type=F32)
    return x + out.astype(x.dtype)


def mamba2_decode(p, x_t, state, cfg: ModelConfig):
    """x_t: (B, d); state: {'s': (B,H,n,64), 'conv': (B,k-1,Cc)}."""
    h = L.rms_norm(x_t, p["norm_scale"], cfg.norm_eps)
    z, xbc, dt_raw = _mamba2_project(p, h, cfg)
    xbc, conv_tail = causal_conv_decode(p["conv"], xbc, state["conv"])
    xbc = jax.nn.silu(xbc.astype(F32)).astype(x_t.dtype)
    q, k, v, log_a, xh = _mamba2_ssm_inputs(p, xbc, dt_raw, cfg)
    y, s = recurrence_decode(q, k, v, log_a, state["s"])
    y = y + p["d_skip"][:, None] * xh.astype(F32)
    y = y.reshape(x_t.shape[0], -1)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y.astype(x_t.dtype), p["out_norm_scale"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"], preferred_element_type=F32)
    return x_t + out.astype(x_t.dtype), {"s": s, "conv": conv_tail}


def mamba2_state_init(cfg: ModelConfig, batch: int):
    d_inner = 2 * cfg.d_model
    n = cfg.ssm_state
    H = d_inner // 64
    return {
        "s": jnp.zeros((batch, H, n, 64), F32),
        "conv": jnp.zeros((batch, cfg.conv_kernel - 1, d_inner + 2 * n), cfg.jdtype),
    }
