"""Phi-3-vision family: a phi3-mini text decoder over stubbed patch embeddings.

Per the brief, the vision encoder (CLIP ViT) is a STUB: the batch provides
``patches`` (B, num_patches, d_vision=d_model here) — the projector output.
The model prepends a learned projector transform of the patches to the token
embeddings and runs the standard causal decoder (the patch prefix attends
bidirectionally among itself in real VLMs; we keep fully-causal ordering
with patches first, a common and valid simplification for decoder-only VLMs).

Training loss is computed on the text positions only. Decode shapes feed a
KV cache sized seq_len (text continues after the patch prefix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import transformer as TF
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


def init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    params = TF.init(k1, cfg)
    params["projector"] = {
        "w": L.dense_init(k2, cfg.d_model, cfg.d_model, cfg.jdtype),
        "b": jnp.zeros((cfg.d_model,), cfg.jdtype),
    }
    return params


def _embed_multimodal(params, patches, tokens, cfg: ModelConfig):
    """[projected patches ; token embeddings] -> (B, P+T, d)."""
    proj = jnp.einsum("bpd,de->bpe", patches, params["projector"]["w"],
                      preferred_element_type=F32)
    proj = (proj + params["projector"]["b"].astype(F32)).astype(patches.dtype)
    tok = jnp.take(params["embed"]["tok"], tokens, axis=0)
    return jnp.concatenate([proj, tok], axis=1)


def forward(params, batch, cfg: ModelConfig):
    """Returns logits over the TEXT positions only: (B, T, V)."""
    patches, tokens = batch["patches"], batch["tokens"]
    B, P, _ = patches.shape
    T = tokens.shape[1]
    x = _embed_multimodal(params, patches, tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(P + T), (B, P + T))
    x = TF._run_stages(params, x, cfg, positions, cfg.sliding_window)
    logits = TF.final_logits(params, x, cfg)
    return logits[:, P:]


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    # text decode continues after the patch prefix; cache spans both
    return TF.init_cache(cfg, batch, max_len)


def prefill_patches(params, cache, patches, cfg: ModelConfig):
    """Feed the patch prefix through the decode path in one pass.

    Serving engines prefill the image first, then decode text token by
    token; here we run the blockwise forward over patches and write its K/V
    into the cache via a scan of single-step decodes (kept simple — the
    serving engine uses forward() for bulk prefill instead).
    """
    raise NotImplementedError("use engine-level prefill via forward()")


def decode_step(params, cache, tokens, cfg: ModelConfig):
    return TF.decode_step(params, cache, tokens, cfg)


@register_family("vlm")
def _build(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: init(key, cfg),
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        forward=lambda p, b: forward(p, b, cfg),
        init_cache=lambda bs, max_len=32768: init_cache(cfg, bs, max_len),
        decode_step=lambda p, c, t: decode_step(p, c, t, cfg),
    )
