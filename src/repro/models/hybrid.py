"""Zamba2-style hybrid: Mamba2 backbone + a SHARED attention block.

Zamba2 [arXiv:2411.15242] interleaves Mamba2 layers with a single
parameter-shared full-attention block applied periodically through the depth.
We implement the assigned spec: ``num_layers`` Mamba2 layers grouped into
runs of ``attn_every``; after each full run the shared attention+MLP block
(one parameter set, reused) is applied. Parameters are shared; KV caches are
NOT (one per application site).

Param layout: the Mamba2 layers live under ``params['stages'][s]['mamba']``
(one stacked leaf tree per virtual pipeline stage) so the compressor's
``_layer_stage`` mapping and the pipeline stage adapter see the same
granularity as the dense/MoE families. Stage boundaries always fall on
GROUP boundaries (a run plus its shared-attention site stays whole — the
hybrid pipelining constraint), so per-stage layer counts are generally
RAGGED; ``stage_group_sizes`` is the single source of truth for the
group->stage assignment. The shared attention block is top-level
(``params['shared']``) — replicated across stages, like embeddings.

Decode carries: per-mamba-layer (SSM state + conv tail) and per-site KV
caches — all O(1) or O(window) per token, so long_500k runs natively.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from . import ssm
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


def _num_groups(cfg: ModelConfig) -> int:
    return (cfg.num_layers + cfg.attn_every - 1) // cfg.attn_every


def _group_sizes(cfg: ModelConfig) -> list[int]:
    from .model import near_even_split
    return near_even_split(cfg.num_layers, _num_groups(cfg))


def stage_group_sizes(cfg: ModelConfig, num_stages: int | None = None
                      ) -> list[list[int]]:
    """Per-stage list of mamba-run lengths (whole groups per stage).

    Groups are assigned to stages contiguously, near-even by group count;
    each group is one mamba run followed by a shared-attention site.
    """
    from .model import near_even_split
    sizes = _group_sizes(cfg)
    S = min(num_stages or cfg.num_stages, len(sizes))
    out, i = [], 0
    for n in near_even_split(len(sizes), S):
        out.append(sizes[i: i + n])
        i += n
    return out


def init(key, cfg: ModelConfig):
    plan = stage_group_sizes(cfg)
    ks = jax.random.split(key, len(plan) + 4)
    dt = cfg.jdtype
    stages = []
    for si, sizes in enumerate(plan):
        skeys = jax.random.split(ks[si], sum(sizes))
        stages.append(
            {"mamba": jax.vmap(lambda k: ssm.mamba2_init(k, cfg))(skeys)})
    shared_key1, shared_key2 = jax.random.split(ks[-4])
    return {
        "embed": {"tok": L.embed_init(ks[-3], cfg.vocab_size, cfg.d_model, dt)},
        "stages": stages,
        "shared": {
            "attn_norm_scale": jnp.ones((cfg.d_model,), dt),
            "attn": L.attn_init(shared_key1, cfg.d_model, cfg.num_heads,
                                cfg.num_kv_heads, cfg.hd, dt),
            "mlp_norm_scale": jnp.ones((cfg.d_model,), dt),
            "mlp": L.mlp_init(shared_key2, cfg.d_model, cfg.d_ff, dt, gated=True),
        },
        "final_norm_scale": jnp.ones((cfg.d_model,), dt),
        "lm_head": L.dense_init(ks[-1], cfg.d_model, cfg.vocab_size, dt),
    }


def _shared_apply(sp, x, cfg: ModelConfig, positions):
    h = L.rms_norm(x, sp["attn_norm_scale"], cfg.norm_eps)
    h = L.attn_apply(
        sp["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.hd, causal=True, positions=positions,
        rope_theta=cfg.rope_theta, use_rope=True, window=cfg.sliding_window,
        norm_eps=cfg.norm_eps, block_q=cfg.block_q,
    )
    x = x + h
    h = L.rms_norm(x, sp["mlp_norm_scale"], cfg.norm_eps)
    return x + L.mlp_apply(sp["mlp"], h, act="silu")


def forward(params, batch, cfg: ModelConfig):
    tokens = batch["tokens"]
    B, T = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    for stage, sizes in zip(params["stages"], stage_group_sizes(cfg)):
        off = 0
        for sz in sizes:
            mp = jax.tree_util.tree_map(lambda a: a[off: off + sz],
                                        stage["mamba"])
            off += sz

            def body(h, m):
                return ssm.mamba2_apply(m, h, cfg), None
            if cfg.remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, mp)
            x = _shared_apply(params["shared"], x, cfg, positions)
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    return L.lm_logits(x, params["lm_head"], tie=False)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    C = cfg.sliding_window if cfg.sliding_window > 0 else max_len
    dt = cfg.jdtype
    groups = []
    for sz in _group_sizes(cfg):
        st = ssm.mamba2_state_init(cfg, batch)
        groups.append({
            "mamba": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a, (sz,) + a.shape), st),
            "attn_k": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.hd), dt),
            "attn_v": jnp.zeros((batch, C, cfg.num_kv_heads, cfg.hd), dt),
        })
    return {"groups": groups, "len": jnp.zeros((), jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    cache_len = cache["len"]
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)     # (B, d)
    new_groups = []
    sp = params["shared"]
    gi = 0
    for stage, sizes in zip(params["stages"], stage_group_sizes(cfg)):
        off = 0
        for sz in sizes:
            gc = cache["groups"][gi]
            gi += 1
            mp = jax.tree_util.tree_map(lambda a: a[off: off + sz],
                                        stage["mamba"])
            off += sz

            def body(h, inp):
                m, st = inp
                h, st = ssm.mamba2_decode(m, h, st, cfg)
                return h, st
            x, new_mamba = jax.lax.scan(body, x, (mp, gc["mamba"]))
            # shared attention on the single token
            h = L.rms_norm(x[:, None], sp["attn_norm_scale"], cfg.norm_eps)
            a, ck, cv = L.attn_decode(
                sp["attn"], h, gc["attn_k"], gc["attn_v"], cache_len,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.hd, rope_theta=cfg.rope_theta, use_rope=True,
                window=cfg.sliding_window, norm_eps=cfg.norm_eps,
            )
            x1 = x[:, None] + a
            h = L.rms_norm(x1, sp["mlp_norm_scale"], cfg.norm_eps)
            x = (x1 + L.mlp_apply(sp["mlp"], h, act="silu"))[:, 0]
            new_groups.append({"mamba": new_mamba, "attn_k": ck, "attn_v": cv})
    x = L.rms_norm(x, params["final_norm_scale"], cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x, params["lm_head"], preferred_element_type=F32)
    return logits, {"groups": new_groups, "len": cache_len + 1}


@register_family("zamba")
def _build(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: init(key, cfg),
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        forward=lambda p, b: forward(p, b, cfg),
        init_cache=lambda bs, max_len=32768: init_cache(cfg, bs, max_len),
        decode_step=lambda p, c, t: decode_step(p, c, t, cfg),
    )
