"""Model registry: one ModelConfig dataclass + build_model() for every family.

``build_model(cfg)`` returns a :class:`Model` bundle with a uniform surface:

  * ``init(key) -> params``
  * ``loss_fn(params, batch) -> (loss, metrics)``   — training objective
  * ``forward(params, batch) -> logits``            — full-seq (prefill)
  * ``init_cache(batch_size) -> cache``             — decode state
  * ``decode_step(params, cache, tokens) -> (logits, cache)`` — ONE token

``batch`` is a dict: always ``tokens``/``labels`` (B, T); audio adds
``frames`` (B, S_audio, d_model) and VLM adds ``patches`` (B, P, d_model) —
the stubbed modality frontends per the brief.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def near_even_split(total: int, parts: int) -> list[int]:
    """Split ``total`` units into ``parts`` near-even contiguous groups —
    the one stage-assignment arithmetic every family layout shares."""
    base, extra = divmod(total, max(1, parts))
    return [base + (1 if i < extra else 0) for i in range(max(1, parts))]


def concat_stage_stacks(stacks: list[Any]) -> Any:
    """Concatenate per-stage stacked subtrees back to one (L, ...) tree
    (the flat forwards' inverse of the ``['stages'][s]`` relayout)."""
    if len(stacks) == 1:
        return stacks[0]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *stacks)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"        # dense | moe | xlstm | zamba | whisper | vlm
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0            # 0 -> d_model // num_heads
    num_stages: int = 1          # virtual pipeline stages (EDGC/DAC grouping)
    # dense options
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    act: str = "silu"            # silu (gated) | gelu (gated) | gelu_plain
    pos: str = "rope"            # rope | learned | none
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    sliding_window: int = 0      # 0 = full attention; >0 = window size
    max_position: int = 1 << 20
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group: int = 1024        # GShard dispatch group size (perf knob)
    # ssm / hybrid
    ssm_state: int = 0
    conv_kernel: int = 4
    chunk: int = 128             # chunk size for linear-recurrence scan
    attn_every: int = 6          # zamba: shared attn block cadence
    slstm_every: int = 2         # xlstm: every k-th block is sLSTM
    # whisper
    encoder_layers: int = 0
    audio_frames: int = 1500     # encoder positions after the conv stub
    # vlm
    num_patches: int = 576       # prepended image patch embeddings
    # numerics
    dtype: str = "float32"       # param/activation dtype
    block_q: int = 512           # attention query-block size
    remat: bool = False          # checkpoint each block (recompute in bwd)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def jdtype(self):
        return getattr(jnp, self.dtype)

    def stage_sizes(self) -> list[int]:
        """Split num_layers into num_stages near-even contiguous groups."""
        return near_even_split(self.num_layers, self.num_stages)


class Model(NamedTuple):
    config: ModelConfig
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, dict], tuple[jax.Array, dict]]
    forward: Callable[[Any, dict], jax.Array]
    init_cache: Callable[[int], Any]
    decode_step: Callable[[Any, Any, jax.Array], tuple[jax.Array, Any]]


_REGISTRY: dict[str, Callable[[ModelConfig], Model]] = {}


def register_family(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn
    return deco


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family not in _REGISTRY:
        # import side-effect registration
        from . import transformer, moe, ssm, hybrid, encdec, vlm  # noqa: F401
    if cfg.family not in _REGISTRY:
        raise KeyError(f"unknown model family {cfg.family!r}")
    return _REGISTRY[cfg.family](cfg)


def param_count(params: Any) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))


def active_param_count(cfg: ModelConfig, params: Any) -> int:
    """Active params per token (MoE: top-k of the expert population)."""
    total = param_count(params)
    if cfg.family != "moe" or cfg.num_experts == 0:
        return total
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    expert_leaves = sum(
        int(l.size) for kp, l in flat if "expert" in jax.tree_util.keystr(kp)
    )
    active_frac = cfg.experts_per_token / max(1, cfg.num_experts)
    return int(total - expert_leaves + expert_leaves * active_frac)
