"""Whisper-style encoder-decoder (audio family).

Per the brief, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: the batch provides precomputed frame embeddings
``frames`` of shape (B, audio_frames, d_model) — exactly what whisper's two
conv layers emit. We implement the transformer backbone: a bidirectional
encoder over frames (sinusoidal positions) and a causal decoder with
cross-attention (learned positions), trained with teacher forcing.

Decode: self-attn KV cache + *precomputed* cross-attention K/V (computed
once from the encoder output at cache init — the standard serving layout).

Param layout: enc/dec blocks live under ``params['stages'][s]`` —
encoder stages first, decoder stages after (``stage_layout``), matching
the pipeline-stage convention every family shares so the compressor's
stage mapping and the enc-dec ``StageAdapter`` see the same granularity.
``num_stages == 1`` keeps both halves in one stage; the forward
concatenates the per-stage stacks back, so compute is unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .model import Model, ModelConfig, register_family

F32 = jnp.float32


def _enc_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    dt = cfg.jdtype
    return {
        "attn_norm_scale": jnp.ones((cfg.d_model,), dt),
        "attn_norm_bias": jnp.zeros((cfg.d_model,), dt),
        "attn": L.attn_init(k1, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                            cfg.hd, dt),
        "mlp_norm_scale": jnp.ones((cfg.d_model,), dt),
        "mlp_norm_bias": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, dt, gated=False, bias=True),
    }


def _dec_block_init(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    p = _enc_block_init(jax.random.fold_in(key, 7), cfg)
    dt = cfg.jdtype
    p["cross_norm_scale"] = jnp.ones((cfg.d_model,), dt)
    p["cross_norm_bias"] = jnp.zeros((cfg.d_model,), dt)
    p["cross"] = L.attn_init(k3, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                             cfg.hd, dt)
    return p


def stage_layout(cfg: ModelConfig, num_stages: int | None = None
                 ) -> list[dict[str, int]]:
    """Per-stage {'enc': n, 'dec': n} layer counts.

    Encoder stages come first, decoder stages after (pipeline order: the
    cross-attention memory flows forward from the last encoder stage). The
    enc/dec split of the stage budget is proportional to layer counts;
    ``num_stages == 1`` keeps both halves in the single stage (the flat
    layout every non-pipelined whisper run uses).
    """
    Le = cfg.encoder_layers or cfg.num_layers
    Ld = cfg.num_layers
    S = max(1, num_stages or cfg.num_stages)
    if S == 1:
        return [{"enc": Le, "dec": Ld}]
    S = min(S, Le + Ld)
    s_e = int(round(S * Le / max(1, Le + Ld)))
    s_e = max(1, min(s_e, S - 1, Le))
    s_d = S - s_e
    if s_d > Ld:                      # more dec stages than dec layers
        s_d = Ld
        s_e = min(S - s_d, Le)
    from .model import near_even_split
    return ([{"enc": n, "dec": 0} for n in near_even_split(Le, s_e)]
            + [{"enc": 0, "dec": n} for n in near_even_split(Ld, s_d)])


def init(key, cfg: ModelConfig):
    layout = stage_layout(cfg)
    ks = jax.random.split(key, len(layout) + 4)
    dt = cfg.jdtype
    stages = []
    for si, counts in enumerate(layout):
        ke, kd = jax.random.split(ks[si])
        st = {}
        if counts["enc"]:
            st["enc_blocks"] = jax.vmap(lambda k: _enc_block_init(k, cfg))(
                jax.random.split(ke, counts["enc"]))
        if counts["dec"]:
            st["dec_blocks"] = jax.vmap(lambda k: _dec_block_init(k, cfg))(
                jax.random.split(kd, counts["dec"]))
        stages.append(st)
    return {
        "stages": stages,
        "enc_norm_scale": jnp.ones((cfg.d_model,), dt),
        "enc_norm_bias": jnp.zeros((cfg.d_model,), dt),
        "embed": {"tok": L.embed_init(ks[-3], cfg.vocab_size, cfg.d_model, dt)},
        "dec_pos": (jax.random.normal(ks[-2], (cfg.max_position, cfg.d_model), F32)
                    * 0.01).astype(dt),
        "final_norm_scale": jnp.ones((cfg.d_model,), dt),
        "final_norm_bias": jnp.zeros((cfg.d_model,), dt),
    }


def _cat_blocks(params, key: str):
    """Concatenate per-stage block stacks back to one (L, ...) tree."""
    from .model import concat_stage_stacks
    return concat_stage_stacks(
        [st[key] for st in params["stages"] if key in st])


def _ln(x, p, prefix, cfg):
    return L.layer_norm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"], cfg.norm_eps)


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, S, d) stubbed conv-frontend output."""
    B, S, d = frames.shape
    x = frames + L.sinusoidal_pos(S, d, frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, bp):
        a = _ln(h, bp, "attn_norm", cfg)
        a = L.attn_apply(bp["attn"], a, num_heads=cfg.num_heads,
                         num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                         causal=False, positions=positions, use_rope=False,
                         norm_eps=cfg.norm_eps, block_q=cfg.block_q)
        h = h + a
        m = _ln(h, bp, "mlp_norm", cfg)
        return h + L.mlp_apply(bp["mlp"], m, act="gelu"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, _cat_blocks(params, "enc_blocks"))
    return _ln(x, params, "enc_norm", cfg)


def decode_train(params, tokens, enc_out, cfg: ModelConfig):
    B, T = tokens.shape
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], 0, T, 0)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(h, bp):
        a = _ln(h, bp, "attn_norm", cfg)
        a = L.attn_apply(bp["attn"], a, num_heads=cfg.num_heads,
                         num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                         causal=True, positions=positions, use_rope=False,
                         norm_eps=cfg.norm_eps, block_q=cfg.block_q)
        h = h + a
        c = _ln(h, bp, "cross_norm", cfg)
        ek, ev = L.cross_kv(bp["cross"], enc_out, num_kv_heads=cfg.num_kv_heads,
                            head_dim=cfg.hd)
        c = L.cross_attn_apply(bp["cross"], c, ek, ev, num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd)
        h = h + c
        m = _ln(h, bp, "mlp_norm", cfg)
        return h + L.mlp_apply(bp["mlp"], m, act="gelu"), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, _cat_blocks(params, "dec_blocks"))
    x = _ln(x, params, "final_norm", cfg)
    return L.lm_logits(x, params["embed"]["tok"], tie=True)  # whisper ties


def forward(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    return decode_train(params, batch["tokens"], enc_out, cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss, {"loss": loss}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_out=None,
               frames=None, params=None):
    """Decode cache. If params+frames given, precompute cross K/V."""
    dt = cfg.jdtype
    Ld = cfg.num_layers
    S = cfg.audio_frames
    cache = {
        "k": jnp.zeros((Ld, batch, max_len, cfg.num_kv_heads, cfg.hd), dt),
        "v": jnp.zeros((Ld, batch, max_len, cfg.num_kv_heads, cfg.hd), dt),
        "cross_k": jnp.zeros((Ld, batch, S, cfg.num_kv_heads, cfg.hd), dt),
        "cross_v": jnp.zeros((Ld, batch, S, cfg.num_kv_heads, cfg.hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }
    if params is not None and (enc_out is not None or frames is not None):
        if enc_out is None:
            enc_out = encode(params, frames, cfg)
        cks, cvs = jax.vmap(
            lambda bp: L.cross_kv(bp["cross"], enc_out,
                                  num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd)
        )(_cat_blocks(params, "dec_blocks"))
        cache["cross_k"], cache["cross_v"] = cks, cvs
    return cache


def decode_step(params, cache, tokens, cfg: ModelConfig):
    B = tokens.shape[0]
    cache_len = cache["len"]
    x = jnp.take(params["embed"]["tok"], tokens[:, None], axis=0)
    pos = jax.lax.dynamic_slice_in_dim(params["dec_pos"], cache_len, 1, 0)
    x = x + pos

    def body(h, inp):
        bp, ck, cv, xk, xv = inp
        a = _ln(h, bp, "attn_norm", cfg)
        a, ck, cv = L.attn_decode(bp["attn"], a, ck, cv, cache_len,
                                  num_heads=cfg.num_heads,
                                  num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                                  use_rope=False, norm_eps=cfg.norm_eps)
        h = h + a
        c = _ln(h, bp, "cross_norm", cfg)
        c = L.cross_attn_apply(bp["cross"], c, xk, xv, num_heads=cfg.num_heads,
                               num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd)
        h = h + c
        m = _ln(h, bp, "mlp_norm", cfg)
        return h + L.mlp_apply(bp["mlp"], m, act="gelu"), (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (_cat_blocks(params, "dec_blocks"), cache["k"], cache["v"],
         cache["cross_k"], cache["cross_v"]))
    x = _ln(x, params, "final_norm", cfg)
    logits = L.lm_logits(x, params["embed"]["tok"], tie=True)[:, 0]
    new_cache = dict(cache)
    new_cache.update({"k": ks, "v": vs, "len": cache_len + 1})
    return logits, new_cache


@register_family("whisper")
def _build(cfg: ModelConfig) -> Model:
    return Model(
        config=cfg,
        init=lambda key: init(key, cfg),
        loss_fn=lambda p, b: loss_fn(p, b, cfg),
        forward=lambda p, b: forward(p, b, cfg),
        init_cache=lambda bs, max_len=448: init_cache(cfg, bs, max_len),
        decode_step=lambda p, c, t: decode_step(p, c, t, cfg),
    )
