"""Shared neural-net layers (pure functional JAX).

Conventions used across the zoo:

  * Params are nested dicts of jnp arrays; weight matrices are stored 2-D
    ``(in, out)`` (so the compressor's matricize is the identity) and
    homogeneous layer stacks carry a leading layer dim (scanned).
  * All matmuls accumulate in float32 (``preferred_element_type``) so bf16
    params are safe on the MXU target.
  * Attention is grouped-query (GQA) with optional qk-norm, qkv-bias,
    sliding window, RoPE or learned/sinusoidal positions; the prefill path
    is blockwise (online softmax) so 32k-token prefill never materializes a
    full (T x T) score matrix.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]
F32 = jnp.float32


# --------------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), F32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), F32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------- norms
def rms_norm(x, weight, eps: float = 1e-5):
    x32 = x.astype(F32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * weight.astype(F32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    x32 = x.astype(F32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(F32) + bias.astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=F32) / head_dim))


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., T, H, Dh); positions: (..., T) int32."""
    dh = x.shape[-1]
    inv = rope_freqs(dh, theta)                          # (Dh/2,)
    ang = positions[..., :, None].astype(F32) * inv      # (..., T, Dh/2)
    cos = jnp.cos(ang)[..., :, None, :]                  # (..., T, 1, Dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(T: int, d: int, dtype=F32):
    pos = jnp.arange(T, dtype=F32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=F32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((T, d), F32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe.astype(dtype)


# --------------------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, dtype, gated: bool = True, bias: bool = False):
    ks = jax.random.split(key, 3)
    p: Params = {
        "up": dense_init(ks[0], d_model, d_ff, dtype),
        "down": dense_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    if bias:
        p["up_bias"] = jnp.zeros((d_ff,), dtype)
        p["down_bias"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(p: Params, x, act: str = "silu"):
    up = jnp.einsum("...d,df->...f", x, p["up"], preferred_element_type=F32)
    if "up_bias" in p:
        up = up + p["up_bias"].astype(F32)
    if "gate" in p:
        gate = jnp.einsum("...d,df->...f", x, p["gate"], preferred_element_type=F32)
        h = jax.nn.silu(gate) * up if act == "silu" else jax.nn.gelu(gate) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    h = h.astype(x.dtype)
    out = jnp.einsum("...f,fd->...d", h, p["down"], preferred_element_type=F32)
    if "down_bias" in p:
        out = out + p["down_bias"].astype(F32)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- attention
def attn_init(
    key,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    dtype,
    qkv_bias: bool = False,
    qk_norm: bool = False,
):
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["q_bias"] = jnp.zeros((num_heads * head_dim,), dtype)
        p["k_bias"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
        p["v_bias"] = jnp.zeros((num_kv_heads * head_dim,), dtype)
    if qk_norm:
        p["q_norm_scale"] = jnp.ones((head_dim,), dtype)
        p["k_norm_scale"] = jnp.ones((head_dim,), dtype)
    return p


def _project_qkv(p, x, num_heads, num_kv_heads, head_dim, positions,
                 rope_theta, use_rope, norm_eps):
    B, T, _ = x.shape
    q = jnp.einsum("btd,de->bte", x, p["wq"], preferred_element_type=F32)
    k = jnp.einsum("btd,de->bte", x, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("btd,de->bte", x, p["wv"], preferred_element_type=F32)
    if "q_bias" in p:
        q = q + p["q_bias"].astype(F32)
        k = k + p["k_bias"].astype(F32)
        v = v + p["v_bias"].astype(F32)
    q = q.reshape(B, T, num_heads, head_dim)
    k = k.reshape(B, T, num_kv_heads, head_dim)
    v = v.reshape(B, T, num_kv_heads, head_dim).astype(x.dtype)
    if "q_norm_scale" in p:
        q = rms_norm(q, p["q_norm_scale"], norm_eps)
        k = rms_norm(k, p["k_norm_scale"], norm_eps)
    if use_rope:
        q = apply_rope(q.astype(x.dtype), positions, rope_theta)
        k = apply_rope(k.astype(x.dtype), positions, rope_theta)
    return q.astype(x.dtype), k.astype(x.dtype), v


def blockwise_attention(
    q, k, v, *, causal: bool, q_offset: int | jax.Array = 0,
    window: int = 0, block_q: int = 512,
):
    """Memory-efficient attention: scan over query blocks, online softmax.

    q: (B, Tq, H, Dh); k, v: (B, Tk, Hkv, Dh) with H a multiple of Hkv (GQA).
    ``q_offset`` is the absolute position of q[0] (prefill: 0; decode: cache
    length). ``window`` > 0 masks keys older than ``window`` (sliding-window
    attention). Never materializes more than (block_q x Tk) scores.
    """
    B, Tq, H, Dh = q.shape
    _, Tk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)

    # pad Tq to a multiple of block_q
    pad = (-Tq) % block_q
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nblk = q.shape[1] // block_q
    qb = q.reshape(B, nblk, block_q, H, Dh).transpose(1, 0, 2, 3, 4)

    k_pos = jnp.arange(Tk)

    def one_block(carry, inp):
        qi, blk_idx = inp
        q_pos = q_offset + blk_idx * block_q + jnp.arange(block_q)
        # scores: (B, H, block_q, Tk)
        qh = qi.reshape(B, block_q, Hkv, rep, Dh)
        # converts ride inside the dots (preferred_element_type) — casting
        # the operands would materialize f32 copies of K/V (see attn_decode)
        s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k,
                       preferred_element_type=F32) * scale
        mask = jnp.ones((block_q, Tk), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        # softmax weights downcast to the value dtype: a mixed f32xbf16 dot
        # makes XLA materialize (and under GSPMD, gather) an f32 copy of V
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v, preferred_element_type=F32)
        return carry, o.reshape(B, block_q, H, Dh).astype(v.dtype)

    _, outs = jax.lax.scan(one_block, None, (qb, jnp.arange(nblk)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nblk * block_q, H, Dh)
    return out[:, :Tq]


def attn_apply(
    p: Params, x, *, num_heads: int, num_kv_heads: int, head_dim: int,
    causal: bool = True, positions=None, rope_theta: float = 1e4,
    use_rope: bool = True, window: int = 0, norm_eps: float = 1e-5,
    block_q: int = 512,
):
    """Full-sequence (training / prefill) GQA attention."""
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_theta, use_rope, norm_eps)
    o = blockwise_attention(q, k, v, causal=causal, window=window, block_q=block_q)
    o = o.reshape(B, T, num_heads * head_dim)
    out = jnp.einsum("bte,ed->btd", o, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype)


def attn_decode(
    p: Params, x, cache_k, cache_v, cache_len, *, num_heads: int,
    num_kv_heads: int, head_dim: int, rope_theta: float = 1e4,
    use_rope: bool = True, window: int = 0, norm_eps: float = 1e-5,
):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, C, Hkv, Dh) where C = max context (full
    cache) or C = window (ring buffer); cache_len: scalar int32 = tokens
    already in the cache (absolute position of the new token).
    Returns (out (B,1,d), new_k, new_v).
    """
    B, _, _ = x.shape
    C = cache_k.shape[1]
    positions = jnp.broadcast_to(cache_len[None], (B, 1)) if jnp.ndim(cache_len) == 0 \
        else cache_len[:, None]
    q, k, v = _project_qkv(p, x, num_heads, num_kv_heads, head_dim,
                           positions, rope_theta, use_rope, norm_eps)
    slot = cache_len % C if window > 0 else cache_len
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    rep = num_heads // num_kv_heads
    qh = q.reshape(B, 1, num_kv_heads, rep, head_dim)
    # NOTE: do NOT .astype(F32) the cache operand — that materializes (and
    # under GSPMD, gathers) a full-width copy of the cache; the convert is
    # free inside the MXU op via preferred_element_type.
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, cache_k,
                   preferred_element_type=F32)
    s = s / math.sqrt(head_dim)
    k_idx = jnp.arange(C)
    if window > 0:
        # ring buffer: valid slots are the last min(cache_len+1, C) writes
        age = (slot - k_idx) % C
        valid = age <= jnp.minimum(cache_len, C - 1)
    else:
        valid = k_idx <= cache_len
    s = jnp.where(valid[None, None, None, None, :], s, -1e30)
    # same downcast rationale as blockwise_attention (avoids f32 V-cache copy)
    pattn = jax.nn.softmax(s, axis=-1).astype(cache_v.dtype)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", pattn, cache_v,
                   preferred_element_type=F32)
    o = o.reshape(B, 1, num_heads * head_dim).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", o, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype), cache_k, cache_v


def cross_attn_apply(p: Params, x, enc_k, enc_v, *, num_heads: int,
                     num_kv_heads: int, head_dim: int):
    """Cross-attention with precomputed encoder K/V (whisper decoder)."""
    B, T, _ = x.shape
    q = jnp.einsum("btd,de->bte", x, p["wq"], preferred_element_type=F32)
    q = q.reshape(B, T, num_heads, head_dim).astype(x.dtype)
    o = blockwise_attention(q, enc_k, enc_v, causal=False, block_q=min(512, max(T, 8)))
    o = o.reshape(B, T, num_heads * head_dim)
    out = jnp.einsum("bte,ed->btd", o, p["wo"], preferred_element_type=F32)
    return out.astype(x.dtype)


def cross_kv(p: Params, enc_out, *, num_kv_heads: int, head_dim: int):
    B, S, _ = enc_out.shape
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"], preferred_element_type=F32)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"], preferred_element_type=F32)
    return (k.reshape(B, S, num_kv_heads, head_dim).astype(enc_out.dtype),
            v.reshape(B, S, num_kv_heads, head_dim).astype(enc_out.dtype))


# --------------------------------------------------------------------------- head
def lm_logits(x, embed_or_head, tie: bool):
    """Final projection to vocab; tied uses the embedding transposed."""
    w = embed_or_head
    if tie:
        return jnp.einsum("btd,vd->btv", x, w, preferred_element_type=F32)
    return jnp.einsum("btd,dv->btv", x, w, preferred_element_type=F32)


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in nats; logits (B,T,V) fp32, labels (B,T) int32."""
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
