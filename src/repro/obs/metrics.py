"""Structured telemetry: a typed metrics registry with pluggable sinks.

The control plane computes rich per-step signals (per-stage entropy, DAC
ranks, wire bytes, EF norms, overlap placement, fault/recovery actions)
and, before this module, threw them away after an ad-hoc ``print``. The
:class:`MetricsRegistry` makes them first-class records:

  scalar   one float per step           (loss, pooled entropy, lr, coded
                                         vs raw wire-format bytes, ...)
  series   one list per step            (per-stage ranks, wire bytes, ...)
  counter  monotone cumulative count    (ef_resets, rollbacks, ...)
  event    structured occurrence        (fault_injected, plan_change,
                                         pod_drop, dryrun OK-line, ...)

Every record is one JSON-able dict ``{"kind", "name", "step", "wall",
...payload}`` delivered to every attached sink. Sinks are tiny:
:class:`JsonlSink` appends one JSON line per record (the run's on-disk
telemetry, consumed by ``repro.launch.report``), :class:`MemorySink`
collects them for test assertions, and :func:`write_csv` exports any
record list as CSV.

Device-sync discipline: ``scalar``/``series`` values may be live
``jax.Array``\\ s. The registry buffers records WITHOUT converting them —
one :func:`jax.block_until_ready` over everything pending runs at
``flush()``, so a training loop can emit every step and still only pay a
device-to-host sync at its flush boundaries (log/window edges).

The registry's cursor (last step, counters, emitted-record count) is a
``state_dict()`` the trainer serializes through checkpoint ``extra``:
a resumed run appends to its telemetry instead of restarting series at
step 0 (mirroring the DAC/CQM state handling).
"""
from __future__ import annotations

import csv
import json
import os
import time
from typing import Any, Iterable

__all__ = [
    "JsonlSink",
    "MemorySink",
    "MetricsRegistry",
    "write_csv",
    "read_jsonl",
]

RECORD_KINDS = ("scalar", "series", "counter", "event")


def _is_device_value(x: Any) -> bool:
    # jnp scalars/arrays (and anything exposing a pending computation).
    return hasattr(x, "block_until_ready") or hasattr(x, "addressable_shards")


def _to_host(x: Any) -> Any:
    if isinstance(x, (list, tuple)):
        return [_to_host(v) for v in x]
    if isinstance(x, (str, bool)) or x is None:
        return x
    if isinstance(x, int):
        return x
    try:
        import numpy as np
        a = np.asarray(x)
        if a.ndim == 0:
            v = a.item()
            return float(v) if isinstance(v, float) else v
        return a.tolist()
    except Exception:
        return x


class JsonlSink:
    """Append-mode JSONL file sink: one record per line.

    Append (not truncate) so a resumed run continues the same file — the
    registry's ``telemetry_resume`` event marks the boundary.
    """

    def __init__(self, path: str, mode: str = "a") -> None:
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, mode)

    def emit(self, record: dict) -> None:
        self._f.write(json.dumps(record) + "\n")

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.close()


class MemorySink:
    """In-memory sink for tests and benchmark harnesses."""

    def __init__(self) -> None:
        self.records: list[dict] = []

    def emit(self, record: dict) -> None:
        self.records.append(record)

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    # ---- query helpers (assertion-friendly views) -----------------------
    def of_kind(self, kind: str) -> list[dict]:
        return [r for r in self.records if r["kind"] == kind]

    def scalars(self, name: str) -> list[tuple[int, float]]:
        return [(r["step"], r["value"]) for r in self.of_kind("scalar")
                if r["name"] == name]

    def series(self, name: str) -> list[tuple[int, list]]:
        return [(r["step"], r["values"]) for r in self.of_kind("series")
                if r["name"] == name]

    def counters(self, name: str) -> list[tuple[int, int]]:
        return [(r["step"], r["value"]) for r in self.of_kind("counter")
                if r["name"] == name]

    def events(self, name: str | None = None) -> list[dict]:
        evs = self.of_kind("event")
        return evs if name is None else [r for r in evs if r["name"] == name]


def read_jsonl(path: str) -> list[dict]:
    """Load a JSONL telemetry file back into a record list."""
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def write_csv(records: Iterable[dict], path: str) -> str:
    """Export scalar/series/counter records as CSV (step,name,kind,value).

    Series values join with ';' so per-stage trajectories stay one row per
    step; event records are skipped (they are not tabular).
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["step", "name", "kind", "value"])
        for r in records:
            if r["kind"] == "event":
                continue
            val = (";".join(str(v) for v in r["values"])
                   if r["kind"] == "series" else r["value"])
            w.writerow([r["step"], r["name"], r["kind"], val])
    return path


class MetricsRegistry:
    """Typed emitters + deferred host conversion + cursor state.

    ``sinks`` may be empty: emitting stays cheap (dict construction only)
    and the cursor/counters still advance, so callers never need a null
    check. ``tags`` ride on every record (``with_tags`` derives a view
    that adds more — e.g. the elastic trainer tagging each pod's inner
    telemetry with its pod index).
    """

    def __init__(self, sinks: Iterable[Any] = (), *,
                 tags: dict | None = None, step: int = 0) -> None:
        self.sinks = list(sinks)
        self._tags = dict(tags or {})
        self._pending: list[dict] = []
        self._counters: dict[str, int] = {}
        self.last_step = step
        self.n_emitted = 0
        self._t0 = time.time()

    # ---------------------------------------------------------- emitters
    def _rec(self, kind: str, name: str, step: int | None,
             **payload: Any) -> None:
        if step is None:
            step = self.last_step
        self.last_step = max(self.last_step, int(step))
        rec = {"kind": kind, "name": name, "step": int(step),
               "wall": round(time.time() - self._t0, 6), **payload}
        if self._tags:
            rec.update(self._tags)
        self._pending.append(rec)

    def scalar(self, name: str, value: Any, step: int | None = None) -> None:
        self._rec("scalar", name, step, value=value)

    def series(self, name: str, values: Any, step: int | None = None) -> None:
        self._rec("series", name, step, values=values)

    def counter(self, name: str, inc: int = 1,
                step: int | None = None) -> int:
        total = self._counters.get(name, 0) + int(inc)
        self._counters[name] = total
        self._rec("counter", name, step, value=total, inc=int(inc))
        return total

    def event(self, name: str, step: int | None = None,
              **data: Any) -> None:
        self._rec("event", name, step, data=data)

    def with_tags(self, **tags: Any) -> "MetricsRegistry":
        """A write-through view adding ``tags`` to every record.

        The view shares this registry's sinks, counters, cursor, and
        pending buffer — ``state_dict``/``flush`` on either see the same
        state.
        """
        return _TaggedView(self, {**self._tags, **tags})

    # ------------------------------------------------------------- flush
    def flush(self) -> None:
        """Convert pending values to host (ONE batched device sync) and
        deliver them to every sink."""
        if not self._pending:
            for s in self.sinks:
                s.flush()
            return
        device_vals = []
        for rec in self._pending:
            for key in ("value", "values"):
                v = rec.get(key)
                if _is_device_value(v):
                    device_vals.append(v)
                elif isinstance(v, (list, tuple)):
                    device_vals.extend(x for x in v if _is_device_value(x))
        if device_vals:
            import jax
            jax.block_until_ready(device_vals)
        for rec in self._pending:
            if "value" in rec:
                rec["value"] = _to_host(rec["value"])
            if "values" in rec:
                rec["values"] = _to_host(rec["values"])
            if "data" in rec:
                rec["data"] = _to_host(rec["data"])
            for s in self.sinks:
                s.emit(rec)
        self.n_emitted += len(self._pending)
        self._pending.clear()
        for s in self.sinks:
            s.flush()

    def close(self) -> None:
        self.flush()
        for s in self.sinks:
            s.close()

    # ------------------------------------------------------ cursor state
    def state_dict(self) -> dict:
        """Checkpoint-able cursor: serialized through the trainer's
        checkpoint ``extra`` so a resumed run appends instead of
        restarting its series at step 0."""
        return {"step": int(self.last_step),
                "emitted": int(self.n_emitted),
                "counters": dict(self._counters)}

    def load_state_dict(self, sd: dict) -> None:
        self.last_step = int(sd.get("step", 0))
        self.n_emitted = int(sd.get("emitted", 0))
        self._counters = {k: int(v)
                         for k, v in sd.get("counters", {}).items()}
        self.event("telemetry_resume", step=self.last_step,
                   emitted=self.n_emitted)


class _TaggedView:
    """Write-through registry view adding fixed tags to each record."""

    def __init__(self, base: MetricsRegistry, tags: dict) -> None:
        self._base = base
        self._tags = tags

    def _rec(self, kind, name, step, **payload):
        saved = self._base._tags
        self._base._tags = self._tags
        try:
            self._base._rec(kind, name, step, **payload)
        finally:
            self._base._tags = saved

    def scalar(self, name, value, step=None):
        self._rec("scalar", name, step, value=value)

    def series(self, name, values, step=None):
        self._rec("series", name, step, values=values)

    def counter(self, name, inc=1, step=None):
        total = self._base._counters.get(name, 0) + int(inc)
        self._base._counters[name] = total
        self._rec("counter", name, step, value=total, inc=int(inc))
        return total

    def event(self, name, step=None, **data):
        self._rec("event", name, step, data=data)

    def with_tags(self, **tags):
        return _TaggedView(self._base, {**self._tags, **tags})

    def flush(self):
        self._base.flush()

    def close(self):
        self._base.close()

    def state_dict(self):
        return self._base.state_dict()

    def load_state_dict(self, sd):
        self._base.load_state_dict(sd)

    @property
    def last_step(self):
        return self._base.last_step

    @property
    def n_emitted(self):
        return self._base.n_emitted

    @property
    def sinks(self):
        return self._base.sinks
