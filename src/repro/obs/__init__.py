"""Unified telemetry: structured metrics, tick tracing, run reports.

- ``repro.obs.metrics`` — :class:`MetricsRegistry` with typed
  scalar/series/counter/event emitters and pluggable sinks (JSONL file,
  in-memory for tests, CSV export). Device values are host-fetched in one
  batched ``block_until_ready`` at flush boundaries only.
- ``repro.obs.trace`` — pipeline tick tracer: tick tables + overlap plan
  -> Chrome trace-event JSON (Perfetto), plus the ``--profile``
  ``jax.profiler`` hook.
- ``repro.launch.report`` — CLI rendering a run's JSONL telemetry as a
  text summary and re-emitting the trace.
"""
from repro.obs.metrics import (  # noqa: F401
    JsonlSink,
    MemorySink,
    MetricsRegistry,
    read_jsonl,
    write_csv,
)
from repro.obs.trace import (  # noqa: F401
    expected_span_count,
    load_trace,
    profiler_session,
    tick_trace_events,
    validate_trace,
    write_chrome_trace,
)

__all__ = [
    "JsonlSink",
    "MemorySink",
    "MetricsRegistry",
    "read_jsonl",
    "write_csv",
    "tick_trace_events",
    "write_chrome_trace",
    "load_trace",
    "validate_trace",
    "expected_span_count",
    "profiler_session",
]
