"""Pipeline tick tracer: tick tables -> Chrome trace-event JSON.

``tick_trace_events`` renders the dependency-timed schedule spans from
``repro.pipeline.schedule.tick_spans`` as Chrome trace-event ``X``
(complete) events — one track (tid) per pipeline stage, one span per
tick-table F/B entry, SYNC spans for the overlap plan's in-loop chunk
launches (plus ``sync-residual`` spans for the post-loop spill), and
``bubble`` spans filling each stage's idle gaps. The output of
``write_chrome_trace`` loads directly in Perfetto / ``chrome://tracing``.

Time axis: ``tick_spans`` works in abstract schedule seconds (units of
``t_f``/``t_b``); ``time_unit_us`` scales those to trace microseconds.
Passing measured per-step wall time lets the launcher emit a trace whose
makespan matches the real step (``scale = measured_step_s /
simulate_schedule(...)['makespan']``).

``profiler_session`` is the ``--profile`` hook: a context manager that
starts/stops ``jax.profiler`` traces around the run when enabled and is
a no-op otherwise.
"""
from __future__ import annotations

import contextlib
import json
import os
from typing import Any

from repro.pipeline.schedule import (
    slot_table,
    stash_points,
    stash_segments,
    tick_spans,
)

__all__ = [
    "tick_trace_events",
    "write_chrome_trace",
    "load_trace",
    "validate_trace",
    "expected_span_count",
    "profiler_session",
]

# Span categories. The count oracle in tests matches cats in
# SCHEDULED_CATS one-to-one against slot_table entries; residual sync and
# bubble filler are annotations outside the tick table.
SCHEDULED_CATS = ("forward", "backward", "sync")
EXTRA_CATS = ("sync-residual", "bubble")


def _meta(pid: int, tid: int | None, name: str, label: str) -> dict:
    ev = {"ph": "M", "pid": pid, "name": name,
          "args": {"name": label}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def tick_trace_events(schedule: str, S: int, M: int, *,
                      t_f: float = 1.0, t_b: float = 1.0,
                      sync_plan: Any = None,
                      stash_policy: str = "replay", n_units: int = 0,
                      stash_every: int = 2,
                      time_unit_us: float = 1000.0,
                      pid: int = 0) -> list[dict]:
    """Chrome trace events for one pipelined step.

    Returns a flat event list: ``M`` metadata rows naming the process and
    one thread per stage, then ``X`` spans. F spans carry the tick,
    microbatch, and the stage's stash points; B spans carry the replayed
    stash segments; SYNC spans (when ``sync_plan`` is an ``OverlapPlan``)
    carry the chunk id and its planned launch tick. Exactly one
    forward/backward/sync span is emitted per ``slot_table`` entry.
    """
    spans = tick_spans(schedule, S, M, t_f, t_b)
    makespan = max(sp["end"] for sp in spans) if spans else 0.0
    us = float(time_unit_us)

    events: list[dict] = [_meta(pid, None, "process_name",
                                f"pipeline {schedule} S={S} M={M}")]
    for s in range(S):
        events.append(_meta(pid, s, "thread_name", f"stage {s}"))

    points = stash_points(stash_policy, n_units, stash_every) if n_units else ()
    segments = (stash_segments(stash_policy, n_units, stash_every)
                if n_units else ())

    busy: dict[int, list[tuple[float, float]]] = {s: [] for s in range(S)}
    for sp in spans:
        s = sp["stage"]
        fwd = sp["kind"] == "F"
        args = {"tick": sp["tick"], "microbatch": sp["mb"]}
        if fwd:
            args["stash_policy"] = stash_policy
            if points:
                args["stash_points"] = list(points)
        elif segments:
            args["replay_segments"] = [list(seg) for seg in segments]
        events.append({
            "ph": "X", "pid": pid, "tid": s,
            "name": f"{sp['kind']}{sp['mb']}",
            "cat": "forward" if fwd else "backward",
            "ts": sp["start"] * us, "dur": (sp["end"] - sp["start"]) * us,
            "args": args,
        })
        busy[s].append((sp["start"], sp["end"]))

    if sync_plan is not None:
        events.extend(_sync_events(sync_plan, spans, makespan, t_b, us,
                                   pid, busy))

    # Idle filler: per-stage gaps between scheduled work inside
    # [first_start, makespan]. Rendered as its own span so the bubble is
    # visible in Perfetto without mentally diffing tracks.
    for s in range(S):
        iv = sorted(busy[s])
        if not iv:
            continue
        cursor = iv[0][0]
        gaps = []
        for a, b in iv:
            if a > cursor + 1e-9:
                gaps.append((cursor, a))
            cursor = max(cursor, b)
        if makespan > cursor + 1e-9:
            gaps.append((cursor, makespan))
        for a, b in gaps:
            events.append({
                "ph": "X", "pid": pid, "tid": s, "name": "bubble",
                "cat": "bubble", "ts": a * us, "dur": (b - a) * us,
                "args": {},
            })
    return events


def _sync_events(plan: Any, spans: list[dict], makespan: float,
                 t_b: float, us: float, pid: int,
                 busy: dict[int, list[tuple[float, float]]]) -> list[dict]:
    """SYNC spans from an OverlapPlan.

    In-loop chunks chain sequentially from the stage's last backward end
    (that is when the overlapped executor's ``lax.switch`` launches them),
    each sized to its share of the launch tick's ``t_b`` budget; residual
    chunks chain after the makespan under cat ``sync-residual``.
    """
    events: list[dict] = []
    S = plan.num_stages
    for s in range(S):
        ends = [sp["end"] for sp in spans
                if sp["stage"] == s and sp["kind"] == "B"]
        cursor = max(ends) if ends else makespan
        for tick, chunk_ids in plan.launches[s]:
            dur = t_b / max(1, len(chunk_ids))
            for cid in chunk_ids:
                events.append({
                    "ph": "X", "pid": pid, "tid": s,
                    "name": f"SYNC c{cid}", "cat": "sync",
                    "ts": cursor * us, "dur": dur * us,
                    "args": {"chunk": int(cid), "planned_tick": int(tick),
                             "residual": False},
                })
                busy[s].append((cursor, cursor + dur))
                cursor += dur
        cursor = max(cursor, makespan)
        for cid in plan.residual[s]:
            events.append({
                "ph": "X", "pid": pid, "tid": s,
                "name": f"SYNC c{cid}", "cat": "sync-residual",
                "ts": cursor * us, "dur": t_b * us,
                "args": {"chunk": int(cid), "residual": True},
            })
            cursor += t_b
    return events


def write_chrome_trace(path: str, events: list[dict],
                       metadata: dict | None = None) -> str:
    """Write a Chrome trace-event JSON object file (Perfetto-loadable)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    obj = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": metadata or {}}
    with open(path, "w") as f:
        json.dump(obj, f)
    return path


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def validate_trace(obj: dict) -> dict:
    """Schema-check a trace object; raise ``ValueError`` on violations.

    Returns a summary (event counts per category, track count, makespan)
    that the CI smoke prints after validating.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be an object with a traceEvents list")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("traceEvents must be a non-empty list")
    cats: dict[str, int] = {}
    tracks: set[tuple[int, int]] = set()
    end_us = 0.0
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev or "name" not in ev:
            raise ValueError(f"event {i}: missing ph/name")
        ph = ev["ph"]
        if ph == "M":
            continue
        if ph != "X":
            raise ValueError(f"event {i}: unexpected phase {ph!r}")
        for key in ("ts", "dur", "pid", "tid"):
            if not isinstance(ev.get(key), (int, float)):
                raise ValueError(f"event {i}: non-numeric {key}")
        if ev["dur"] < 0:
            raise ValueError(f"event {i}: negative dur")
        cat = ev.get("cat", "")
        cats[cat] = cats.get(cat, 0) + 1
        tracks.add((ev["pid"], ev["tid"]))
        end_us = max(end_us, ev["ts"] + ev["dur"])
    if not tracks:
        raise ValueError("trace has no X spans")
    return {"spans": sum(cats.values()), "by_cat": cats,
            "tracks": len(tracks), "end_us": end_us}


def expected_span_count(schedule: str, S: int, M: int,
                        sync_plan: Any = None) -> int:
    """Tick-table oracle: one scheduled span per slot_table entry."""
    table = slot_table(schedule, S, M, sync_plan)
    return sum(len(table[s][t]) for s in range(len(table))
               for t in range(len(table[s])))


@contextlib.contextmanager
def profiler_session(enabled: bool, logdir: str):
    """``--profile`` hook: jax.profiler trace around the run when enabled."""
    if not enabled:
        yield None
        return
    import jax
    os.makedirs(logdir, exist_ok=True)
    jax.profiler.start_trace(logdir)
    try:
        yield logdir
    finally:
        jax.profiler.stop_trace()
