"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

Proves the distribution config is coherent without hardware: 512 fake host
devices stand in for 2 pods x 256 v5e chips; every combination must
``.lower().compile()``, and the compiled artifacts yield the roofline terms
(cost_analysis = per-device FLOPs/bytes; collective bytes parsed from the
partitioned HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                   # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod       # 2-pod mesh
  ... --out results.json
"""
# The fake-device flag MUST precede any jax import (device count locks at
# first init). Do NOT move these lines or set this flag anywhere global.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config, sharding_mode
from repro.core import classify_leaves, make_plan
from repro.core.compressor import NO_COMPRESSION
from repro.dist.sharding import batch_pspec, cache_pspecs, param_shardings
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim import adam
from repro.train.step import (
    TrainStepConfig, make_train_step, replicate_comp_state, state_shardings,
)

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


# ------------------------------------------------------------ HLO parsing
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes of all array shapes in an HLO result type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-op-kind result bytes of every collective in a partitioned module."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w,\[\]{}\s]*?)\s*"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start)?\(", ls)
        if m:
            out[m.group(2)] += _shape_bytes(m.group(1))
    return out


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of one input shape."""
    spec = INPUT_SHAPES[shape_name]
    B, T = spec["global_batch"], spec["seq_len"]
    kind = spec["kind"]
    tok = jax.ShapeDtypeStruct
    if kind in ("train", "prefill"):
        batch = {"tokens": tok((B, T), jnp.int32)}
        if kind == "train":
            batch["labels"] = tok((B, T), jnp.int32)
        if cfg.family == "whisper":
            batch["frames"] = tok((B, cfg.audio_frames, cfg.d_model), cfg.jdtype)
        if cfg.family == "vlm":
            batch["patches"] = tok((B, cfg.num_patches, cfg.d_model), cfg.jdtype)
        return batch
    # decode: ONE new token against a seq_len-deep cache
    return {"tokens": tok((B,), jnp.int32)}


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


# ------------------------------------------------------------- one combo
def lower_one(arch: str, shape_name: str, mesh, policy: str = "edgc",
              rank: int = 64, verbose: bool = True,
              opt_dtype: str = "float32", stash: str = "replay",
              stash_every: int = 2, overlap: bool = False,
              chunk_bytes: int = 0, outer_k: int = 0,
              outer_rank: int = 32, inject: bool = False) -> dict:
    """Lower+compile one (arch, shape, mesh); return the roofline record."""
    spec = INPUT_SHAPES[shape_name]
    kind = spec["kind"]
    B, T = spec["global_batch"], spec["seq_len"]
    mode = sharding_mode(arch)
    variant = "long" if shape_name == "long_500k" else "full"
    cfg = get_config(arch, variant)
    if cfg is None:
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "long_500k inapplicable (see DESIGN §5)"}
    pipe = "pipe" in mesh.axis_names
    if pipe and kind != "train":
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "pipeline mesh applies to train shapes only"}
    if pipe:
        # The stage adapter's own reason string is surfaced verbatim (a
        # family without an adapter, a layer/stage mismatch, ...) instead
        # of a bare traceback. Memory-bound 'auto' archs lower dp_tp-style
        # here: the pipe axis splits the params S ways, standing in for
        # the FSDP sharding the flat auto path would use.
        from repro.launch.mesh import pipe_size
        from repro.pipeline.partition import pipeline_supported
        cfg = dataclasses.replace(cfg, num_stages=pipe_size(mesh))
        reason = pipeline_supported(cfg, pipe_size(mesh))
        if reason is not None:
            return {"arch": arch, "shape": shape_name, "skipped": True,
                    "reason": f"pipeline: {reason}"}
    model = build_model(cfg)
    t0 = time.time()

    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = param_shardings(params_shapes, mesh, fsdp=(mode == "auto"))

    if kind == "train" and pipe:
        rec = _lower_train_pipelined(arch, cfg, model, mesh, params_shapes,
                                     shape_name, policy, rank, opt_dtype,
                                     stash=stash, stash_every=stash_every,
                                     overlap=overlap,
                                     chunk_bytes=chunk_bytes)
    elif kind == "train":
        rec = _lower_train(arch, cfg, model, mesh, mode, params_shapes,
                           pshard, shape_name, policy, rank, opt_dtype,
                           inject=inject)
    elif kind == "prefill":
        rec = _lower_prefill(cfg, model, mesh, params_shapes, pshard, shape_name)
    else:
        rec = _lower_decode(cfg, model, mesh, params_shapes, pshard, shape_name)
    if outer_k and kind == "train":
        if "pod" in mesh.axis_names:
            rec["outer_sync"] = _lower_outer_sync(cfg, mesh, params_shapes,
                                                  outer_rank)
            rec["outer_sync"]["outer_k"] = outer_k
        else:
            rec["outer_sync"] = {"skipped": True,
                                 "reason": "outer loop needs --multi-pod"}
    rec.update({"arch": arch, "shape": shape_name, "mode": mode,
                "mesh": "x".join(map(str, mesh.devices.shape)),
                "compile_s": round(time.time() - t0, 1)})
    return rec


def _record(compiled, hlo_text: str, pod_size: int = 0) -> dict:
    from repro.launch.hlo_cost import analyze_hlo, xla_cost_analysis
    ca = xla_cost_analysis(compiled)
    ma = compiled.memory_analysis()
    # loop-scaled walker: cost_analysis counts while bodies ONCE, which
    # undercounts layer-scanned models by their trip counts (hlo_cost.py)
    walked = analyze_hlo(hlo_text, pod_size=pod_size)
    coll = {k: int(v) for k, v in walked["collective_bytes"].items()}
    cross = {k: int(v) for k, v in walked.get("collective_bytes_cross", {}).items()}
    return {
        "flops_per_chip": float(walked["flops"]),
        "bytes_per_chip": float(walked["bytes"]),
        "collective_bytes_per_chip": coll,
        "collective_total": int(sum(coll.values())),
        "collective_cross_pod": cross,
        "collective_cross_total": int(sum(cross.values())),
        "xla_cost_analysis": {
            "flops_unscaled": float(ca.get("flops", 0.0)),
            "bytes_unscaled": float(ca.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "code_bytes": ma.generated_code_size_in_bytes,
        },
    }


def _lower_outer_sync(cfg, mesh, params_shapes, rank):
    """Lower+compile the DiLoCo outer sync on the pod-lead sub-mesh.

    The outer all-reduce runs on ONE lead device per pod over the cross-pod
    links — exactly the topology ``make_pod_mesh`` gives the elastic
    trainer. Deltas ship fp32 (parameter scale); the record carries the
    compressed-vs-raw outer wire bytes the EDGC plan buys per round.
    """
    from repro.core.compressor import init_compressor_state, plan_wire_bytes
    from repro.core.entropy import GDSConfig
    from repro.optim.outer import make_outer_sync_step

    n_pods = mesh.devices.shape[list(mesh.axis_names).index("pod")]
    leads = mesh.devices.reshape(n_pods, -1)[:, 0]
    omesh = jax.make_mesh((n_pods,), ("pod",), devices=list(leads))

    leaves = classify_leaves(params_shapes, cfg.num_layers, 1, min_dim=128)
    plan = make_plan("fixed", leaves, fixed_rank=rank, num_stages=1)
    delta_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                       sharding=NamedSharding(omesh, P())),
        params_shapes)
    comp_shapes = jax.eval_shape(lambda: replicate_comp_state(
        init_compressor_state(delta_shapes, plan, jax.random.PRNGKey(2)),
        n_pods))
    comp_shapes = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                       sharding=NamedSharding(omesh,
                                                              P("pod"))),
        comp_shapes)
    step = make_outer_sync_step(omesh, plan, GDSConfig())
    with omesh:
        compiled = step.lower(delta_shapes, comp_shapes).compile()
    # On the lead mesh every device IS a pod: pod_size=1 marks every
    # collective byte as crossing the pod boundary.
    rec = _record(compiled, compiled.as_text(), pod_size=1)
    compressed, full = plan_wire_bytes(leaves, plan, 4)
    rec.update({"n_pods": int(n_pods), "outer_rank": int(rank),
                "compressed_leaves": len(plan.ranks),
                "wire_bytes_compressed": int(compressed),
                "wire_bytes_full": int(full)})
    return rec


def _lower_train(arch, cfg, model, mesh, mode, params_shapes, pshard,
                 shape_name, policy, rank, opt_dtype="float32",
                 inject=False):
    spec = INPUT_SHAPES[shape_name]
    B = spec["global_batch"]
    axes = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = int(np.prod([sizes.get(a, 1) for a in axes])) or 1

    if mode == "auto":
        plan = NO_COMPRESSION
    else:
        leaves = classify_leaves(params_shapes, cfg.num_layers, cfg.num_stages,
                                 min_dim=128)
        plan = make_plan(policy if policy != "edgc" else "edgc", leaves,
                         stage_ranks=[rank] * cfg.num_stages,
                         fixed_rank=rank, num_stages=cfg.num_stages)

    acfg = adam.AdamConfig(opt_dtype=opt_dtype)

    # Same executor-eligibility rule the Trainer applies, so the reported
    # collective counts model what production actually lowers (not the
    # per-leaf parity oracle).
    from repro.core.bucketing import bucketing_supported
    bucketed = mode == "dp_tp" and bucketing_supported(mesh)

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        ost = adam.init(params, acfg)
        from repro.core.bucketing import layout_for_tree
        from repro.core.compressor import init_compressor_state
        layout = layout_for_tree(params, plan) if bucketed else None
        comp = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                     layout=layout)
        comp = replicate_comp_state(comp, world if mode == "dp_tp" else 1)
        return {"params": params, "opt_m": ost.m, "opt_v": ost.v,
                "opt_step": ost.step, "comp": comp}

    state_shapes = jax.eval_shape(init_state)
    sshard = state_shardings(state_shapes, model, mesh, fsdp=(mode == "auto"))
    if mode == "auto":
        # params/opt sharded FSDP+TP; comp empty
        sshard["params"] = pshard
        sshard["opt_m"] = pshard
        sshard["opt_v"] = pshard

    batch = input_specs(cfg, shape_name)
    if inject:
        # the fault-injection channel rides in the batch (constant batch
        # structure keeps one compiled variant; see train/faults.py)
        batch["_inject"] = jax.ShapeDtypeStruct((B,), jnp.float32)
    bshard = {k: NamedSharding(mesh, batch_pspec(v.ndim, mesh, B))
              for k, v in batch.items()}

    scfg = TrainStepConfig(mode=mode if mode == "dp_tp" else "auto",
                           policy_plan=plan, measure_entropy=(mode == "dp_tp"),
                           bucketed=bucketed or None,
                           remat=cfg.remat, adam=acfg,
                           guard_nonfinite=inject)
    step = make_train_step(model, mesh, scfg)
    jstep = jax.jit(step, in_shardings=(sshard, bshard),
                    out_shardings=(sshard, NamedSharding(mesh, P())),
                    donate_argnums=0)
    with mesh:
        lowered = jstep.lower(state_shapes, batch)
        compiled = lowered.compile()
    pod = 256 if "pod" in mesh.axis_names else 0
    rec = _record(compiled, compiled.as_text(), pod_size=pod)
    rec["policy"] = policy if plan.ranks else "none"
    rec["compressed_leaves"] = len(plan.ranks)
    rec["guarded"] = bool(inject)
    return rec


def _lower_train_pipelined(arch, cfg, model, mesh, params_shapes, shape_name,
                           policy, rank, opt_dtype="float32",
                           stash="replay", stash_every=2, overlap=False,
                           chunk_bytes=0):
    """Lower+compile the pipelined train step (pipe mesh): stage-partitioned
    state, 1F1B schedule, per-stage DP sync — what a pipelined pod runs.
    ``stash`` picks the executor's activation-stashing policy; the record
    carries the per-stage ``peak_activation_bytes`` ledger for it.
    ``overlap`` lowers the schedule-interleaved sync executor and records
    the overlap planner's launch/residual/feasibility summary."""
    from repro.launch.mesh import pipe_size
    from repro.pipeline import partition as ppart
    from repro.pipeline import sync as psync
    from repro.pipeline.config import PipelineConfig
    from repro.pipeline.schedule import (
        boundary_nbytes, peak_activation_bytes, pipeline_state_shardings,
        plan_overlap,
    )

    spec = INPUT_SHAPES[shape_name]
    B = spec["global_batch"]
    S = pipe_size(mesh)
    axes = dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = int(np.prod([sizes.get(a, 1) for a in axes])) or 1

    leaves = classify_leaves(params_shapes, cfg.num_layers, S, min_dim=128)
    plan = make_plan(policy, leaves, stage_ranks=[rank] * S,
                     fixed_rank=rank, num_stages=S)
    part = ppart.make_partition(model, S)
    stage_shapes = jax.eval_shape(
        lambda p: part.partition_params(p)[0], params_shapes)
    splans = psync.make_stage_plans(
        plan, S, psync.stage_local_leaves(stage_shapes),
        chunk_bytes=chunk_bytes,
        local_path=part.local_leaf_path)
    acfg = adam.AdamConfig(opt_dtype=opt_dtype)

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        sp, sh = part.partition_params(params)
        ost = adam.init({"stage": sp, "shared": sh}, acfg)
        comp = psync.init_pipeline_comp_state(params, plan,
                                              jax.random.PRNGKey(1), splans)
        comp = psync.replicate_pipeline_comp_state(comp, world)
        return {"stage_params": sp, "shared_params": sh,
                "opt_m": ost.m, "opt_v": ost.v, "opt_step": ost.step,
                "comp": comp}

    state_shapes = jax.eval_shape(init_state)
    sshard = pipeline_state_shardings(state_shapes, model, mesh)

    batch = input_specs(cfg, shape_name)
    bshard = {k: NamedSharding(mesh, batch_pspec(v.ndim, mesh, B))
              for k, v in batch.items()}

    scfg = TrainStepConfig(mode="dp_tp", policy_plan=plan,
                           measure_entropy=True, remat=cfg.remat,
                           pipeline=PipelineConfig(
                               num_stages=S, schedule="1f1b",
                               stash_policy=stash, stash_every=stash_every,
                               overlap_sync=overlap,
                               chunk_bytes=chunk_bytes),
                           adam=acfg)
    step = make_train_step(model, mesh, scfg)
    jstep = jax.jit(step, in_shardings=(sshard, bshard),
                    out_shardings=(sshard, NamedSharding(mesh, P())),
                    donate_argnums=0)
    with mesh:
        lowered = jstep.lower(state_shapes, batch)
        compiled = lowered.compile()
    pod = 256 if "pod" in mesh.axis_names else 0
    rec = _record(compiled, compiled.as_text(), pod_size=pod)
    rec["policy"] = policy if plan.ranks else "none"
    rec["compressed_leaves"] = len(plan.ranks)
    # Per-stage (compressed, full) DP-sync bytes — the Algorithm-2 wire
    # ledger, reported per family so `--pipe` runs show where the bytes go.
    rec["pipeline"] = {"num_stages": S, "schedule": "1f1b",
                       "family": cfg.family,
                       "distinct_plans": len(splans.distinct),
                       "stage_bytes": psync.stage_wire_bytes(leaves, plan, S)}
    # Activation-memory ledger: per-rank microbatch boundary bytes (the
    # local batch is B / dp_world, split M ways) x the stash policy's
    # live ring entries from the tick table.
    M = S  # the executor's default microbatch count
    mb = {k: jax.ShapeDtypeStruct((max(1, v.shape[0] // (world * M)),)
                                  + v.shape[1:], v.dtype)
          for k, v in batch.items()}
    rec["pipeline"]["stash_policy"] = stash
    rec["pipeline"]["peak_activation_bytes"] = peak_activation_bytes(
        "1f1b", S, M, stash, boundary_bytes=boundary_nbytes(part, mb),
        n_units=part.num_units(), stash_every=stash_every)
    if overlap:
        # The overlap planner's summary for this lowering: how many chunks
        # each stage hides in its drain ticks vs runs post-loop, and the
        # Eq. 4 feasibility signal the DAC would consume.
        oplan = plan_overlap("1f1b", S, M, splans)
        rec["pipeline"]["overlap"] = {
            "chunk_bytes": chunk_bytes,
            "in_loop_chunks": [sum(len(ids) for _, ids in oplan.launches[s])
                               for s in range(S)],
            "residual_chunks": [len(oplan.residual[s]) for s in range(S)],
            "feasible": list(oplan.feasible),
        }
    return rec


def _lower_prefill(cfg, model, mesh, params_shapes, pshard, shape_name):
    spec = INPUT_SHAPES[shape_name]
    B = spec["global_batch"]
    batch = input_specs(cfg, shape_name)
    bshard = {k: NamedSharding(mesh, batch_pspec(v.ndim, mesh, B))
              for k, v in batch.items()}
    out_shard = NamedSharding(mesh, batch_pspec(3, mesh, B))

    jfwd = jax.jit(model.forward, in_shardings=(pshard, bshard),
                   out_shardings=out_shard)
    with mesh:
        lowered = jfwd.lower(params_shapes, batch)
        compiled = lowered.compile()
    pod = 256 if "pod" in mesh.axis_names else 0
    return _record(compiled, compiled.as_text(), pod_size=pod)


def _lower_decode(cfg, model, mesh, params_shapes, pshard, shape_name):
    spec = INPUT_SHAPES[shape_name]
    B, T = spec["global_batch"], spec["seq_len"]

    if cfg.family == "whisper":
        from repro.models import encdec
        cache_shapes = jax.eval_shape(lambda: encdec.init_cache(cfg, B, T))
    else:
        cache_shapes = jax.eval_shape(lambda: model.init_cache(B, T))
    cshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        cache_pspecs(cache_shapes, mesh, B))
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    tshard = NamedSharding(mesh, batch_pspec(1, mesh, B))
    logit_shard = NamedSharding(mesh, batch_pspec(2, mesh, B))

    jdec = jax.jit(model.decode_step,
                   in_shardings=(pshard, cshard, tshard),
                   out_shardings=(logit_shard, cshard),
                   donate_argnums=1)
    with mesh:
        lowered = jdec.lower(params_shapes, cache_shapes, tokens)
        compiled = lowered.compile()
    pod = 256 if "pod" in mesh.axis_names else 0
    return _record(compiled, compiled.as_text(), pod_size=pod)


# ------------------------------------------------------------------- main
def record_summary(rec: dict) -> dict:
    """Machine-checkable summary of one lowering record — the structured
    twin of the human OK/SKIP/FAIL line, emitted as a ``dryrun`` event so
    CI can assert on lowerings instead of grepping stdout."""
    out = {"arch": rec.get("arch"), "shape": rec.get("shape")}
    if rec.get("skipped"):
        out["status"] = "skipped"
        out["reason"] = rec.get("reason")
        return out
    if "error" in rec:
        out["status"] = "failed"
        out["error"] = rec["error"]
        return out
    out["status"] = "ok"
    for key in ("flops_per_chip", "bytes_per_chip", "collective_total",
                "compile_s", "policy", "compressed_leaves", "guarded"):
        if key in rec:
            out[key] = rec[key]
    mem = rec.get("memory")
    if mem:
        out["per_chip_bytes"] = int(mem.get("argument_bytes", 0)
                                    + mem.get("temp_bytes", 0))
    pipe = rec.get("pipeline")
    if pipe:
        out["pipeline"] = {
            "num_stages": pipe.get("num_stages"),
            "schedule": pipe.get("schedule"),
            "stash_policy": pipe.get("stash_policy"),
            "stage_bytes": pipe.get("stage_bytes"),
            "peak_activation_bytes": pipe.get("peak_activation_bytes"),
        }
        if "overlap" in pipe:
            out["pipeline"]["overlap"] = pipe["overlap"]
    osync = rec.get("outer_sync")
    if osync and not osync.get("skipped"):
        out["outer_sync"] = {
            "wire_bytes_compressed": osync.get("wire_bytes_compressed"),
            "wire_bytes_full": osync.get("wire_bytes_full"),
            "outer_k": osync.get("outer_k"),
            "outer_rank": osync.get("outer_rank"),
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true",
                    help="use the 2x16x16 (512-chip) mesh")
    ap.add_argument("--pipe", type=int, default=0,
                    help="pipeline stages: adds a 'pipe' mesh axis and "
                         "lowers the pipelined (1F1B) train step")
    ap.add_argument("--policy", default="edgc")
    ap.add_argument("--rank", type=int, default=64)
    ap.add_argument("--stash", default="replay",
                    choices=["replay", "full", "every_k"],
                    help="pipeline activation-stash policy (with --pipe): "
                         "how much of each stage's forward survives to its "
                         "backward tick")
    ap.add_argument("--stash-every", type=int, default=2,
                    help="k for --stash every_k")
    ap.add_argument("--overlap", action="store_true",
                    help="with --pipe: lower the schedule-interleaved "
                         "(overlapped) per-stage sync executor")
    ap.add_argument("--chunk-bytes", type=int, default=0,
                    help="with --overlap: max bytes per sync transfer "
                         "chunk (0 = one chunk per bucket)")
    ap.add_argument("--outer-k", type=int, default=0,
                    help="with --multi-pod: also lower the DiLoCo outer "
                         "sync (EDGC-compressed outer-delta all-reduce on "
                         "the pod-lead mesh); K = inner steps per round")
    ap.add_argument("--outer-rank", type=int, default=32,
                    help="PowerSGD rank for the outer-sync lowering")
    ap.add_argument("--inject", action="store_true",
                    help="lower the fault-guarded train step variant "
                         "(non-finite guard + injection channel)")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--metrics-dir", default=None,
                    help="also emit one structured 'dryrun' event per combo "
                         "to DIR/metrics.jsonl (the telemetry sink format)")
    args = ap.parse_args()

    registry = None
    if args.metrics_dir:
        import os

        from repro.obs import JsonlSink, MetricsRegistry
        registry = MetricsRegistry(
            [JsonlSink(os.path.join(args.metrics_dir, "metrics.jsonl"))])

    mesh = make_production_mesh(multi_pod=args.multi_pod, pipe=args.pipe)
    archs = [args.arch] if args.arch else [a for a in ARCHS if a != "gpt2"]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    records = []
    for arch in archs:
        for shape_name in shapes:
            tag = f"{arch} x {shape_name} [{'x'.join(map(str, mesh.devices.shape))}]"
            try:
                rec = lower_one(arch, shape_name, mesh,
                                policy=args.policy, rank=args.rank,
                                stash=args.stash,
                                stash_every=args.stash_every,
                                overlap=args.overlap,
                                chunk_bytes=args.chunk_bytes,
                                outer_k=args.outer_k,
                                outer_rank=args.outer_rank,
                                inject=args.inject)
                if rec.get("skipped"):
                    print(f"SKIP {tag}: {rec['reason']}", flush=True)
                else:
                    mem = rec["memory"]
                    per_chip_gb = (mem["argument_bytes"] + mem["temp_bytes"]) / 2**30
                    extra = ""
                    if "pipeline" in rec:
                        sb = ";".join(str(c) for c, _ in
                                      rec["pipeline"]["stage_bytes"])
                        extra = (f", {rec['pipeline']['family']} "
                                 f"stage-sync [{sb}] B")
                        if "overlap" in rec["pipeline"]:
                            ov = rec["pipeline"]["overlap"]
                            extra += (", overlap in-loop "
                                      f"{ov['in_loop_chunks']} residual "
                                      f"{ov['residual_chunks']}")
                    if rec.get("guarded"):
                        extra += ", guarded"
                    osync = rec.get("outer_sync")
                    if osync and not osync.get("skipped"):
                        extra += (", outer-sync "
                                  f"{osync['wire_bytes_compressed']/2**20:.1f}"
                                  f"/{osync['wire_bytes_full']/2**20:.1f} MiB"
                                  f" (K={osync['outer_k']}, "
                                  f"r={osync['outer_rank']})")
                    print(f"OK   {tag}: {rec['flops_per_chip']:.3e} FLOP/chip, "
                          f"{rec['bytes_per_chip']:.3e} B/chip, "
                          f"coll {rec['collective_total']/2**20:.1f} MiB/chip, "
                          f"mem {per_chip_gb:.2f} GiB/chip, "
                          f"{rec['compile_s']}s{extra}", flush=True)
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "error": str(e),
                       "traceback": traceback.format_exc()}
                print(f"FAIL {tag}: {e}", flush=True)
            records.append(rec)
            if registry is not None:
                registry.event("dryrun", **record_summary(rec))
                registry.flush()
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    n_ok = sum(1 for r in records if "flops_per_chip" in r)
    n_skip = sum(1 for r in records if r.get("skipped"))
    n_fail = len(records) - n_ok - n_skip
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if registry is not None:
        registry.event("dryrun_summary", ok=n_ok, skipped=n_skip,
                       failed=n_fail)
        registry.close()
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
