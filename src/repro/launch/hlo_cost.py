"""HLO-text cost model with while-loop trip-count scaling.

``compiled.cost_analysis()`` counts each while-loop BODY once — under
layer-scanned models (every family here scans its blocks) that undercounts
FLOPs, HBM bytes and collective bytes by the trip count (126x for llama3).
This walker parses the partitioned HLO text, builds the computation call
graph, extracts while trip counts from their condition computations, and
returns loop-scaled per-device totals:

  * flops            — 2 * numel(result) * contraction for every dot
                       (MXU work; elementwise VPU flops excluded, they are
                       irrelevant against the roofline's MXU peak)
  * bytes            — sum over materializing ops (fusion/dot/copy/
                       dynamic-slice/dus/collectives/...) of result +
                       operand bytes: fusion boundaries are exactly XLA's
                       buffer materialization points, so this approximates
                       HBM traffic the way a fused TPU program would see it
  * collective_bytes — per collective kind, result-shape bytes

Validated against analytic counts in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{} ]+?))\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _arrays(type_str: str):
    """All (dtype, numel) arrays in an HLO type string (handles tuples)."""
    out = []
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append((dt, n))
    return out


def _type_bytes(type_str: str) -> int:
    return sum(n * DTYPE_BYTES[dt] for dt, n in _arrays(type_str))


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # everything after the opening paren
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]
    shapes: dict[str, str]      # %name -> type string (params + op results)


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Parse computations; returns ({name: comp}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        ls = line.strip()
        # computation headers sit at column 0, contain '->', end with '{'
        if line and not line[0].isspace() and "->" in line and line.endswith("{"):
            m = _COMP_RE.match(line)
            if m:
                cur = Computation(name=m.group(1), ops=[], shapes={})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                # parameters: "name: <type>" pairs (types may be tuples)
                header = line[: line.rfind("->")]
                for pname, ptype in re.findall(
                        r"([\w.\-]+):\s*(\([^)]*\)|[\w\[\],{}]+)", header):
                    cur.shapes[pname] = ptype
                continue
        if ls == "}":
            cur = None
            continue
        if cur is None:
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, type_str, opcode, rest = dm.groups()
        # operand references (first-level %names before any '),' metadata)
        arg_str = rest.split("),")[0]
        operands = re.findall(r"%([\w.\-]+)", arg_str)
        cur.shapes[name] = type_str
        cur.ops.append(Op(name=name, type_str=type_str, opcode=opcode,
                          rest=rest, operands=operands))
    if entry is None:
        # fall back: last computation
        entry = list(comps)[-1]
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * numel(result) * contraction size."""
    res = _arrays(op.type_str)
    if not res:
        return 0.0
    numel = res[0][1]
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m or not op.operands:
        return 2.0 * numel  # degenerate
    lhs_shape = comp.shapes.get(op.operands[0], "")
    arrs = _ARRAY_RE.findall(lhs_shape)
    if not arrs:
        return 2.0 * numel
    dims = [int(d) for d in arrs[0][1].split(",") if d]
    contraction = 1
    for i in m.group(1).split(","):
        if i != "" and int(i) < len(dims):
            contraction *= dims[int(i)]
    # batch dims are part of numel already
    return 2.0 * numel * contraction


def _trip_count(cond: Computation) -> int:
    """Trip count from a scan-style condition: max s32 constant compared LT."""
    consts = []
    for op in cond.ops:
        m = re.match(r"constant\((\d+)\)", op.opcode + "(" + op.rest)
        if op.opcode == "constant":
            mm = re.match(r"(\d+)\)", op.rest)
            if mm:
                consts.append(int(mm.group(1)))
    return max(consts) if consts else 1


# Ops that mark buffer materialization points. Standalone layout/data-
# movement ops (transpose/broadcast/reshape/slice/pad/iota/concatenate) are
# EXCLUDED: the CPU backend leaves them unfused where a TPU compiler would
# fold them into the consumer, and counting them inflates the HBM estimate
# by integer factors on dispatch-heavy (MoE) programs.
_MATERIALIZING = {"fusion", "dot", "copy", "dynamic-slice",
                  "dynamic-update-slice", "convolution", "gather", "scatter",
                  "reduce", "sort", "rng",
                  *COLLECTIVES, *(c + "-start" for c in COLLECTIVES),
                  *(c + "-done" for c in COLLECTIVES)}

_FREE = {"bitcast", "reshape", "get-tuple-element", "tuple", "parameter",
         "constant", "after-all"}


def _called_comps(op: Op) -> list[tuple[str, str]]:
    """(role, computation-name) pairs this op invokes."""
    out = []
    for key in ("condition", "body", "to_apply", "calls"):
        m = re.search(rf"{key}=%?([\w.\-]+)", op.rest)
        if m:
            out.append((key, m.group(1)))
    # conditional: branch_computations={%a, %b}
    m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
    if m:
        for name in re.findall(r"%([\w.\-]+)", m.group(1)):
            out.append(("branch", name))
    return out


_IOTA_RG_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_LIST_RG_RE = re.compile(r"replica_groups=\{(\{[\d,{} ]*\})\}")


def crosses_pod(rest: str, pod_size: int) -> bool:
    """True if any replica group of this collective spans a pod boundary.

    Decodes both the iota form ``[G,S]<=[dims]T(perm)`` and explicit group
    lists. Device i belongs to pod i // pod_size.
    """
    import numpy as np

    m = _IOTA_RG_RE.search(rest)
    if m:
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(d) for d in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(p) for p in m.group(4).split(",")]
            ids = ids.transpose(perm)
        pods = ids.reshape(g, s) // pod_size
        return bool((pods.max(axis=1) != pods.min(axis=1)).any())
    m = _LIST_RG_RE.search(rest)
    if m:
        for grp in re.findall(r"\{([\d, ]*)\}", m.group(1)):
            ids = [int(x) for x in grp.replace(" ", "").split(",") if x]
            if ids and (min(ids) // pod_size) != (max(ids) // pod_size):
                return True
    return False


def analyze_hlo(text: str, pod_size: int = 0) -> dict:
    """Loop-scaled per-device {flops, bytes, collective_bytes{kind}}.

    With ``pod_size`` > 0, collective bytes are additionally split into
    ``collective_bytes_intra`` (groups inside one pod — ICI) and
    ``collective_bytes_cross`` (groups spanning pods — DCN).
    """
    comps, entry = parse_hlo(text)
    memo: dict[str, dict] = {}

    def _zero():
        return {"flops": 0.0, "bytes": 0.0, "coll": defaultdict(float),
                "coll_cross": defaultdict(float)}

    def _add(total, sub, mult=1.0):
        total["flops"] += mult * sub["flops"]
        total["bytes"] += mult * sub["bytes"]
        for k, v in sub["coll"].items():
            total["coll"][k] += mult * v
        for k, v in sub["coll_cross"].items():
            total["coll_cross"][k] += mult * v

    def cost_of(name: str) -> dict:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None:
            return _zero()
        total = _zero()
        memo[name] = total  # guard (no true recursion in HLO)
        for op in comp.ops:
            called = _called_comps(op)
            if op.opcode == "while":
                cond = next((c for r, c in called if r == "condition"), None)
                body = next((c for r, c in called if r == "body"), None)
                trips = _trip_count(comps[cond]) if cond and cond in comps else 1
                if body:
                    _add(total, cost_of(body), trips)
                if cond:
                    _add(total, cost_of(cond), trips)
                continue
            for role, cname in called:
                _add(total, cost_of(cname))

            if op.opcode == "dot":
                total["flops"] += _dot_flops(op, comp)
            base = op.opcode.removesuffix("-start").removesuffix("-done")
            if base in COLLECTIVES and not op.opcode.endswith("-done"):
                b = _type_bytes(op.type_str)
                total["coll"][base] += b
                if pod_size and crosses_pod(op.rest, pod_size):
                    total["coll_cross"][base] += b
            if op.opcode in _MATERIALIZING and op.opcode not in _FREE:
                rb = _type_bytes(op.type_str)
                ob = sum(_type_bytes(comp.shapes.get(o, "")) for o in op.operands)
                total["bytes"] += rb + ob
        return total

    out = cost_of(entry)
    cross = dict(out["coll_cross"])
    intra = {k: v - cross.get(k, 0.0) for k, v in out["coll"].items()}
    return {"flops": out["flops"], "bytes": out["bytes"],
            "collective_bytes": dict(out["coll"]),
            "collective_bytes_cross": cross,
            "collective_bytes_intra": intra}


def xla_cost_analysis(compiled) -> dict:
    """XLA's own (unscaled) cost analysis as a flat dict.

    jaxlib has flipped ``Compiled.cost_analysis()`` between returning a dict
    and a one-element list of dicts across releases; normalize to a dict so
    callers can ``.get`` regardless of the installed version.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})
