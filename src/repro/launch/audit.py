"""Collective-safety audit: static analysis over traced train steps.

Walks the closed jaxprs of representative compiled-step variants — flat,
pipelined, overlap-scheduled, wire-coded, and every pipeline family
adapter — and machine-checks the invariants the EDGC design stands on:

  * **collective parity** — every ``lax.switch``/``cond`` either launches
    identical collective sequences in all branches or branches on a
    predicate provably uniform across the collectives' mesh axes (SPMD
    deadlock freedom; ``repro.analysis.parity``),
  * **psum budgets** — the overlapped executor's switch branches launch
    exactly the collectives the overlap planner declared, and the
    entropy-off variant lowers exactly 3 fewer psums (the ISR gate;
    ``repro.analysis.budget``),
  * **host syncs** — no device->host callback is traced into any step,
    and a short real run keeps the trainer's compile cache
    window-bounded (``repro.analysis.hostcalls``),
  * **source lint** — repo-specific AST rules: duplicate dict keys,
    host calls in jit hot paths, collectives without an explicit axis
    name, unhashable compile-cache keys (``repro.analysis.lint``).

Everything but the trainer run is pure abstract tracing
(``jax.make_jaxpr`` over ShapeDtypeStruct trees — no FLOPs), so zoo
configs audit at production scale on fake host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.audit                  # everything
  PYTHONPATH=src python -m repro.launch.audit --lint-only
  PYTHONPATH=src python -m repro.launch.audit --skip-train     # no real run
  PYTHONPATH=src python -m repro.launch.audit --arch qwen3-moe-235b-a22b \
      --shape train_4k --pipe 4 --overlap                      # zoo config

Exit status is non-zero when any violation survives — CI runs this as a
blocking gate.
"""
# The fake-device flag MUST precede any jax import (device count locks at
# first init). Do NOT move these lines or set this flag anywhere global.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis
from repro.core import EDGCConfig, SyncConfig, classify_leaves, make_plan
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.models.model import ModelConfig, build_model
from repro.optim import adam
from repro.pipeline import PipelineConfig
from repro.pipeline import partition as ppart
from repro.pipeline import sync as psync
from repro.pipeline.schedule import overlap_branch_psums, plan_overlap
from repro.train.step import TrainStepConfig, make_train_step, \
    replicate_comp_state

# Tiny-but-representative configs: one per pipeline family adapter, all
# 2-stage (zamba deliberately ragged — 3 layers over 2 stages).  Shapes
# mirror the pipeline test suite's; the audit only traces them.
FAMILY_CFGS = {
    "dense": ModelConfig(name="audit-dense", family="dense", num_layers=4,
                         d_model=128, num_heads=4, num_kv_heads=2, d_ff=256,
                         vocab_size=512, num_stages=2),
    "moe": ModelConfig(name="audit-moe", family="moe", num_layers=4,
                       d_model=128, num_heads=4, num_kv_heads=2, d_ff=128,
                       vocab_size=512, num_experts=2, experts_per_token=1,
                       capacity_factor=4.0, num_stages=2),
    "xlstm": ModelConfig(name="audit-xlstm", family="xlstm", num_layers=4,
                         d_model=128, num_heads=2, num_kv_heads=2,
                         vocab_size=512, chunk=16, num_stages=2),
    "zamba": ModelConfig(name="audit-zamba", family="zamba", num_layers=3,
                         d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                         vocab_size=512, ssm_state=16, chunk=16,
                         attn_every=2, num_stages=2),
    "whisper": ModelConfig(name="audit-whisper", family="whisper",
                           num_layers=2, encoder_layers=2, d_model=128,
                           num_heads=4, num_kv_heads=4, d_ff=256,
                           vocab_size=512, audio_frames=16,
                           max_position=512, num_stages=2),
    "vlm": ModelConfig(name="audit-vlm", family="vlm", num_layers=2,
                       d_model=128, num_heads=4, num_kv_heads=4, d_ff=256,
                       vocab_size=512, num_patches=4, num_stages=2),
}

LINT_ROOTS = ("src/repro", "tests", "benchmarks", "examples")


def _family_batch(cfg: ModelConfig, B: int = 8, T: int = 16) -> dict:
    """Abstract batch specs for one family (modality stubs included)."""
    tok = jax.ShapeDtypeStruct
    batch = {"tokens": tok((B, T), jnp.int32),
             "labels": tok((B, T), jnp.int32)}
    if cfg.family == "whisper":
        batch["frames"] = tok((B, cfg.audio_frames, cfg.d_model), cfg.jdtype)
    if cfg.family == "vlm":
        batch["patches"] = tok((B, cfg.num_patches, cfg.d_model), cfg.jdtype)
    return batch


def _trace_pipelined(cfg: ModelConfig, mesh, *, overlap: bool,
                     measure_entropy: bool = True, chunk_bytes: int = 1 << 16,
                     sync: SyncConfig | None = None, rank: int = 8):
    """Abstract-trace a pipelined step; return (jaxpr, oplan, splans)."""
    S = cfg.num_stages
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params, cfg.num_layers, S, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=[rank] * S, num_stages=S)
    part = ppart.make_partition(model, S)
    stage_shapes = jax.eval_shape(lambda p: part.partition_params(p)[0],
                                  params)
    sync = sync or SyncConfig()
    splans = psync.make_stage_plans(
        plan, S, psync.stage_local_leaves(stage_shapes),
        bucket_bytes=sync.bucket_bytes, chunk_bytes=chunk_bytes,
        local_path=part.local_leaf_path)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = int(np.prod([sizes.get(a, 1) for a in dp_axes(mesh)])) or 1
    M = S * 2

    def init_state():
        p = model.init(jax.random.PRNGKey(0))
        sp, sh = part.partition_params(p)
        ost = adam.init({"stage": sp, "shared": sh}, adam.AdamConfig())
        comp = psync.init_pipeline_comp_state(p, plan, jax.random.PRNGKey(1),
                                              splans)
        comp = psync.replicate_pipeline_comp_state(comp, world)
        return {"stage_params": sp, "shared_params": sh,
                "opt_m": ost.m, "opt_v": ost.v, "opt_step": ost.step,
                "comp": comp}

    state = jax.eval_shape(init_state)
    scfg = TrainStepConfig(
        mode="dp_tp", policy_plan=plan, measure_entropy=measure_entropy,
        pipeline=PipelineConfig(num_stages=S, schedule="1f1b",
                                num_microbatches=M, overlap_sync=overlap,
                                chunk_bytes=chunk_bytes),
        sync=sync)
    step = make_train_step(model, mesh, scfg)
    traced = jax.make_jaxpr(step)(state, _family_batch(cfg))
    oplan = plan_overlap("1f1b", S, M, splans) if overlap else None
    return traced, oplan, splans


def _trace_flat(cfg: ModelConfig, mesh, *, measure_entropy: bool = True,
                sync: SyncConfig | None = None, rank: int = 8):
    """Abstract-trace the flat (non-pipelined) bucketed step."""
    from repro.core.bucketing import layout_for_tree
    from repro.core.compressor import init_compressor_state

    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params, cfg.num_layers, 1, min_dim=64)
    plan = make_plan("edgc", leaves, stage_ranks=[rank], num_stages=1)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    world = int(np.prod([sizes.get(a, 1) for a in dp_axes(mesh)])) or 1

    def init_state():
        p = model.init(jax.random.PRNGKey(0))
        ost = adam.init(p, adam.AdamConfig())
        layout = layout_for_tree(p, plan)
        comp = init_compressor_state(p, plan, jax.random.PRNGKey(1),
                                     layout=layout)
        comp = replicate_comp_state(comp, world)
        return {"params": p, "opt_m": ost.m, "opt_v": ost.v,
                "opt_step": ost.step, "comp": comp}

    state = jax.eval_shape(init_state)
    scfg = TrainStepConfig(mode="dp_tp", policy_plan=plan,
                           measure_entropy=measure_entropy,
                           sync=sync or SyncConfig(bucketed=True))
    step = make_train_step(model, mesh, scfg)
    return jax.make_jaxpr(step)(state, _family_batch(cfg))


class Report:
    """Violation accumulator with per-target timing."""

    def __init__(self) -> None:
        self.violations: list[tuple[str, analysis.Violation]] = []
        self.targets: list[dict] = []

    def run(self, name: str, fn) -> None:
        t0 = time.time()
        try:
            found = fn()
        except Exception as e:                       # surface, don't crash
            found = [analysis.Violation(
                rule="audit-error", path=name,
                message=f"{type(e).__name__}: {e}")]
        dt = round(time.time() - t0, 1)
        self.violations.extend((name, v) for v in found)
        self.targets.append({"target": name, "violations": len(found),
                             "seconds": dt})
        status = "ok" if not found else f"{len(found)} VIOLATION(S)"
        print(f"  {name:<44} {status}  ({dt}s)")
        for v in found:
            print(f"    {v}")

    def as_json(self) -> dict:
        return {"targets": self.targets,
                "violations": [{"target": t, "rule": v.rule, "path": v.path,
                                "message": v.message}
                               for t, v in self.violations]}


def _audit_step_family(rep: Report, fam: str, *, sync: SyncConfig | None
                       = None, tag: str = "") -> None:
    """Parity + declared-budget + host-sync audit of one family's
    overlapped pipelined step."""
    cfg = FAMILY_CFGS[fam]
    mesh = make_host_mesh(pipe=cfg.num_stages, data=2, model=1)
    name = f"{fam}{tag}:pipelined-overlapped"
    holder: dict = {}

    def go():
        traced, oplan, splans = _trace_pipelined(cfg, mesh, overlap=True,
                                                 sync=sync)
        holder.update(traced=traced, oplan=oplan, splans=splans)
        return analysis.check_collective_parity(traced)

    rep.run(f"{name}:parity", go)
    if not holder:
        return
    rep.run(f"{name}:psum-budget",
            lambda: analysis.check_overlap_branches(
                holder["traced"], holder["oplan"], holder["splans"]))
    rep.run(f"{name}:host-sync",
            lambda: analysis.check_host_transfers(holder["traced"]))


def _audit_entropy_gates(rep: Report) -> None:
    cfg = FAMILY_CFGS["dense"]
    mesh_p = make_host_mesh(pipe=2, data=2, model=1)
    mesh_f = make_host_mesh(data=2, model=1)

    def gate_pipelined():
        on, _, _ = _trace_pipelined(cfg, mesh_p, overlap=True,
                                    measure_entropy=True)
        off, _, _ = _trace_pipelined(cfg, mesh_p, overlap=True,
                                     measure_entropy=False)
        return analysis.check_entropy_gate(on, off, analysis.ENTROPY_PSUMS,
                                           where="dense:pipelined")

    def gate_flat():
        # the flat step measures entropy on already-synced grads: the off
        # variant must lower ZERO fewer collectives (pure compute gate)
        flat_cfg = dataclasses.replace(cfg, num_stages=1)
        on = _trace_flat(flat_cfg, mesh_f, measure_entropy=True)
        off = _trace_flat(flat_cfg, mesh_f, measure_entropy=False)
        return analysis.check_entropy_gate(on, off, 0, where="dense:flat")

    rep.run("dense:pipelined:entropy-gate", gate_pipelined)
    rep.run("dense:flat:entropy-gate", gate_flat)


def _audit_flat(rep: Report) -> None:
    cfg = dataclasses.replace(FAMILY_CFGS["dense"], num_stages=1)
    mesh = make_host_mesh(data=2, model=1)
    holder: dict = {}

    def go():
        traced = _trace_flat(cfg, mesh)
        holder["traced"] = traced
        return analysis.check_collective_parity(traced)

    rep.run("dense:flat:parity", go)
    if holder:
        rep.run("dense:flat:host-sync",
                lambda: analysis.check_host_transfers(holder["traced"]))


def _audit_trainer_cache(rep: Report) -> None:
    """Short REAL run; prove compiled-step variants stay window-bounded."""
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = dataclasses.replace(FAMILY_CFGS["dense"], num_layers=2, d_model=64,
                              d_ff=128, num_stages=1)

    def go():
        mesh = make_host_mesh(data=2, model=1)
        model = build_model(cfg)
        edgc = EDGCConfig()
        edgc = dataclasses.replace(
            edgc, dac=dataclasses.replace(edgc.dac, window=3))
        tr = Trainer(model, mesh, edgc,
                     TrainerConfig(total_steps=6, log_every=100))
        rng = np.random.default_rng(0)

        def data():
            while True:
                toks = rng.integers(0, cfg.vocab_size,
                                    (8, 16)).astype(np.int32)
                yield {"tokens": toks, "labels": toks}

        tr.run(data())
        return analysis.audit_recompiles(tr)

    rep.run("trainer:recompile-window", go)


def _audit_lint(rep: Report) -> None:
    roots = [r for r in LINT_ROOTS if os.path.isdir(r)]

    def go():
        return [analysis.Violation(rule=f.rule, path=f"{f.file}:{f.line}",
                                   message=f.message)
                for f in analysis.run_lint(roots)]

    rep.run(f"lint:{','.join(roots)}", go)


def _audit_zoo(rep: Report, arch: str, shape: str, pipe: int,
               overlap: bool) -> None:
    """Frontier-scale audit of one zoo config — abstract tracing only, so
    a 235B MoE on a 256-chip mesh walks in seconds."""
    from repro.configs import get_config
    from repro.launch.dryrun import input_specs
    from repro.launch.mesh import make_production_mesh, pipe_size
    from repro.pipeline.partition import pipeline_supported

    cfg = get_config(arch, "full")
    mesh = make_production_mesh(pipe=pipe)
    S = pipe_size(mesh)
    cfg = dataclasses.replace(cfg, num_stages=S)
    reason = pipeline_supported(cfg, S)
    if reason is not None:
        print(f"  zoo:{arch}: skipped ({reason})")
        return
    batch = input_specs(cfg, shape)
    holder: dict = {}

    def go():
        traced, oplan, splans = _trace_zoo(cfg, mesh, batch, overlap)
        holder.update(traced=traced, oplan=oplan, splans=splans)
        return analysis.check_collective_parity(traced)

    def _trace_zoo(cfg, mesh, batch, overlap):
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        leaves = classify_leaves(params, cfg.num_layers, S, min_dim=128)
        plan = make_plan("edgc", leaves, stage_ranks=[64] * S, num_stages=S)
        part = ppart.make_partition(model, S)
        stage_shapes = jax.eval_shape(
            lambda p: part.partition_params(p)[0], params)
        splans = psync.make_stage_plans(
            plan, S, psync.stage_local_leaves(stage_shapes),
            chunk_bytes=1 << 22, local_path=part.local_leaf_path)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        world = int(np.prod([sizes.get(a, 1)
                             for a in dp_axes(mesh)])) or 1
        M = S

        def init_state():
            p = model.init(jax.random.PRNGKey(0))
            sp, sh = part.partition_params(p)
            ost = adam.init({"stage": sp, "shared": sh}, adam.AdamConfig())
            comp = psync.init_pipeline_comp_state(
                p, plan, jax.random.PRNGKey(1), splans)
            comp = psync.replicate_pipeline_comp_state(comp, world)
            return {"stage_params": sp, "shared_params": sh,
                    "opt_m": ost.m, "opt_v": ost.v, "opt_step": ost.step,
                    "comp": comp}

        state = jax.eval_shape(init_state)
        scfg = TrainStepConfig(
            mode="dp_tp", policy_plan=plan, measure_entropy=True,
            remat=cfg.remat,
            pipeline=PipelineConfig(num_stages=S, schedule="1f1b",
                                    num_microbatches=M,
                                    overlap_sync=overlap,
                                    chunk_bytes=1 << 22))
        step = make_train_step(model, mesh, scfg)
        traced = jax.make_jaxpr(step)(state, batch)
        oplan = plan_overlap("1f1b", S, M, splans) if overlap else None
        return traced, oplan, splans

    rep.run(f"zoo:{arch}:{shape}:parity", go)
    if not holder:
        return
    rep.run(f"zoo:{arch}:{shape}:host-sync",
            lambda: analysis.check_host_transfers(holder["traced"]))
    if overlap and holder["oplan"] is not None:
        rep.run(f"zoo:{arch}:{shape}:psum-budget",
                lambda: analysis.check_overlap_branches(
                    holder["traced"], holder["oplan"], holder["splans"]))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Collective-safety audit (parity / budgets / host "
                    "syncs / lint) over traced train-step variants.")
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--skip-lint", action="store_true")
    ap.add_argument("--skip-train", action="store_true",
                    help="skip the short real trainer run (cache audit)")
    ap.add_argument("--families", default=None,
                    help=f"comma list from {sorted(FAMILY_CFGS)} "
                         f"(default: all)")
    ap.add_argument("--arch", default=None,
                    help="audit one zoo config instead of the built-ins")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--pipe", type=int, default=4)
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--out", default=None, help="write a JSON report")
    args = ap.parse_args(argv)

    rep = Report()
    print("collective-safety audit")
    if not args.skip_lint:
        _audit_lint(rep)
    if args.lint_only:
        pass
    elif args.arch:
        _audit_zoo(rep, args.arch, args.shape, args.pipe, args.overlap)
    else:
        _audit_flat(rep)
        _audit_entropy_gates(rep)
        fams = (args.families.split(",") if args.families
                else list(FAMILY_CFGS))
        for fam in fams:
            _audit_step_family(rep, fam)
        # the wire-coded executor swaps packed payloads under the same
        # collectives: the switch budgets must survive the codec
        _audit_step_family(rep, "dense", sync=SyncConfig(wire="quant8"),
                           tag="+quant8")
        if not args.skip_train:
            _audit_trainer_cache(rep)

    n = len(rep.violations)
    print(f"{len(rep.targets)} target(s), {n} violation(s)")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(rep.as_json(), fh, indent=2)
    return 1 if n else 0


if __name__ == "__main__":
    sys.exit(main())
