"""Training driver.

CPU-runnable end to end with reduced configs; the same flags drive the
production mesh on real hardware.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --variant reduced \
      --policy edgc --steps 300 --window 50
  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --variant reduced \
      --policy fixed --rank 32 --steps 200

Pipeline parallelism: ``--pipe S`` adds a ``pipe`` axis of size S to the
mesh (total devices = pipe * data * model), rebuilds the model config with
``num_stages=S``, and routes the Trainer through the pipelined executor
(family permitting — the stage adapter's reason is surfaced otherwise).
``--pipe 1`` exercises the full pipelined path on a single device:

  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --pipe 1 \
      --micro 2 --policy edgc --steps 100

Elastic outer loop: ``--outer-k K`` routes through the DiLoCo-style
ElasticTrainer — ``--pods`` pod-local inner Trainers (one device each; set
XLA_FLAGS=--xla_force_host_platform_device_count=N to simulate pods), K
inner steps per outer round, EDGC-compressed outer-delta all-reduce.
``--inject`` schedules faults; ``--recover`` arms the recovery policies:

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --outer-k 20 \
      --pods 2 --rounds 8 --recover \
      --inject 'nan_grad@30,pod_drop:1@r3,pod_join@r5'
"""
from __future__ import annotations

import argparse
import dataclasses
import json


from repro.configs import ARCHS, get_config
from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM, add_modality_stubs
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2", choices=sorted(ARCHS))
    ap.add_argument("--variant", default="reduced", choices=["full", "reduced"])
    ap.add_argument("--policy", default="edgc",
                    choices=["none", "fixed", "optimus", "edgc"])
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--window", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--stages", type=int, default=0, help="0 = config default")
    ap.add_argument("--pipe", type=int, default=0,
                    help="pipeline stages: adds a 'pipe' mesh axis and runs "
                         "the pipelined (GPipe/1F1B) executor")
    ap.add_argument("--schedule", default="1f1b", choices=["gpipe", "1f1b"])
    ap.add_argument("--micro", type=int, default=0,
                    help="microbatches per step (0 -> num_stages)")
    ap.add_argument("--stash", default="replay",
                    choices=["replay", "full", "every_k"],
                    help="pipeline activation stashing: replay re-derives "
                         "each stage's forward in its backward (memory "
                         "floor); full/every_k stash inter-unit carries "
                         "into a second ring and replay only the un-stashed "
                         "segments")
    ap.add_argument("--stash-every", type=int, default=2,
                    help="k for --stash every_k")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap each stage's DP sync with the pipeline "
                         "drain: sync chunks launch inside the schedule's "
                         "free back-of-drain ticks instead of after the "
                         "loop (pipelined executor only)")
    ap.add_argument("--chunk-bytes", type=int, default=0,
                    help="split flat sync buckets into transfer chunks of "
                         "at most this many bytes for overlap scheduling "
                         "(0 = one chunk per bucket)")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--wire", default="raw",
                    choices=["raw", "quant8", "quant4", "entropy"],
                    help="lossless-training wire coding of the DP sync "
                         "payloads: scaled int8/int4 quantization + bit "
                         "packing with error feedback; 'entropy' picks the "
                         "bit width per window from the controller's "
                         "entropy reading (quant8 until the first one)")
    # ---- fault injection + recovery -------------------------------------
    ap.add_argument("--inject", default=None,
                    help="comma-separated fault specs kind[:arg]@N (step) "
                         "or kind[:arg]@rN (outer round); kinds: nan_grad, "
                         "corrupt_payload, torn_ckpt, pod_drop, pod_join. "
                         "e.g. 'nan_grad@40,pod_drop:1@r3'")
    ap.add_argument("--recover", action="store_true",
                    help="arm the recovery policies: non-finite step guard "
                         "+ error-feedback reset, loss-spike rollback to "
                         "the checkpoint ring, uncompressed-sync fallback "
                         "after repeated anomalies")
    ap.add_argument("--spike-factor", type=float, default=4.0,
                    help="loss > factor * EMA counts as an anomaly")
    ap.add_argument("--max-rollbacks", type=int, default=3)
    ap.add_argument("--fallback-after", type=int, default=4,
                    help="anomalies before pinning uncompressed sync")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in steps (rollback needs > 0)")
    ap.add_argument("--ckpt-path", default="ckpt/state")
    # ---- elastic DiLoCo outer loop --------------------------------------
    ap.add_argument("--outer-k", type=int, default=0,
                    help="> 0 routes through the elastic outer loop: K "
                         "inner steps per pod per outer round")
    ap.add_argument("--pods", type=int, default=2,
                    help="initial pod count (needs that many devices)")
    ap.add_argument("--rounds", type=int, default=10,
                    help="outer rounds to run")
    ap.add_argument("--outer-lr", type=float, default=0.7)
    ap.add_argument("--outer-momentum", type=float, default=0.9)
    ap.add_argument("--outer-policy", default="edgc",
                    choices=["none", "fixed", "edgc"],
                    help="outer-delta compression policy")
    ap.add_argument("--outer-rank", type=int, default=32)
    ap.add_argument("--outer-window", type=int, default=2,
                    help="outer DAC window, counted in ROUNDS")
    # ---- observability (repro.obs) --------------------------------------
    ap.add_argument("--metrics-dir", default=None,
                    help="write structured telemetry (scalars/series/events) "
                         "as JSONL to <dir>/metrics.jsonl; read it back with "
                         "python -m repro.launch.report <dir>")
    ap.add_argument("--trace", default=None,
                    help="emit a Chrome trace-event JSON of the pipeline "
                         "schedule (Perfetto-loadable) to this path, with "
                         "tick durations scaled to the measured mean step "
                         "time (pipelined runs only)")
    ap.add_argument("--profile", default=None, metavar="LOGDIR",
                    help="wrap the run in a jax.profiler trace written to "
                         "LOGDIR (view with TensorBoard/Perfetto)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.train.faults import RecoveryConfig, parse_inject
    faults = parse_inject(args.inject) if args.inject else None
    recovery = RecoveryConfig(
        spike_factor=args.spike_factor, max_rollbacks=args.max_rollbacks,
        fallback_after=args.fallback_after) if args.recover else None
    if args.outer_k and args.pipe:
        raise SystemExit("--outer-k does not compose with --pipe: the outer "
                         "loop wraps flat pod-local trainers")
    if args.outer_k:
        total_steps = args.outer_k * args.rounds
    else:
        total_steps = args.steps

    cfg = get_config(args.arch, args.variant)
    if args.pipe:
        from repro.pipeline.partition import pipeline_supported
        if args.stages and args.stages != args.pipe:
            raise SystemExit(f"--pipe {args.pipe} conflicts with --stages "
                             f"{args.stages}: the pipe axis size IS the "
                             "stage count")
        num_stages = args.pipe
        cfg = dataclasses.replace(cfg, num_stages=num_stages)
        reason = pipeline_supported(cfg, num_stages)
        if reason is not None:
            raise SystemExit(f"--pipe {args.pipe} unsupported for "
                             f"{cfg.name}: {reason}")
        mesh = make_host_mesh(pipe=args.pipe, data=args.data_mesh,
                              model=args.model_mesh)
    else:
        num_stages = args.stages or cfg.num_stages
        mesh = make_host_mesh(data=args.data_mesh, model=args.model_mesh)
    model = build_model(cfg)

    # The unified config surface: one PipelineConfig + one SyncConfig,
    # shared by the EDGC controller, the Trainer, and (by identity) every
    # step build.
    from repro.core import SyncConfig
    from repro.pipeline import PipelineConfig
    pipe_cfg = PipelineConfig(
        num_stages=num_stages, schedule=args.schedule,
        num_microbatches=args.micro, stash_policy=args.stash,
        stash_every=args.stash_every, overlap_sync=args.overlap,
        chunk_bytes=args.chunk_bytes,
    )
    sync_cfg = SyncConfig(use_kernels=args.use_kernels, wire=args.wire)

    edgc = EDGCConfig(
        policy=args.policy, fixed_rank=args.rank,
        total_iterations=total_steps,
        gds=GDSConfig(alpha=0.5, beta=0.25),
        dac=DACConfig(window=args.window, adjust_limit=4),
        pipeline=pipe_cfg, sync=sync_cfg,
    )
    tcfg = TrainerConfig(
        total_steps=total_steps, log_every=max(1, total_steps // 20),
        ckpt_every=args.ckpt_every, ckpt_path=args.ckpt_path,
        recovery=recovery, faults=faults,
        pipeline=pipe_cfg, sync=sync_cfg,
        metrics_dir=args.metrics_dir,
        adam=AdamConfig(lr=args.lr, warmup_steps=max(10, total_steps // 10),
                        total_steps=total_steps),
    )
    from repro.obs import profiler_session

    def pod_batches(pod: int):
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           batch_size=args.batch, seed=args.seed + 1000 * pod)
        for b in data.batches():
            yield add_modality_stubs(b, cfg.family,
                                     audio_frames=cfg.audio_frames,
                                     num_patches=cfg.num_patches,
                                     d_model=cfg.d_model, seed=args.seed)

    if args.outer_k:
        from repro.optim.outer import OuterConfig
        from repro.train.elastic import ElasticTrainer
        ocfg = OuterConfig(outer_k=args.outer_k, lr=args.outer_lr,
                           momentum=args.outer_momentum,
                           policy=args.outer_policy,
                           fixed_rank=args.outer_rank,
                           window=args.outer_window,
                           total_rounds=args.rounds)
        et = ElasticTrainer(model, edgc, tcfg, ocfg, args.pods,
                            pod_batches, seed=args.seed)
        print(f"{cfg.name}: elastic outer loop, {args.pods} pods x "
              f"K={args.outer_k} inner steps, outer policy="
              f"{args.outer_policy}, {args.rounds} rounds"
              + (f", inject={args.inject}" if args.inject else ""))
        with profiler_session(bool(args.profile), args.profile or "profile"):
            hist = et.run_rounds(args.rounds)
        et.metrics.close()
        for h in hist:
            ev = f" {h['membership_events']}" if h["membership_events"] else ""
            losses = "/".join(f"{x:.3f}" for x in h["pod_losses"])
            print(f"round {h['round']:4d} pods {h['n_pods']} "
                  f"loss {losses} H {h['entropy']:+.3f} "
                  f"outer-bytes {h['bytes_synced']}/{h['bytes_full']}{ev}")
        print(f"outer comm savings vs raw fp32: {et.outer.comm_savings():.2%}")
        if et.pods[0].recovery is not None:
            print(f"recovery: {et.pods[0].recovery.as_dict()}")
        if args.out:
            with open(args.out, "w") as f:
                json.dump({"history": hist, "arch": cfg.name,
                           "outer": dataclasses.asdict(ocfg),
                           "comm_savings": et.outer.comm_savings()},
                          f, indent=1)
        return

    trainer = Trainer(model, mesh, edgc, tcfg, seed=args.seed)
    pipe_tag = (f", pipe={args.pipe} ({args.schedule}, stash={args.stash}"
                f"{', overlapped sync' if args.overlap else ''})"
                if args.pipe else "")
    print(f"{cfg.name}: {trainer.n_params/1e6:.1f}M params, "
          f"policy={args.policy}{pipe_tag}, {trainer.controller.describe()}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=args.seed)

    def batches():
        for b in data.batches():
            yield add_modality_stubs(b, cfg.family,
                                     audio_frames=cfg.audio_frames,
                                     num_patches=cfg.num_patches,
                                     d_model=cfg.d_model, seed=args.seed)

    with profiler_session(bool(args.profile), args.profile or "profile"):
        hist = trainer.run(batches())
    for h in hist:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} H {h['entropy']:+.3f} "
              f"ranks {h['ranks']} comm-saved "
              f"{1 - h['bytes_synced']/max(1, h['bytes_full']):.1%}")
    print(f"final comm savings vs no-compression: {trainer.comm_savings():.2%}")
    if args.wire != "raw" and trainer.bytes_wire_raw:
        print(f"wire coding ({args.wire}): {trainer.bytes_synced}/"
              f"{trainer.bytes_wire_raw} coded/raw payload bytes "
              f"({trainer.bytes_synced / trainer.bytes_wire_raw:.2%})")

    if args.trace:
        if not args.pipe:
            raise SystemExit("--trace requires --pipe: the tick tracer "
                             "renders the pipeline schedule")
        from repro.obs import (load_trace, tick_trace_events, validate_trace,
                               write_chrome_trace)
        from repro.pipeline.schedule import simulate_schedule
        S, M = args.pipe, (args.micro or args.pipe)
        sim = simulate_schedule(args.schedule, S, M)
        # Scale the unit-tick spans so the trace's makespan matches the
        # measured mean step wall time (first->last history record).
        if len(hist) >= 2 and hist[-1]["step"] > hist[0]["step"]:
            mean_step_s = ((hist[-1]["wall_s"] - hist[0]["wall_s"])
                           / (hist[-1]["step"] - hist[0]["step"]))
        else:
            mean_step_s = float(sim["makespan"])
        scale = mean_step_s / float(sim["makespan"])
        events = tick_trace_events(
            args.schedule, S, M, t_f=scale, t_b=scale,
            sync_plan=trainer.overlap_plan, stash_policy=args.stash,
            n_units=trainer._part.num_units(), stash_every=args.stash_every,
            time_unit_us=1e6)
        write_chrome_trace(args.trace, events, metadata={
            "arch": cfg.name, "schedule": args.schedule, "S": S, "M": M,
            "mean_step_s": mean_step_s})
        summary = validate_trace(load_trace(args.trace))
        print(f"trace: {args.trace} — {summary['spans']} spans on "
              f"{summary['tracks']} stage tracks, "
              f"{summary['end_us']/1e6:.3f}s span horizon")
    trainer.metrics.close()
    if trainer.recovery is not None:
        print(f"recovery: {trainer.recovery.as_dict()}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": hist, "arch": cfg.name,
                       "policy": args.policy,
                       "comm_savings": trainer.comm_savings()}, f, indent=1)


if __name__ == "__main__":
    main()
