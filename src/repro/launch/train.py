"""Training driver.

CPU-runnable end to end with reduced configs; the same flags drive the
production mesh on real hardware.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --variant reduced \
      --policy edgc --steps 300 --window 50
  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --variant reduced \
      --policy fixed --rank 32 --steps 200

Pipeline parallelism: ``--pipe S`` adds a ``pipe`` axis of size S to the
mesh (total devices = pipe * data * model), rebuilds the model config with
``num_stages=S``, and routes the Trainer through the pipelined executor
(family permitting — the stage adapter's reason is surfaced otherwise).
``--pipe 1`` exercises the full pipelined path on a single device:

  PYTHONPATH=src python -m repro.launch.train --arch gpt2 --pipe 1 \
      --micro 2 --policy edgc --steps 100
"""
from __future__ import annotations

import argparse
import dataclasses
import json


from repro.configs import ARCHS, get_config
from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM, add_modality_stubs
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2", choices=sorted(ARCHS))
    ap.add_argument("--variant", default="reduced", choices=["full", "reduced"])
    ap.add_argument("--policy", default="edgc",
                    choices=["none", "fixed", "optimus", "edgc"])
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--window", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--stages", type=int, default=0, help="0 = config default")
    ap.add_argument("--pipe", type=int, default=0,
                    help="pipeline stages: adds a 'pipe' mesh axis and runs "
                         "the pipelined (GPipe/1F1B) executor")
    ap.add_argument("--schedule", default="1f1b", choices=["gpipe", "1f1b"])
    ap.add_argument("--micro", type=int, default=0,
                    help="microbatches per step (0 -> num_stages)")
    ap.add_argument("--stash", default="replay",
                    choices=["replay", "full", "every_k"],
                    help="pipeline activation stashing: replay re-derives "
                         "each stage's forward in its backward (memory "
                         "floor); full/every_k stash inter-unit carries "
                         "into a second ring and replay only the un-stashed "
                         "segments")
    ap.add_argument("--stash-every", type=int, default=2,
                    help="k for --stash every_k")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap each stage's DP sync with the pipeline "
                         "drain: sync chunks launch inside the schedule's "
                         "free back-of-drain ticks instead of after the "
                         "loop (pipelined executor only)")
    ap.add_argument("--chunk-bytes", type=int, default=0,
                    help="split flat sync buckets into transfer chunks of "
                         "at most this many bytes for overlap scheduling "
                         "(0 = one chunk per bucket)")
    ap.add_argument("--data-mesh", type=int, default=1)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--use-kernels", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    if args.pipe:
        from repro.pipeline.partition import pipeline_supported
        if args.stages and args.stages != args.pipe:
            raise SystemExit(f"--pipe {args.pipe} conflicts with --stages "
                             f"{args.stages}: the pipe axis size IS the "
                             "stage count")
        num_stages = args.pipe
        cfg = dataclasses.replace(cfg, num_stages=num_stages)
        reason = pipeline_supported(cfg, num_stages)
        if reason is not None:
            raise SystemExit(f"--pipe {args.pipe} unsupported for "
                             f"{cfg.name}: {reason}")
        mesh = make_host_mesh(pipe=args.pipe, data=args.data_mesh,
                              model=args.model_mesh)
    else:
        num_stages = args.stages or cfg.num_stages
        mesh = make_host_mesh(data=args.data_mesh, model=args.model_mesh)
    model = build_model(cfg)

    # The unified config surface: one PipelineConfig + one SyncConfig,
    # shared by the EDGC controller, the Trainer, and (by identity) every
    # step build.
    from repro.core import SyncConfig
    from repro.pipeline import PipelineConfig
    pipe_cfg = PipelineConfig(
        num_stages=num_stages, schedule=args.schedule,
        num_microbatches=args.micro, stash_policy=args.stash,
        stash_every=args.stash_every, overlap_sync=args.overlap,
        chunk_bytes=args.chunk_bytes,
    )
    sync_cfg = SyncConfig(use_kernels=args.use_kernels)

    edgc = EDGCConfig(
        policy=args.policy, fixed_rank=args.rank,
        total_iterations=args.steps,
        gds=GDSConfig(alpha=0.5, beta=0.25),
        dac=DACConfig(window=args.window, adjust_limit=4),
        pipeline=pipe_cfg, sync=sync_cfg,
    )
    tcfg = TrainerConfig(
        total_steps=args.steps, log_every=max(1, args.steps // 20),
        pipeline=pipe_cfg, sync=sync_cfg,
        adam=AdamConfig(lr=args.lr, warmup_steps=max(10, args.steps // 10),
                        total_steps=args.steps),
    )
    trainer = Trainer(model, mesh, edgc, tcfg, seed=args.seed)
    pipe_tag = (f", pipe={args.pipe} ({args.schedule}, stash={args.stash}"
                f"{', overlapped sync' if args.overlap else ''})"
                if args.pipe else "")
    print(f"{cfg.name}: {trainer.n_params/1e6:.1f}M params, "
          f"policy={args.policy}{pipe_tag}, {trainer.controller.describe()}")

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       batch_size=args.batch, seed=args.seed)

    def batches():
        for b in data.batches():
            yield add_modality_stubs(b, cfg.family,
                                     audio_frames=cfg.audio_frames,
                                     num_patches=cfg.num_patches,
                                     d_model=cfg.d_model, seed=args.seed)

    hist = trainer.run(batches())
    for h in hist:
        print(f"step {h['step']:5d} loss {h['loss']:.4f} H {h['entropy']:+.3f} "
              f"ranks {h['ranks']} comm-saved "
              f"{1 - h['bytes_synced']/max(1, h['bytes_full']):.1%}")
    print(f"final comm savings vs no-compression: {trainer.comm_savings():.2%}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": hist, "arch": cfg.name,
                       "policy": args.policy,
                       "comm_savings": trainer.comm_savings()}, f, indent=1)


if __name__ == "__main__":
    main()
