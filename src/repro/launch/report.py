"""Run-report CLI: turn a telemetry JSONL stream into a readable summary.

``python -m repro.launch.report RUN_DIR`` (or a metrics.jsonl path) prints
what a run did — entropy and DAC-rank trajectories, wire bytes saved vs the
uncompressed baseline, pipeline bubble fraction, measured step time, and the
fault/recovery timeline — all from the structured records the trainer's
``MetricsRegistry`` emitted. No JAX import is needed to read a report;
``--trace`` (re-emit a Chrome trace from the run's schedule shape and
measured step time) is the only path that touches the schedule simulator.

    python -m repro.launch.report runs/obs_run
    python -m repro.launch.report runs/obs_run --trace trace.json --csv m.csv
"""
from __future__ import annotations

import argparse
import os

from repro.obs.metrics import read_jsonl, write_csv

__all__ = ["build_report", "main"]


def _find_jsonl(path: str) -> str:
    if os.path.isdir(path):
        cand = os.path.join(path, "metrics.jsonl")
        if not os.path.exists(cand):
            raise SystemExit(f"no metrics.jsonl in {path}")
        return cand
    return path


def _traj(pairs: list[tuple[int, float]]) -> str:
    """first -> last (min/max over the run) for a scalar trajectory."""
    vals = [v for _, v in pairs]
    return (f"{vals[0]:.4g} -> {vals[-1]:.4g}  "
            f"(min {min(vals):.4g}, max {max(vals):.4g}, n={len(vals)})")


def _scalars(records: list[dict], name: str) -> list[tuple[int, float]]:
    return [(r["step"], r["value"]) for r in records
            if r.get("kind") == "scalar" and r.get("name") == name]


def _series(records: list[dict], name: str) -> list[tuple[int, list]]:
    return [(r["step"], r["values"]) for r in records
            if r.get("kind") == "series" and r.get("name") == name]


def _events(records: list[dict], name: str | None = None) -> list[dict]:
    return [r for r in records if r.get("kind") == "event"
            and (name is None or r.get("name") == name)]


def build_report(records: list[dict]) -> list[str]:
    """Render the text report as a list of lines (testable without I/O)."""
    lines: list[str] = []
    meta = next((e for e in _events(records, "run_meta")), None)
    if meta is not None:
        d = meta.get("data", {})
        lines.append(f"run: {d.get('model')} ({d.get('family')}) "
                     f"policy={d.get('policy')} world={d.get('world')} "
                     f"steps={d.get('total_steps')}")
        if d.get("pipelined"):
            S, M = d.get("num_stages"), d.get("num_microbatches")
            lines.append(f"pipeline: S={S} M={M} {d.get('schedule')} "
                         f"stash={d.get('stash_policy')} "
                         f"overlap_sync={d.get('overlap_sync')}")
            try:
                from repro.pipeline.schedule import bubble_fraction
                lines.append(
                    f"bubble fraction: {bubble_fraction(S, M):.3f} "
                    f"((S-1)/(M+S-1), schedule-ideal)")
            except Exception:
                pass
    plan = next((e for e in _events(records, "overlap_plan")), None)
    if plan is not None:
        d = plan.get("data", {})
        lines.append(f"overlap plan: in-loop {d.get('in_loop_chunks')} "
                     f"residual {d.get('residual_chunks')} chunks, "
                     f"slack util {d.get('slack_utilization', 0):.2f}, "
                     f"feasible={d.get('feasible')}")

    for name, label in (("loss", "loss"), ("entropy", "entropy"),
                        ("ef_norm", "EF norm"), ("grad_norm", "grad norm")):
        pairs = _scalars(records, name)
        if pairs:
            lines.append(f"{label}: {_traj(pairs)}")

    ranks = _series(records, "dac_applied_ranks")
    if ranks:
        first, last = ranks[0], ranks[-1]
        lines.append(f"DAC ranks: step {first[0]} {first[1]} -> "
                     f"step {last[0]} {last[1]}")
    stage_ent = _series(records, "stage_entropy")
    if stage_ent:
        last = stage_ent[-1]
        lines.append("stage entropy (last): "
                     + " ".join(f"{v:.3f}" for v in last[1]))

    syn = _scalars(records, "bytes_synced")
    full = _scalars(records, "bytes_full")
    if syn and full:
        b_syn, b_full = syn[-1][1], full[-1][1]
        saved = b_full - b_syn
        ratio = b_full / b_syn if b_syn else float("inf")
        lines.append(f"wire bytes: {b_syn / 2**20:.1f} MiB compressed vs "
                     f"{b_full / 2**20:.1f} MiB raw "
                     f"({saved / 2**20:.1f} MiB saved, {ratio:.1f}x)")
    coded = _scalars(records, "wire_bytes_coded")
    raw = _scalars(records, "wire_bytes_raw")
    if coded and raw:
        b_c, b_r = coded[-1][1], raw[-1][1]
        bits = _scalars(records, "wire_bits")
        tag = (f", {int(bits[-1][1])}-bit last" if bits else "")
        lines.append(f"wire coding: {b_c / 2**20:.1f} MiB coded vs "
                     f"{b_r / 2**20:.1f} MiB uncoded payload "
                     f"({b_c / b_r:.2f}x raw{tag})" if b_r else
                     "wire coding: active (no payload bytes recorded)")
    swb = _series(records, "stage_wire_bytes")
    if swb:
        lines.append("per-stage wire bytes (last): "
                     + " ".join(str(int(v)) for v in swb[-1][1]))

    walls = _scalars(records, "wall_s")
    if len(walls) >= 2:
        dt = (walls[-1][1] - walls[0][1]) / max(1, walls[-1][0] - walls[0][0])
        lines.append(f"measured step time: {dt * 1e3:.1f} ms/step "
                     f"(over steps {walls[0][0]}..{walls[-1][0]})")

    timeline = [e for e in _events(records)
                if e.get("name") in ("fault_injected", "guard_skip",
                                     "ef_reset", "rollback", "recovered",
                                     "pod_drop", "pod_join",
                                     "telemetry_resume")]
    if timeline:
        lines.append("fault/recovery timeline:")
        for e in timeline:
            d = e.get("data", {})
            detail = " ".join(f"{k}={v}" for k, v in sorted(d.items()))
            lines.append(f"  step {e.get('step')}: {e.get('name')}"
                         + (f" ({detail})" if detail else ""))

    rounds = _events(records, "outer_round")
    if rounds:
        last = rounds[-1].get("data", {})
        lines.append(f"elastic: {len(rounds)} outer rounds, final "
                     f"n_pods={last.get('n_pods')} "
                     f"pod_losses={last.get('pod_losses')}")

    counters: dict[str, float] = {}
    for r in records:
        if r.get("kind") == "counter":
            counters[r["name"]] = counters.get(r["name"], 0) + r["value"]
    for name, total in sorted(counters.items()):
        lines.append(f"counter {name}: {total:g}")
    if not lines:
        lines.append("(no recognizable telemetry records)")
    return lines


def _emit_trace(records: list[dict], path: str) -> None:
    meta = next((e for e in _events(records, "run_meta")), None)
    if meta is None or not meta.get("data", {}).get("pipelined"):
        raise SystemExit("--trace needs a run_meta event from a pipelined run")
    d = meta["data"]
    S, M = int(d["num_stages"]), int(d["num_microbatches"])
    schedule = d.get("schedule", "1f1b")
    from repro.obs.trace import (tick_trace_events, validate_trace,
                                 write_chrome_trace)
    from repro.pipeline.schedule import simulate_schedule
    walls = _scalars(records, "wall_s")
    sim = simulate_schedule(schedule, S, M)
    if len(walls) >= 2:
        dt = (walls[-1][1] - walls[0][1]) / max(1, walls[-1][0] - walls[0][0])
        scale = dt / float(sim["makespan"])
    else:
        scale = 1e-3
    events = tick_trace_events(schedule, S, M, t_f=scale, t_b=scale,
                               time_unit_us=1e6)
    write_chrome_trace(path, events,
                       metadata={"source": "report", "schedule": schedule,
                                 "num_stages": S, "num_microbatches": M})
    stats = validate_trace({"traceEvents": events})
    print(f"trace: {path} ({stats['spans']} spans, "
          f"{stats['tracks']} tracks)")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="summarize a telemetry JSONL run record")
    ap.add_argument("run", help="run directory (containing metrics.jsonl) "
                                "or a .jsonl path")
    ap.add_argument("--trace", default=None,
                    help="re-emit a Chrome trace JSON from the run's "
                         "schedule shape and measured step time")
    ap.add_argument("--csv", default=None,
                    help="export scalar/series/counter records as CSV")
    args = ap.parse_args()

    path = _find_jsonl(args.run)
    records = read_jsonl(path)
    print(f"{path}: {len(records)} records")
    for line in build_report(records):
        print(line)
    if args.csv:
        write_csv(records, args.csv)
        print(f"csv: {args.csv}")
    if args.trace:
        _emit_trace(records, args.trace)


if __name__ == "__main__":
    main()
