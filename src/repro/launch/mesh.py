"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests/benches must keep seeing 1 device.

Mesh shapes (per the brief):
  single-pod : (16, 16)       axes (data, model)        = 256 chips
  multi-pod  : (2, 16, 16)    axes (pod, data, model)   = 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "dp_axes", "tp_axis"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (fake or real) devices exist — tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (manual / EDGC-compressed) axes of a mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None
