"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests/benches must keep seeing 1 device.

Mesh shapes (per the brief):
  single-pod : (16, 16)       axes (data, model)        = 256 chips
  multi-pod  : (2, 16, 16)    axes (pod, data, model)   = 512 chips

With pipeline parallelism (``pipe`` stages), the pipe axis splits the data
axis — total chip count is unchanged, the DP width shrinks by ``pipe``:
  single-pod : (pipe, 16/?, 16)      axes (pipe, data, model)
  multi-pod  : (2, pipe, ?, 16)      axes (pod, pipe, data, model)
Pod stays outermost (cross-pod links are the scarce resource); pipe sits
between pod and data so each pipeline stage owns a contiguous DP group.
"""
from __future__ import annotations

import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "make_pod_mesh",
    "dp_axes",
    "tp_axis",
    "pipe_size",
]


def make_production_mesh(*, multi_pod: bool = False, pipe: int = 0):
    if pipe and pipe > 1:
        data = (16 * 16) // (pipe * 16)
        if data < 1 or (pipe * data * 16) != 256:
            raise ValueError(f"pipe={pipe} does not divide the 256-chip pod")
        if multi_pod:
            return jax.make_mesh((2, pipe, data, 16),
                                 ("pod", "pipe", "data", "model"))
        return jax.make_mesh((pipe, data, 16), ("pipe", "data", "model"))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0, pipe: int = 0,
                   devices=None):
    """Small mesh over however many (fake or real) devices exist — tests.

    ``devices``: explicit device subset (elastic training builds one
    pod-local mesh per pod over disjoint subsets; ``None`` = all devices).
    """
    kw = {} if devices is None else {"devices": devices}
    if pipe:
        if pod:
            return jax.make_mesh((pod, pipe, data, model),
                                 ("pod", "pipe", "data", "model"), **kw)
        return jax.make_mesh((pipe, data, model),
                             ("pipe", "data", "model"), **kw)
    if pod:
        return jax.make_mesh((pod, data, model),
                             ("pod", "data", "model"), **kw)
    return jax.make_mesh((data, model), ("data", "model"), **kw)


def make_pod_mesh(n_pods: int, devices=None):
    """1-D ``pod`` mesh carrying ONLY the rare compressed outer syncs.

    One device per pod (each pod's lead device); inner DP/TP traffic never
    crosses it. This is the axis the DiLoCo outer optimizer all-reduces
    the EDGC-compressed outer deltas over.
    """
    kw = {} if devices is None else {"devices": devices}
    return jax.make_mesh((n_pods,), ("pod",), **kw)


def dp_axes(mesh) -> tuple[str, ...]:
    """The data-parallel (manual / EDGC-compressed) axes of a mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axis(mesh) -> str | None:
    return "model" if "model" in mesh.axis_names else None


def pipe_size(mesh) -> int:
    """Size of the pipeline axis (1 when the mesh has no ``pipe`` axis)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pipe", 1)
