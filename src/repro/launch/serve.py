"""Serving driver: batched generation with the decode engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --variant reduced \
      --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, get_config
from repro.models.model import build_model, param_count
from repro.serve.engine import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--variant", default="reduced", choices=["full", "reduced"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--bench-context", type=int, default=0,
                    help="if set, time decode at this context length")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, args.variant)
    if cfg.family == "whisper":
        raise SystemExit("use examples/serve_decode.py for the enc-dec path")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    print(f"{cfg.name}: {param_count(params)/1e6:.1f}M params")

    eng = Engine(model, params, ServeConfig(max_new_tokens=args.new_tokens,
                                            temperature=args.temperature,
                                            seed=args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    out = eng.generate(prompts)
    print(f"generated {out.shape} tokens; first row: {out[0][:16].tolist()}")

    if args.bench_context:
        s = eng.decode_benchmark(args.batch, args.bench_context)
        print(f"decode @ context={args.bench_context}, batch={args.batch}: "
              f"{s*1e3:.2f} ms/token")


if __name__ == "__main__":
    main()
