"""Communication-time model and rank bounds (paper §IV-D1, Fig. 9, Eq. 2-3).

The paper measures DP all-reduce time on real clusters and finds it linear in
the compression rank, T_com(r) = eta * r (MAPE 2.85%). That linearity is
structural: PowerSGD rank-r compression of an m x n gradient moves
(m + n) * r * bytes_per_elem through the ring, and ring all-reduce time is
2 (k-1)/k * bytes / link_bw — linear in bytes, hence in r.

On the TPU target we cannot wall-clock the ring (CPU container), so the model
is built from exact byte counts + the analytic ring model with the v5e
constants from the brief. The same class accepts *measured* (rank, seconds)
samples on real hardware — ``fit`` recovers eta and reports the MAPE, which
benchmarks/comm_linearity.py uses to reproduce Fig. 9 / the 2.85% claim.

Eq. 2 gates compression: it only pays when
    T_compress + D_compressed / B + T_decompress <= D_original / B
which yields r_max; r_min defaults into the paper's [r_max/6, r_max/4] band.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["HardwareSpec", "TPU_V5E", "CommModel", "rank_bounds"]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip peak numbers (defaults: TPU v5e per the brief)."""

    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per ICI link
    bytes_per_elem: int = 2             # bf16 on the wire


TPU_V5E = HardwareSpec()


def ring_allreduce_seconds(nbytes: float, world: int, link_bw: float) -> float:
    """Classic ring all-reduce: 2 (k-1)/k * nbytes / link_bw."""
    if world <= 1:
        return 0.0
    return 2.0 * (world - 1) / world * nbytes / link_bw


@dataclasses.dataclass
class CommModel:
    """T_com(r) = eta * r for one compressed leaf population (Eq. 3).

    ``eta`` is derived analytically (``from_shapes``) or fit from measured
    samples (``fit``). ``compress_overhead_s`` folds T_compress +
    T_decompress (Eq. 2), modeled as the 2 m n r matmul FLOPs of the
    PowerSGD factor products at the chip's peak.
    """

    eta: float                      # seconds per unit rank
    overhead_per_rank: float = 0.0  # compress+decompress seconds per unit rank
    full_bytes: float = 0.0         # D_original in bytes (for Eq. 2)
    world: int = 1
    hw: HardwareSpec = TPU_V5E

    @classmethod
    def from_shapes(
        cls,
        shapes: list[tuple[int, int]],
        world: int,
        hw: HardwareSpec = TPU_V5E,
        mxu_efficiency: float = 0.35,
    ) -> "CommModel":
        """Analytic eta for a set of compressed (m, n) leaves.

        Per unit rank, PowerSGD ships (m + n) elements per leaf and spends
        ~ 2*(2 m n) FLOPs (M@Q and M^T@P) on compress + ~2 m n on decompress.
        """
        bpe = hw.bytes_per_elem
        bytes_per_rank = sum((m + n) * bpe for m, n in shapes)
        eta = ring_allreduce_seconds(bytes_per_rank, world, hw.ici_bw)
        flops_per_rank = sum(6.0 * m * n for m, n in shapes)
        overhead = flops_per_rank / (hw.peak_flops * mxu_efficiency)
        full = sum(m * n * bpe for m, n in shapes)
        return cls(eta=eta, overhead_per_rank=overhead, full_bytes=full,
                   world=world, hw=hw)

    @classmethod
    def fit(cls, ranks: np.ndarray, seconds: np.ndarray) -> tuple["CommModel", float]:
        """Least-squares fit of T = eta*r from measurements; returns (model, MAPE)."""
        ranks = np.asarray(ranks, dtype=np.float64)
        seconds = np.asarray(seconds, dtype=np.float64)
        eta = float(np.sum(ranks * seconds) / np.sum(ranks * ranks))
        pred = eta * ranks
        mape = float(np.mean(np.abs(pred - seconds) / np.maximum(seconds, 1e-12)))
        return cls(eta=eta), mape

    # -- Eq. 3 ---------------------------------------------------------------
    def t_com(self, r: int) -> float:
        return self.eta * r

    def t_total(self, r: int) -> float:
        """Eq. 2 LHS: compress + wire + decompress."""
        return self.overhead_per_rank * r + self.t_com(r)

    def t_uncompressed(self) -> float:
        """Eq. 2 RHS: D_original / B as a ring all-reduce."""
        return ring_allreduce_seconds(self.full_bytes, self.world, self.hw.ici_bw)

    def rank_for_time(self, t: float, r_min: int, r_max: int) -> int:
        """Invert Eq. 3 (used by stage alignment, Alg. 2 line 4)."""
        if self.eta <= 0:
            return r_max
        return int(np.clip(round(t / self.eta), r_min, r_max))


def rank_bounds(model: CommModel, max_possible: int,
                r_min_divisor: float = 5.0) -> tuple[int, int]:
    """(r_min, r_max) from Eq. 2 + the paper's footnote-1 band.

    r_max is the largest rank for which compression still beats the
    uncompressed all-reduce; r_min = r_max / divisor with the paper's
    recommended divisor in [4, 6] (default 5).
    """
    t_full = model.t_uncompressed()
    if t_full <= 0:
        return 1, max(1, max_possible)
    r_max = max_possible
    # t_total is linear in r: solve overhead*r + eta*r <= t_full directly.
    per_rank = model.overhead_per_rank + model.eta
    if per_rank > 0:
        r_max = int(t_full / per_rank)
    r_max = int(np.clip(r_max, 1, max_possible))
    r_min = max(1, int(round(r_max / r_min_divisor)))
    return r_min, r_max
