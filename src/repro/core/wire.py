"""Entropy-coded wire format: lossless second stage under the lossy sync.

EDGC's control plane estimates gradient entropy every gated step (Lemma 2)
to pick per-stage PowerSGD ranks — but the factors and flat buckets then
ship as raw fp32/bf16. Following ZipCCL's hybrid lossy+lossless design
(PAPERS.md), this module adds the lossless stage: symmetric per-group
scaled quantization to b-bit codes (b in {4, 8}) that are bit-packed into
uint32 words by the Pallas pack/unpack kernels (kernels/pack.py), with the
bit-width chosen from the *measured* entropy the controller already holds
— the rank-selection estimate doubles as the codec model, a fusion neither
ZipCCL nor EDGC does.

Training math is unchanged: every coded payload passes through the
existing error-feedback loops. PowerSGD factors are coded by wrapping the
injected ``psum_mean`` (``coded_psum``) — the P/Q quantization error lands
in ``m_mat - p_hat @ q_new.T`` and is absorbed by the per-leaf EF residual
with zero new state. Flat-bucket members get an explicit EF entry
(``ef:<path>`` in the compressor state, core/bucketing.py).

Chunk invariance: quantization groups never span members — each member is
coded independently (own scales, own padding) — so a member's coded value
is identical whether it syncs inside a monolithic bucket or a split
SyncChunk, preserving PR 6's chunked-vs-monolithic bit-equality at the
coded-payload level.

The collective itself runs on locally-dequantized values (codes from
different workers are not summable); the coded representation is what a
real transport would put on the wire, and ``coded_bytes`` is the ledger
model for it: packed words + one fp32 scale per group.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = [
    "WIRE_MODES",
    "ChunkCodec",
    "resolve_codec",
    "select_bits",
    "quantize",
    "dequantize",
    "roundtrip",
    "roundtrip_arr",
    "coded_psum",
    "coded_bytes",
    "predicted_code_bits",
]

F32 = jnp.float32

#: SyncConfig.wire knob values. ``raw`` ships uncoded payloads (seed
#: behaviour); ``quant8``/``quant4`` fix the bit-width; ``entropy`` picks
#: it per window from the measured gradient entropy (falling back to
#: quant8 until the first reading lands).
WIRE_MODES = ("raw", "quant8", "quant4", "entropy")

_LN2 = math.log(2.0)


@dataclass(frozen=True)
class ChunkCodec:
    """Static quantizer parameters for one sync payload.

    Frozen/hashable so it can ride in SyncConfig and key the trainer's
    step compile cache — a codec change (entropy mode moving the
    bit-width at a window boundary) recompiles exactly like a plan change.
    """

    bits: int = 8    # code width; 32 % bits == 0 (4 or 8 in practice)
    group: int = 1024  # elements per quantization scale

    def __post_init__(self):
        if 32 % self.bits != 0 or not (2 <= self.bits <= 16):
            raise ValueError(f"bits must divide 32 (got {self.bits})")
        if self.group < 1:
            raise ValueError(f"group must be >= 1 (got {self.group})")

    @property
    def qmax(self) -> int:
        return (1 << (self.bits - 1)) - 1


def select_bits(entropy_nats: float, ref_nats: float) -> int:
    """Map a measured entropy reading to a code width, anchored at 8 bits.

    The first reading of the run (``ref_nats``) gets 8 bits; each full nat
    the entropy drops below it sheds ~1.44 bits. The continuous value then
    snaps to the nearest width the pack kernels support ({4, 8} — widths
    must divide 32), so the wire narrows to 4 bits once entropy has fallen
    ~2 nats below the run start (the paper's Fig. 2 observation).
    """
    bits = 8 + (entropy_nats - ref_nats) / _LN2
    return 8 if bits >= 6 else 4


def resolve_codec(wire: str, entropy_nats: float | None = None,
                  ref_nats: float | None = None) -> ChunkCodec | None:
    """Static codec for a wire mode (None = raw/uncoded).

    ``entropy`` mode needs a measured reading and its run-start reference;
    with neither available yet it falls back to quant8.
    """
    if wire not in WIRE_MODES:
        raise ValueError(f"wire must be one of {WIRE_MODES}, got {wire!r}")
    if wire == "raw":
        return None
    if wire == "quant4":
        bits = 4
    elif wire == "quant8" or entropy_nats is None or ref_nats is None:
        bits = 8
    else:
        bits = select_bits(entropy_nats, ref_nats)
    # Narrower codes get finer scale granularity to hold reconstruction
    # error (and the EF residual) down; scales are a small fraction of the
    # payload either way (coded_bytes accounts for them).
    return ChunkCodec(bits=bits, group=256 if bits <= 4 else 1024)


# --------------------------------------------------------------- numerics

def quantize(x: jax.Array, codec: ChunkCodec) -> tuple[jax.Array, jax.Array]:
    """Flat fp32 (n,) -> (unsigned int32 codes (n,), per-group scales).

    Symmetric per-group quantization: scale = max|x| / qmax over each
    contiguous ``codec.group`` slice (zero-max groups guarded to scale 1),
    codes offset by +qmax into [0, 2*qmax] so they pack unsigned.
    """
    n = x.shape[0]
    g = codec.group
    pad = (-n) % g
    xf = x.astype(F32)
    if pad:
        xf = jnp.pad(xf, (0, pad))
    grouped = xf.reshape(-1, g)
    amax = jnp.max(jnp.abs(grouped), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / codec.qmax, 1.0)
    q = jnp.clip(jnp.round(grouped / scale), -codec.qmax, codec.qmax)
    codes = (q + codec.qmax).astype(jnp.int32).reshape(-1)[:n]
    return codes, scale[:, 0]


def dequantize(codes: jax.Array, scales: jax.Array,
               codec: ChunkCodec) -> jax.Array:
    """Inverse of quantize: codes (n,) + per-group scales -> fp32 (n,)."""
    n = codes.shape[0]
    g = codec.group
    pad = (-n) % g
    q = codes.astype(F32) - codec.qmax
    if pad:
        q = jnp.pad(q, (0, pad))
    x = q.reshape(-1, g) * scales[:, None]
    return x.reshape(-1)[:n]


def roundtrip(x: jax.Array, codec: ChunkCodec) -> jax.Array:
    """quantize -> pack -> unpack -> dequantize one flat fp32 vector.

    The pack/unpack leg is a bit-exact identity, so numerically this
    equals quantize∘dequantize — but it runs the actual wire kernels, so
    the sync path exercises exactly what a transport would ship.
    """
    from repro.kernels import ops as kops

    codes, scales = quantize(x, codec)
    words = kops.pack_bits(codes, codec.bits)
    back = kops.unpack_bits(words, codec.bits, int(x.shape[0]))
    return dequantize(back, scales, codec)


def roundtrip_arr(x: jax.Array, codec: ChunkCodec | None) -> jax.Array:
    """roundtrip for an arbitrary-shape array, preserving shape and dtype."""
    if codec is None:
        return x
    flat = x.astype(F32).reshape(-1)
    return roundtrip(flat, codec).reshape(x.shape).astype(x.dtype)


def coded_psum(psum_mean, codec: ChunkCodec | None):
    """Wrap a psum-mean collective so each worker's contribution is coded.

    Every worker quantizes its local payload through the wire round trip
    before the mean; the caller's error feedback sees the dequantized
    value, so the quantization error is absorbed, not accumulated.
    """
    if codec is None:
        return psum_mean
    return lambda a: psum_mean(roundtrip_arr(a, codec))


# ------------------------------------------------------------- accounting

def coded_bytes(n_elems: int, codec: ChunkCodec | None,
                raw_bytes_per_elem: int = 4) -> int:
    """Wire bytes for n payload elements: packed words + fp32 scales.

    With codec None this is the raw ledger (n * raw_bytes_per_elem), so
    call sites can thread one accounting function for both modes.
    """
    if n_elems <= 0:
        return 0
    if codec is None:
        return n_elems * raw_bytes_per_elem
    epw = 32 // codec.bits
    nwords = -(-n_elems // epw)
    ngroups = -(-n_elems // codec.group)
    return nwords * 4 + ngroups * 4


def predicted_code_bits(entropy_nats: float, step: float) -> float:
    """Model code entropy (bits/elem) of a quantized continuous source.

    Standard high-resolution quantization result: H(Q(X)) ~ h(X) - log2(Δ)
    for differential entropy h and step Δ. The property tests check the
    achieved empirical code entropy against this using the sampled-entropy
    estimate the controller computed — the codec-model fusion in one line.
    """
    if step <= 0:
        return 0.0
    return max(0.0, (entropy_nats - math.log(step)) / _LN2)
