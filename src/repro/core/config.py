"""SyncConfig + the shared legacy-field shim for the unified config surface.

The DP-sync knobs (``bucketed`` / ``use_kernels`` / ``bucket_bytes``) used
to be scattered across ``TrainStepConfig`` / ``TrainerConfig`` /
``EDGCConfig``; they now live in one :class:`SyncConfig` that all three
embed, next to ``repro.pipeline.PipelineConfig`` for the pipeline knobs.
``resolve_embedded`` is the init-shim those dataclasses share: it accepts
the old flat keyword arguments and folds them into the embedded configs,
so existing call sites keep working unchanged.

``COMM_MODES`` names the three communication modes the
:class:`~repro.core.sync_executor.SyncExecutor` facade dispatches on.
"""
from __future__ import annotations

import dataclasses

from .bucketing import DEFAULT_BUCKET_BYTES

__all__ = ["SyncConfig", "SYNC_FIELDS", "COMM_MODES", "resolve_embedded"]

#: Communication modes of the SyncExecutor facade.
#:   flat                  one DP sync over the whole gradient tree
#:   per-stage             one bucketed schedule per distinct stage plan,
#:                         run monolithically after the pipeline drain
#:   per-stage-overlapped  the same schedules split into chunks and
#:                         interleaved with the pipeline's drain ticks
COMM_MODES = ("flat", "per-stage", "per-stage-overlapped")


@dataclasses.dataclass(frozen=True)
class SyncConfig:
    """DP gradient-sync executor knobs (hashable, compile-cache safe).

    ``bucketed``: True = shape-grouped stacked compression + flat buckets
    (O(groups + buckets) collectives), False = the per-leaf parity oracle,
    None = infer (the trainer resolves to "bucketed where supported", the
    flat step infers from the compressor-state format).
    """

    bucketed: bool | None = None
    use_kernels: bool = False      # route matmuls through Pallas ops
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    #: Wire format under the collectives (core/wire.py WIRE_MODES):
    #: raw | quant8 | quant4 | entropy. Anything but raw requires the
    #: bucketed executor (the per-leaf path stays the uncoded parity
    #: oracle).
    wire: str = "raw"
    #: Resolved static quantizer (wire.ChunkCodec) — filled in by the
    #: trainer / outer optimizer from ``wire`` + the controller's entropy
    #: reading; carried here so it reaches the executor and keys the step
    #: compile cache. Leave None to have it resolved from ``wire``.
    codec: object | None = None


SYNC_FIELDS = tuple(f.name for f in dataclasses.fields(SyncConfig))


def resolve_embedded(pipeline, sync, legacy: dict, where: str):
    """Fold deprecated flat config kwargs into the embedded configs.

    ``legacy`` maps old flat field names (``num_stages``, ``schedule``,
    ``bucketed``, ``use_kernels``, ...) to explicitly-passed values; they
    override the matching field of the embedded ``pipeline`` / ``sync``
    config (which default-construct when not given). Unknown names raise
    ``TypeError`` exactly like a normal bad keyword. Returns the resolved
    ``(PipelineConfig, SyncConfig)`` pair.

    The PipelineConfig import is deferred so ``repro.core`` (whose
    ``EDGCConfig`` also uses this shim) never imports ``repro.pipeline``
    at module-load time.
    """
    from repro.pipeline.config import PIPELINE_FIELDS, PipelineConfig

    pipe_over = {k: v for k, v in legacy.items() if k in PIPELINE_FIELDS}
    sync_over = {k: v for k, v in legacy.items() if k in SYNC_FIELDS}
    unknown = set(legacy) - set(pipe_over) - set(sync_over)
    if unknown:
        raise TypeError(f"{where} got unexpected keyword argument(s) "
                        f"{sorted(unknown)}")
    if pipeline is None:
        pipeline = PipelineConfig()
    if sync is None:
        sync = SyncConfig()
    if pipe_over:
        pipeline = dataclasses.replace(pipeline, **pipe_over)
    if sync_over:
        sync = dataclasses.replace(sync, **sync_over)
    return pipeline, sync


def alias_property(container: str, name: str, settable: bool = False):
    """A ``cfg.<name>`` property delegating to ``cfg.<container>.<name>``.

    The deprecated flat fields of the three config dataclasses are these:
    reads keep working forever; ``settable=True`` (mutable TrainerConfig
    only) writes through by replacing the embedded frozen config.
    """
    def get(self):
        return getattr(getattr(self, container), name)

    def set_(self, value):
        setattr(self, container,
                dataclasses.replace(getattr(self, container), **{name: value}))

    return property(get, set_ if settable else None,
                    doc=f"Deprecated alias for .{container}.{name}")
