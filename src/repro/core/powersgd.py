"""Rank-r low-rank gradient compression with error feedback (PowerSGD [66]).

This is EDGC's compression engine (paper §II-B, §III-B "Insights"): one power
iteration with a warm-started Q factor, Gram–Schmidt orthonormalization, and
an error-feedback residual that makes the compressor unbiased over time.

The data-parallel collective is *injected* (``psum_mean`` callable) so the
identical code path runs:
  * single-device (identity collective) — unit tests, fidelity runs;
  * inside ``shard_map`` manual over the (pod, data) axes — production, where
    the two factor all-reduces replace the full-gradient all-reduce
    (dist/collectives.py).

Leaves are matricized to (m, n) with n = trailing dim; 3-D leaves (MoE
expert stacks, (E, m, n)) are compressed per-expert via vmap. Compression
internals run in float32 regardless of the gradient dtype.

Communication per step and leaf: (m + n) * r elements, vs m * n uncompressed
— the byte counts that feed comm_model / the Fig. 9 reproduction.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "LowRankState",
    "gram_schmidt",
    "init_leaf_state",
    "compress_leaf",
    "resize_rank",
    "compressed_bytes",
]

PsumFn = Callable[[jax.Array], jax.Array]


def _identity_psum(x: jax.Array) -> jax.Array:
    return x


class LowRankState(NamedTuple):
    """Per-leaf compressor state: warm-start Q and error-feedback residual."""

    q: jax.Array    # (n, r) or (E, n, r)
    err: jax.Array  # (m, n) or (E, m, n), same dtype as the gradient


def gram_schmidt(p: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Orthonormalize the columns of p (m x r), modified Gram–Schmidt.

    r is small (<= a few hundred) so the column loop is unrolled at trace
    time; each step is a rank-1 update — this is also the reference for the
    Pallas panel kernel.
    """
    m, r = p.shape
    cols = []
    for i in range(r):
        v = p[:, i]
        for u in cols:
            v = v - jnp.dot(u, v) * u
        v = v / (jnp.linalg.norm(v) + eps)
        cols.append(v)
    return jnp.stack(cols, axis=1)


def _orthonormalize(p: jax.Array) -> jax.Array:
    """QR-based orthonormalization (same span as Gram–Schmidt, O(m r^2)).

    jnp.linalg.qr lowers to a TPU-supported kernel; gram_schmidt above is the
    semantic reference and the Pallas kernel's oracle.
    """
    q, _ = jnp.linalg.qr(p.astype(jnp.float32))
    return q


def matricize(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    """Fold a leaf to (m, n) (2-D) or (E, m, n) (3-D expert stacks)."""
    if x.ndim == 2:
        return x, x.shape
    if x.ndim == 3:
        return x, x.shape
    if x.ndim > 3:
        folded = x.reshape((-1,) + x.shape[-2:])
        return folded, x.shape
    raise ValueError(f"cannot matricize ndim={x.ndim}")


def init_leaf_state(
    shape: tuple[int, ...], rank: int, key: jax.Array, dtype=jnp.float32
) -> LowRankState:
    """Random warm-start Q (as PowerSGD) + zero error-feedback residual."""
    if len(shape) == 2:
        m, n = shape
        q = jax.random.normal(key, (n, rank), jnp.float32)
    elif len(shape) >= 3:
        n = shape[-1]
        q = jax.random.normal(key, shape[:-2] + (n, rank), jnp.float32)
    else:
        raise ValueError(f"unsupported leaf shape {shape}")
    err = jnp.zeros(shape, dtype)
    return LowRankState(q=q, err=err)


def ef_norm_sq(comp) -> jax.Array:
    """Total squared error-feedback residual across a compressor pytree.

    Skips non-LowRank leaves (flat buckets carry no EF); the caller takes
    sqrt after its collective reduction, so this stays additive across
    pipe stages and DP workers.
    """
    total = jnp.zeros((), jnp.float32)
    leaves = jax.tree_util.tree_leaves(
        comp, is_leaf=lambda x: isinstance(x, LowRankState))
    for leaf in leaves:
        if isinstance(leaf, LowRankState):
            total = total + jnp.sum(jnp.square(leaf.err.astype(jnp.float32)))
    return total


def _compress_2d(
    grad: jax.Array,
    state: LowRankState,
    psum_mean: PsumFn,
    use_kernels: bool = False,
) -> tuple[jax.Array, LowRankState]:
    """One PowerSGD round on an (m, n) leaf. Returns (decompressed, state)."""
    if use_kernels:
        # Pallas path: EF add fused into each gradient sweep (DESIGN §3).
        from repro.kernels import ops as kops
        p = kops.lowrank_p(grad, state.err, state.q)   # (m, r), fused EF
        p = psum_mean(p)                               # DP collective #1
        p_hat = kops.orthonormalize(p)
        q_new = kops.lowrank_q(grad, state.err, p_hat)  # (n, r), fused EF
        q_new = psum_mean(q_new)                       # DP collective #2
        g_hat, err = kops.decompress_residual(p_hat, q_new, grad, state.err)
        return g_hat.astype(grad.dtype), LowRankState(q=q_new, err=err.astype(grad.dtype))

    g32 = grad.astype(jnp.float32)
    m_mat = g32 + state.err.astype(jnp.float32)       # error feedback add
    p = m_mat @ state.q                                # (m, r)
    p = psum_mean(p)                                   # DP collective #1
    p_hat = _orthonormalize(p)                         # (m, r) orthonormal
    q_new = m_mat.T @ p_hat                            # (n, r)
    q_new = psum_mean(q_new)                           # DP collective #2
    g_hat = p_hat @ q_new.T                            # decompress (m, n)
    err = (m_mat - g_hat).astype(grad.dtype)           # new residual
    return g_hat.astype(grad.dtype), LowRankState(q=q_new, err=err)


def compress_leaf(
    grad: jax.Array,
    state: LowRankState,
    psum_mean: PsumFn = _identity_psum,
    use_kernels: bool = False,
) -> tuple[jax.Array, LowRankState]:
    """Compress+allreduce+decompress one leaf (2-D, or batched/vmapped).

    ``psum_mean`` must compute the mean over the data-parallel axes; for
    batched leaves it is applied to the stacked factors (one collective per
    leaf, not per expert/layer). Leaves with >3 dims (stacked layers x
    experts) are folded to one batch dim and restored on the way out.
    """
    if grad.ndim > 3:
        shape = grad.shape
        folded = grad.reshape((-1,) + shape[-2:])
        st = LowRankState(
            q=state.q.reshape((-1,) + state.q.shape[-2:]),
            err=state.err.reshape((-1,) + shape[-2:]),
        )
        g_hat, st2 = compress_leaf(folded, st, psum_mean, use_kernels)
        return g_hat.reshape(shape), LowRankState(
            q=st2.q.reshape(state.q.shape[:-1] + (st2.q.shape[-1],)),
            err=st2.err.reshape(shape),
        )
    if grad.ndim == 2:
        return _compress_2d(grad, state, psum_mean, use_kernels)
    if grad.ndim == 3:
        if use_kernels:
            # Batched Pallas path: grid-over-E kernels with the EF add fused
            # into each stacked-gradient sweep (kernels/lowrank.py).
            from repro.kernels import ops as kops
            p = kops.lowrank_p3(grad, state.err, state.q)     # (E, m, r)
            p = psum_mean(p)                                  # DP collective #1
            p_hat = kops.orthonormalize3(p)
            q_new = kops.lowrank_q3(grad, state.err, p_hat)   # (E, n, r)
            q_new = psum_mean(q_new)                          # DP collective #2
            g_hat, err = kops.decompress_residual3(p_hat, q_new, grad, state.err)
            return g_hat.astype(grad.dtype), LowRankState(
                q=q_new, err=err.astype(grad.dtype))
        # vmap the matmuls/orthonormalization; do the collective on the stack.
        def _local(m_mat, q):
            p = m_mat @ q
            return p

        g32 = grad.astype(jnp.float32)
        m_mat = g32 + state.err.astype(jnp.float32)
        p = jax.vmap(_local)(m_mat, state.q)           # (E, m, r)
        p = psum_mean(p)
        p_hat = jax.vmap(_orthonormalize)(p)
        q_new = jax.vmap(lambda mm, ph: mm.swapaxes(-1, -2) @ ph)(m_mat, p_hat)
        q_new = psum_mean(q_new)
        g_hat = jax.vmap(lambda ph, qn: ph @ qn.swapaxes(-1, -2))(p_hat, q_new)
        err = (m_mat - g_hat).astype(grad.dtype)
        return g_hat.astype(grad.dtype), LowRankState(q=q_new, err=err)
    raise ValueError(f"unsupported grad ndim {grad.ndim}")


def resize_rank(state: LowRankState, new_rank: int, key: jax.Array) -> LowRankState:
    """Grow/shrink the warm-start Q when DAC moves the rank (window boundary).

    Shrinking keeps the leading columns (the best-aligned directions);
    growing appends fresh random columns. The EF residual is preserved — it
    is exactly what makes rank changes safe mid-training.
    """
    q = state.q
    r = q.shape[-1]
    if new_rank == r:
        return state
    if new_rank < r:
        q_new = q[..., :new_rank]
    else:
        extra_shape = q.shape[:-1] + (new_rank - r,)
        q_new = jnp.concatenate(
            [q, jax.random.normal(key, extra_shape, q.dtype)], axis=-1
        )
    return LowRankState(q=q_new, err=state.err)


def compressed_bytes(shape: tuple[int, ...], rank: int, bytes_per_elem: int = 2) -> int:
    """Wire bytes for one leaf at one rank: (m + n) * r (* batch)."""
    m, n = shape[-2:]
    batch = 1
    for d in shape[:-2]:
        batch *= d
    return batch * (m + n) * rank * bytes_per_elem
