"""Bucketed DP gradient sync: shape-grouped stacked compression + flat buckets.

The per-leaf ``sync_grads`` loop issues one collective per uncompressed leaf
and two per compressed leaf — O(num_leaves) tiny all-reduces per step, each
paying full launch latency (TAGC, L-GreCo: fusing layers into communication
buckets is what turns theoretical compression ratios into wall-clock wins).
This module collapses that to O(num_shape_groups + num_buckets):

  * **Shape groups** — compressed leaves sharing a matricized shape ``(m, n)``
    and plan rank ``r`` are stacked into one ``(E, m, n)`` batch. One vmapped
    PowerSGD round (the existing 3-D path in ``powersgd.py``) syncs the whole
    group with exactly two stacked-factor collectives. Transformer stacks are
    the best case: every attention projection of every layer lands in one
    group.
  * **Flat buckets** — uncompressed / ineligible leaves are packed in tree
    order into size-capped buckets (default 32 MiB); each bucket moves
    through a single collective and is sliced back apart.

The :class:`BucketLayout` is derived *statically* from the leaf shapes and
the :class:`~repro.core.compressor.CompressionPlan` — it is a hashable frozen
dataclass, a pure function of (shapes, plan, cap), so the same layout falls
out at trace time inside the jitted step, at init time on the host, and at
DAC window re-plans; it composes with the trainer's plan-keyed compile cache
without being threaded through as an extra static argument.

Stacked compressor state lives in fp32 under group keys (``group:MxN:r``);
``stack_state``/``unstack_state`` convert to/from the per-leaf format, which
remains the parity oracle (``sync_grads(..., bucketed=False)``).

Dtypes: each bucket moves in the widest dtype among its members (uniform
bf16 trees sync in bf16, exactly the bytes and rounding of the per-leaf
psums; only mixed-dtype buckets upcast the narrower members), so
``plan_wire_bytes`` accounting holds for the bucketed executor too.
Stacked compressor state is fp32 — compression internals are fp32 in both
executors, but the stacked EF residual costs 2x the per-leaf bf16 one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from . import wire as _wire
from .powersgd import LowRankState, compress_leaf, init_leaf_state, resize_rank

__all__ = [
    "DEFAULT_BUCKET_BYTES",
    "ShapeGroup",
    "FlatBucket",
    "BucketLayout",
    "SyncChunk",
    "make_bucket_layout",
    "layout_for_tree",
    "sync_chunks",
    "is_stacked_state",
    "init_flat_ef",
    "stack_state",
    "unstack_state",
    "resize_stacked_state",
    "bucketed_sync_grads",
    "sync_chunk_grads",
]

PsumFn = Callable[[jax.Array], jax.Array]

DEFAULT_BUCKET_BYTES = 32 << 20     # 32 MiB of fp32 per flat bucket
GROUP_PREFIX = "group:"             # stacked-state dict keys start with this
EF_PREFIX = "ef:"                   # flat-bucket wire-EF state keys

Member = tuple[str, tuple[int, ...]]    # (leaf path, original leaf shape)


def _batch_of(shape: tuple[int, ...]) -> int:
    """Number of (m, n) slices a leaf contributes to its group's stack."""
    return math.prod(shape[:-2]) if len(shape) > 2 else 1


@dataclasses.dataclass(frozen=True)
class ShapeGroup:
    """All compressed leaves sharing matricized shape (m, n) and rank."""

    m: int
    n: int
    rank: int
    members: tuple[Member, ...]     # stack order = tree-flatten order

    @property
    def key(self) -> str:
        return f"{GROUP_PREFIX}{self.m}x{self.n}:r{self.rank}"

    @property
    def stack_size(self) -> int:
        return sum(_batch_of(shape) for _, shape in self.members)


@dataclasses.dataclass(frozen=True)
class FlatBucket:
    """Uncompressed leaves packed into one flat all-reduce.

    ``itemsizes`` parallels ``members``: the byte width of each member's
    dtype (4 when the layout was derived from shapes alone). The bucket
    moves in the widest member dtype (``_sync_flat``), so its raw wire
    bytes are ``num_elements * max(itemsizes)`` — not the fp32 assumption
    the ledger used to make.
    """

    members: tuple[Member, ...]
    itemsizes: tuple[int, ...] = ()

    @property
    def num_elements(self) -> int:
        return sum(math.prod(shape) for _, shape in self.members)


@dataclasses.dataclass(frozen=True)
class BucketLayout:
    """Static, hashable sync schedule: stacked groups + flat buckets.

    ``chunk_bytes`` is the schedule-overlap transfer cap: ``sync_chunks``
    splits each flat bucket into member runs of at most that many (fp32)
    bytes, so one chunk's collective fits under one pipeline backward tick.
    0 keeps the natural per-collective granularity. It does NOT change the
    groups/buckets packing (state keys and stacking are chunk-agnostic).
    """

    groups: tuple[ShapeGroup, ...]
    buckets: tuple[FlatBucket, ...]
    chunk_bytes: int = 0

    def num_collectives(self) -> int:
        """Collectives per step: two factor psums per group, one per bucket."""
        return 2 * len(self.groups) + len(self.buckets)


@dataclasses.dataclass(frozen=True)
class SyncChunk:
    """One independently-launchable slice of a bucketed sync schedule.

    Either one whole shape group (stacked PowerSGD is atomic: its factor
    psums and error feedback act on the full stack) or a member run of one
    flat bucket. Chunks partition the layout's leaves exactly — running
    every chunk of a layout reproduces ``bucketed_sync_grads`` bit for bit
    (a psum of a packed sub-run equals the matching slice of the packed
    whole-bucket psum), which is what lets the pipelined executor spread
    them over drain ticks.
    """

    kind: str                           # "group" | "bucket"
    group: ShapeGroup | None = None
    members: tuple[Member, ...] = ()    # kind="bucket": the packed run
    itemsizes: tuple[int, ...] = ()     # kind="bucket": member dtype widths

    @property
    def member_paths(self) -> tuple[str, ...]:
        src = self.group.members if self.kind == "group" else self.members
        return tuple(path for path, _ in src)

    @property
    def num_collectives(self) -> int:
        """Collectives this chunk launches: two factor psums for a stacked
        group, one packed psum for a bucket run — the per-chunk term of
        ``BucketLayout.num_collectives`` the auditor sums over launches."""
        return 2 if self.kind == "group" else 1

    def wire_bytes(self, bytes_per_elem: int | None = None,
                   codec: "_wire.ChunkCodec | None" = None) -> int:
        """Collective payload bytes (factor psums / packed bucket).

        Raw: group chunks move fp32 factors (4 B/elem); bucket chunks move
        the widest member dtype from the layout's ``itemsizes`` (4 B/elem
        when the layout carries no dtype info). An explicit
        ``bytes_per_elem`` overrides both. With ``codec``, returns the
        entropy-coded size (packed words + scales, per member for buckets
        since quantization groups never span members).
        """
        if self.kind == "group":
            g = self.group
            n_elems = (g.m + g.n) * g.rank * g.stack_size
            if codec is not None:
                return _wire.coded_bytes(n_elems, codec)
            return n_elems * (4 if bytes_per_elem is None else bytes_per_elem)
        if codec is not None:
            return sum(_wire.coded_bytes(math.prod(shape) if shape else 1,
                                         codec)
                       for _, shape in self.members)
        if bytes_per_elem is None:
            bytes_per_elem = max(self.itemsizes) if self.itemsizes else 4
        return sum(math.prod(shape) if shape else 1
                   for _, shape in self.members) * bytes_per_elem


def sync_chunks(layout: BucketLayout) -> tuple[SyncChunk, ...]:
    """Split a layout into launchable chunks (groups first, tree order).

    Shape groups are atomic — one chunk each. Flat buckets split into
    member runs capped at ``layout.chunk_bytes`` of fp32 payload (a single
    oversized member still gets its own chunk); ``chunk_bytes == 0`` keeps
    one chunk per bucket.
    """
    chunks = [SyncChunk(kind="group", group=g) for g in layout.groups]
    cap_elems = max(1, layout.chunk_bytes // 4) if layout.chunk_bytes > 0 else 0
    for bucket in layout.buckets:
        sizes = bucket.itemsizes or (4,) * len(bucket.members)
        if cap_elems <= 0:
            chunks.append(SyncChunk(kind="bucket", members=bucket.members,
                                    itemsizes=tuple(sizes)))
            continue
        run: list[Member] = []
        run_sizes: list[int] = []
        run_elems = 0
        for (path, shape), isz in zip(bucket.members, sizes):
            nelem = math.prod(shape) if shape else 1
            if run and run_elems + nelem > cap_elems:
                chunks.append(SyncChunk(kind="bucket", members=tuple(run),
                                        itemsizes=tuple(run_sizes)))
                run, run_sizes, run_elems = [], [], 0
            run.append((path, shape))
            run_sizes.append(isz)
            run_elems += nelem
        if run:
            chunks.append(SyncChunk(kind="bucket", members=tuple(run),
                                    itemsizes=tuple(run_sizes)))
    return tuple(chunks)


def make_bucket_layout(
    leaves: Iterable[Any],
    plan,
    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
    chunk_bytes: int = 0,
) -> BucketLayout:
    """Derive the bucketed sync schedule from leaf shapes and a plan.

    ``leaves`` is a sequence of ``LeafInfo`` (``.path``/``.shape``), plain
    ``(path, shape)`` pairs, or ``(path, shape, itemsize)`` triples, in
    pytree-flatten order — the order fixes both the stack order inside each
    group and the bucket packing, so host-side and trace-time derivations
    agree exactly. The dtype itemsize (when the leaf carries one; default 4)
    feeds the flat buckets' wire-byte accounting only — the packing itself
    stays a pure function of (shapes, plan, cap).
    """
    pairs: list[Member] = []
    size_of: dict[str, int] = {}
    for leaf in leaves:
        if isinstance(leaf, tuple):
            path, shape = leaf[0], leaf[1]
            isz = leaf[2] if len(leaf) > 2 else None
        else:
            path, shape = leaf.path, leaf.shape
            isz = getattr(leaf, "itemsize", None)
        pairs.append((path, tuple(shape)))
        size_of[path] = int(isz) if isz else 4

    rank_by_path = plan.as_dict()
    grouped: dict[tuple[int, int, int], list[Member]] = {}
    buckets: list[FlatBucket] = []
    pending: list[Member] = []
    pending_elems = 0
    cap_elems = max(1, bucket_bytes // 4)   # cap assumes 4 B/elem (widest)

    def _flush(run: list[Member]) -> FlatBucket:
        return FlatBucket(members=tuple(run),
                          itemsizes=tuple(size_of[p] for p, _ in run))

    for path, shape in pairs:
        if path in rank_by_path:
            m, n = shape[-2:]
            grouped.setdefault((m, n, rank_by_path[path]), []).append((path, shape))
        else:
            nelem = math.prod(shape) if shape else 1
            if pending and pending_elems + nelem > cap_elems:
                buckets.append(_flush(pending))
                pending, pending_elems = [], 0
            pending.append((path, shape))
            pending_elems += nelem
    if pending:
        buckets.append(_flush(pending))

    groups = tuple(
        ShapeGroup(m=m, n=n, rank=r, members=tuple(members))
        for (m, n, r), members in grouped.items()   # first-appearance order
    )
    return BucketLayout(groups=groups, buckets=tuple(buckets),
                        chunk_bytes=chunk_bytes)


def layout_for_tree(tree: Any, plan,
                    bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                    chunk_bytes: int = 0) -> BucketLayout:
    """Layout from a (gradient/param) pytree — shapes are static at trace."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return make_bucket_layout(
        [(jax.tree_util.keystr(kp), tuple(leaf.shape),
          jnp.dtype(leaf.dtype).itemsize) for kp, leaf in flat],
        plan, bucket_bytes, chunk_bytes,
    )


def is_stacked_state(state: dict) -> bool:
    """True iff ``state`` is keyed by shape groups rather than leaf paths.

    Wire-EF entries (``ef:<path>``, see :func:`init_flat_ef`) only exist in
    bucketed-format state, so they count too — a coded layout with zero
    shape groups still infers as bucketed.
    """
    return any(k.startswith((GROUP_PREFIX, EF_PREFIX)) for k in state)


def init_flat_ef(layout: BucketLayout) -> dict[str, jax.Array]:
    """Zero error-feedback residuals for every flat-bucket member.

    Coded flat buckets need explicit EF (shape groups get theirs for free
    through PowerSGD's residual): each member's quantization error is
    carried under ``ef:<path>`` in the compressor state, fp32, and added
    back into the next step's payload before re-quantizing.
    """
    return {EF_PREFIX + path: jnp.zeros(shape, jnp.float32)
            for bucket in layout.buckets for path, shape in bucket.members}


def bucketing_supported(mesh) -> bool:
    """Whether the bucketed executor is appropriate for this mesh.

    Only TP=1: stacked group state mixes leaves with different TP specs in
    one array, so its EF residual must be replicated over 'model' — and a
    replicated EF forces XLA to all-gather the TP-sharded gradient to add
    it (train/step.py::state_shardings). Trainer and launch/dryrun both
    consult this so the dry-run lowers exactly what production runs.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("model", 1) == 1


# ------------------------------------------------------------ state plumbing
def stack_state(per_leaf: dict[str, LowRankState],
                layout: BucketLayout) -> dict[str, LowRankState]:
    """Per-leaf states -> one fp32 (E, ., .) LowRankState per shape group."""
    stacked: dict[str, LowRankState] = {}
    for group in layout.groups:
        qs, errs = [], []
        for path, shape in group.members:
            st = per_leaf[path]
            qs.append(st.q.astype(jnp.float32).reshape(-1, group.n, st.q.shape[-1]))
            errs.append(st.err.astype(jnp.float32).reshape(-1, group.m, group.n))
        stacked[group.key] = LowRankState(
            q=jnp.concatenate(qs, axis=0), err=jnp.concatenate(errs, axis=0)
        )
    return stacked


def unstack_state(stacked: dict[str, LowRankState],
                  layout: BucketLayout) -> dict[str, LowRankState]:
    """Inverse of :func:`stack_state` (per-leaf states come back in fp32)."""
    per_leaf: dict[str, LowRankState] = {}
    for group in layout.groups:
        st = stacked[group.key]
        rank = st.q.shape[-1]
        offset = 0
        for path, shape in group.members:
            e = _batch_of(shape)
            q = st.q[offset:offset + e]
            err = st.err[offset:offset + e].reshape(shape)
            q = q[0] if len(shape) == 2 else q.reshape(shape[:-2] + (group.n, rank))
            per_leaf[path] = LowRankState(q=q, err=err)
            offset += e
    return per_leaf


def resize_stacked_state(
    stacked: dict[str, LowRankState],
    old_layout: BucketLayout,
    new_layout: BucketLayout,
    key: jax.Array,
) -> dict[str, LowRankState]:
    """Migrate stacked state across a DAC re-plan (window boundary).

    Previously-compressed leaves keep their warm-start Q (leading columns on
    shrink, fresh random tail columns on grow) and their EF residual; leaves
    entering compression get a fresh ``init_leaf_state``.

    Wire-EF entries migrate self-describingly: if the old state carries any
    ``ef:`` keys, the new state gets one per new-layout bucket member —
    preserved where the member stayed flat, fresh zeros where it left a
    shape group (its PowerSGD residual is dropped with the group slot).
    """
    per_leaf = unstack_state(stacked, old_layout)
    new_per_leaf: dict[str, LowRankState] = {}
    i = 0
    for group in new_layout.groups:
        for path, shape in group.members:
            subkey = jax.random.fold_in(key, i)
            i += 1
            if path in per_leaf:
                new_per_leaf[path] = resize_rank(per_leaf[path], group.rank, subkey)
            else:
                new_per_leaf[path] = init_leaf_state(shape, group.rank, subkey,
                                                     jnp.float32)
    new_state: dict[str, Any] = stack_state(new_per_leaf, new_layout)
    if any(k.startswith(EF_PREFIX) for k in stacked):
        for k, zeros in init_flat_ef(new_layout).items():
            new_state[k] = stacked.get(k, zeros)
    return new_state


# ------------------------------------------------------------- sync executor
def _sync_group(
    by_path: dict[str, jax.Array],
    group: ShapeGroup,
    state: LowRankState,
    psum_mean: PsumFn,
    use_kernels: bool = False,
    codec: "_wire.ChunkCodec | None" = None,
) -> tuple[dict[str, jax.Array], LowRankState]:
    """One shape group: concat -> stacked PowerSGD (2 psums) -> slice back.

    With a codec the factor collectives are wrapped (``wire.coded_psum``)
    so each worker ships quantized P/Q; the resulting reconstruction error
    lands in PowerSGD's own EF residual — no extra state.
    """
    stack = jnp.concatenate(
        [by_path[path].astype(jnp.float32).reshape(-1, group.m, group.n)
         for path, _ in group.members],
        axis=0,
    )
    g_hat, st = compress_leaf(stack, state, _wire.coded_psum(psum_mean, codec),
                              use_kernels=use_kernels)
    out: dict[str, jax.Array] = {}
    offset = 0
    for path, shape in group.members:
        e = _batch_of(shape)
        out[path] = (g_hat[offset:offset + e]
                     .reshape(shape).astype(by_path[path].dtype))
        offset += e
    return out, st


def _sync_flat(
    by_path: dict[str, jax.Array],
    members: tuple[Member, ...],
    psum_mean: PsumFn,
    codec: "_wire.ChunkCodec | None" = None,
    comp_state: dict | None = None,
) -> tuple[dict[str, jax.Array], dict[str, jax.Array]]:
    """One flat member run: [code ->] pack -> psum-mean -> slice back.

    The psum is elementwise, so syncing a bucket's member runs separately
    is bit-identical to syncing the packed whole bucket — chunked and
    monolithic flat transfers reassemble to the same values. (The widest
    member dtype is computed per RUN: sub-runs of a mixed-dtype bucket may
    move narrower than the whole bucket would; uniform trees are exact.)

    With a codec, each member is quantized through the wire round trip
    *independently* (own scales and padding — quantization groups never
    span members, so the chunked-vs-monolithic equality holds at the coded
    payload too) with its error-feedback residual (``ef:<path>`` in
    ``comp_state``) added before and updated after coding. Returns
    ``(synced leaves, EF-state updates)`` — the latter empty in raw mode
    or for members whose state carries no EF entry (those code EF-less).
    """
    wire_dtype = jnp.result_type(*[by_path[path].dtype for path, _ in members])
    parts: list[jax.Array] = []
    ef_out: dict[str, jax.Array] = {}
    for path, shape in members:
        g = by_path[path]
        if codec is None:
            parts.append(g.astype(wire_dtype).reshape(-1))
            continue
        v = g.astype(jnp.float32).reshape(-1)
        ef = (comp_state or {}).get(EF_PREFIX + path)
        if ef is not None:
            v = v + ef.astype(jnp.float32).reshape(-1)
        sent = _wire.roundtrip(v, codec).astype(wire_dtype)
        if ef is not None:
            ef_out[EF_PREFIX + path] = (v - sent.astype(jnp.float32)
                                        ).reshape(g.shape)
        parts.append(sent)
    packed = psum_mean(jnp.concatenate(parts))
    out: dict[str, jax.Array] = {}
    offset = 0
    for path, shape in members:
        nelem = math.prod(shape) if shape else 1
        out[path] = (packed[offset:offset + nelem]
                     .reshape(shape).astype(by_path[path].dtype))
        offset += nelem
    return out, ef_out


def bucketed_sync_grads(
    grads: Any,
    comp_state: dict[str, LowRankState],
    layout: BucketLayout,
    psum_mean: PsumFn,
    use_kernels: bool = False,
    codec: "_wire.ChunkCodec | None" = None,
) -> tuple[Any, dict[str, LowRankState]]:
    """Execute the bucketed schedule: 2 psums per group, 1 per flat bucket.

    Numerically matches the per-leaf loop to fp32 tolerance (same PowerSGD
    math, batched; flat buckets are an elementwise-identical mean). With a
    codec every collective payload moves entropy-coded (core/wire.py).
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    by_path = {jax.tree_util.keystr(kp): g for kp, g in flat}
    out: dict[str, jax.Array] = {}
    new_state = dict(comp_state)

    for group in layout.groups:
        upd, st = _sync_group(by_path, group, comp_state[group.key],
                              psum_mean, use_kernels=use_kernels, codec=codec)
        out.update(upd)
        new_state[group.key] = st

    for bucket in layout.buckets:
        upd, ef_upd = _sync_flat(by_path, bucket.members, psum_mean,
                                 codec=codec, comp_state=comp_state)
        out.update(upd)
        new_state.update(ef_upd)

    out_leaves = [out[jax.tree_util.keystr(kp)] for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, out_leaves), new_state


def sync_chunk_grads(
    grads_by_path: dict[str, jax.Array],
    comp_state: dict[str, LowRankState],
    chunk: SyncChunk,
    psum_mean: PsumFn,
    use_kernels: bool = False,
    codec: "_wire.ChunkCodec | None" = None,
) -> tuple[dict[str, jax.Array], dict[str, LowRankState]]:
    """Execute ONE chunk of a layout's schedule (the overlap primitive).

    ``grads_by_path`` only needs the chunk's own members. Returns the
    synced leaves (by path) and the state entries the chunk touched
    ({group key: new state} for a group chunk, the coded run's ``ef:``
    updates for a flat run) — the same helpers ``bucketed_sync_grads``
    runs, and per-member coding partitions the EF exactly, so executing
    every chunk of a layout in any order reproduces the monolithic
    schedule exactly, coded or raw.
    """
    if chunk.kind == "group":
        upd, st = _sync_group(grads_by_path, chunk.group,
                              comp_state[chunk.group.key], psum_mean,
                              use_kernels=use_kernels, codec=codec)
        return upd, {chunk.group.key: st}
    return _sync_flat(grads_by_path, chunk.members, psum_mean,
                      codec=codec, comp_state=comp_state)
