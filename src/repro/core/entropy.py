"""GDS — Gradient Data Sampler (paper §IV-B).

Estimates the differential entropy of the gradient distribution cheaply via
two-level down-sampling:

  * GSR beta  — fraction of gradient entries sampled within one iteration.
  * ISR alpha — fraction of iterations (within a window) at which entropy is
    measured at all.

Two estimators are provided:

  * ``gaussian_entropy``  — the paper's Lemma 2 closed form
    H = log(sigma) + 0.5*log(2*pi*e).  This is what CQM's Theorem 3 actually
    consumes (only entropy *differences* matter, and under the paper's
    normality assumption H0 - H1 == log(sigma0/sigma1)).
  * ``histogram_entropy`` — a distribution-free plug-in estimator
    H ≈ -sum p_i log(p_i / w_i); used to validate the Gaussian assumption and
    for the Observation-1 reproduction.

Everything is pure JAX so it can run on-device inside the training step; the
Pallas kernel in ``repro.kernels.entropy_hist`` implements the histogram
variant for TPU and is validated against :func:`histogram_entropy`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable

import jax
import jax.numpy as jnp

_LOG_2PI_E = float(jnp.log(2.0 * jnp.pi) + 1.0)  # log(2*pi*e)  # lint: allow(host-call-in-hot-path) import-time constant


def strided_sample(x: jax.Array, beta: float) -> jax.Array:
    """Deterministic strided sub-sample of a flattened array.

    A strided (rather than random) sample keeps the estimate identical across
    data-parallel replicas — no RNG sync or extra collective required — and is
    unbiased for the order statistics of a (near-)stationary gradient
    distribution. ``beta`` is the GSR in (0, 1].
    """
    flat = x.reshape(-1)
    if beta >= 1.0:
        return flat
    n = flat.shape[0]
    k = max(1, int(n * beta))
    stride = max(1, n // k)
    return jax.lax.slice(flat, (0,), (stride * k,), (stride,))


def gaussian_entropy(x: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Lemma 2: H(N(mu, sigma^2)) = log sigma + 1/2 log(2 pi e)  [nats]."""
    x = x.astype(jnp.float32)
    sigma = jnp.std(x)
    return jnp.log(sigma + eps) + 0.5 * _LOG_2PI_E


def histogram_entropy(
    x: jax.Array,
    num_bins: int = 256,
    range_sigmas: float = 8.0,
    eps: float = 1e-12,
) -> jax.Array:
    """Plug-in differential entropy from a fixed-width histogram [nats].

    Bins span ``mu ± range_sigmas * sigma`` so the support adapts to the
    (shrinking, Observation 2) gradient range; H = -sum p log p + log(w)
    where w is the bin width (differential-entropy correction).
    """
    x = x.astype(jnp.float32).reshape(-1)
    mu = jnp.mean(x)
    sigma = jnp.std(x) + eps
    lo = mu - range_sigmas * sigma
    width = (2.0 * range_sigmas * sigma) / num_bins
    idx = jnp.clip(((x - lo) / width).astype(jnp.int32), 0, num_bins - 1)
    counts = jnp.zeros((num_bins,), jnp.float32).at[idx].add(1.0)
    p = counts / x.shape[0]
    plogp = jnp.where(p > 0, p * jnp.log(p + eps), 0.0)
    return -jnp.sum(plogp) + jnp.log(width + eps)


@dataclasses.dataclass(frozen=True)
class GDSConfig:
    """Sampling configuration (paper defaults: beta=0.25, alpha=0.1)."""

    beta: float = 0.25          # GSR: fraction of entries per measured iter
    alpha: float = 0.1          # ISR: fraction of iters measured per window
    estimator: str = "gaussian"  # "gaussian" | "histogram"
    num_bins: int = 256

    def measure_every(self) -> int:
        """GDS measures gradient entropy once every 1/alpha iterations."""
        return max(1, round(1.0 / self.alpha))

    def should_measure(self, step_in_window: int) -> bool:
        return step_in_window % self.measure_every() == 0


def _leaf_entropy(leaf: jax.Array, cfg: GDSConfig) -> tuple[jax.Array, jax.Array]:
    s = strided_sample(leaf, cfg.beta)
    if cfg.estimator == "histogram":
        h = histogram_entropy(s, cfg.num_bins)
    else:
        h = gaussian_entropy(s)
    return h, jnp.asarray(s.shape[0], jnp.float32)


def sample_moments(grads, cfg: GDSConfig = GDSConfig(), lead_mask=None):
    """(count, sum, sum-of-squares) of the pooled beta-sample of a pytree.

    The three scalars are sufficient statistics for the Gaussian (Lemma 2)
    estimator, and — unlike the pooled sample itself — they are additive:
    the pipeline-parallel train step computes them per stage and psums over
    the ``pipe`` axis, reproducing the single-program pooled entropy exactly
    (moments are permutation-invariant, so partial-sum grouping only moves
    fp32 association error).

    ``lead_mask`` (a boolean (Lmax,) live-unit vector for a stage-stacked
    tree whose leaves all lead with that dim) excludes zero-PADDED slots
    exactly: the mask broadcasts over each leaf, is strided-sampled at the
    SAME positions as the values, and only live samples enter n/s1/s2.
    Without it a ragged pipeline stage would pool its pad zeros — n
    inflated, sigma (and the entropy CQM's Theorem 3 consumes) biased low.
    Since the pad slots are a contiguous tail per unit row and the stride
    divides the row evenly for the usual power-of-two leaf shapes, the
    surviving sample positions coincide with the flat (unpadded) leaf's,
    keeping pipelined pooled entropy equal to the flat ``grads_entropy``.
    """
    leaves = [l for l in jax.tree_util.tree_leaves(grads) if l.size > 16]
    if not leaves:
        z = jnp.zeros((), jnp.float32)
        return z, z, z
    samples = [strided_sample(l, cfg.beta).astype(jnp.float32) for l in leaves]
    if lead_mask is None:
        n = jnp.asarray(sum(s.shape[0] for s in samples), jnp.float32)
        s1 = sum(jnp.sum(s) for s in samples)
        s2 = sum(jnp.sum(jnp.square(s)) for s in samples)
        return n, s1, s2
    masks = [
        strided_sample(
            jnp.broadcast_to(
                lead_mask.reshape((lead_mask.shape[0],) + (1,) * (l.ndim - 1)),
                l.shape).astype(jnp.float32),
            cfg.beta)
        for l in leaves
    ]
    n = sum(jnp.sum(m) for m in masks)
    s1 = sum(jnp.sum(s * m) for s, m in zip(samples, masks))
    s2 = sum(jnp.sum(jnp.square(s) * m) for s, m in zip(samples, masks))
    return n, s1, s2


def entropy_from_moments(n, s1, s2, eps: float = 1e-12) -> jax.Array:
    """Lemma 2 from pooled sufficient statistics: H = log sigma + c."""
    n = jnp.maximum(n, 1.0)
    mean = s1 / n
    var = jnp.maximum(s2 / n - mean * mean, 0.0)
    return jnp.log(jnp.sqrt(var) + eps) + 0.5 * _LOG_2PI_E


@partial(jax.jit, static_argnames=("cfg",))
def grads_entropy(grads, cfg: GDSConfig = GDSConfig()) -> jax.Array:
    """Entropy of the pooled beta-sample over all leaves of a gradient pytree.

    This is GDS's per-iteration measurement: beta-sampled, on-device, one
    scalar out. Single-pass: the per-leaf strided samples are reduced to
    pooled sufficient statistics (``sample_moments``) and the estimator
    runs ONCE over them — one pass per leaf instead of 2x num_leaves tiny
    reductions (the per-leaf variant below remains for the per-stage API).
    The alpha gate (whether to call it at all this iteration) lives in the
    host-side controller.
    """
    if cfg.estimator == "histogram":
        leaves = [l for l in jax.tree_util.tree_leaves(grads) if l.size > 16]
        pooled = jnp.concatenate(
            [strided_sample(l, cfg.beta).astype(jnp.float32) for l in leaves]
        )
        return histogram_entropy(pooled, cfg.num_bins)
    return entropy_from_moments(*sample_moments(grads, cfg))


@partial(jax.jit, static_argnames=("cfg",))
def grads_entropy_per_leaf(grads, cfg: GDSConfig = GDSConfig()) -> jax.Array:
    """Size-weighted mean of per-leaf entropies (the per-stage estimator).

    Weighting per-leaf entropies keeps each stage's layers comparable even
    when their gradient scales differ, which is what the per-stage DAC
    readings want; the pooled single-pass ``grads_entropy`` is the cheap
    whole-model measurement used inside the train step.
    """
    leaves = [l for l in jax.tree_util.tree_leaves(grads) if l.size > 16]
    hs, ws = zip(*(_leaf_entropy(l, cfg) for l in leaves))
    h = jnp.stack(hs)
    w = jnp.stack(ws)
    return jnp.sum(h * w) / jnp.sum(w)


def grads_entropy_per_group(grads_by_group: Iterable, cfg: GDSConfig = GDSConfig()):
    """Entropy per (pipeline-stage) group — list of pytrees -> list of scalars."""
    return [grads_entropy_per_leaf(g, cfg) for g in grads_by_group]


def grad_std(grads) -> jax.Array:
    """Global std of a gradient pytree (used by Obs. 2 reproduction).

    One sweep per leaf via var = E[x^2] - E[x]^2 (the two-pass version read
    every leaf twice: once for the mean, once for the deviations).
    """
    leaves = jax.tree_util.tree_leaves(grads)
    total = sum(l.size for l in leaves)
    s1 = sum(jnp.sum(l.astype(jnp.float32)) for l in leaves)
    s2 = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    mean = s1 / total
    return jnp.sqrt(jnp.maximum(s2 / total - mean * mean, 0.0))
