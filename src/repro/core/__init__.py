"""EDGC core: entropy-driven dynamic gradient compression (the paper's contribution)."""
from .bucketing import BucketLayout, SyncChunk, make_bucket_layout, sync_chunks
from .comm_model import CommModel, HardwareSpec, TPU_V5E, rank_bounds
from .config import COMM_MODES, SyncConfig
from .compressor import (
    CompressionPlan,
    LeafInfo,
    NO_COMPRESSION,
    classify_leaves,
    init_compressor_state,
    make_plan,
    plan_wire_bytes,
    resize_compressor_state,
    sync_grads,
)
from .controller import EDGCConfig, EDGCController
from .cqm import CQM, rank_from_entropy_delta, theoretical_error
from .dac import DAC, DACConfig, stage_aligned_ranks, window_rank_adjust
from .entropy import (
    GDSConfig,
    gaussian_entropy,
    grads_entropy,
    grads_entropy_per_leaf,
    histogram_entropy,
)
from .mp_law import GTable, g_table, mp_cdf, mp_support, sample_eigenvalues
from .powersgd import LowRankState, compress_leaf, gram_schmidt, init_leaf_state
from .sync_executor import SyncExecutor

__all__ = [
    "BucketLayout", "SyncChunk", "make_bucket_layout", "sync_chunks",
    "CommModel", "HardwareSpec", "TPU_V5E", "rank_bounds",
    "COMM_MODES", "SyncConfig", "SyncExecutor",
    "CompressionPlan", "LeafInfo", "NO_COMPRESSION", "classify_leaves",
    "init_compressor_state", "make_plan", "plan_wire_bytes",
    "resize_compressor_state", "sync_grads",
    "EDGCConfig", "EDGCController",
    "CQM", "rank_from_entropy_delta", "theoretical_error",
    "DAC", "DACConfig", "stage_aligned_ranks", "window_rank_adjust",
    "GDSConfig", "gaussian_entropy", "grads_entropy",
    "grads_entropy_per_leaf", "histogram_entropy",
    "GTable", "g_table", "mp_cdf", "mp_support", "sample_eigenvalues",
    "LowRankState", "compress_leaf", "gram_schmidt", "init_leaf_state",
]
