"""DAC — Dynamic Alignment Compressor (paper §IV-D, Algorithms 1 and 2).

Host-side control plane. Owns:

  * rank bounds [r_min, r_max] from the comm model (Eq. 2 / footnote 1),
  * the adaptive warm-up decision (§IV-D2),
  * window-based rank adjustment for pipeline stage 1 (Algorithm 1),
  * stage-aligned rank adjustment for stages i > 1 (Algorithm 2, Eq. 4).

Nothing here touches device state: DAC consumes scalar entropy readings
(produced on-device by GDS) and emits per-stage integer ranks; the trainer
re-specializes the compiled step only when the rank vector changes
(window-level, as the paper prescribes to amortize "memory reallocation").
"""
from __future__ import annotations

import dataclasses

from .comm_model import CommModel
from .cqm import CQM

__all__ = ["DACConfig", "window_rank_adjust", "stage_aligned_ranks", "DAC"]


@dataclasses.dataclass(frozen=True)
class DACConfig:
    window: int = 1000            # w, iterations per adjustment window (Tab. VII)
    adjust_limit: int = 2         # s, max |rank delta| per window (Constraint 2)
    warmup_frac_min: float = 0.10  # empirical floor on the warm-up phase
    r_min_divisor: float = 5.0    # r_min = r_max / divisor, in [4, 6]
    quantize_to: int = 2          # snap ranks to multiples (bounds compile cache)


def window_rank_adjust(
    r_prev: int,
    r_new: int,
    r_min: int,
    r_max: int,
    s: int,
) -> int:
    """Algorithm 1 lines 3-10: limit the per-window move to ±s and clamp.

    ``r_new`` is the Theorem-3 (Eq. 11/15) rank computed by CQM from the
    window-mean entropy; the output is the applied rank for stage 1.
    """
    if abs(r_new - r_prev) > s:
        r_new = r_prev + s if r_new > r_prev else r_prev - s
    return max(r_min, min(r_max, r_new))


def stage_aligned_ranks(
    r_stage1: int,
    num_stages: int,
    comm: CommModel,
    t_micro_back: float,
    r_min: int,
    r_max: int,
    slack_seconds: list | None = None,
) -> list[int]:
    """Algorithm 2: align all stages' comm completion with stage 1 (Eq. 4).

    Stage 1 starts its DP sync last (its backward finishes last in 1F1B);
    stage i has an (i-1) * T_microBack head start, so it can afford
    T_com(r^{s1}) + (i-1) * T_microBack of communication — i.e. a *larger*
    (more accurate) rank — and still finish with stage 1.

    ``slack_seconds`` (0-indexed per stage, entry 0 ignored) replaces the
    analytic ``(i-1) * t_micro_back`` head start with the overlap planner's
    measured Eq. 4 slack (``simulate_schedule``'s calibrated event times):
    the rank vector then reflects what the schedule-interleaved sync can
    actually hide, not the unit-tick idealization. With
    ``slack_seconds[s] == s * t_micro_back`` (the unit model) the two
    formulations coincide exactly.
    """
    t1 = comm.t_com(r_stage1)
    ranks = [r_stage1]
    for i in range(2, num_stages + 1):
        head = (slack_seconds[i - 1] if slack_seconds is not None
                else (i - 1) * t_micro_back)
        t_i = t1 + head
        ranks.append(comm.rank_for_time(t_i, r_min, r_max))
    return ranks


@dataclasses.dataclass
class DAC:
    """Stateful per-training-run DAC instance.

    One CQM anchors the entropy->rank law (on the representative — largest —
    compressed shape, as the paper's layer-invariance observation justifies:
    relative error trends are consistent across layers, Fig. 10).
    """

    cqm: CQM
    comm: CommModel
    cfg: DACConfig
    r_min: int
    r_max: int
    num_stages: int
    t_micro_back: float
    total_iterations: int

    # mutable control state
    warmed_up: bool = False
    r_stage1: int = 0
    window_index: int = 0
    # per-stage ranks actually APPLIED last window (Constraint 2 is a
    # bound on the applied move, so every stage — not just stage 1 —
    # tracks its previous value); None until the first post-warm-up update
    applied_ranks: list | None = None
    # Overlap feedback (set via set_overlap): the planner's measured
    # per-stage Eq. 4 slack in seconds. When present it (a) replaces the
    # analytic (i-1)*t_micro_back head start in stage alignment and
    # (b) turns on the feasibility clamp — a stage's applied rank is
    # lowered until its comm fits T_com(r_stage1) + slack, so the rank
    # vector trades rank for OVERLAP FEASIBILITY, not just raw bytes.
    slack_seconds: list | None = None

    def __post_init__(self) -> None:
        self.r_stage1 = self.r_max

    def set_overlap(self, slack_seconds) -> None:
        """Feed the overlap planner's per-stage Eq. 4 slack (seconds).

        ``slack_seconds[s]`` is how long before stage 0's last backward
        stage s's last backward retires (``simulate_schedule(...)
        ["slack_seconds"]``, possibly calibrated with measured t_f/t_b).
        Must be per-stage, non-negative, with stage 0 at zero slack.
        """
        slack = [float(t) for t in slack_seconds]
        if len(slack) != self.num_stages:
            raise ValueError(f"slack_seconds has {len(slack)} entries, "
                             f"DAC drives {self.num_stages} stages")
        if any(t < 0 for t in slack):
            raise ValueError(f"negative Eq. 4 slack: {slack}")
        self.slack_seconds = slack

    def _feasible_clamp(self, ranks: list[int]) -> list[int]:
        """Lower any stage's rank until its comm fits its overlap budget.

        Budget = T_com(r_stage1) + slack_s (Eq. 4 with measured slack).
        Like the [r_min, r_max] bounds this is a Constraint-1-style hard
        limit, applied after the ±adjust_limit window: an infeasible rank
        would push the stage's sync past stage 0's and stall the pipeline,
        so feasibility wins over move smoothness (downward only — the
        clamp never raises a rank).
        """
        if self.slack_seconds is None:
            return ranks
        q = max(1, self.cfg.quantize_to)
        t1 = self.comm.t_com(ranks[0])
        out = [ranks[0]]
        for s in range(1, len(ranks)):
            budget = t1 + self.slack_seconds[s]
            r = ranks[s]
            while r - q >= self.r_min and self.comm.t_com(r) > budget:
                r -= q
            out.append(max(self.r_min, r))
        return out

    def _snap_limited(self, r: int, r_prev: int) -> int:
        """Quantize to the rank grid WITHOUT leaving the ±adjust_limit
        window around ``r_prev``: the snap happens INSIDE the clamp, so
        the applied move can never exceed ``adjust_limit`` (the old
        clamp-then-round order could emit adjust_limit + quantize_to/2,
        a Constraint-2 violation). Rank bounds still win last — they are
        Constraint 1."""
        q = max(1, self.cfg.quantize_to)
        s = self.cfg.adjust_limit
        rq = round(r / q) * q
        if rq > r_prev + s:
            rq -= q * (-(-(rq - (r_prev + s)) // q))     # ceil-div steps
            if rq < r_prev - s:
                rq = r_prev   # no grid point in the window (q > 2s): hold
        elif rq < r_prev - s:
            rq += q * (-(-((r_prev - s) - rq) // q))
            if rq > r_prev + s:
                rq = r_prev
        return max(self.r_min, min(self.r_max, rq))

    # -- §IV-D2: adaptive warm-up -------------------------------------------
    def maybe_end_warmup(self, h_window: float, step: int) -> bool:
        """End warm-up when the Theorem-3 rank first drops below r_max, but
        never before 10% of total iterations (the empirical constraint)."""
        if self.warmed_up:
            return True
        if step < self.cfg.warmup_frac_min * self.total_iterations:
            return False
        if not self.cqm.anchored:
            # anchor the fixed-error constraint at (r_max, current entropy)
            self.cqm.anchor(self.r_max, h_window)
            return False
        r_new = self.cqm.rank_for_entropy(h_window)
        if r_new < self.r_max:
            self.warmed_up = True
            self.r_stage1 = self.r_max
        return self.warmed_up

    # -- Algorithm 1 + 2 ------------------------------------------------------
    def update(self, h_window: float) -> list[int]:
        """Per-window update: new per-stage rank vector (stage 1 first).

        Quantization happens INSIDE the Constraint-2 clamp for every
        stage: the Theorem-3 target is first limited to ±adjust_limit of
        the stage's previously APPLIED rank, then snapped to the rank
        grid without leaving that window (``_snap_limited``). Monotone
        clamps over monotone previous/target vectors keep the Algorithm-2
        non-decreasing-over-stages invariant intact.
        """
        self.window_index += 1
        if not self.cqm.anchored:
            self.cqm.anchor(self.r_max, h_window)
        prev = list(self.applied_ranks or [self.r_max] * self.num_stages)
        r_new = self.cqm.rank_for_entropy(h_window)
        r1 = window_rank_adjust(
            prev[0], r_new, self.r_min, self.r_max, self.cfg.adjust_limit
        )
        r1 = self._snap_limited(r1, prev[0])
        self.r_stage1 = r1
        ranks = stage_aligned_ranks(
            r1, self.num_stages, self.comm, self.t_micro_back,
            self.r_min, self.r_max, slack_seconds=self.slack_seconds,
        )
        out = [r1]
        for i in range(1, self.num_stages):
            r_i = window_rank_adjust(
                prev[i], ranks[i], self.r_min, self.r_max,
                self.cfg.adjust_limit
            )
            out.append(self._snap_limited(r_i, prev[i]))
        out = self._feasible_clamp(out)
        self.applied_ranks = out
        return list(out)

    def current_ranks(self) -> list[int]:
        if self.applied_ranks is not None:
            return list(self.applied_ranks)
        return self._feasible_clamp(stage_aligned_ranks(
            self.r_stage1, self.num_stages, self.comm, self.t_micro_back,
            self.r_min, self.r_max, slack_seconds=self.slack_seconds,
        ))
