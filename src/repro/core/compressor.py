"""Gradient-sync compressor: policies, leaf classification, plans, state.

This is the layer the trainer and dist/collectives call into. It decides —
statically, per compiled step — *which* gradient leaves are low-rank
compressed and at *what* rank, then executes compress → (injected psum) →
decompress with error feedback for those leaves and a plain psum for the
rest.

Policies (all four share this code path; they differ only in plan-making):

  * ``none``      — Megatron-LM baseline: full-gradient all-reduce.
  * ``fixed``     — PowerSGD baseline: one static rank everywhere.
  * ``optimus``   — Optimus-CC-style: static rank, embeddings/1-D excluded
                    (which this framework always excludes) plus first/last
                    stage relaxed, error feedback on.
  * ``edgc``      — per-stage dynamic ranks from the DAC controller.

The plan is a hashable static argument, so rank changes re-specialize the
jitted step at window boundaries only (paper §IV-C: windowing amortizes the
reallocation cost; here, the recompile).
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable

import jax

from . import bucketing
from .bucketing import BucketLayout
from .powersgd import (
    LowRankState,
    compress_leaf,
    compressed_bytes,
    init_leaf_state,
    resize_rank,
)

__all__ = [
    "LeafInfo",
    "CompressionPlan",
    "classify_leaves",
    "make_plan",
    "init_compressor_state",
    "sync_grads",
    "plan_wire_bytes",
    "resize_compressor_state",
]

PsumFn = Callable[[jax.Array], jax.Array]

# Leaves whose path matches are never compressed (Optimus-CC's own carve-out:
# embedding/vocab projections; norms and biases are 1-D and excluded anyway).
# ``shared`` (Zamba's parameter-shared attention block, applied on every
# stage) and ``dec_pos`` (whisper's learned positional table) join the
# embedding carve-out: they replicate over the pipe axis, and pipeline-shared
# leaves must stay uncompressed (per-stage plans cover stage leaves only).
DEFAULT_EXCLUDE = (
    r"(embed|lm_head|norm|bias|scale|router|conv|a_log|dt|state"
    r"|shared|dec_pos|projector)"
)


@dataclasses.dataclass(frozen=True)
class LeafInfo:
    path: str
    shape: tuple[int, ...]
    stage: int          # pipeline stage (0-based) this leaf belongs to
    eligible: bool      # structurally compressible (>=2-D, big enough)
    dtype: str | None = None   # param dtype name (None: unknown, assume fp32)

    @property
    def itemsize(self) -> int:
        """Bytes per element on the raw wire (4 when dtype is unknown)."""
        import numpy as np
        return int(np.dtype(self.dtype).itemsize) if self.dtype else 4


# Non-block leaves are pinned to the pipeline boundary stages explicitly:
# embeddings live with the first stage (they feed it), the LM head and the
# final norm with the last (they consume its output). Letting them fall
# through the index regexes put them wherever the regex missed — stage 0 —
# which is wrong for the head on every S > 1 model. Pipeline-REPLICATED
# leaves (Zamba's ``shared`` attention block, vision projectors) charge to
# stage 0 like embeddings — one owner in the wire ledger, psum'd over pipe
# in execution.
_STAGE0_PAT = re.compile(r"embed|wte|wpe|patch_proj|pos|projector|shared",
                         re.IGNORECASE)
_STAGE_LAST_PAT = re.compile(r"lm_head|final_norm|head\b", re.IGNORECASE)
_STAGE_IDX_PAT = re.compile(r"stages?\W{0,3}(\d+)")
_LAYER_IDX_PAT = re.compile(r"layers?[/\[.](\d+)")


def _layer_stage(path: str, num_layers: int, num_stages: int,
                 param_stages: int | None = None) -> int:
    """Map a param path to its pipeline stage.

    Priority: explicit boundary pins (embeddings -> 0, head/final norm ->
    S-1), then the model's own ``['stages'][i]`` index (rescaled when the
    param layout has ``param_stages`` != ``num_stages`` groups), then a
    flat ``layers.<i>`` index mapped through ``num_layers``.
    """
    if num_stages <= 1:
        return 0
    m = _STAGE_IDX_PAT.search(path)
    if m is not None:
        i = int(m.group(1))
        groups = max(param_stages or num_stages, i + 1)
        return min(num_stages - 1, i * num_stages // groups)
    if _STAGE0_PAT.search(path):
        return 0
    if _STAGE_LAST_PAT.search(path):
        return num_stages - 1
    m = _LAYER_IDX_PAT.search(path)
    if m is None:
        m = re.search(r"\b(\d+)\b", path) if "layer" in path else None
    if m is None or num_layers <= 0:
        return 0
    layer = int(m.group(1))
    return min(num_stages - 1, layer * num_stages // max(1, num_layers))


def classify_leaves(
    params: Any,
    num_layers: int,
    num_stages: int = 1,
    min_dim: int = 64,
    exclude: str = DEFAULT_EXCLUDE,
) -> list[LeafInfo]:
    """Walk the param pytree and classify every leaf.

    Eligibility: 2-D/3-D, both matricized dims >= min_dim, path not excluded.
    min_dim guards Eq. 2 — tiny matrices never win from compression — and
    keeps rank <= min(m, n)/2 meaningful.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    infos = []
    pat = re.compile(exclude, re.IGNORECASE)
    # The model's own stage granularity: number of distinct ['stages'][i]
    # groups in the layout. _layer_stage rescales when it differs from the
    # requested num_stages (e.g. a 4-stage param layout classified for 2).
    paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
    idxs = [int(m.group(1)) for p in paths
            for m in [_STAGE_IDX_PAT.search(p)] if m is not None]
    param_stages = (max(idxs) + 1) if idxs else None
    for (key_path, leaf), path in zip(flat, paths):
        shape = tuple(leaf.shape)
        mat_dims = shape[-2:] if len(shape) >= 2 else shape
        eligible = (
            len(shape) >= 2
            and len(mat_dims) == 2
            and min(mat_dims) >= min_dim
            and pat.search(path) is None
        )
        infos.append(
            LeafInfo(
                path=path,
                shape=shape,
                stage=_layer_stage(path, num_layers, num_stages, param_stages),
                eligible=eligible,
                dtype=str(leaf.dtype) if hasattr(leaf, "dtype") else None,
            )
        )
    return infos


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Static (hashable) map path -> rank for compressed leaves.

    ``ranks`` holds only compressed leaves; everything else is plain-psum'd.
    """

    ranks: tuple[tuple[str, int], ...]

    @functools.cached_property
    def _rank_map(self) -> dict[str, int]:
        # rank_of is called per leaf per trace; the dict makes it O(1) while
        # hashing/eq still go through the ``ranks`` tuple field only.
        return dict(self.ranks)

    def rank_of(self, path: str) -> int | None:
        return self._rank_map.get(path)

    def as_dict(self) -> dict[str, int]:
        return dict(self._rank_map)


NO_COMPRESSION = CompressionPlan(ranks=())


def make_plan(
    policy: str,
    leaves: list[LeafInfo],
    stage_ranks: list[int] | None = None,
    fixed_rank: int = 64,
    num_stages: int = 1,
) -> CompressionPlan:
    """Build the per-leaf rank plan for a policy (see module docstring)."""
    if policy == "none":
        return NO_COMPRESSION
    if policy == "edgc":
        if stage_ranks is None:
            raise ValueError("edgc plan needs DAC stage ranks")
        if len(stage_ranks) != num_stages:
            # A short vector used to clamp silently onto the last entry,
            # hiding stage/rank misalignment (Algorithm 2 emits exactly one
            # rank per stage). Fail loudly instead.
            raise ValueError(
                f"stage_ranks has {len(stage_ranks)} entries for "
                f"num_stages={num_stages}; Algorithm 2 must emit one rank "
                f"per pipeline stage"
            )
    ranks: list[tuple[str, int]] = []
    for info in leaves:
        if not info.eligible:
            continue
        max_r = min(info.shape[-2:]) // 2
        if policy == "fixed":
            r = fixed_rank
        elif policy == "optimus":
            # Optimus-CC relaxes compression on the pipeline-boundary stages
            # (they carry embedding-adjacent signal); interior stages fixed.
            boundary = info.stage in (0, num_stages - 1)
            r = min(fixed_rank * 2, max_r) if boundary else fixed_rank
        elif policy == "edgc":
            r = stage_ranks[info.stage]
        else:
            raise ValueError(f"unknown policy {policy!r}")
        r = max(1, min(r, max_r))
        ranks.append((info.path, int(r)))
    return CompressionPlan(ranks=tuple(ranks))


def _leaves_by_path(tree: Any) -> dict[str, jax.Array]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(kp): leaf for kp, leaf in flat}


def init_compressor_state(
    params: Any, plan: CompressionPlan, key: jax.Array, *,
    layout: BucketLayout | None = None,
    wire_ef: bool = False,
) -> dict[str, LowRankState]:
    """Compressor state for a plan.

    Default: one LowRankState per compressed leaf, keyed by path string (the
    per-leaf parity oracle). With a ``layout``, the same per-leaf warm starts
    are stacked into one fp32 state per shape group, keyed by group — the
    format the bucketed executor consumes. Identical Q values either way, so
    the two formats start bit-equivalent. ``wire_ef`` (coded wire modes)
    additionally seeds a zero error-feedback residual per flat-bucket member
    (``ef:<path>``), which the coded ``_sync_flat`` reads and updates.
    """
    by_path = _leaves_by_path(params)
    state: dict[str, LowRankState] = {}
    for i, (path, rank) in enumerate(plan.ranks):
        leaf = by_path[path]
        state[path] = init_leaf_state(
            tuple(leaf.shape), rank, jax.random.fold_in(key, i), leaf.dtype
        )
    if layout is None:
        return state
    state = bucketing.stack_state(state, layout)
    if wire_ef:
        state.update(bucketing.init_flat_ef(layout))
    return state


def resize_compressor_state(
    state: dict[str, LowRankState], plan: CompressionPlan, key: jax.Array, *,
    old_layout: BucketLayout | None = None,
    new_layout: BucketLayout | None = None,
) -> dict[str, LowRankState]:
    """Migrate warm-start Q / EF buffers when DAC changes ranks or leaves.

    Stacked (group-keyed) states pass the layouts they were/will be packed
    under; per-leaf states keep the legacy path-keyed resize.
    """
    if old_layout is not None or bucketing.is_stacked_state(state):
        if old_layout is None or new_layout is None:
            raise ValueError("stacked compressor state needs old_layout and "
                             "new_layout to resize")
        return bucketing.resize_stacked_state(state, old_layout, new_layout, key)
    new_state: dict[str, LowRankState] = {}
    for i, (path, rank) in enumerate(plan.ranks):
        if path in state:
            new_state[path] = resize_rank(state[path], rank, jax.random.fold_in(key, i))
        else:
            raise KeyError(f"no compressor state for newly-compressed leaf {path}")
    return new_state


def sync_grads(
    grads: Any,
    comp_state: dict[str, LowRankState],
    plan: CompressionPlan,
    psum_mean: PsumFn,
    use_kernels: bool = False,
    bucketed: bool | None = None,
    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
    codec=None,
) -> tuple[Any, dict[str, LowRankState]]:
    """Data-parallel gradient synchronization under a compression plan.

    Runs inside the (manual pod+data) shard_map region of the train step.
    Two executors share this entry point:

      * ``bucketed=False`` — the per-leaf loop (parity oracle): PowerSGD
        factor psums + error feedback per compressed leaf, one plain
        psum-mean per remaining leaf — O(num_leaves) collectives.
      * ``bucketed=True``  — the bucketed schedule (core/bucketing.py):
        shape-grouped stacked compression + flat fp32 buckets —
        O(num_shape_groups + num_buckets) collectives. Requires stacked
        (group-keyed) ``comp_state``; the layout is re-derived here from the
        static leaf shapes + plan, so it always matches the state's packing.

    ``bucketed=None`` infers the executor from the state format. ``codec``
    (wire.ChunkCodec) entropy-codes every collective payload — bucketed
    executor only; the per-leaf loop stays the uncoded parity oracle.
    Returns (synced grads, new compressor state).
    """
    if bucketed is None:
        bucketed = bucketing.is_stacked_state(comp_state)
    if codec is not None and not bucketed:
        raise ValueError("wire coding (codec) requires the bucketed executor; "
                         "the per-leaf path is the raw parity oracle")
    if bucketed:
        layout = bucketing.layout_for_tree(grads, plan, bucket_bytes)
        return bucketing.bucketed_sync_grads(grads, comp_state, layout,
                                             psum_mean, use_kernels=use_kernels,
                                             codec=codec)
    rank_by_path = plan.as_dict()
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    out_leaves = []
    new_state = dict(comp_state)
    for key_path, g in flat:
        path = jax.tree_util.keystr(key_path)
        if path in rank_by_path:
            g_hat, st = compress_leaf(
                g, comp_state[path], psum_mean, use_kernels=use_kernels
            )
            new_state[path] = st
            out_leaves.append(g_hat)
        else:
            out_leaves.append(psum_mean(g))
    synced = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return synced, new_state


def plan_wire_bytes(
    leaves: list[LeafInfo], plan: CompressionPlan, bytes_per_elem: int = 2,
    codec=None,
) -> tuple[int, int]:
    """(compressed_bytes, full_bytes) moved per step by the DP sync.

    Exact byte accounting — this feeds comm_model, Fig. 9, Tables III/VI.
    With a ``codec`` (wire.ChunkCodec), ``compressed_bytes`` is the
    entropy-coded payload (packed words + scales for the PowerSGD factor
    elements and each uncompressed leaf); ``full_bytes`` stays the raw
    uncoded baseline either way, so the pair reads as coded-vs-raw.
    """
    from . import wire as _wire

    rank_by_path = plan.as_dict()
    comp = 0
    full = 0
    for info in leaves:
        nelem = 1
        for d in info.shape:
            nelem *= d
        full += nelem * bytes_per_elem
        if info.path in rank_by_path:
            rank = rank_by_path[info.path]
            if codec is not None:
                comp += _wire.coded_bytes(
                    compressed_bytes(info.shape, rank, 1), codec)
            else:
                comp += compressed_bytes(info.shape, rank, bytes_per_elem)
        elif codec is not None:
            comp += _wire.coded_bytes(nelem, codec)
        else:
            comp += nelem * bytes_per_elem
    return comp, full
