"""EDGCController — ties GDS + CQM + DAC together over the training run.

The trainer calls ``on_step(step, grads)`` every iteration:

  * GDS's alpha gate decides whether entropy is measured this iteration
    (the measurement itself is the on-device, beta-sampled ``grads_entropy``);
  * at window boundaries the window-mean entropy drives the DAC:
      - during warm-up: the adaptive warm-up check (§IV-D2),
      - after: Algorithm 1 (+ stage alignment, Algorithm 2),
    producing a new per-stage rank vector and hence a new CompressionPlan;
  * the trainer re-specializes its compiled step iff the plan changed.

All controller state is host-side Python; the only device work it requests
is the alpha-gated scalar entropy.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from .comm_model import CommModel, rank_bounds
from .compressor import (
    NO_COMPRESSION,
    CompressionPlan,
    LeafInfo,
    make_plan,
)
from .config import alias_property, resolve_embedded
from .cqm import CQM
from .dac import DAC, DACConfig
from .entropy import GDSConfig

__all__ = ["EDGCConfig", "EDGCController"]


@dataclasses.dataclass(frozen=True, init=False)
class EDGCConfig:
    """EDGC policy configuration.

    The execution knobs live in the embedded configs: ``pipeline``
    (``repro.pipeline.PipelineConfig`` — ``num_stages``, schedule, overlap)
    and ``sync`` (``repro.core.SyncConfig`` — bucketing, kernels). The old
    flat fields (``num_stages``, ``use_kernels``) are accepted as init
    kwargs and readable as properties, deprecated in favor of
    ``cfg.pipeline.num_stages`` / ``cfg.sync.use_kernels``.
    """

    policy: str = "edgc"          # none | fixed | optimus | edgc
    fixed_rank: int = 64          # for the fixed / optimus baselines
    gds: GDSConfig = GDSConfig()
    dac: DACConfig = DACConfig()
    total_iterations: int = 10_000
    mxu_efficiency: float = 0.35  # for the analytic comm/compute model
    pipeline: Any = None          # PipelineConfig (resolved in __init__)
    sync: Any = None              # SyncConfig (resolved in __init__)

    def __init__(self, policy: str = "edgc", fixed_rank: int = 64,
                 gds: GDSConfig | None = None, dac: DACConfig | None = None,
                 total_iterations: int = 10_000, mxu_efficiency: float = 0.35,
                 pipeline=None, sync=None, **legacy) -> None:
        pipeline, sync = resolve_embedded(pipeline, sync, legacy,
                                          where="EDGCConfig")
        set_ = lambda k, v: object.__setattr__(self, k, v)
        set_("policy", policy)
        set_("fixed_rank", fixed_rank)
        set_("gds", gds if gds is not None else GDSConfig())
        set_("dac", dac if dac is not None else DACConfig())
        set_("total_iterations", total_iterations)
        set_("mxu_efficiency", mxu_efficiency)
        set_("pipeline", pipeline)
        set_("sync", sync)


# Deprecated flat-field aliases (kept for existing call sites/tests).
EDGCConfig.num_stages = alias_property("pipeline", "num_stages")
EDGCConfig.use_kernels = alias_property("sync", "use_kernels")


class EDGCController:
    """Host-side orchestration of the EDGC policy (and the baselines)."""

    def __init__(
        self,
        cfg: EDGCConfig,
        leaves: list[LeafInfo],
        world: int,
        t_micro_back: float | None = None,
    ) -> None:
        self.cfg = cfg
        self.leaves = leaves
        self.world = world

        eligible = [l for l in leaves if l.eligible]
        if not eligible and cfg.policy != "none":
            raise ValueError("no compressible leaves; use policy='none'")

        # Analytic comm model over the eligible population (Eq. 2-3).
        shapes = []
        for l in eligible:
            m, n = l.shape[-2:]
            reps = l.shape[0] if len(l.shape) == 3 else 1
            shapes.extend([(m, n)] * reps)
        self.comm = CommModel.from_shapes(
            shapes or [(1, 1)], world=world, mxu_efficiency=cfg.mxu_efficiency
        )

        # Representative shape for the CQM anchor: the largest eligible
        # matrix (layer-invariance, Fig. 10, lets one law drive all stages).
        if eligible:
            rep = max(eligible, key=lambda l: l.shape[-2] * l.shape[-1])
            m, n = sorted(rep.shape[-2:])
            max_possible = m // 2
        else:
            m, n, max_possible = 64, 64, 32
        self.cqm = CQM(m=m, n=n)

        self.r_min, self.r_max = rank_bounds(
            self.comm, max_possible, cfg.dac.r_min_divisor
        )

        # Analytic per-stage backprop time if not measured (see DESIGN §3).
        if t_micro_back is None:
            t_micro_back = self.comm.t_com(max(1, (self.r_max - self.r_min) // 4))
        self.dac = DAC(
            cqm=self.cqm,
            comm=self.comm,
            cfg=cfg.dac,
            r_min=self.r_min,
            r_max=self.r_max,
            num_stages=cfg.num_stages,
            t_micro_back=t_micro_back,
            total_iterations=cfg.total_iterations,
        )

        # entropy bookkeeping
        self._window_h: list[float] = []
        self._history: list[tuple[int, float]] = []     # (step, entropy)
        self._rank_history: list[tuple[int, list[int]]] = []
        self._fallback = False   # recovery: pin to uncompressed sync
        self._plan = self._initial_plan()

    # ------------------------------------------------------------------ plans
    def _initial_plan(self) -> CompressionPlan:
        p = self.cfg.policy
        if p == "none":
            return NO_COMPRESSION
        if p in ("fixed", "optimus"):
            return make_plan(
                p, self.leaves, fixed_rank=self.cfg.fixed_rank,
                num_stages=self.cfg.num_stages,
            )
        # EDGC starts in warm-up: no compression until DAC says go.
        return NO_COMPRESSION

    @property
    def plan(self) -> CompressionPlan:
        return self._plan

    @property
    def in_warmup(self) -> bool:
        return self.cfg.policy == "edgc" and not self.dac.warmed_up

    @property
    def in_fallback(self) -> bool:
        return self._fallback

    def force_fallback(self) -> bool:
        """Recovery policy: pin the plan to uncompressed sync permanently.

        Called by the trainer after repeated anomalies (non-finite steps,
        loss spikes) — if aggressive compression is the suspected cause,
        the safe terminal state is a plain all-reduce. Window ends stop
        producing plans; the flag survives checkpoints. Returns True iff
        the plan changed (the trainer then re-specializes its step).
        """
        self._fallback = True
        changed = self._plan != NO_COMPRESSION
        self._plan = NO_COMPRESSION
        return changed

    def set_overlap_feedback(self, slack_seconds) -> None:
        """Feed the overlap planner's measured per-stage Eq. 4 slack.

        The trainer calls this (pipelined + ``overlap_sync`` runs) with
        ``simulate_schedule``'s per-stage slack in seconds; the DAC then
        aligns ranks against the REAL schedule geometry and clamps any
        stage whose comm would not fit its overlap budget
        (``DAC._feasible_clamp``) — Algorithm 2 trading rank for overlap
        feasibility.
        """
        self.dac.set_overlap(slack_seconds)

    # ------------------------------------------------------------------ hooks
    def wants_entropy(self, step: int) -> bool:
        """The ISR (alpha) gate — the trainer dispatches an entropy-OFF
        compiled step variant when False, so skipped iterations lower no
        moment work at all (§IV-B measures entropy on a FRACTION of
        iterations). The gate is a GDS sampling property, not an EDGC-
        policy one: baselines keep the same schedule so their
        observational entropy histories stay comparable."""
        return self.cfg.gds.should_measure(step % self.cfg.dac.window)

    def on_entropy(self, step: int, h: float) -> None:
        self._window_h.append(float(h))
        self._history.append((step, float(h)))

    def on_window_end(self, step: int) -> bool:
        """Called every ``window`` steps. Returns True iff the plan changed."""
        if self._fallback:
            self._window_h.clear()
            return False
        if self.cfg.policy != "edgc" or not self._window_h:
            self._window_h.clear()
            return False
        h_mean = float(np.mean(self._window_h))
        self._window_h.clear()

        old_plan = self._plan
        if not self.dac.warmed_up:
            self.dac.maybe_end_warmup(h_mean, step)
            if not self.dac.warmed_up:
                return False
            stage_ranks = [self.r_max] * self.cfg.num_stages
        else:
            stage_ranks = self.dac.update(h_mean)
        self._rank_history.append((step, stage_ranks))
        self._plan = make_plan(
            "edgc", self.leaves, stage_ranks=stage_ranks,
            num_stages=self.cfg.num_stages,
        )
        return self._plan != old_plan

    # --------------------------------------------------------- checkpointing
    def state_dict(self) -> dict[str, Any]:
        """JSON-serializable control-plane state for checkpoints.

        Everything the window loop mutates: DAC warm-up flag / stage-1 rank
        / window index, the CQM anchor, the entropy+rank histories, the
        partial window buffer, and the current plan. Without this, a
        resumed run silently restarts warm-up (the device tree alone says
        nothing about where the controller was).
        """
        return {
            "policy": self.cfg.policy,
            "dac": {
                "warmed_up": bool(self.dac.warmed_up),
                "r_stage1": int(self.dac.r_stage1),
                "window_index": int(self.dac.window_index),
                "applied_ranks": (None if self.dac.applied_ranks is None
                                  else [int(r) for r in
                                        self.dac.applied_ranks]),
            },
            "cqm": {
                "h_anchor": self.cqm._h_anchor,
                "g_anchor": self.cqm._g_anchor,
            },
            "window_h": [float(h) for h in self._window_h],
            "entropy_history": [[int(s), float(h)] for s, h in self._history],
            "rank_history": [[int(s), [int(r) for r in rs]]
                             for s, rs in self._rank_history],
            "plan": [[p, int(r)] for p, r in self._plan.ranks],
            "fallback": bool(self._fallback),
        }

    def load_state_dict(self, sd: dict[str, Any]) -> None:
        if sd.get("policy") != self.cfg.policy:
            raise ValueError(
                f"checkpoint controller policy {sd.get('policy')!r} != "
                f"configured {self.cfg.policy!r}")
        self.dac.warmed_up = bool(sd["dac"]["warmed_up"])
        self.dac.r_stage1 = int(sd["dac"]["r_stage1"])
        self.dac.window_index = int(sd["dac"]["window_index"])
        ar = sd["dac"].get("applied_ranks")
        self.dac.applied_ranks = None if ar is None else [int(r) for r in ar]
        h, g = sd["cqm"]["h_anchor"], sd["cqm"]["g_anchor"]
        self.cqm._h_anchor = None if h is None else float(h)
        self.cqm._g_anchor = None if g is None else float(g)
        self._window_h = [float(x) for x in sd["window_h"]]
        self._history = [(int(s), float(x)) for s, x in sd["entropy_history"]]
        self._rank_history = [(int(s), [int(r) for r in rs])
                              for s, rs in sd["rank_history"]]
        self._plan = CompressionPlan(
            ranks=tuple((p, int(r)) for p, r in sd["plan"]))
        self._fallback = bool(sd.get("fallback", False))

    # ------------------------------------------------------------- reporting
    @property
    def entropy_history(self) -> list[tuple[int, float]]:
        return list(self._history)

    @property
    def rank_history(self) -> list[tuple[int, list[int]]]:
        return list(self._rank_history)

    def describe(self) -> dict[str, Any]:
        return {
            "policy": self.cfg.policy,
            "r_min": self.r_min,
            "r_max": self.r_max,
            "eta_s_per_rank": self.comm.eta,
            "warmed_up": not self.in_warmup,
            "stage_ranks": self.dac.current_ranks() if not self.in_warmup else [],
            "num_compressed_leaves": len(self._plan.ranks),
        }
