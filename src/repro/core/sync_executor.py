"""SyncExecutor — the one entry point for DP gradient synchronization.

Three call paths grew up around the compressor (``core/compressor.
sync_grads`` for the flat step, ``dist/collectives.dp_sync_grads`` as its
mesh-axis convenience, and ``pipeline/sync.stage_sync_grads`` for the
pipelined executor), each threading ``use_kernels`` / ``bucketed`` /
bucket sizes by hand. This facade collapses them behind one object taking
a :class:`~repro.core.config.SyncConfig` plus a ``CommMode``:

  flat                   ``sync(grads, comp, psum_mean)`` — the whole
                         gradient tree under one CompressionPlan.
  per-stage              ``sync(stage_grads, comp, psum_mean,
                         shared_grads=..., my_stage=...)`` — one bucketed
                         schedule per distinct stage plan, run after the
                         pipeline drain (PR 3 semantics).
  per-stage-overlapped   the same schedules split into
                         :class:`~repro.core.bucketing.SyncChunk`s that the
                         pipelined executor launches inside its drain ticks
                         (``chunks`` / ``run_chunks`` / ``sync_shared``);
                         any chunks the launch plan left over run through
                         ``run_chunks`` after the loop.

The legacy entry points remain as thin wrappers (they ARE the primitives
this facade dispatches to), so nothing downstream breaks; new code should
construct a SyncExecutor.
"""
from __future__ import annotations

from typing import Any, Callable

from . import bucketing, wire
from .compressor import CompressionPlan, sync_grads
from .config import COMM_MODES, SyncConfig

__all__ = ["SyncExecutor"]

PsumFn = Callable[[Any], Any]


class SyncExecutor:
    """Facade over the flat / per-stage / overlapped DP-sync executors.

    Static construction (cfg + mode + plan or stage plans) happens at
    trace/build time; the ``sync``/``run_chunks`` methods are called inside
    the shard_map region with the traced psum hook.
    """

    def __init__(self, cfg: SyncConfig | None = None, mode: str = "flat", *,
                 plan: CompressionPlan | None = None, splans=None) -> None:
        if mode not in COMM_MODES:
            raise ValueError(f"unknown CommMode {mode!r} "
                             f"(want one of {COMM_MODES})")
        if mode == "flat" and plan is None:
            raise ValueError("mode='flat' requires a CompressionPlan")
        if mode != "flat" and splans is None:
            raise ValueError(f"mode={mode!r} requires StagePlans")
        self.cfg = cfg or SyncConfig()
        if self.cfg.wire not in wire.WIRE_MODES:
            raise ValueError(f"unknown wire mode {self.cfg.wire!r} "
                             f"(want one of {wire.WIRE_MODES})")
        # The trainer/outer optimizer resolve the codec (entropy mode needs
        # the controller's reading); a bare quant mode resolves here so
        # direct SyncExecutor construction works too.
        self.codec = self.cfg.codec
        if self.codec is None and self.cfg.wire != "raw":
            self.codec = wire.resolve_codec(self.cfg.wire)
        if self.codec is not None and mode == "flat" and self.cfg.bucketed is False:
            raise ValueError("wire coding requires the bucketed executor "
                             "(SyncConfig.bucketed must not be False)")
        self.mode = mode
        self.plan = plan
        self.splans = splans

    # ------------------------------------------------------------- monolithic
    def sync(self, grads: Any, comp_state: dict, psum_mean: PsumFn, *,
             shared_grads: Any = None, my_stage=None):
        """One-call sync for the flat and per-stage modes.

        flat: returns (synced, new_state). per-stage modes: ``grads`` is
        the rank's stage tree, returns (synced_stage, synced_shared,
        new_state). In per-stage-overlapped mode this is the no-chunks-
        launched fallback — identical to per-stage.
        """
        if self.mode == "flat":
            return sync_grads(grads, comp_state, self.plan, psum_mean,
                              use_kernels=self.cfg.use_kernels,
                              bucketed=self.cfg.bucketed,
                              bucket_bytes=self.cfg.bucket_bytes,
                              codec=self.codec)
        from repro.pipeline.sync import stage_sync_grads
        return stage_sync_grads(grads, shared_grads, comp_state, self.splans,
                                psum_mean, my_stage,
                                use_kernels=self.cfg.use_kernels,
                                codec=self.codec)

    # ------------------------------------------------------------- overlapped
    def chunks(self, d: int) -> tuple[bucketing.SyncChunk, ...]:
        """Launchable chunks of distinct schedule ``d`` (static)."""
        return bucketing.sync_chunks(self.splans.layouts[d])

    def run_chunks(self, d: int, chunk_ids, grads_by_path: dict,
                   comp_state: dict, psum_mean: PsumFn):
        """Run a subset of schedule ``d``'s chunks for one stage.

        ``grads_by_path`` maps stage-local leaf paths to wire-dtype grads
        (only the chunks' members are read). Returns (synced updates by
        path, full new comp dict with schedule ``d``'s touched keys
        replaced).
        """
        from repro.pipeline.sync import stage_sync_chunks
        return stage_sync_chunks(grads_by_path, comp_state, self.splans, d,
                                 chunk_ids, psum_mean,
                                 use_kernels=self.cfg.use_kernels,
                                 codec=self.codec)

    def sync_shared(self, shared_grads: Any, psum_mean: PsumFn):
        """Flat-bucket sync of the pipe-replicated shared leaves."""
        from repro.pipeline.sync import sync_shared_grads
        return sync_shared_grads(shared_grads, psum_mean)
