"""CQM — Compression Quantification Model (paper §IV-C, Appendix A).

Ties gradient entropy to compression rank:

  Theorem 1  g(r; m, n)     expected truncation error, unit variance (mp_law)
  Lemma  2   H = log(sigma) + 0.5 log(2 pi e)
  Theorem 2  r1 = g^{-1}((sigma0/sigma1) g(r0))   fixed absolute error
  Theorem 3  r1 = g^{-1}(e^{H0-H1} g(r0))         via Lemma 2

The CQM object is per gradient-matrix-shape; the controller owns one per
compressed leaf shape (they are cached by shape in mp_law.g_table).
"""
from __future__ import annotations

import dataclasses
import math


from .mp_law import GTable, g_table

__all__ = ["CQM", "theoretical_error", "rank_from_entropy_delta"]


def theoretical_error(r: int, m: int, n: int, sigma: float = 1.0) -> float:
    """E||A - A_r||_F for an m x n i.i.d. matrix with entry std ``sigma``.

    Observation 3 predicts the *actual* error of real LLM gradients sits
    below this (correlation ⇒ faster spectral decay); tests assert that.
    """
    if m > n:
        m, n = n, m
    return sigma * g_table(m, n)(r)


def rank_from_entropy_delta(r0: int, h0: float, h1: float, m: int, n: int) -> int:
    """Theorem 3 (Eq. 15): the rank that keeps the absolute error fixed."""
    if m > n:
        m, n = n, m
    return g_table(m, n).theorem3_rank(r0, h0, h1)


@dataclasses.dataclass
class CQM:
    """Entropy -> rank control law for one matrix shape (m <= n enforced).

    ``anchor(r, h)`` pins the fixed-error constraint epsilon_ini = g(r)*sigma(h)
    at compression activation (Constraint 1 / §IV-D2); ``rank_for_entropy(h)``
    then returns the Theorem-3 rank for any later entropy reading. Anchoring
    once (rather than chaining window-to-window deltas) avoids compounding
    integer-quantization drift; both reduce to Eq. 15 exactly when ranks are
    continuous.
    """

    m: int
    n: int
    _table: GTable = dataclasses.field(init=False, repr=False)
    _h_anchor: float | None = dataclasses.field(default=None, init=False)
    _g_anchor: float | None = dataclasses.field(default=None, init=False)

    def __post_init__(self) -> None:
        if self.m > self.n:
            self.m, self.n = self.n, self.m
        self._table = g_table(self.m, self.n)

    # -- Constraint 1: fix the absolute error at activation time ------------
    def anchor(self, r0: int, h0: float) -> None:
        self._h_anchor = float(h0)
        self._g_anchor = self._table(r0)

    @property
    def anchored(self) -> bool:
        return self._h_anchor is not None

    def rank_for_entropy(self, h1: float) -> int:
        """Theorem 3 against the anchored (r0, H0)."""
        if not self.anchored:
            raise RuntimeError("CQM.anchor() must be called before rank_for_entropy")
        target = math.exp(self._h_anchor - float(h1)) * self._g_anchor
        return self._table.rank_for_error(target)

    def step_rank(self, r_prev: int, h_prev: float, h_new: float) -> int:
        """One-shot Theorem 3 from (r_prev, h_prev) -> h_new (windowed form)."""
        return self._table.theorem3_rank(r_prev, h_prev, h_new)

    def error_at(self, r: int, sigma: float = 1.0) -> float:
        return sigma * self._table(r)

    def max_rank(self) -> int:
        return self.m
