"""Marchenko–Pastur law and the compression-error function g(r; m, n).

Paper Appendix A (Lemma 1 / Theorem 1): for a random gradient matrix
A in R^{m x n} (i.i.d. entries, mean 0, variance sigma^2), the eigenvalues
of A A^T follow the Marchenko–Pastur distribution; by Eckart–Young–Mirsky the
squared rank-r truncation error is the sum of the smallest m - r eigenvalues.
Theorem 1 estimates that sum by Monte-Carlo / quantile sampling of the MP CDF.

We expose:

  * ``mp_support(m, n)``      — [a, b] = [(sqrt(n)-sqrt(m))^2, (sqrt(n)+sqrt(m))^2]
  * ``mp_cdf(lam, m, n)``     — the closed-form CDF from Lemma 1
  * ``sample_eigenvalues``    — inverse-CDF sampling of the m eigenvalues
  * ``GTable``                — tabulated, invertible g(r) = E||A - A_r||_F
                                for unit-variance entries (Theorem 1)

All of this is host-side control-plane code (numpy): it runs once per
matrix shape at setup and never touches device state.
"""
from __future__ import annotations

import dataclasses
import math
from functools import lru_cache

import numpy as np

__all__ = [
    "mp_support",
    "mp_cdf",
    "sample_eigenvalues",
    "expected_sq_error",
    "GTable",
    "g_table",
]


def mp_support(m: int, n: int) -> tuple[float, float]:
    """Support [a, b] of the eigenvalues of A A^T, A in R^{m x n}, unit var.

    Lemma 1: a = (sqrt(n) - sqrt(m))^2, b = (sqrt(n) + sqrt(m))^2.
    (Requires m <= n; callers transpose to enforce it.)
    """
    a = (math.sqrt(n) - math.sqrt(m)) ** 2
    b = (math.sqrt(n) + math.sqrt(m)) ** 2
    return a, b


def mp_cdf(lam: np.ndarray, m: int, n: int) -> np.ndarray:
    """CDF of an eigenvalue of A A^T under the MP law (Lemma 1).

    F(lambda; m, n) = 1/(2 pi m) * F(lambda; a, b) with

      F(lam; a, b) = -2 sqrt(ab) * arctan( sqrt( b (lam - a) / (a (b - lam)) ) )
                     + (a + b) * arcsin( sqrt( (lam - a) / (b - a) ) )
                     + sqrt( (lam - a)(b - lam) )

    normalized so F(a) = 0 and F(b) = 1. The paper's constant 1/(2 pi m)
    matches the standard MP density integrated in the lambda' = lambda / n
    variable; we normalize numerically against F(b) to be safe for all
    (m, n) aspect ratios.
    """
    a, b = mp_support(m, n)
    lam = np.clip(np.asarray(lam, dtype=np.float64), a, b)

    def _raw(l: np.ndarray) -> np.ndarray:
        eps = 1e-12 * max(1.0, b)
        l = np.clip(l, a + eps, b - eps)
        t1 = -2.0 * math.sqrt(a * b) * np.arctan(
            np.sqrt(b * (l - a) / (max(a, eps) * (b - l)))
        ) if a > 0 else np.zeros_like(l)
        t2 = (a + b) * np.arcsin(np.sqrt((l - a) / (b - a)))
        t3 = np.sqrt((l - a) * (b - l))
        return t1 + t2 + t3

    raw = _raw(lam)
    lo = _raw(np.asarray([a + 1e-12]))[0]
    hi = _raw(np.asarray([b - 1e-12]))[0]
    return np.clip((raw - lo) / (hi - lo), 0.0, 1.0)


def _inverse_cdf_grid(m: int, n: int, grid: int = 4096) -> tuple[np.ndarray, np.ndarray]:
    """Pairs {(lambda_0, p_0)} for Theorem 1 steps a-b.

    Quadratic spacing concentrates grid points near the lower edge a, where
    the MP density diverges for square-ish matrices (a -> 0, density ~
    lambda^-1/2) — a uniform grid badly resolves the small eigenvalues that
    dominate high-rank truncation errors.
    """
    a, b = mp_support(m, n)
    u = np.linspace(0.0, 1.0, grid)
    lam0 = a + (b - a) * u ** 2
    p0 = mp_cdf(lam0, m, n)
    return lam0, p0


def sample_eigenvalues(
    m: int,
    n: int,
    *,
    stratified: bool = True,
    rng: np.random.Generator | None = None,
    grid: int = 4096,
) -> np.ndarray:
    """Theorem 1 step c: draw m eigenvalues of A A^T by inverse-CDF sampling.

    ``stratified=True`` uses the quantile mid-points p_i = (i + 0.5)/m —
    a deterministic low-variance version of the paper's uniform draws
    (the paper draws p ~ U(0,1)); ``stratified=False`` reproduces the paper's
    randomized variant exactly.
    """
    lam0, p0 = _inverse_cdf_grid(m, n, grid)
    if stratified:
        p = (np.arange(m, dtype=np.float64) + 0.5) / m
    else:
        if rng is None:
            rng = np.random.default_rng(0)
        p = rng.uniform(0.0, 1.0, size=m)
    # interpolate p -> lambda through the (p0, lam0) pairs
    lam = np.interp(p, p0, lam0)
    return np.sort(lam)


def expected_sq_error(r: int, m: int, n: int, lam_sorted: np.ndarray | None = None) -> float:
    """Theorem 1 step d: E ||A - A_r||_F^2 = sum of the smallest m - r eigenvalues."""
    if lam_sorted is None:
        lam_sorted = sample_eigenvalues(m, n)
    r = int(np.clip(r, 0, m))
    return float(np.sum(lam_sorted[: m - r]))


@dataclasses.dataclass(frozen=True)
class GTable:
    """Tabulated g(r) = E||A - A_r||_F for a unit-variance m x n matrix.

    g is strictly decreasing in r (g(m) = 0), so it is invertible on [0, m]:
    ``rank_for_error`` returns the smallest rank whose expected error is at
    most the target — the conservative choice (errs toward accuracy).
    Theorem 3 is then

        r1 = g^{-1}( exp(H0 - H1) * g(r0) ).
    """

    m: int
    n: int
    g: np.ndarray  # shape (m + 1,): g[r] for r = 0..m

    def __call__(self, r: int) -> float:
        r = int(np.clip(r, 0, self.m))
        return float(self.g[r])

    def rank_for_error(self, eps: float) -> int:
        """Smallest r with g(r) <= eps (monotone inverse of g)."""
        # g is descending; searchsorted on the reversed array.
        idx = np.searchsorted(self.g[::-1], eps, side="right")
        r = self.m - idx + 1
        return int(np.clip(r, 0, self.m))

    def theorem3_rank(self, r0: int, h0: float, h1: float) -> int:
        """r1 = g^{-1}(e^{H0-H1} g(r0))  (paper Eq. 15)."""
        target = math.exp(h0 - h1) * self(r0)
        return self.rank_for_error(target)


@lru_cache(maxsize=512)
def g_table(m: int, n: int) -> GTable:
    """Build (and cache) the g(r) table for an m x n gradient matrix.

    Callers should pass m <= n (transpose otherwise): PowerSGD factors and
    Eckart–Young both operate on min(m, n) singular values.
    """
    if m > n:
        m, n = n, m
    lam = sample_eigenvalues(m, n)
    # prefix sums: csum[k] = sum of the k smallest eigenvalues, so the
    # expected squared rank-r error is sq_err[r] = csum[m - r].
    csum = np.concatenate([[0.0], np.cumsum(lam)])
    sq_err = csum[::-1]  # sq_err[r] = csum[m - r], r = 0..m
    g = np.sqrt(np.maximum(sq_err, 0.0))
    return GTable(m=m, n=n, g=g)
