"""Distribution substrate: partition rules + DP-sync collectives.

``sharding``    — path-based TP/FSDP partition rules over ("pod", "data",
                  "model") meshes: params, batches, KV caches.
``collectives`` — the manual-axis (pod, data) gradient-sync primitives the
                  EDGC compressor plugs into, plus a shard_map compat shim.
"""
from repro.dist.collectives import (
    dp_sync_grads,
    dp_world_size,
    make_dp_pmean,
    make_dp_psum,
    shard_map_dp,
)
from repro.dist.sharding import (
    apply_fsdp,
    batch_pspec,
    cache_pspecs,
    param_pspecs,
    param_shardings,
)

__all__ = [
    "apply_fsdp",
    "batch_pspec",
    "cache_pspecs",
    "dp_sync_grads",
    "dp_world_size",
    "make_dp_pmean",
    "make_dp_psum",
    "param_pspecs",
    "param_shardings",
    "shard_map_dp",
]
