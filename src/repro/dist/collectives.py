"""Data-parallel gradient-sync collectives (the manual pod/data axes).

EDGC's contribution lives here: the DP gradient all-reduce that the
compressor intercepts. The train step runs its body in a shard_map MANUAL
region over the ("pod", "data") axes, and the primitives below are what it
calls inside that region:

  * ``make_dp_pmean(axes)`` / ``make_dp_psum(axes)`` — mean/sum over the
    manual DP axes, identity when there are none (single worker). These are
    the ``psum_mean`` hooks handed to ``repro.core.compressor.sync_grads``:
    compressed leaves pmean their rank-r PowerSGD factors, everything else
    pmeans in full.
  * ``dp_sync_grads`` — the one-call entry point: compress -> pmean ->
    decompress with error feedback under a CompressionPlan.
  * ``shard_map_dp`` — version shim: newer jax exposes ``jax.shard_map``
    with ``axis_names=``/``check_vma=``; older releases have
    ``jax.experimental.shard_map.shard_map`` with the complementary
    ``auto=``/``check_rep=`` spelling. The step builder targets one surface.
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax

from repro.core.compressor import CompressionPlan, sync_grads

__all__ = [
    "dp_sync_grads",
    "dp_world_size",
    "make_dp_pmean",
    "make_dp_psum",
    "shard_map_dp",
]


def dp_world_size(mesh) -> int:
    """Number of data-parallel workers = product of the (pod, data) sizes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return math.prod(sizes.get(a, 1) for a in ("pod", "data"))


def make_dp_pmean(axes) -> Callable[[Any], Any]:
    """Mean over the manual DP axes; identity for an empty axis set.

    Works on a single array or a whole pytree (gradient trees, metrics).
    Must be called inside the shard_map region that binds ``axes``.
    """
    axes_t = tuple(axes)
    if not axes_t:
        return lambda x: x
    return lambda tree: jax.tree_util.tree_map(
        lambda a: jax.lax.pmean(a, axes_t), tree
    )


def make_dp_psum(axes) -> Callable[[Any], Any]:
    """Sum over the manual DP axes; identity for an empty axis set."""
    axes_t = tuple(axes)
    if not axes_t:
        return lambda x: x
    return lambda tree: jax.tree_util.tree_map(
        lambda a: jax.lax.psum(a, axes_t), tree
    )


def dp_sync_grads(grads: Any, comp_state: dict, plan: CompressionPlan,
                  axes, use_kernels: bool = False,
                  bucketed: bool | None = None) -> tuple[Any, dict]:
    """Compression-aware DP gradient sync over the manual ``axes``.

    Compressed leaves move rank-r factors through the pmean (with error
    feedback); the rest move in full. ``bucketed`` picks the executor
    (None = infer from the state format): the per-leaf loop, or the
    shape-grouped stacked + flat-bucket schedule from core/bucketing.py
    that collapses O(num_leaves) collectives to O(groups + buckets).
    Returns (synced grads, new state).
    """
    return sync_grads(grads, comp_state, plan, make_dp_pmean(axes),
                      use_kernels=use_kernels, bucketed=bucketed)


def shard_map_dp(f, mesh, in_specs, out_specs, manual_axes,
                 check: bool = False):
    """shard_map with ``manual_axes`` manual and every other axis AUTO.

    The 'model' axis stays AUTO so GSPMD applies the TP rules from
    dist/sharding.py inside the body, while the (pod, data) gradient sync
    is explicit (EDGC's compressed pmeans). Bridges the two shard_map
    APIs: ``jax.shard_map(axis_names=..., check_vma=...)`` on current jax,
    ``jax.experimental.shard_map.shard_map(auto=..., check_rep=...)`` on
    older releases.
    """
    manual = frozenset(manual_axes)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=set(manual),
                             check_vma=check)
    from jax.experimental.shard_map import shard_map
    # Legacy partial-auto manual subgroups crash XLA's partitioner
    # ("Check failed: sharding.IsManualSubgroup()") whenever an auto axis
    # has size > 1, so bind EVERY axis manual instead. The in/out specs
    # never mention the non-DP axes, so those ranks carry replicated
    # compute — same math, no TP compute split — and GSPMD reshards at the
    # jit boundary. Current jax takes the partial-auto branch above and
    # keeps real tensor parallelism inside the body.
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check, auto=frozenset())
