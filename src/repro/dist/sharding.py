"""Path-based partition rules for ("pod", "data", "model") meshes.

Megatron-style tensor parallelism over the 'model' axis, keyed on the leaf's
path string (works for both ``jax.tree_util.keystr`` output like
``['stages'][0]['blocks']['attn']['wq']`` and dotted paths like
``stages[0].blocks.attn.wq``):

  * column-parallel (shard the OUTPUT dim): wq/wk/wv, mlp up/gate, ssm
    in_proj / up_x / up_z, lm_head — activations stay sharded into the
    row-parallel partner, no resharding in between;
  * row-parallel (shard the INPUT dim): wo, mlp down, ssm out_proj — the
    all-reduce lands after the matmul, once per block;
  * expert-parallel: MoE ``experts`` stacks (..., E, d, f) shard the expert
    dim over 'model' (GShard expert parallelism);
  * vocab-parallel: token embeddings shard dim 0 (the vocab dim);
  * replicated: norms, biases, scales, routers, convs, SSM time constants,
    positional tables — small or routing-noise-sensitive leaves.

Every rule passes through a divisibility guard: a dim that the model-axis
size does not divide is silently left unsharded (GSPMD would otherwise pad
or error), so the same rules serve 1x1 host meshes and 2x16x16 pods.

The DATA side: ``batch_pspec`` shards the leading batch dim over the
("pod", "data") prefix whose size divides the global batch; ``apply_fsdp``
adds the data axis to large parameter leaves (ZeRO-3 style weight
sharding) for the memory-bound archs that cannot hold replicated params.
"""
from __future__ import annotations

import math
import re
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "apply_fsdp",
    "batch_pspec",
    "cache_pspecs",
    "param_pspecs",
    "param_shardings",
    "stage_param_pspecs",
    "stage_param_shardings",
]

# Leaves that stay replicated regardless of shape: norms/biases/scales are
# 1-D; routers are routing-noise sensitive (DESIGN §4); convs and SSM time
# constants are depthwise/tiny; positional tables are gathered dynamically.
_REPLICATED = re.compile(
    r"norm|bias|scale|router|conv|a_log|\bdt\b|pos", re.IGNORECASE
)
# Column-parallel: output dim (last) over 'model'.
_COLUMN = re.compile(r"\b(wq|wk|wv|up|gate|in_proj|up_x|up_z|lm_head)\b")
# Row-parallel: input dim (second to last) over 'model'.
_ROW = re.compile(r"\b(wo|down|out_proj)\b")


def _axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _dp_prefix(mesh) -> tuple[str, ...]:
    """The ("pod", "data") axes present on this mesh, pod-major."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _spec_for(path: str, shape: tuple[int, ...], mesh) -> P:
    """Partition spec for one parameter leaf, with divisibility guards."""
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 1)
    ndim = len(shape)
    if ndim < 2 or "model" not in sizes:
        return P()
    if _REPLICATED.search(path):
        return P()

    entries: list[Any] = [None] * ndim

    def shard(dim: int) -> P:
        if shape[dim] % msize == 0:
            entries[dim] = "model"
        return P(*entries)

    if "experts" in path and ndim >= 3:
        return shard(ndim - 3)          # (..., E, d, f): expert dim
    if "embed" in path:
        return shard(0)                 # (V, d): vocab-parallel
    if _COLUMN.search(path):
        return shard(ndim - 1)
    if _ROW.search(path):
        return shard(ndim - 2)
    return P()


def param_pspecs(params: Any, mesh) -> Any:
    """PartitionSpec pytree for a param pytree (TP rules only)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _spec_for(
            jax.tree_util.keystr(kp), tuple(leaf.shape), mesh
        ),
        params,
    )


def stage_param_pspecs(stacked: Any, mesh) -> Any:
    """Partition specs for a STAGE-STACKED param tree (pipeline parallelism).

    Every leaf carries a leading stage dim of size S = |pipe| (produced by
    the family's ``StageAdapter.partition_params``): dim 0 shards over the
    ``pipe`` axis so each pipeline rank holds exactly its stage's subtree,
    and the remaining dims follow the same Megatron TP rules as the flat
    layout. This is per-family by construction because the rules key on
    the leaf PATH, which the adapters preserve: a MoE expert stack
    ``(S, L, E, d, f)`` still names ``experts`` so the E axis shards over
    'model' (expert parallelism under TP), Mamba2 ``in_proj``/``out_proj``
    keep their column/row rules, conv/dt/a_log leaves stay replicated,
    and whisper's enc/dec attention projections shard like decoder ones.
    Zero-padded slices of ragged (hybrid) stage plans shard with their
    stack — padding never changes a leaf's path or trailing dims.
    """
    has_pipe = "pipe" in mesh.axis_names

    def one(kp, leaf) -> P:
        path = jax.tree_util.keystr(kp)
        shape = tuple(leaf.shape)
        inner = _spec_for(path, shape[1:], mesh)
        entries = list(inner) + [None] * (len(shape) - 1 - len(inner))
        return P("pipe" if has_pipe else None, *entries)

    return jax.tree_util.tree_map_with_path(one, stacked)


def stage_param_shardings(stacked: Any, mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), stage_param_pspecs(stacked, mesh))


def apply_fsdp(specs: Any, params: Any, mesh, axes,
               min_size: int = 1 << 20) -> Any:
    """Add the data axis to big leaves: ZeRO-3 style weight sharding.

    For every leaf with >= ``min_size`` elements whose spec does not already
    use ``axes``, the first unsharded dim divisible by the axis size picks
    up the axis. Small leaves stay replicated — sharding them buys nothing
    and costs an all-gather each step.
    """
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    sizes = _axis_sizes(mesh)
    n = math.prod(sizes.get(a, 1) for a in axes_t)
    entry = axes_t[0] if len(axes_t) == 1 else axes_t

    def one(spec: P, leaf) -> P:
        shape = tuple(leaf.shape)
        if not axes_t or n <= 1 or leaf.size < min_size:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for e in entries:
            used.update(e if isinstance(e, tuple) else (e,))
        if used.intersection(axes_t):
            return spec
        for i, d in enumerate(shape):
            if entries[i] is None and d % n == 0:
                entries[i] = entry
                return P(*entries)
        return spec

    return jax.tree_util.tree_map(one, specs, params)


def param_shardings(params: Any, mesh, fsdp: bool = False) -> Any:
    """NamedSharding pytree: TP rules, optionally + FSDP over (pod, data)."""
    specs = param_pspecs(params, mesh)
    if fsdp:
        specs = apply_fsdp(specs, params, mesh, _dp_prefix(mesh))
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def _batch_entry(batch_size: int, mesh):
    """The spec entry for a global-batch dim: the longest ("pod", "data")
    prefix whose total size divides the batch, pod-major (cross-pod traffic
    is the scarce resource, so pod splits first)."""
    sizes = _axis_sizes(mesh)
    axes = _dp_prefix(mesh)
    while axes:
        n = math.prod(sizes[a] for a in axes)
        if batch_size % n == 0:
            return axes[0] if len(axes) == 1 else axes
        axes = axes[:-1]
    return None


def batch_pspec(ndim: int, mesh, batch_size: int) -> P:
    """Batch-dim-leading spec for an input array of rank ``ndim``."""
    if ndim == 0:
        return P()
    return P(_batch_entry(batch_size, mesh), *([None] * (ndim - 1)))


def cache_pspecs(cache: Any, mesh, batch_size: int) -> Any:
    """Partition specs for a decode-cache pytree.

    KV leaves — ``k``/``v`` of rank >= 4, laid out (..., B, C, Hkv, hd) —
    shard batch over the data axes and kv-heads over 'model' (they were
    produced by the column-parallel wk/wv, so this is where the values
    already live). Everything else (SSM states, conv tails) is batch-major:
    dim 0 shards over the data axes; scalars (the length counter) stay
    replicated.
    """
    sizes = _axis_sizes(mesh)
    msize = sizes.get("model", 1)
    dp_entry = _batch_entry(batch_size, mesh)

    def one(kp, leaf) -> P:
        shape = tuple(leaf.shape)
        if not shape:
            return P()
        name = str(getattr(kp[-1], "key", getattr(kp[-1], "idx", "")))
        entries: list[Any] = [None] * len(shape)
        if name in ("k", "v") and len(shape) >= 4:
            if shape[len(shape) - 4] == batch_size:
                entries[len(shape) - 4] = dp_entry
            if msize > 1 and shape[-2] % msize == 0:
                entries[-2] = "model"
        elif shape[0] == batch_size:
            entries[0] = dp_entry
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, cache)
