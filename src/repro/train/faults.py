"""Fault injection + recovery policy surface for the training tiers.

The elastic multi-pod regime this repo targets (ROADMAP item 3) fails in
specific, reproducible ways: pods drop or join between outer rounds,
aggressive compression occasionally produces non-finite gradients, a
corrupted wire payload poisons the PowerSGD warm-start/EF state, and a
crash mid-save tears a checkpoint pair. This module gives each failure a
name, a schedule syntax (``--inject``), and the recovery-policy knobs the
Trainer/ElasticTrainer wire against it:

  * ``nan_grad@30``          — the step-30 gradients become NaN (pre-sync).
  * ``corrupt_payload@45``   — the compressor state (Q/EF) is NaN-poisoned
                               on the host before step 45.
  * ``torn_ckpt@50``         — the *next* checkpoint written at/after step
                               50 is truncated after the save (simulating a
                               crash mid-write on the old non-atomic path).
  * ``pod_drop:1@r2``        — pod 1 leaves before outer round 2.
  * ``pod_join@r4``          — a pod joins before outer round 4.

``@N`` schedules on the inner global step; ``@rN`` on the outer round.

Recovery (``RecoveryConfig``): a non-finite guard in the compiled step
skips the parameter/optimizer/compressor update and reports ``skipped``;
the host resets the error-feedback state and counts the anomaly. A
loss-spike detector (EMA) rolls back to the newest intact checkpoint in
the ring, with bounded retries and a re-arm backoff. After
``fallback_after`` anomalies the controller pins the plan to uncompressed
sync for the rest of the run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "RecoveryConfig",
    "RecoveryState",
    "parse_inject",
    "truncate_file",
    "poison_lowrank_state",
]

#: step-scheduled kinds hit the inner Trainer loop; round-scheduled kinds
#: hit the ElasticTrainer's membership logic.
FAULT_KINDS = ("nan_grad", "corrupt_payload", "torn_ckpt",
               "pod_drop", "pod_join")
_ROUND_KINDS = ("pod_drop", "pod_join")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str
    at: int             # inner global step, or outer round for pod events
    on_round: bool      # True => ``at`` is an outer-round index
    arg: int = -1       # pod index for pod_drop (-1 = highest-index pod)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if (self.kind in _ROUND_KINDS) != self.on_round:
            where = "an outer round (@rN)" if self.kind in _ROUND_KINDS \
                else "an inner step (@N)"
            raise ValueError(f"{self.kind} must be scheduled on {where}")


def parse_inject(specs: str | Iterable[str]) -> "FaultPlan":
    """Parse ``--inject`` specs: ``kind[:arg]@N`` or ``kind[:arg]@rN``.

    Accepts a comma-separated string or an iterable of specs.
    """
    if isinstance(specs, str):
        specs = [s for s in specs.split(",") if s.strip()]
    events = []
    for spec in specs:
        spec = spec.strip()
        try:
            head, at_s = spec.rsplit("@", 1)
        except ValueError:
            raise ValueError(f"bad --inject spec {spec!r}: expected "
                             "kind[:arg]@step or kind[:arg]@rROUND") from None
        kind, _, arg_s = head.partition(":")
        on_round = at_s.startswith("r")
        at = int(at_s[1:] if on_round else at_s)
        arg = int(arg_s) if arg_s else -1
        events.append(FaultEvent(kind=kind, at=at, on_round=on_round,
                                 arg=arg))
    return FaultPlan(events=tuple(events))


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    events: tuple[FaultEvent, ...] = ()

    def __bool__(self) -> bool:
        return bool(self.events)

    def has(self, kind: str) -> bool:
        return any(e.kind == kind for e in self.events)

    def step_events(self, step: int) -> list[FaultEvent]:
        return [e for e in self.events if not e.on_round and e.at == step]

    def round_events(self, rnd: int) -> list[FaultEvent]:
        return [e for e in self.events if e.on_round and e.at == rnd]


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Recovery policy knobs; ``None`` on the trainer disables all of it."""

    guard_nonfinite: bool = True  # compiled-step skip of non-finite updates
    spike_factor: float = 4.0     # loss > factor * EMA  =>  anomaly
    ema_decay: float = 0.9
    spike_warmup: int = 10        # steps of EMA before the detector arms
    rollback: bool = True         # roll back to the ring on spike/NaN loss
    max_rollbacks: int = 3
    backoff_steps: int = 5        # detector re-arm distance after rollback
    fallback_after: int = 4       # anomalies before uncompressed fallback
    ckpt_ring: int = 3            # checkpoints kept for rollback


@dataclasses.dataclass
class RecoveryState:
    """Mutable recovery counters; serialized into checkpoint ``extra``."""

    skipped_steps: int = 0
    ef_resets: int = 0
    rollbacks: int = 0
    anomalies: int = 0
    fallback: bool = False
    loss_ema: float | None = None
    backoff_until: int = -1

    def as_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "RecoveryState":
        return cls(**{f.name: d[f.name] for f in dataclasses.fields(cls)
                      if f.name in d})


# ------------------------------------------------------------------ injectors
def truncate_file(path: str, keep_frac: float = 0.5) -> None:
    """Tear a file in place (keep the leading ``keep_frac`` of its bytes).

    Models a crash mid-write for the torn-checkpoint fault; applied to the
    ``.npz`` archive after a completed save so the manifest's recorded size
    / nonce no longer match.
    """
    import os
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(1, int(size * keep_frac)))


def poison_lowrank_state(comp_host: Any) -> Any:
    """NaN-poison the first compressed leaf's state (host-side pytree).

    Models a corrupted compressed payload: the warm-start Q and EF residual
    that next step's cooperative compression would consume are garbage, so
    the synced gradients go non-finite and the guard must trip.
    """
    import jax

    poisoned = False

    def _poison(x):
        nonlocal poisoned
        a = np.array(x)
        if not poisoned and a.dtype.kind == "f" and a.size:
            a.reshape(-1)[:1] = np.nan
            poisoned = True
        return a

    out = jax.tree_util.tree_map(_poison, comp_host)
    if not poisoned:
        raise ValueError("corrupt_payload fault: no float compressor state "
                         "to poison (is compression enabled yet?)")
    return out
