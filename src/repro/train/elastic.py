"""Elastic multi-pod outer-loop training (DiLoCo-style local SGD).

One ``ElasticTrainer`` owns N pod-local inner ``Trainer``s — each on its own
disjoint device subset with its own data shard — plus an ``OuterOptimizer``
over a 1-device-per-pod ``pod`` mesh. Per outer round: every pod runs K
inner steps from the shared anchor, the anchor-minus-pod deltas all-reduce
over the pod axis (EDGC-compressed, outer DAC window), and a Nesterov outer
update moves the anchor; the new anchor is broadcast back into every pod.

Elastic membership (pod drop/join between rounds) is a mesh resize driven
through a checkpoint round-trip: the lead survivor's inner checkpoint
(params/opt + DAC/CQM/controller state) seeds every rebuilt pod trainer,
and the outer optimizer migrates its per-pod EF rows (survivors keep
theirs, joiners get the shared warm-start Q + zero EF). Training continues
degraded rather than aborting — the "unreliable pods" production story.

Simulated-pod execution: pods run sequentially on the host process over
fake/real local devices; the outer sync is a REAL collective over the pod
mesh. On hardware the same program structure maps each inner Trainer onto
its pod's slice.
"""
from __future__ import annotations

import copy
from typing import Any, Callable, Iterator

import jax
import numpy as np

from repro.launch.mesh import make_host_mesh, make_pod_mesh
from repro.optim.outer import OuterConfig, OuterOptimizer
from repro.train import checkpoint as ckpt_mod
from repro.train.faults import FaultPlan
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["ElasticTrainer"]


class ElasticTrainer:
    """N inner Trainers + one OuterOptimizer + elastic membership.

    ``batch_fn(pod_index)`` must yield a fresh batch iterator for a pod —
    pods train on DIFFERENT data shards (that is what the outer average
    buys). Inner-step fault injection (``tcfg.faults``) targets pod 0;
    round-scheduled events (``pod_drop``/``pod_join``) are handled here.
    """

    def __init__(self, model, edgc_cfg, tcfg: TrainerConfig,
                 ocfg: OuterConfig, n_pods: int,
                 batch_fn: Callable[[int], Iterator[dict]],
                 seed: int = 0) -> None:
        if ocfg.outer_k < 1:
            raise ValueError("outer_k must be >= 1")
        devices = jax.devices()
        if len(devices) < n_pods:
            raise ValueError(f"{n_pods} pods need {n_pods} devices, have "
                             f"{len(devices)} (set "
                             "XLA_FLAGS=--xla_force_host_platform_device_"
                             "count=N for simulated pods)")
        self.model = model
        self.edgc_cfg = edgc_cfg
        self.tcfg = tcfg
        self.ocfg = ocfg
        self.seed = seed
        self.batch_fn = batch_fn
        self.faults = tcfg.faults if tcfg.faults is not None else FaultPlan()
        self._fired_round_faults: set[int] = set()
        self.round_index = 0
        self.history: list[dict] = []

        # ONE registry for the fleet; each pod trainer writes through a
        # pod-tagged view (a tcfg.metrics_dir here would otherwise open one
        # JSONL appender per pod on the same file).
        from repro.obs import JsonlSink, MetricsRegistry
        if tcfg.metrics is not None:
            self.metrics = tcfg.metrics
        elif tcfg.metrics_dir:
            import os
            self.metrics = MetricsRegistry(
                [JsonlSink(os.path.join(tcfg.metrics_dir, "metrics.jsonl"))])
        else:
            self.metrics = MetricsRegistry()

        self.pods: list[Trainer] = []
        self._batches: list[Iterator[dict]] = []
        self._build_pods(n_pods)
        self.outer = OuterOptimizer(
            self.pods[0].state["params"], ocfg, self.pod_mesh,
            model.config.num_layers, seed=seed)
        # All pods init from the same seed, so pod 0's params ARE the anchor.
        self.anchor = jax.device_get(self.pods[0].state["params"])

    # ------------------------------------------------------------------ pods
    @property
    def n_pods(self) -> int:
        return len(self.pods)

    def _pod_tcfg(self, pod: int) -> TrainerConfig:
        t = copy.copy(self.tcfg)
        t.ckpt_every = 0          # checkpoints are composed, at round level
        t.total_steps = max(t.total_steps,
                            self.ocfg.outer_k * self.ocfg.total_rounds)
        t.metrics = self.metrics.with_tags(pod=pod)
        t.metrics_dir = None
        if pod != 0:
            t.faults = None       # inner-step fault injection hits pod 0
        return t

    def _build_pods(self, n_pods: int) -> None:
        devices = jax.devices()[:n_pods]
        self.pods = []
        self._batches = []
        for p in range(n_pods):
            mesh = make_host_mesh(data=1, model=1, devices=[devices[p]])
            tr = Trainer(self.model, mesh, self.edgc_cfg,
                         self._pod_tcfg(p), seed=self.seed)
            self.pods.append(tr)
            self._batches.append(self.batch_fn(p))
        self.pod_mesh = make_pod_mesh(n_pods, devices)

    def _set_pod_params(self, params_host: Any) -> None:
        for tr in self.pods:
            tr.state = dict(tr.state)
            tr.state["params"] = jax.tree_util.tree_map(
                np.asarray, params_host)
            tr._shard_state()

    # ------------------------------------------------------------ membership
    def resize(self, survivors: list[int], n_new: int,
               ckpt_base: str | None = None) -> None:
        """Membership change to ``n_new`` pods via a checkpoint round-trip.

        ``survivors`` are OLD pod indices whose outer EF rows carry over
        (order = new pod index for the first ``len(survivors)`` pods);
        extra pods beyond that are joiners. The lead survivor's inner
        checkpoint seeds every rebuilt pod (params/opt/controller/DAC/CQM
        migrate through restore), so joiners resume mid-run instead of
        restarting warm-up.
        """
        if not survivors:
            raise ValueError("at least one pod must survive")
        if len(survivors) > n_new:
            raise ValueError(f"{len(survivors)} survivors > {n_new} pods")
        base = ckpt_base or f"{self.tcfg.ckpt_path}_elastic_r{self.round_index}"
        lead = self.pods[survivors[0]]
        lead.save_checkpoint(f"{base}_inner",
                             step=getattr(lead, "_global_step", 0))
        self._build_pods(n_new)
        for tr in self.pods:
            tr.restore_checkpoint(f"{base}_inner")
        self.outer.resize_pods(self.pod_mesh, survivors)
        self.anchor = jax.device_get(self.pods[0].state["params"])

    def _handle_round_faults(self) -> list[str]:
        applied = []
        for i, ev in enumerate(self.faults.events):
            if (not ev.on_round or ev.at != self.round_index
                    or i in self._fired_round_faults):
                continue
            self._fired_round_faults.add(i)
            if ev.kind == "pod_drop":
                if self.n_pods == 1:
                    continue      # never drop the last pod
                target = ev.arg if 0 <= ev.arg < self.n_pods \
                    else self.n_pods - 1
                survivors = [p for p in range(self.n_pods) if p != target]
                self.resize(survivors, self.n_pods - 1)
                applied.append(f"pod_drop:{target}")
                self.metrics.event("pod_drop", round=self.round_index,
                                   target=int(target), n_pods=self.n_pods)
            elif ev.kind == "pod_join":
                if self.n_pods >= len(jax.devices()):
                    continue      # no device for the joiner
                self.resize(list(range(self.n_pods)), self.n_pods + 1)
                applied.append("pod_join")
                self.metrics.event("pod_join", round=self.round_index,
                                   n_pods=self.n_pods)
        return applied

    # ----------------------------------------------------------------- round
    def run_rounds(self, rounds: int) -> list[dict]:
        for _ in range(rounds):
            events = self._handle_round_faults()
            for p, tr in enumerate(self.pods):
                tr.run(self._batches[p], num_steps=self.ocfg.outer_k)
            deltas = []
            for tr in self.pods:
                pod_params = jax.device_get(tr.state["params"])
                deltas.append(jax.tree_util.tree_map(
                    lambda a, b: np.asarray(a, np.float32)
                    - np.asarray(b, np.float32),
                    self.anchor, pod_params))
            new_params, info = self.outer.round(self.anchor, deltas)
            self._set_pod_params(new_params)
            self.anchor = new_params
            losses = [tr.history[-1]["loss"] if tr.history else float("nan")
                      for tr in self.pods]
            info.update({
                "n_pods": self.n_pods,
                "membership_events": events,
                "pod_losses": losses,
                "recovery": (self.pods[0].recovery.as_dict()
                             if self.pods[0].recovery is not None else None),
            })
            self.history.append(info)
            self.metrics.event(
                "outer_round", round=self.round_index,
                **{k: v for k, v in info.items()
                   if k != "round"
                   and isinstance(v, (int, float, str, bool, list, dict,
                                      type(None)))})
            self.metrics.flush()
            self.round_index += 1
        return self.history

    # --------------------------------------------------------- checkpointing
    def save_checkpoint(self, path: str) -> None:
        """Composed elastic checkpoint: lead pod's inner state + the outer
        arrays/control-plane. Valid at round boundaries only (pod params ==
        anchor there, so the anchor needs no separate copy)."""
        self.pods[0].save_checkpoint(
            f"{path}_inner", step=getattr(self.pods[0], "_global_step", 0))
        ckpt_mod.save(f"{path}_outer", self.outer.arrays, extra={
            "outer": self.outer.state_dict(),
            "round": int(self.round_index),
            "n_pods": int(self.n_pods),
        })

    def restore_checkpoint(self, path: str) -> int:
        """Restore to the checkpoint's pod count (elastic resume): rebuilds
        the pod fleet at the saved size, restores inner + outer state, and
        returns the restored round index."""
        extra = ckpt_mod.read_extra(f"{path}_outer")
        n_saved = int(extra["n_pods"])
        if n_saved != self.n_pods:
            self._build_pods(n_saved)
        for tr in self.pods:
            tr.restore_checkpoint(f"{path}_inner")
        # Shared telemetry cursor restores once, at the fleet level (the
        # per-pod restores write through tagged views and skip it).
        inner_extra = ckpt_mod.read_extra(f"{path}_inner")
        if "metrics" in inner_extra:
            self.metrics.load_state_dict(inner_extra["metrics"])
        self.outer.set_mesh(self.pod_mesh)
        self.outer.load_state_dict(extra["outer"],
                                   self.pods[0].state["params"])
        arrs, _ = ckpt_mod.restore(f"{path}_outer", self.outer.arrays)
        self.outer.load_arrays(arrs)
        self.anchor = jax.device_get(self.pods[0].state["params"])
        self.round_index = int(extra["round"])
        return self.round_index
