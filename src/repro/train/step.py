"""Train / eval / serve step builders.

Two distribution modes (DESIGN §3, §5):

  * ``dp_tp``  — paper-faithful Megatron semantics. The step body runs in a
    ``shard_map`` MANUAL over the (pod, data) axes — each replica computes
    local grads for its batch shard — while the 'model' axis stays AUTO
    (GSPMD applies the Megatron TP rules from dist/sharding.py). The DP
    gradient sync is explicit: EDGC/PowerSGD factor pmeans for compressed
    leaves, plain pmean for the rest. This is where the paper lives.

  * ``auto``   — pure pjit (no shard_map): params FSDP-sharded over 'data'
    + TP over 'model'; XLA inserts the gradient reduce. Used by the
    memory-bound monster archs where replicated-DP params cannot fit
    (llama3-405b, kimi-k2-1t, qwen3-moe-235b); compression policy must be
    'none' in this mode (the sync is a fused reduce-scatter).

The returned step functions are NOT jitted here — launch/dryrun.py lowers
them with explicit in/out shardings, and the trainer wraps them in its
compile cache keyed by CompressionPlan.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.compressor import CompressionPlan
from repro.core.config import SYNC_FIELDS, alias_property, resolve_embedded
from repro.core import powersgd
from repro.core.powersgd import LowRankState
from repro.core.entropy import GDSConfig, grads_entropy
from repro.core.sync_executor import SyncExecutor
from repro.dist.collectives import make_dp_pmean, shard_map_dp
from repro.dist.sharding import batch_pspec, param_shardings
from repro.launch.mesh import dp_axes
from repro.models.model import Model
from repro.optim import adam
from repro.pipeline.config import PIPELINE_FIELDS

__all__ = ["TrainStepConfig", "make_train_step", "make_serve_step",
           "make_prefill_step", "TrainState"]


@dataclasses.dataclass(frozen=True, init=False)
class TrainStepConfig:
    """Step-builder config.

    Execution-surface knobs live in the embedded configs: ``pipeline``
    (``repro.pipeline.PipelineConfig`` — stages, schedule, microbatching,
    stashing, sync overlap) and ``sync`` (``repro.core.SyncConfig`` —
    bucketing and kernels for the DP sync). The old flat fields
    (``num_stages``, ``schedule``, ``bucketed``, ``use_kernels``, ...)
    are still accepted as init kwargs and readable as properties —
    deprecated aliases for ``cfg.pipeline.*`` / ``cfg.sync.*``.
    """

    mode: str = "dp_tp"            # dp_tp | auto
    policy_plan: CompressionPlan = CompressionPlan(ranks=())
    gds: GDSConfig = GDSConfig()
    measure_entropy: bool = True
    remat: bool = True             # activation checkpointing over blocks
    guard_nonfinite: bool = False  # recovery: skip non-finite updates
    # Pipeline parallelism + sync-executor surfaces (resolved in __init__;
    # pipeline.num_stages > 1 routes make_train_step to the pipelined
    # builder — the mesh must carry a matching 'pipe' axis).
    pipeline: object = None        # repro.pipeline.PipelineConfig
    sync: object = None            # repro.core.SyncConfig
    adam: adam.AdamConfig = dataclasses.field(default_factory=adam.AdamConfig)

    def __init__(self, mode: str = "dp_tp",
                 policy_plan: CompressionPlan = CompressionPlan(ranks=()),
                 gds: GDSConfig | None = None, measure_entropy: bool = True,
                 remat: bool = True, guard_nonfinite: bool = False,
                 pipeline=None, sync=None,
                 adam=None, **legacy) -> None:
        pipeline, sync = resolve_embedded(pipeline, sync, legacy,
                                          where="TrainStepConfig")
        if adam is None:
            from repro.optim.adam import AdamConfig
            adam = AdamConfig()
        set_ = lambda k, v: object.__setattr__(self, k, v)
        set_("mode", mode)
        set_("policy_plan", policy_plan)
        set_("gds", gds if gds is not None else GDSConfig())
        set_("measure_entropy", measure_entropy)
        set_("remat", remat)
        set_("guard_nonfinite", guard_nonfinite)
        set_("pipeline", pipeline)
        set_("sync", sync)
        set_("adam", adam)


# Deprecated flat-field aliases (kept for existing call sites/tests); the
# canonical homes are cfg.pipeline.* and cfg.sync.*.
for _name in PIPELINE_FIELDS:
    setattr(TrainStepConfig, _name, alias_property("pipeline", _name))
for _name in SYNC_FIELDS:
    setattr(TrainStepConfig, _name, alias_property("sync", _name))
del _name


class TrainState(dict):
    """params / opt / comp (compressor) / step — a plain dict pytree."""


def _loss_with_remat(model: Model, remat: bool):
    if not remat:
        return model.loss_fn
    return jax.checkpoint(model.loss_fn, static_argnums=())


def make_train_step(model: Model, mesh, cfg: TrainStepConfig):
    """Returns (step_fn, in_shardings, out_shardings) ready for jax.jit.

    step signature: (state, batch) -> (state, metrics)
      state = {params, opt_m, opt_v, opt_step, comp}
      metrics = {loss, grad_norm, lr, entropy}

    ``cfg.num_stages > 1`` routes to the pipeline-parallel builder
    (``repro.pipeline.schedule``): same signature, but the state carries
    the stage-partitioned layout of the model family's ``StageAdapter``
    (``repro.pipeline.adapters``) — stage-stacked stacks zero-padded to
    the widest stage for ragged (hybrid/enc-dec) plans, plus the shared
    (pipe-replicated) remainder.
    """
    if cfg.num_stages > 1 or "pipe" in mesh.axis_names:
        from repro.pipeline.schedule import make_pipeline_train_step
        return make_pipeline_train_step(model, mesh, cfg)
    axes = dp_axes(mesh)
    adam_cfg = cfg.adam

    loss_fn = _loss_with_remat(model, cfg.remat)

    manual = cfg.mode == "dp_tp" and bool(axes)
    sync_exec = SyncExecutor(cfg.sync, mode="flat", plan=cfg.policy_plan)

    def local_step(state, batch):
        params = state["params"]
        # Compressor state (the PowerSGD error-feedback residual) is
        # PER-WORKER: it enters with a leading replica dim sharded over the
        # manual axes (locally size 1) — squeeze it here, restore on exit.
        comp_in = state["comp"]
        if manual:
            comp_in = jax.tree_util.tree_map(lambda a: a[0], comp_in)

        # Fault-injection channel: a (B,)-shaped flag array the trainer
        # adds when a nan_grad fault is scheduled (batch-dim shaped so the
        # uniform manual batch spec shards it like any other batch leaf).
        batch = dict(batch)
        inject = batch.pop("_inject", None)

        def lf(p):
            loss, mets = loss_fn(p, batch)
            return loss, mets

        (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(params)
        if inject is not None:
            bad = jnp.max(inject) > 0
            grads = jax.tree_util.tree_map(
                lambda g: jnp.where(bad, jnp.full_like(g, jnp.nan), g), grads)
        pmean = make_dp_pmean(axes) if manual else (lambda x: x)
        loss = pmean(loss)
        synced, comp = sync_exec.sync(grads, comp_in, pmean)
        entropy = (grads_entropy(synced, cfg.gds)
                   if cfg.measure_entropy else jnp.zeros((), jnp.float32))
        opt_state = adam.AdamState(state["opt_step"], state["opt_m"], state["opt_v"])
        if cfg.guard_nonfinite:
            # Recovery guard: a non-finite loss or synced-grad norm (NaN
            # injection, corrupted compressor payload, divergence) must not
            # reach the optimizer OR the compressor's warm-start/EF state.
            # The whole update is computed and discarded leaf-wise — the
            # host sees metrics['skipped'] == 1 and resets the EF state.
            gnorm = adam.global_norm(synced)
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            new_params, new_opt, opt_mets = adam.update(
                params, synced, opt_state, adam_cfg, gnorm=gnorm)
            keep = lambda new, old: jax.tree_util.tree_map(
                lambda a, b: jnp.where(ok, a, b), new, old)
            params = keep(new_params, params)
            opt_state = adam.AdamState(
                step=keep(new_opt.step, opt_state.step),
                m=keep(new_opt.m, opt_state.m),
                v=keep(new_opt.v, opt_state.v))
            comp = keep(comp, comp_in)
            skipped = 1.0 - ok.astype(jnp.float32)
        else:
            params, opt_state, opt_mets = adam.update(
                params, synced, opt_state, adam_cfg)
            skipped = None
        # EF-residual norm on the per-worker comp state BEFORE the replica
        # dim is restored — one scalar, fetched lazily by the obs flush.
        ef_norm = jnp.sqrt(pmean(powersgd.ef_norm_sq(comp)))
        if manual:
            comp = jax.tree_util.tree_map(lambda a: a[None], comp)
        new_state = {
            "params": params,
            "opt_m": opt_state.m, "opt_v": opt_state.v, "opt_step": opt_state.step,
            "comp": comp,
        }
        metrics = {"loss": loss, "entropy": entropy, "ef_norm": ef_norm,
                   **opt_mets,
                   **{k: pmean(v) for k, v in mets.items() if k != "loss"}}
        if skipped is not None:
            metrics["skipped"] = skipped
        return new_state, metrics

    if manual:
        state_specs = {
            "params": P(), "opt_m": P(), "opt_v": P(), "opt_step": P(),
            "comp": P(tuple(axes)),   # per-worker EF/Q, replica dim first
        }
        step = shard_map_dp(
            local_step, mesh,
            in_specs=(state_specs, _batch_specs_manual(axes)),
            out_specs=({**state_specs}, P()),
            manual_axes=axes,
        )
    else:
        step = local_step
    return step


def replicate_comp_state(comp, world: int):
    """Give compressor leaves their leading per-worker replica dim.

    The warm-start Q must be IDENTICAL across workers at init (PowerSGD
    requirement), so a broadcast — not independent inits — is correct.
    """
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (world,) + a.shape), comp)


def _batch_specs_manual(axes):
    """Manual in_spec for the batch dict: leading dim sharded over DP axes.

    shard_map accepts a pytree-prefix of specs; a single spec broadcasts to
    every dict entry, and all batch arrays carry the batch dim first.
    """
    return P(tuple(axes))


def state_shardings(state, model: Model, mesh, fsdp: bool = False):
    """NamedShardings for the TrainState pytree.

    params (and their opt m/v mirrors) follow the TP rules. Compressor
    state: the per-worker replica dim leads (manual axes); the EF residual's
    TRAILING dims must mirror its param's TP spec — a replicated EF is
    param-sized per chip AND forces XLA to all-gather the (TP-sharded)
    gradient to add it (observed: +120 GiB/chip of gathers on qwen3-32b,
    EXPERIMENTS §Perf H1). Q factors are rank-thin and stay replicated.
    Stacked (group-keyed) compressor states mix leaves with different TP
    specs in one array, so their trailing dims fall back to replicated via
    the pspec lookup below (group keys are not param paths).
    """
    from repro.dist.sharding import param_pspecs

    pshard = param_shardings(state["params"], mesh, fsdp=fsdp)
    rep = NamedSharding(mesh, P())
    axes = dp_axes(mesh)
    lead = (tuple(axes),) if axes else ()

    pspecs_flat = {
        jax.tree_util.keystr(kp): spec
        for kp, spec in jax.tree_util.tree_flatten_with_path(
            param_pspecs(state["params"], mesh))[0]
    }

    comp_shardings = {}
    for path, st in state["comp"].items():
        if not isinstance(st, LowRankState):
            # Raw-array entries (flat-bucket wire-EF residuals, ef:<path>):
            # bucketed-only, hence TP=1 — replicate the trailing dims.
            comp_shardings[path] = NamedSharding(mesh, P(*lead))
            continue
        pspec = pspecs_flat.get(path, P())
        comp_shardings[path] = type(st)(
            q=NamedSharding(mesh, P(*lead)),
            err=NamedSharding(mesh, P(*lead, *tuple(pspec))),
        )
    return {
        "params": pshard,
        "opt_m": pshard, "opt_v": pshard,
        "opt_step": rep,
        "comp": comp_shardings,
    }


def batch_shardings(batch, mesh, batch_size: int):
    return {
        k: NamedSharding(mesh, batch_pspec(v.ndim, mesh, batch_size))
        for k, v in batch.items()
    }


# ----------------------------------------------------------------- serving
def make_prefill_step(model: Model):
    """Full-sequence forward (inference prefill): (params, batch) -> logits."""
    def prefill(params, batch):
        return model.forward(params, batch)
    return prefill


def make_serve_step(model: Model):
    """One decode step: (params, cache, tokens (B,)) -> (logits, cache)."""
    def serve(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return serve
