"""Minimal-but-real checkpointing: numpy-archive of the full train state.

No orbax offline, so checkpoints are ``.npz`` files plus a JSON manifest of
the pytree structure. Works for any state pytree (params, opt, compressor),
restores onto the host, and the trainer re-device_puts with its shardings.

Crash safety: both files are written to temp paths and ``os.replace``d into
place (atomic on POSIX), and the pair is tied together by a per-save nonce
stored in both the archive and the manifest — a crash between the two
renames, or a truncated archive, surfaces as a clean ``CheckpointError``
("torn checkpoint") instead of a silent mix of two saves. The rollback
recovery policy in the trainer depends on this: a torn newest checkpoint
must *fail to restore* so the ring can fall through to an older intact one.
"""
from __future__ import annotations

import json
import os
import uuid
import warnings
import zipfile
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointError", "save", "read_extra", "restore"]

_NONCE_KEY = "__manifest_nonce__"


class CheckpointError(RuntimeError):
    """A checkpoint pair is missing, torn, or structurally incompatible."""


def _flatten(state: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    names = [jax.tree_util.keystr(kp) for kp, _ in flat]
    arrays = [np.asarray(leaf) for _, leaf in flat]
    return names, arrays, treedef


def save(path: str, state: Any, extra: dict | None = None) -> None:
    """Atomically write the ``path + '.npz'`` / ``path + '.json'`` pair.

    Archive first, manifest last: an interrupted save leaves either the old
    pair intact (crash before the first rename) or a nonce mismatch the
    restore path rejects (crash between renames).
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    names, arrays, _ = _flatten(state)
    nonce = uuid.uuid4().hex

    tmp_npz = f"{path}.npz.tmp.{nonce[:8]}"
    with open(tmp_npz, "wb") as f:
        np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(arrays)},
                 **{_NONCE_KEY: np.array(nonce)})
    npz_bytes = os.path.getsize(tmp_npz)

    manifest = {"names": names, "extra": extra or {},
                "nonce": nonce, "npz_bytes": npz_bytes}
    tmp_json = f"{path}.json.tmp.{nonce[:8]}"
    with open(tmp_json, "w") as f:
        json.dump(manifest, f)

    os.replace(tmp_npz, path + ".npz")
    os.replace(tmp_json, path + ".json")


def _load_manifest(path: str) -> dict:
    mpath = path + ".json"
    if not os.path.exists(mpath):
        raise CheckpointError(f"no checkpoint manifest at {mpath}")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(
            f"corrupt checkpoint manifest {mpath}: {e}") from e
    if "names" not in manifest or "extra" not in manifest:
        raise CheckpointError(
            f"checkpoint manifest {mpath} is missing required keys "
            f"(has {sorted(manifest)})")
    return manifest


def read_extra(path: str) -> dict:
    """Manifest ``extra`` dict only — no array loading.

    The trainer reads this FIRST on resume: the controller state inside it
    determines the compression plan, and the plan determines the shapes of
    the compressor-state arrays that ``restore`` will then be checked
    against.
    """
    return _load_manifest(path)["extra"]


def _load_archive(path: str, manifest: dict):
    apath = path + ".npz"
    if not os.path.exists(apath):
        raise CheckpointError(
            f"torn checkpoint: manifest {path}.json exists but archive "
            f"{apath} is missing")
    expect = manifest.get("npz_bytes")
    actual = os.path.getsize(apath)
    if expect is not None and actual != expect:
        raise CheckpointError(
            f"torn checkpoint: archive {apath} is {actual} bytes, manifest "
            f"recorded {expect} (truncated write or mixed save?)")
    try:
        data = np.load(apath, allow_pickle=False)
        keys = set(data.files)
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointError(
            f"torn checkpoint: archive {apath} is unreadable: {e}") from e
    nonce = manifest.get("nonce")
    if nonce is not None and _NONCE_KEY in keys:
        if str(data[_NONCE_KEY]) != nonce:
            raise CheckpointError(
                f"torn checkpoint: archive {apath} and manifest {path}.json "
                f"come from different saves (nonce mismatch)")
    return data


def _structure_mismatch_msg(want: list[str], have: list[str]) -> str:
    missing = [n for n in want if n not in set(have)]
    unexpected = [n for n in have if n not in set(want)]
    parts = [f"checkpoint structure mismatch: expected {len(want)} leaves, "
             f"archive has {len(have)}"]
    if missing:
        parts.append("first missing from checkpoint: "
                     + ", ".join(missing[:3]))
    if unexpected:
        parts.append("first unexpected in checkpoint: "
                     + ", ".join(unexpected[:3]))
    if not missing and not unexpected:
        # Same leaf set, different order/structure: name the first diff.
        i = next(i for i, (a, b) in enumerate(zip(want, have)) if a != b)
        parts.append(f"first differing leaf at index {i}: expected "
                     f"{want[i]!r}, checkpoint has {have[i]!r}")
    return "; ".join(parts)


def restore(path: str, like: Any,
            on_dtype_mismatch: str = "warn") -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype checked).

    ``on_dtype_mismatch``: "warn" (coerce with a warning naming the leaf),
    "raise" (CheckpointError), or "silent" (the pre-PR-7 behaviour).
    """
    if on_dtype_mismatch not in ("warn", "raise", "silent"):
        raise ValueError(f"on_dtype_mismatch={on_dtype_mismatch!r} not in "
                         "('warn', 'raise', 'silent')")
    manifest = _load_manifest(path)
    data = _load_archive(path, manifest)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    names = [jax.tree_util.keystr(kp) for kp, _ in flat]
    if names != manifest["names"]:
        raise CheckpointError(
            _structure_mismatch_msg(names, list(manifest["names"])))
    leaves = []
    for i, (_, ref) in enumerate(flat):
        try:
            arr = data[f"leaf_{i}"]
        except KeyError as e:
            raise CheckpointError(
                f"torn checkpoint: archive {path}.npz is missing leaf_{i} "
                f"({names[i]})") from e
        if tuple(arr.shape) != tuple(ref.shape):
            raise CheckpointError(
                f"shape mismatch for {names[i]}: checkpoint {arr.shape} vs "
                f"expected {ref.shape}")
        want_dtype = np.asarray(ref).dtype
        if arr.dtype != want_dtype:
            msg = (f"dtype mismatch for {names[i]}: checkpoint {arr.dtype} "
                   f"vs expected {want_dtype}")
            if on_dtype_mismatch == "raise":
                raise CheckpointError(msg)
            if on_dtype_mismatch == "warn":
                warnings.warn(msg + " (coercing)", stacklevel=2)
            arr = arr.astype(want_dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
