"""Minimal-but-real checkpointing: numpy-archive of the full train state.

No orbax offline, so checkpoints are ``.npz`` files plus a JSON manifest of
the pytree structure. Works for any state pytree (params, opt, compressor),
restores onto the host, and the trainer re-device_puts with its shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(state: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    names = [jax.tree_util.keystr(kp) for kp, _ in flat]
    arrays = [np.asarray(leaf) for _, leaf in flat]
    return names, arrays, treedef


def save(path: str, state: Any, extra: dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    names, arrays, _ = _flatten(state)
    np.savez(path + ".npz", **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    manifest = {"names": names, "extra": extra or {}}
    with open(path + ".json", "w") as f:
        json.dump(manifest, f)


def read_extra(path: str) -> dict:
    """Manifest ``extra`` dict only — no array loading.

    The trainer reads this FIRST on resume: the controller state inside it
    determines the compression plan, and the plan determines the shapes of
    the compressor-state arrays that ``restore`` will then be checked
    against.
    """
    with open(path + ".json") as f:
        return json.load(f)["extra"]


def restore(path: str, like: Any) -> tuple[Any, dict]:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    names = [jax.tree_util.keystr(kp) for kp, _ in flat]
    if names != manifest["names"]:
        raise ValueError("checkpoint structure mismatch")
    leaves = []
    for i, (_, ref) in enumerate(flat):
        arr = data[f"leaf_{i}"]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {names[i]}: {arr.shape} vs {ref.shape}")
        leaves.append(arr.astype(np.asarray(ref).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
