"""Trainer: the host loop that runs EDGC (or a baseline policy) end to end.

Responsibilities:
  * build model/optimizer/compressor state (+ shardings on a mesh),
  * drive the EDGCController: alpha-gated entropy readings, window
    boundaries, plan changes,
  * maintain the compile cache — one jitted step per CompressionPlan
    (rank changes re-specialize at window boundaries only, paper §IV-C),
  * account exact DP-sync wire bytes per step (feeds Tables III/VI),
  * checkpoint.

Runs identically on 1 CPU device (fidelity experiments) and on a mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (
    EDGCConfig,
    EDGCController,
    classify_leaves,
    init_compressor_state,
    plan_wire_bytes,
    resize_compressor_state,
)
from repro.core import wire
from repro.core.bucketing import bucketing_supported, make_bucket_layout
from repro.core.config import SYNC_FIELDS, SyncConfig, alias_property, \
    resolve_embedded
from repro.models.model import Model
from repro.optim import adam
from repro.train import checkpoint as ckpt_mod
from repro.train.step import (
    TrainStepConfig,
    make_train_step,
    replicate_comp_state,
    state_shardings,
)
from repro.launch.mesh import dp_axes, pipe_size
from repro.pipeline.config import PIPELINE_FIELDS


@dataclasses.dataclass(init=False)
class TrainerConfig:
    """Host-loop config.

    The execution knobs live in the embedded configs: ``pipeline``
    (``repro.pipeline.PipelineConfig`` — schedule, microbatching,
    stashing, sync overlap) and ``sync`` (``repro.core.SyncConfig`` —
    bucketing/kernels; ``bucketed=None`` resolves to "bucketed where the
    mesh supports it", matching the old ``bucketed=True`` default — the
    stacked group state cannot mirror per-leaf TP specs, so TP>1 meshes
    drop to the per-leaf executor). The old flat fields (``schedule``,
    ``bucketed``, ``use_kernels``, ...) remain accepted as init kwargs
    and readable/settable as properties, deprecated in favor of
    ``tcfg.pipeline.*`` / ``tcfg.sync.*``.
    """

    total_steps: int = 1000
    log_every: int = 50
    ckpt_every: int = 0             # 0 = no checkpoints
    ckpt_path: str = "ckpt/state"
    min_compress_dim: int = 64
    measure_entropy: bool = True
    remat: bool = False
    recovery: Any = None            # repro.train.faults.RecoveryConfig
    faults: Any = None              # repro.train.faults.FaultPlan (injection)
    pipeline: Any = None            # repro.pipeline.PipelineConfig
    sync: Any = None                # repro.core.SyncConfig
    metrics: Any = None             # repro.obs.MetricsRegistry (or a view)
    metrics_dir: str | None = None  # convenience: JSONL sink at <dir>/metrics.jsonl
    adam: adam.AdamConfig = dataclasses.field(default_factory=adam.AdamConfig)

    def __init__(self, total_steps: int = 1000, log_every: int = 50,
                 ckpt_every: int = 0, ckpt_path: str = "ckpt/state",
                 min_compress_dim: int = 64, measure_entropy: bool = True,
                 remat: bool = False, recovery=None, faults=None,
                 pipeline=None, sync=None, metrics=None, metrics_dir=None,
                 adam=None, **legacy) -> None:
        pipeline, sync = resolve_embedded(pipeline, sync, legacy,
                                          where="TrainerConfig")
        self.total_steps = total_steps
        self.log_every = log_every
        self.ckpt_every = ckpt_every
        self.ckpt_path = ckpt_path
        self.min_compress_dim = min_compress_dim
        self.measure_entropy = measure_entropy
        self.remat = remat
        self.recovery = recovery
        self.faults = faults
        self.pipeline = pipeline
        self.sync = sync
        self.metrics = metrics
        self.metrics_dir = metrics_dir
        if adam is None:
            from repro.optim.adam import AdamConfig
            adam = AdamConfig()
        self.adam = adam


# Deprecated flat-field aliases; TrainerConfig is mutable, so writes pass
# through too (replacing the embedded frozen config).
for _name in PIPELINE_FIELDS:
    setattr(TrainerConfig, _name,
            alias_property("pipeline", _name, settable=True))
for _name in SYNC_FIELDS:
    setattr(TrainerConfig, _name, alias_property("sync", _name,
                                                 settable=True))
del _name


class Trainer:
    def __init__(self, model: Model, mesh, edgc_cfg: EDGCConfig,
                 tcfg: TrainerConfig, seed: int = 0) -> None:
        self.model = model
        self.mesh = mesh
        self.edgc_cfg = edgc_cfg
        self.tcfg = tcfg
        if edgc_cfg.policy == "edgc" and not tcfg.measure_entropy:
            # The DAC window would silently fill with the step's 0.0
            # placeholder entropies and drive ranks off a constant — an
            # unconditionally corrupt control loop, so refuse up front.
            raise ValueError("policy='edgc' requires measure_entropy=True: "
                             "the DAC consumes the GDS entropy readings")

        key = jax.random.PRNGKey(seed)
        params = model.init(key)
        from repro.models.model import param_count
        self.n_params = param_count(params)   # true count (pre-padding)
        self.leaves = classify_leaves(
            params, model.config.num_layers, edgc_cfg.num_stages,
            min_dim=tcfg.min_compress_dim,
        )
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.world = int(np.prod([sizes.get(a, 1) for a in dp_axes(mesh)])) or 1
        self.controller = EDGCController(edgc_cfg, self.leaves, world=self.world)

        # Pipeline-parallel execution: a 'pipe' mesh axis + num_stages > 1
        # routes everything through repro.pipeline (stage-partitioned state,
        # microbatch schedule, per-stage DP sync). Without a pipe axis,
        # num_stages > 1 keeps the legacy "virtual stages" semantics (DAC
        # emits per-stage ranks, the sync runs on the flat DP mesh).
        self.pipelined = "pipe" in mesh.axis_names
        if self.pipelined and pipe_size(mesh) != edgc_cfg.num_stages:
            raise ValueError(
                f"mesh pipe axis size {pipe_size(mesh)} != "
                f"num_stages={edgc_cfg.num_stages}")

        # The ONE canonical config pair every step build sees (the step
        # builder receives these exact objects, not copied fields): the
        # trainer's PipelineConfig pinned to the executed stage count, and
        # its SyncConfig with ``bucketed`` resolved against the mesh.
        pcfg = tcfg.pipeline
        s_exec = edgc_cfg.num_stages if self.pipelined else 1
        if pcfg.num_stages != s_exec:
            pcfg = dataclasses.replace(pcfg, num_stages=s_exec)
        self.pipeline_cfg = pcfg
        if self.pipelined:
            # pipelined sync is always the per-stage bucketed executor;
            # the flag is only meaningful on the flat path
            self.sync_cfg = (tcfg.sync if tcfg.sync.bucketed is None
                             else dataclasses.replace(tcfg.sync,
                                                      bucketed=None))
        else:
            self._bucketed = ((tcfg.sync.bucketed is not False)
                              and bucketing_supported(mesh))
            self.sync_cfg = dataclasses.replace(tcfg.sync,
                                                bucketed=self._bucketed)

        # ----- wire coding (PR 9) ----------------------------------------
        # The lossless-training wire format rides on the bucketed executor
        # (per-member quantize+pack happens inside the flat-bucket sync);
        # the per-leaf TP fallback has no coded path.
        if self.sync_cfg.wire != "raw" and not self.pipelined \
                and not self._bucketed:
            raise ValueError(
                f"wire={self.sync_cfg.wire!r} requires the bucketed sync "
                "executor (unsupported mesh or SyncConfig.bucketed=False)")
        # entropy mode re-resolves the codec at window boundaries against
        # the first measured entropy (the reference distribution); until a
        # reading exists it falls back to quant8 inside resolve_codec.
        self._wire_ref_entropy: float | None = None
        codec = self.sync_cfg.codec
        if codec is None and self.sync_cfg.wire != "raw":
            codec = wire.resolve_codec(self.sync_cfg.wire)
            self.sync_cfg = dataclasses.replace(self.sync_cfg, codec=codec)
        self._codec = codec

        self._comp_key = jax.random.fold_in(key, 123)
        if self.pipelined:
            self._init_pipelined_state(params, jax.random.fold_in(key, 99),
                                       tcfg.adam)
        else:
            ost = adam.init(params, tcfg.adam)
            # Stacked (group-keyed) compressor state + the bucketed sync
            # executor: O(shape groups + flat buckets) DP collectives
            # instead of O(leaves). TP>1 keeps the per-leaf executor (see
            # TrainerConfig.sync / SyncConfig.bucketed).
            self._layout = (make_bucket_layout(self.leaves,
                                               self.controller.plan,
                                               self.sync_cfg.bucket_bytes)
                            if self._bucketed else None)
            comp = init_compressor_state(params, self.controller.plan,
                                         jax.random.fold_in(key, 99),
                                         layout=self._layout,
                                         wire_ef=self._codec is not None)
            comp = replicate_comp_state(comp, self.world)
            self.state = {"params": params, "opt_m": ost.m, "opt_v": ost.v,
                          "opt_step": ost.step, "comp": comp}
        self._shard_state()

        # Overlapped per-stage sync: hand the DAC the schedule's measured
        # Eq. 4 slack so Algorithm 2 aligns (and feasibility-clamps) ranks
        # against the geometry the overlap planner actually schedules.
        self.overlap_plan = None
        if self.pipelined and self.pipeline_cfg.overlap_sync:
            from repro.pipeline.schedule import plan_overlap
            s_count = self.pipeline_cfg.num_stages
            mb = self.pipeline_cfg.num_microbatches or s_count
            self.overlap_plan = plan_overlap(
                self.pipeline_cfg.schedule, s_count, mb, self._splans)
            t_mb = self.controller.dac.t_micro_back
            self.controller.set_overlap_feedback(
                [t * t_mb for t in self.overlap_plan.slack_seconds])

        self._step_cache: dict[Any, Any] = {}
        self.step_configs: dict[Any, TrainStepConfig] = {}
        self.history: list[dict] = []
        self.bytes_synced = 0           # exact DP wire bytes so far (coded)
        self.bytes_wire_raw = 0         # same payloads priced uncoded
        self.bytes_full = 0             # what no-compression would have moved
        self._last_entropy = 0.0        # most recent alpha-gated reading
        self._last_stage_entropy = None  # per-stage hold (pipelined only)

        # ----- telemetry (repro.obs) --------------------------------------
        # tcfg.metrics wins (shared registry / tagged elastic view); else
        # metrics_dir attaches a JSONL sink; else a bare no-sink registry so
        # the loop never needs a null check.
        from repro.obs import JsonlSink, MetricsRegistry
        if tcfg.metrics is not None:
            self.metrics = tcfg.metrics
        elif tcfg.metrics_dir:
            import os
            self.metrics = MetricsRegistry(
                [JsonlSink(os.path.join(tcfg.metrics_dir, "metrics.jsonl"))])
        else:
            self.metrics = MetricsRegistry()
        pcfg = self.pipeline_cfg
        self.metrics.event(
            "run_meta", step=0,
            model=model.config.name, family=model.config.family,
            policy=edgc_cfg.policy, n_params=int(self.n_params),
            world=self.world, pipelined=self.pipelined,
            num_stages=int(edgc_cfg.num_stages), schedule=pcfg.schedule,
            num_microbatches=int(pcfg.num_microbatches or pcfg.num_stages),
            stash_policy=pcfg.stash_policy, overlap_sync=pcfg.overlap_sync,
            window=int(edgc_cfg.dac.window), log_every=int(tcfg.log_every),
            total_steps=int(tcfg.total_steps))
        if self.overlap_plan is not None:
            op = self.overlap_plan
            n_in = [sum(len(ids) for _, ids in op.launches[s])
                    for s in range(op.num_stages)]
            n_res = [len(op.residual[s]) for s in range(op.num_stages)]
            total = sum(n_in) + sum(n_res)
            self.metrics.event(
                "overlap_plan", step=0,
                in_loop=n_in, residual=n_res,
                slack_seconds=list(op.slack_seconds),
                est_sync_seconds=list(op.est_sync_seconds),
                feasible=list(op.feasible),
                slack_utilization=(sum(n_in) / total if total else 0.0))

        # ----- fault injection + recovery policy (PR 7) -------------------
        from repro.train.faults import FaultPlan, RecoveryState
        self.faults = tcfg.faults if tcfg.faults is not None else FaultPlan()
        self.recovery = (RecoveryState() if tcfg.recovery is not None
                         else None)
        self._guard = bool(tcfg.recovery is not None
                           and tcfg.recovery.guard_nonfinite
                           and not self.pipelined)
        if self.pipelined and (self.faults.has("nan_grad")
                               or self.faults.has("corrupt_payload")):
            raise ValueError("nan_grad/corrupt_payload fault injection "
                             "requires the flat (non-pipelined) trainer: "
                             "the pipelined step has no guard/injection "
                             "channel yet")
        self._ckpt_ring: list[tuple[str, int]] = []  # newest last
        self._tear_next_ckpt = False                 # torn_ckpt fault armed
        self._last_step_ok = True                    # recovered-event edge
        self._ema_seen = 0                           # spike-detector warmup
        # Faults are one-shot (transient): a rollback that replays past a
        # fired event's step must NOT re-inject it, or a deterministic
        # fault would defeat every retry.
        self._fired_faults: set[int] = set()

    def _init_pipelined_state(self, params, comp_key, acfg) -> None:
        from repro.pipeline import partition as ppart
        from repro.pipeline import sync as psync

        S = self.edgc_cfg.num_stages
        reason = ppart.pipeline_supported(self.model.config, S)
        if reason is not None:
            raise ValueError(f"pipeline trainer unsupported: {reason}")
        # The family's stage adapter owns the layout (stacked stage keys,
        # ragged-plan padding, local<->global leaf paths).
        self._part = ppart.make_partition(self.model, S,
                                          remat=self.tcfg.remat)
        stage_p, shared_p = self._part.partition_params(params)
        ost = adam.init({"stage": stage_p, "shared": shared_p}, acfg)
        self._splans = psync.make_stage_plans(
            self.controller.plan, S, psync.stage_local_leaves(stage_p),
            bucket_bytes=self.sync_cfg.bucket_bytes,
            chunk_bytes=self.pipeline_cfg.chunk_bytes,
            local_path=self._part.local_leaf_path)
        comp = psync.init_pipeline_comp_state(
            params, self.controller.plan, comp_key, self._splans,
            wire_ef=self._codec is not None)
        comp = psync.replicate_pipeline_comp_state(comp, self.world)
        self.state = {
            "stage_params": stage_p, "shared_params": shared_p,
            "opt_m": ost.m, "opt_v": ost.v, "opt_step": ost.step,
            "comp": comp,
        }

    # ------------------------------------------------------------------ setup
    def _shard_state(self) -> None:
        if self.pipelined:
            from repro.pipeline.schedule import pipeline_state_shardings
            self._sshard = pipeline_state_shardings(self.state, self.model,
                                                    self.mesh)
        else:
            self._sshard = state_shardings(self.state, self.model, self.mesh)
        self.state = jax.device_put(self.state, self._sshard)

    def _get_step(self, measure_entropy: bool | None = None):
        """Compiled step for the current plan; ``measure_entropy`` picks
        the entropy-on or entropy-off variant (the GDS ISR/alpha gate —
        off-steps must lower no moment work at all, §IV-B)."""
        if measure_entropy is None:
            measure_entropy = self.tcfg.measure_entropy
        plan = self.controller.plan
        # sync_cfg is part of the key: entropy-mode wire coding swaps the
        # codec at window boundaries, which must re-specialize the step.
        key = (plan, measure_entropy, self.sync_cfg)
        if key not in self._step_cache:
            # The step builder sees the trainer's canonical embedded
            # configs BY IDENTITY (no field copying): one source of truth
            # for the pipeline/sync surface across host loop and step.
            scfg = TrainStepConfig(
                mode="dp_tp", policy_plan=plan,
                gds=self.edgc_cfg.gds,
                measure_entropy=measure_entropy,
                remat=self.tcfg.remat,
                guard_nonfinite=self._guard,
                pipeline=self.pipeline_cfg,
                sync=self.sync_cfg,
                adam=self.tcfg.adam,
            )
            self.step_configs[key] = scfg
            raw = make_train_step(self.model, self.mesh, scfg)
            self._step_cache[key] = jax.jit(
                raw,
                in_shardings=(self._sshard, None),
                out_shardings=(self._sshard, NamedSharding(self.mesh, P())),
                donate_argnums=0,
            )
        return self._step_cache[key]

    def step_cache_keys(self) -> tuple:
        """Every ``(plan, measure_entropy, sync_cfg)`` key a compiled step
        variant exists for — the auditor's recompile pass proves the count
        stays window-bounded (plans/codecs only change at DAC windows)."""
        return tuple(self._step_cache)

    def _refresh_codec(self) -> bool:
        """Entropy-mode wire coding: re-pick the bit width from the most
        recent pooled entropy reading (reference = the run's first
        measurement). Returns True when the codec changed, i.e. the byte
        ledger must re-price. Called at window boundaries only, so the
        step re-specialization it triggers rides the existing
        plan-change recompile cadence."""
        if self.sync_cfg.wire != "entropy":
            return False
        hist = self.controller.entropy_history
        if not hist:
            return False
        if self._wire_ref_entropy is None:
            self._wire_ref_entropy = float(hist[0][1])
        new = wire.resolve_codec("entropy",
                                 entropy_nats=self._last_entropy,
                                 ref_nats=self._wire_ref_entropy)
        if new == self._codec:
            return False
        self._codec = new
        self.sync_cfg = dataclasses.replace(self.sync_cfg, codec=new)
        return True

    def _price_plan(self) -> tuple[int, int, int]:
        """(coded, raw-payload, no-compression) bytes per step under the
        current plan. ``coded == raw`` when wire coding is off; ``raw`` is
        the same sync payload priced at its uncoded wire dtype, so
        coded/raw is the measured wire-format reduction."""
        comp, full = plan_wire_bytes(self.leaves, self.controller.plan,
                                     codec=self._codec)
        raw = (plan_wire_bytes(self.leaves, self.controller.plan)[0]
               if self._codec is not None else comp)
        return comp, raw, full

    def _apply_plan_change(self) -> None:
        """Resize/extend compressor state to the new plan (host-side).

        Stacked states migrate between bucket layouts: existing leaves keep
        their warm-start Q (resized) and EF residual; newly-compressed
        leaves get fresh state.
        """
        plan = self.controller.plan
        if self.pipelined:
            from repro.pipeline import sync as psync
            S = self.edgc_cfg.num_stages
            new_splans = psync.make_stage_plans(
                plan, S,
                psync.stage_local_leaves(self.state["stage_params"]),
                bucket_bytes=self.sync_cfg.bucket_bytes,
                chunk_bytes=self.pipeline_cfg.chunk_bytes,
                local_path=self._part.local_leaf_path)
            comp_host = jax.device_get(self.state["comp"])
            fresh = psync.resize_pipeline_comp_state(
                comp_host, self._splans, new_splans, self._comp_key)
            self._splans = new_splans
            comp = psync.replicate_pipeline_comp_state(fresh, self.world)
            self.state = dict(self.state)
            self.state["comp"] = comp
            self._shard_state()
            return
        comp_host = jax.tree_util.tree_map(lambda a: a[0], self.state["comp"])
        if self._bucketed:
            new_layout = make_bucket_layout(self.leaves, plan,
                                            self.sync_cfg.bucket_bytes)
            fresh = resize_compressor_state(
                comp_host, plan, self._comp_key,
                old_layout=self._layout, new_layout=new_layout,
            )
            self._layout = new_layout
        else:
            # per-leaf path: fresh state for new leaves, resize the rest
            params = self.state["params"]
            fresh = init_compressor_state(params, plan, self._comp_key)
            from repro.core.powersgd import resize_rank
            for path in list(fresh.keys()):
                if path in comp_host:
                    fresh[path] = resize_rank(
                        comp_host[path], plan.rank_of(path), self._comp_key)
        comp = replicate_comp_state(fresh, self.world)
        self.state = dict(self.state)
        self.state["comp"] = comp
        self._shard_state()

    # ------------------------------------------------------------------- run
    def run(self, batches: Iterator[dict], num_steps: int | None = None
            ) -> list[dict]:
        """Run ``num_steps`` (default: remaining up to total_steps).

        Can be called repeatedly; the global step counter persists, so
        windows/warm-up continue correctly across calls.

        With ``tcfg.recovery`` set, the loop additionally watches every
        step's outcome: a guarded skip (non-finite update) triggers an EF
        reset, a non-finite or spiking loss rolls back to the newest intact
        checkpoint in the ring (bounded retries + re-arm backoff), and
        repeated anomalies pin the controller to uncompressed sync.
        """
        tcfg, ctrl = self.tcfg, self.controller
        rcfg, rs = tcfg.recovery, self.recovery
        comp_bytes, raw_bytes, full_bytes = self._price_plan()
        stage_b = self.stage_bytes()    # refreshed only at plan changes
        window = self.edgc_cfg.dac.window
        t0 = time.time()
        start = getattr(self, "_global_step", 0)
        end = min(tcfg.total_steps, start + (num_steps if num_steps is not None
                                             else tcfg.total_steps - start))
        inject_nan_faults = self.faults.has("nan_grad")
        # Deferred metric fetch: steps buffer their device metrics here and
        # ONE batched block_until_ready runs at flush boundaries (log_every,
        # window ends, checkpoints, run end) — the step loop itself never
        # forces a device->host sync. The recovery guard is the documented
        # exception: it must read each step's loss to decide skip/rollback.
        pending: list[tuple] = []
        step_idx = start
        while step_idx < end:
            batch = next(batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            fired_now = [(i, ev) for i, ev in enumerate(self.faults.events)
                         if not ev.on_round and ev.at == step_idx
                         and i not in self._fired_faults]
            self._fired_faults.update(i for i, _ in fired_now)
            for _, ev in fired_now:
                self.metrics.event("fault_injected", step=step_idx,
                                   kind=ev.kind, at=int(ev.at))
                if ev.kind == "corrupt_payload":
                    self._poison_comp_state()
                elif ev.kind == "torn_ckpt":
                    self._tear_next_ckpt = True
            if inject_nan_faults:
                # Constant batch structure (one compiled variant): the flag
                # array is present on EVERY step once any nan_grad fault is
                # scheduled, zero except at the scheduled steps.
                flag = float(any(ev.kind == "nan_grad"
                                 for _, ev in fired_now))
                bsz = next(iter(batch.values())).shape[0]
                batch["_inject"] = jnp.full((bsz,), flag, jnp.float32)
            # ISR (alpha) gate: off-iterations dispatch the entropy-off
            # step variant, so the skipped measurements never lower any
            # device work (§IV-B's "fraction of iterations" sampling).
            measure = tcfg.measure_entropy and ctrl.wants_entropy(step_idx)
            step_fn = self._get_step(measure)
            self.state, mets = step_fn(self.state, batch)

            self.bytes_synced += comp_bytes
            self.bytes_wire_raw += raw_bytes
            self.bytes_full += full_bytes

            step_ok = True
            if rs is not None:
                loss = float(mets["loss"])
                skipped = float(mets.get("skipped", 0.0)) > 0.5
                if skipped:
                    # The compiled guard already refused the update; the
                    # compressor warm-start/EF may still hold the garbage
                    # that caused it (corrupted payload), so reset it.
                    rs.skipped_steps += 1
                    rs.anomalies += 1
                    self.metrics.event("guard_skip", step=step_idx,
                                       loss=loss)
                    self._reset_comp_state()
                    rs.ef_resets += 1
                    self.metrics.counter("ef_resets", step=step_idx)
                    self.metrics.event("ef_reset", step=step_idx)
                    step_ok = False
                elif not np.isfinite(loss):
                    rs.anomalies += 1
                    step_ok = False
                    rolled = self._maybe_rollback()
                    if rolled is not None:
                        self.metrics.event("rollback", step=step_idx,
                                           restored_step=int(rolled))
                        self._maybe_fallback(ctrl)
                        comp_bytes, raw_bytes, full_bytes = self._price_plan()
                        stage_b = self.stage_bytes()
                        step_idx = rolled
                        continue
                else:
                    armed = (self._ema_seen >= rcfg.spike_warmup
                             and step_idx >= rs.backoff_until)
                    if (armed and rs.loss_ema is not None and rcfg.rollback
                            and loss > rcfg.spike_factor
                            * max(rs.loss_ema, 1e-8)):
                        rs.anomalies += 1
                        rolled = self._maybe_rollback()
                        if rolled is not None:
                            self.metrics.event("rollback", step=step_idx,
                                               restored_step=int(rolled),
                                               spike_loss=loss)
                            self._maybe_fallback(ctrl)
                            comp_bytes, raw_bytes, full_bytes = \
                                self._price_plan()
                            stage_b = self.stage_bytes()
                            step_idx = rolled
                            continue
                    rs.loss_ema = (loss if rs.loss_ema is None else
                                   rcfg.ema_decay * rs.loss_ema
                                   + (1 - rcfg.ema_decay) * loss)
                    self._ema_seen += 1
                if self._maybe_fallback(ctrl):
                    comp_bytes, raw_bytes, full_bytes = self._price_plan()
                    stage_b = self.stage_bytes()
                if step_ok and not self._last_step_ok:
                    self.metrics.event("recovered", step=step_idx)
                self._last_step_ok = step_ok

            # Buffer this step's device metrics + host-side snapshots; the
            # host reads (on_entropy, history, telemetry) happen in-order at
            # the next flush boundary. Snapshots are taken NOW because the
            # cumulative byte ledgers and rank plan advance under the buffer.
            pending.append((
                step_idx, measure and step_ok, mets,
                self.bytes_synced, self.bytes_wire_raw, self.bytes_full,
                stage_b,
                ctrl.dac.current_ranks() if not ctrl.in_warmup else [],
                rs.as_dict() if rs is not None else None,
                time.time() - t0,
            ))

            at_window = (step_idx + 1) % window == 0
            logged = (step_idx % tcfg.log_every == 0
                      or step_idx == tcfg.total_steps - 1)
            at_ckpt = bool(tcfg.ckpt_every
                           and (step_idx + 1) % tcfg.ckpt_every == 0)
            if at_window or logged or at_ckpt:
                # Window ends flush BEFORE on_window_end so every gated
                # entropy reading in the window reaches the DAC; records
                # therefore snapshot the plan the step actually ran under.
                self._flush_pending(pending, t0)

            if at_window:
                plan_changed = ctrl.on_window_end(step_idx)
                if plan_changed:
                    self._apply_plan_change()
                    self.metrics.event(
                        "plan_change", step=step_idx,
                        ranks=ctrl.dac.current_ranks())
                # entropy-mode wire coding re-picks its bit width here,
                # on the same cadence as plan changes (one recompile max
                # per window)
                if self._refresh_codec():
                    plan_changed = True
                    self.metrics.event(
                        "wire_codec", step=step_idx,
                        bits=int(self._codec.bits),
                        entropy=self._last_entropy)
                if plan_changed:
                    comp_bytes, raw_bytes, full_bytes = self._price_plan()
                    stage_b = self.stage_bytes()

            if at_ckpt:
                path = f"{tcfg.ckpt_path}_{step_idx+1}"
                self.save_checkpoint(path, step=step_idx + 1)
                self.metrics.event("checkpoint", step=step_idx, path=path)
                if self._tear_next_ckpt:
                    # torn_ckpt fault: simulate a crash mid-write AFTER the
                    # save completed — the atomic-rename path cannot tear,
                    # so the injector truncates the archive in place.
                    from repro.train.faults import truncate_file
                    truncate_file(path + ".npz")
                    self._tear_next_ckpt = False
                self._ring_push(path, step_idx + 1)
            step_idx += 1
        self._flush_pending(pending, t0)
        self._global_step = end
        return self.history

    def _flush_pending(self, pending: list[tuple], t0: float) -> None:
        """Drain the deferred-metrics buffer: ONE batched device sync, then
        in-order host processing (controller entropy feed, history records,
        telemetry emission) and a registry flush."""
        if pending:
            jax.block_until_ready([m["loss"] for (_, _, m, *_rest) in pending])
        tcfg, ctrl = self.tcfg, self.controller
        for (s_i, meas, m, b_syn, b_raw, b_full, st_b, ranks, rec_rs,
             wall) in pending:
            if meas:
                self._last_entropy = float(m["entropy"])
                if "stage_entropy" in m:
                    self._last_stage_entropy = [
                        float(h) for h in np.asarray(m["stage_entropy"])]
                ctrl.on_entropy(s_i, self._last_entropy)
            if s_i % tcfg.log_every == 0 or s_i == tcfg.total_steps - 1:
                rec = {
                    "step": s_i,
                    "loss": float(m["loss"]),
                    # zero-order hold: off-gate steps report the most
                    # recent alpha-gated reading, not the step's 0.0
                    # placeholder (the sampled trajectory stays usable)
                    "entropy": self._last_entropy,
                    "grad_norm": float(m["grad_norm"]),
                    "lr": float(m["lr"]),
                    "bytes_synced": b_syn,
                    "bytes_full": b_full,
                    "stage_bytes": st_b,
                    "ranks": ranks,
                    "wall_s": wall,
                }
                if b_raw != b_syn:      # wire coding active
                    rec["bytes_wire_raw"] = b_raw
                if rec_rs is not None:
                    rec["recovery"] = rec_rs
                self.history.append(rec)
                self._emit_step_telemetry(s_i, m, b_syn, b_raw, b_full,
                                          st_b, ranks, wall)
        pending.clear()
        self.metrics.flush()

    def _emit_step_telemetry(self, s_i: int, m: dict, b_syn: int,
                             b_raw: int, b_full: int, st_b, ranks,
                             wall: float) -> None:
        """One logged step's structured records (values already on host)."""
        reg = self.metrics
        reg.scalar("loss", float(m["loss"]), s_i)
        reg.scalar("entropy", self._last_entropy, s_i)
        reg.scalar("grad_norm", float(m["grad_norm"]), s_i)
        reg.scalar("lr", float(m["lr"]), s_i)
        if "ef_norm" in m:
            reg.scalar("ef_norm", float(m["ef_norm"]), s_i)
        reg.scalar("bytes_synced", int(b_syn), s_i)
        reg.scalar("bytes_full", int(b_full), s_i)
        if b_syn:
            reg.scalar("compression_ratio", b_full / b_syn, s_i)
        if self.sync_cfg.wire != "raw":
            # coded vs raw payload bytes: the measured wire-format
            # reduction, orthogonal to the rank-compression ratio above
            reg.scalar("wire_bytes_coded", int(b_syn), s_i)
            reg.scalar("wire_bytes_raw", int(b_raw), s_i)
            if b_raw:
                reg.scalar("wire_reduction", b_syn / b_raw, s_i)
            if self._codec is not None:
                reg.scalar("wire_bits", int(self._codec.bits), s_i)
        reg.scalar("wall_s", wall, s_i)
        reg.series("stage_wire_bytes", [int(c) for c, _ in st_b], s_i)
        reg.series("stage_wire_bytes_full", [int(f) for _, f in st_b], s_i)
        if ranks:
            reg.series("dac_applied_ranks", [int(r) for r in ranks], s_i)
            cqm = self.controller.cqm
            if cqm.anchored:
                reg.series("cqm_error",
                           [float(cqm.error_at(int(r))) for r in ranks], s_i)
        if self._last_stage_entropy is not None:
            # same zero-order hold as the pooled reading: off-gate steps
            # report the most recent measured per-stage vector
            reg.series("stage_entropy", list(self._last_stage_entropy), s_i)

    # ------------------------------------------------------------- recovery
    def _ring_push(self, path: str, step: int) -> None:
        keep = (self.tcfg.recovery.ckpt_ring
                if self.tcfg.recovery is not None else 3)
        self._ckpt_ring.append((path, step))
        del self._ckpt_ring[:-keep]

    def _maybe_rollback(self) -> int | None:
        """Try the ring newest-to-oldest; returns the restored step or None.

        A torn newest checkpoint (CheckpointError) falls through to the
        next older one — the atomic-save + nonce machinery is what makes
        this safe.
        """
        rcfg, rs = self.tcfg.recovery, self.recovery
        if not (rcfg.rollback and rs.rollbacks < rcfg.max_rollbacks):
            return None
        while self._ckpt_ring:
            path, _ = self._ckpt_ring[-1]
            try:
                restored = self.restore_checkpoint(path, load_recovery=False)
            except ckpt_mod.CheckpointError:
                self._ckpt_ring.pop()
                continue
            rs.rollbacks += 1
            rs.backoff_until = restored + rcfg.backoff_steps
            rs.loss_ema = None          # re-warm the spike detector
            self._ema_seen = 0
            return restored
        return None

    def _maybe_fallback(self, ctrl) -> bool:
        """After ``fallback_after`` anomalies, pin to uncompressed sync."""
        rcfg, rs = self.tcfg.recovery, self.recovery
        if rs.fallback or rs.anomalies < rcfg.fallback_after:
            return False
        rs.fallback = True
        if ctrl.force_fallback():
            self._apply_plan_change()
            return True
        return False

    def _reset_comp_state(self) -> None:
        """Fresh compressor state under the current plan (EF reset).

        Wholesale re-init rather than surgical repair: after a corrupted
        payload there is no trustworthy row to keep, and the warm-start Q
        must be identical across workers anyway.
        """
        if self.pipelined:
            raise RuntimeError("EF reset requires the flat trainer")
        fresh = init_compressor_state(self.state["params"],
                                      self.controller.plan, self._comp_key,
                                      layout=self._layout,
                                      wire_ef=self._codec is not None)
        comp = replicate_comp_state(fresh, self.world)
        self.state = dict(self.state)
        self.state["comp"] = comp
        self._shard_state()

    def _poison_comp_state(self) -> None:
        """corrupt_payload fault: NaN-poison the compressor state."""
        from repro.train.faults import poison_lowrank_state
        comp_host = jax.device_get(self.state["comp"])
        self.state = dict(self.state)
        self.state["comp"] = poison_lowrank_state(comp_host)
        self._shard_state()

    # --------------------------------------------------------- checkpointing
    def save_checkpoint(self, path: str, step: int | None = None) -> None:
        """Device tree + the host control plane (controller/DAC/CQM state).

        The ``extra`` dict carries everything the window loop mutates, so a
        resumed run continues mid-window instead of silently restarting
        warm-up (paper §IV-D2: warm-up is a once-per-run phase).
        """
        extra = {
            "step": int(step if step is not None
                        else getattr(self, "_global_step", 0)),
            "bytes_synced": int(self.bytes_synced),
            "bytes_wire_raw": int(self.bytes_wire_raw),
            "bytes_full": int(self.bytes_full),
            "controller": self.controller.state_dict(),
            "metrics": self.metrics.state_dict(),
        }
        if self.recovery is not None:
            extra["recovery"] = self.recovery.as_dict()
        ckpt_mod.save(path, self.state, extra=extra)

    def restore_checkpoint(self, path: str, load_recovery: bool = True) -> int:
        """Restore device tree + control plane; returns the global step.

        Order matters: the controller state (and with it the compression
        plan) is restored FIRST, the state template is re-shaped to that
        plan, and only then are the arrays loaded into it.

        ``load_recovery=False`` keeps the live recovery counters (rollback
        must not rewind its own retry budget).
        """
        extra = ckpt_mod.read_extra(path)
        if "controller" in extra:
            self.controller.load_state_dict(extra["controller"])
            self._apply_plan_change()     # reshape comp state to the plan
        if load_recovery and self.recovery is not None and "recovery" in extra:
            from repro.train.faults import RecoveryState
            self.recovery = RecoveryState.from_dict(extra["recovery"])
        from repro.obs.metrics import MetricsRegistry as _Registry
        if (load_recovery and "metrics" in extra
                and isinstance(self.metrics, _Registry)):
            # Telemetry cursor: a resumed run appends to its series instead
            # of restarting at step 0. In-run rollback (load_recovery=False)
            # keeps the LIVE registry — the telemetry already written is
            # real history, not state to rewind. Tagged pod views skip the
            # load too: the fleet owner (ElasticTrainer) restores the shared
            # cursor exactly once.
            self.metrics.load_state_dict(extra["metrics"])
        self.bytes_synced = int(extra.get("bytes_synced", 0))
        self.bytes_wire_raw = int(extra.get("bytes_wire_raw", 0))
        self.bytes_full = int(extra.get("bytes_full", 0))
        self._global_step = int(extra.get("step", 0))
        # re-seed the zero-order hold so post-resume off-gate history
        # records carry the last real reading, not the 0.0 init
        hist = self.controller.entropy_history
        self._last_entropy = float(hist[-1][1]) if hist else 0.0
        # entropy-mode wire coding re-derives its reference (the run's
        # first reading) and current bit width from the restored history
        self._wire_ref_entropy = None
        self._refresh_codec()
        restored, _ = ckpt_mod.restore(path, jax.device_get(self.state))
        self.state = restored
        self._shard_state()
        return self._global_step

    # --------------------------------------------------------------- summary
    def stage_bytes(self) -> list[tuple[int, int]]:
        """Per-stage (compressed, full) DP-sync bytes under the current plan
        — the Algorithm-2 ledger (sums to ``plan_wire_bytes``)."""
        from repro.pipeline.sync import stage_wire_bytes
        return stage_wire_bytes(self.leaves, self.controller.plan,
                                max(1, self.edgc_cfg.num_stages),
                                codec=self._codec)

    def comm_savings(self) -> float:
        """Fraction of DP-sync bytes saved vs no compression (Table III)."""
        if self.bytes_full == 0:
            return 0.0
        return 1.0 - self.bytes_synced / self.bytes_full
