"""Pipeline-parallel execution subsystem (paper §IV-D, Algorithm 2).

The control plane (``core/dac.py``) has always emitted stage-aligned rank
vectors; this package is the execution layer that makes them real: the
per-family ``StageAdapter`` registry and stage partitioning of a model's
parameters (``adapters`` / ``partition``), GPipe / 1F1B microbatch
schedules over a ``pipe`` mesh axis (``schedule``), and the per-stage
data-parallel gradient sync that applies one DAC rank per stage
(``sync``).
"""
from .adapters import StageAdapter, adapter_families, register_adapter
from .partition import (
    PipelinePartition,
    make_partition,
    merge_params,
    partition_params,
    pipeline_supported,
)
from .schedule import (
    SCHEDULES,
    STASH_POLICIES,
    bubble_fraction,
    make_pipeline_train_step,
    peak_activation_bytes,
    peak_inflight,
    policy_tick_cost,
    simulate_schedule,
    slot_table,
    stash_points,
    stash_segments,
)
from .sync import (
    StagePlans,
    init_pipeline_comp_state,
    make_stage_plans,
    resize_pipeline_comp_state,
    stage_sync_grads,
    stage_wire_bytes,
)

__all__ = [
    "StageAdapter", "adapter_families", "register_adapter",
    "PipelinePartition", "make_partition", "merge_params",
    "partition_params", "pipeline_supported",
    "SCHEDULES", "STASH_POLICIES", "bubble_fraction",
    "make_pipeline_train_step", "peak_activation_bytes", "peak_inflight",
    "policy_tick_cost", "simulate_schedule", "slot_table",
    "stash_points", "stash_segments",
    "StagePlans", "init_pipeline_comp_state", "make_stage_plans",
    "resize_pipeline_comp_state", "stage_sync_grads", "stage_wire_bytes",
]
