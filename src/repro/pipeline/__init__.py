"""Pipeline-parallel execution subsystem (paper §IV-D, Algorithm 2).

The control plane (``core/dac.py``) has always emitted stage-aligned rank
vectors; this package is the execution layer that makes them real: the
per-family ``StageAdapter`` registry and stage partitioning of a model's
parameters (``adapters`` / ``partition``), GPipe / 1F1B microbatch
schedules over a ``pipe`` mesh axis (``schedule``), and the per-stage
data-parallel gradient sync that applies one DAC rank per stage
(``sync``). ``PipelineConfig`` (``config``) is the one config surface the
trainer, step builder, and EDGC controller share for these knobs;
``plan_overlap`` / ``OverlapPlan`` (``schedule``) interleave the sync with
the schedule's drain ticks.
"""
from .adapters import StageAdapter, adapter_families, register_adapter
from .config import PipelineConfig
from .partition import (
    PipelinePartition,
    make_partition,
    merge_params,
    partition_params,
    pipeline_supported,
)
from .schedule import (
    SCHEDULES,
    STASH_POLICIES,
    OverlapPlan,
    bubble_fraction,
    last_backward_tick,
    make_pipeline_train_step,
    peak_activation_bytes,
    peak_inflight,
    plan_overlap,
    policy_tick_cost,
    simulate_schedule,
    slot_table,
    stash_points,
    stash_segments,
    sync_ticks,
)
from .sync import (
    StagePlans,
    init_pipeline_comp_state,
    make_stage_plans,
    resize_pipeline_comp_state,
    stage_sync_chunks,
    stage_sync_grads,
    stage_wire_bytes,
    sync_shared_grads,
)

__all__ = [
    "StageAdapter", "adapter_families", "register_adapter",
    "PipelineConfig",
    "PipelinePartition", "make_partition", "merge_params",
    "partition_params", "pipeline_supported",
    "SCHEDULES", "STASH_POLICIES", "OverlapPlan", "bubble_fraction",
    "last_backward_tick", "make_pipeline_train_step",
    "peak_activation_bytes", "peak_inflight", "plan_overlap",
    "policy_tick_cost", "simulate_schedule", "slot_table",
    "stash_points", "stash_segments", "sync_ticks",
    "StagePlans", "init_pipeline_comp_state", "make_stage_plans",
    "resize_pipeline_comp_state", "stage_sync_chunks", "stage_sync_grads",
    "stage_wire_bytes", "sync_shared_grads",
]
