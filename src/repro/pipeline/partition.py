"""Stage partitioning: split a Model's params into S pipeline stages.

The dense families already lay their transformer blocks out per virtual
stage (``params["stages"][s]["blocks"]`` with every leaf stacked over the
stage's layers), so partitioning is a relayout, not a re-trace:

  * **stage params** — the S per-stage block subtrees stacked into one tree
    whose leaves carry a leading stage dim ``(S, L/S, ...)``. Sharded over
    the ``pipe`` mesh axis, each pipeline rank holds exactly its stage.
  * **shared params** — everything else (embeddings, positional table,
    final norm, LM head). Replicated over ``pipe``; the schedule uses the
    embedding only on stage 0 and the head only on stage S-1, and their
    gradients are psum'd over ``pipe`` (all other ranks contribute zeros).

Ownership follows the same ``_layer_stage`` mapping the compressor uses
(``core/compressor.py``): block leaves go to their ``['stages'][s]`` index,
embeddings pin to stage 0, head/final-norm to stage S-1 — so the DAC's
per-stage rank vector and the physical layout agree leaf-for-leaf.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.model import Model, ModelConfig

__all__ = [
    "PipelinePartition",
    "make_partition",
    "merge_params",
    "partition_params",
    "pipeline_supported",
    "local_leaf_path",
    "global_leaf_path",
]

_STAGE_PREFIX = re.compile(r"^\['stages'\]\[(\d+)\]")


def pipeline_supported(cfg: ModelConfig, num_stages: int) -> str | None:
    """None if the config can run the pipeline executor, else the reason."""
    if num_stages <= 0:
        return f"num_stages={num_stages} must be >= 1"
    if cfg.family != "dense":
        return (f"family {cfg.family!r} has no stage-partition adapter yet "
                "(dense only)")
    if cfg.num_stages != num_stages:
        return (f"model was built with num_stages={cfg.num_stages}, "
                f"pipeline wants {num_stages}; rebuild the model config")
    if cfg.num_layers % num_stages != 0:
        return (f"num_layers={cfg.num_layers} not divisible by "
                f"num_stages={num_stages}: stages would be ragged and could "
                "not stack over the pipe axis")
    return None


def global_leaf_path(stage: int, local_path: str) -> str:
    """Stage-local keystr -> the flat-layout keystr the plans use."""
    return f"['stages'][{stage}]{local_path}"


def local_leaf_path(path: str) -> tuple[int, str] | None:
    """Flat-layout keystr -> (stage, stage-local keystr); None if shared."""
    m = _STAGE_PREFIX.match(path)
    if m is None:
        return None
    return int(m.group(1)), path[m.end():]


def partition_params(params: Any, num_stages: int) -> tuple[Any, Any]:
    """Split a flat param tree into (stage_stacked, shared).

    ``stage_stacked`` is the blocks tree with every leaf stacked over a new
    leading stage dim; ``shared`` is the remainder with its original keys
    (so the model family's embed/head functions apply to it unchanged).
    """
    stages = params["stages"]
    if len(stages) != num_stages:
        raise ValueError(
            f"param layout has {len(stages)} stages, expected {num_stages}")
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[st["blocks"] for st in stages])
    shared = {k: v for k, v in params.items() if k != "stages"}
    return {"blocks": stacked}, shared


def merge_params(stage_stacked: Any, shared: Any, num_stages: int) -> Any:
    """Inverse of :func:`partition_params` — back to the flat layout."""
    params = dict(shared)
    params["stages"] = [
        {"blocks": jax.tree_util.tree_map(lambda a: a[s],
                                          stage_stacked["blocks"])}
        for s in range(num_stages)
    ]
    return params


@dataclasses.dataclass(frozen=True)
class PipelinePartition:
    """Static stage-partition description + the per-stage model functions.

    The three callables are the units the schedule executes on every pipe
    rank (SPMD: each rank applies them to ITS stage's params / the shared
    tree; embed and head_loss results are masked off non-boundary ranks):

      * ``embed(shared, tokens) -> x``           (b, T) -> (b, T, D)
      * ``blocks(stage_blocks, x) -> y``         one stage's scanned blocks
      * ``head_loss(shared, y, labels) -> loss`` final norm + logits + CE
    """

    num_stages: int
    d_model: int
    dtype: Any
    embed: Callable[[Any, jax.Array], jax.Array]
    blocks: Callable[[Any, jax.Array], jax.Array]
    head_loss: Callable[[Any, jax.Array, jax.Array], jax.Array]

    def boundary_spec(self, micro_batch: int, seq_len: int):
        """ShapeDtype of one boundary activation (what ppermute moves)."""
        return jax.ShapeDtypeStruct(
            (micro_batch, seq_len, self.d_model), self.dtype)


def make_partition(model: Model, num_stages: int,
                   remat: bool | None = None) -> PipelinePartition:
    """Build the stage adapter for a model (dense family only for now)."""
    cfg = model.config
    reason = pipeline_supported(cfg, num_stages)
    if reason is not None:
        raise ValueError(f"pipeline partition unsupported: {reason}")

    from repro.models import layers as L
    from repro.models import transformer as T

    def embed(shared, tokens):
        return T.embed_tokens(shared, tokens, cfg)

    def blocks(stage_blocks, x):
        B, T_len = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(T_len), (B, T_len))
        return T.apply_block_stack(stage_blocks["blocks"], x, cfg, positions,
                                   window=cfg.sliding_window, remat=remat)

    def head_loss(shared, y, labels):
        logits = T.final_logits(shared, y, cfg)
        return L.cross_entropy(logits, labels)

    return PipelinePartition(
        num_stages=num_stages,
        d_model=cfg.d_model,
        dtype=cfg.jdtype,
        embed=embed,
        blocks=blocks,
        head_loss=head_loss,
    )
