"""Stage partitioning: split a Model's params into S pipeline stages.

Every family lays its stage-assignable parameters under
``params['stages'][s]`` (dense/MoE blocks, xLSTM pairs, Zamba mamba runs,
whisper enc/dec blocks), so partitioning is a relayout, not a re-trace:

  * **stage params** — the S per-stage stacks stacked into one tree whose
    leaves carry a leading stage dim ``(S, Lmax, ...)``, zero-padded where
    a stage owns fewer units than the widest stage (ragged/hybrid plans).
    Sharded over the ``pipe`` mesh axis, each pipeline rank holds exactly
    its stage.
  * **shared params** — everything else (embeddings, positional tables,
    final norms, heads, Zamba's shared attention block). Replicated over
    ``pipe``; the schedule uses each piece only on the stages that own it
    and their gradients are psum'd over ``pipe`` (other ranks contribute
    zeros).

WHICH units land on which stage, what the boundary activation looks like,
how a stage computes, and the stash granularity the executor's selective
activation stashing cuts at (``num_units`` / ``stash_spec`` /
``blocks_segment`` — see the stash contract in ``adapters.py``) are
family decisions owned by the
:class:`~repro.pipeline.adapters.StageAdapter` registry —
``make_partition`` returns the family's adapter instance (``remat``
False runs the stage scans un-remat'ed, which the stashed policies use
to bound residual spans by the segment instead). Ownership
follows the same ``_layer_stage`` mapping the compressor uses
(``core/compressor.py``): ``['stages'][s]`` leaves go to their stage
index, embeddings pin to stage 0, head/final-norm to stage S-1 — so the
DAC's per-stage rank vector and the physical layout agree leaf-for-leaf.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import Model, ModelConfig
from repro.pipeline.adapters import (
    StageAdapter,
    global_leaf_path,
    local_leaf_path,
    make_adapter,
    supported_reason,
)

__all__ = [
    "PipelinePartition",
    "make_partition",
    "merge_params",
    "partition_params",
    "pipeline_supported",
    "local_leaf_path",
    "global_leaf_path",
]

# The partition object IS the family's stage adapter (embed/blocks/head
# closures, boundary spec, partition/merge, path mapping).
PipelinePartition = StageAdapter


def pipeline_supported(cfg: ModelConfig, num_stages: int) -> str | None:
    """None if the config can run the pipeline executor, else the reason.

    The reason string comes from the family's own stage adapter (or names
    the missing adapter), so callers — ``dryrun --pipe`` in particular —
    can surface exactly what is unsupported instead of a generic message.
    """
    return supported_reason(cfg, num_stages)


def make_partition(model: Model, num_stages: int,
                   remat: bool | None = None) -> PipelinePartition:
    """Build the stage adapter for a model's family (see adapters.py)."""
    return make_adapter(model, num_stages, remat)


def partition_params(params: Any, num_stages: int) -> tuple[Any, Any]:
    """Uniform-layout split of ``params['stages']`` into (stacked, shared).

    Family-agnostic helper for trees whose stages share one treedef and
    equal stack sizes (the dense/MoE common case, and every test oracle).
    Production code paths go through the adapter's ``partition_params``,
    which also handles ragged stages and per-family stack keys.
    """
    stages = params["stages"]
    if len(stages) != num_stages:
        raise ValueError(
            f"param layout has {len(stages)} stages, expected {num_stages}")
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *list(stages))
    shared = {k: v for k, v in params.items() if k != "stages"}
    return stacked, shared


def merge_params(stage_stacked: Any, shared: Any, num_stages: int) -> Any:
    """Inverse of :func:`partition_params` — back to the flat layout."""
    params = dict(shared)
    params["stages"] = [
        jax.tree_util.tree_map(lambda a: a[s], stage_stacked)
        for s in range(num_stages)
    ]
    return params
