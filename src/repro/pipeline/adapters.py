"""Per-family stage adapters — the pipeline-partition contract.

Mirrors ``models/model.py``'s ``register_family``: every model family that
can run the pipeline executor registers a :class:`StageAdapter` subclass
here. The adapter owns everything the executor used to assume was "dense
GPT-2 shaped":

  * the **support check** (``check``) — a family-specific reason string
    when a config cannot be pipelined (surfaced verbatim by
    ``pipeline_supported`` and ``dryrun --pipe``);
  * the **layer->stage assignment** (``unit_counts``) — how many stacked
    units (dense/MoE blocks, xLSTM pairs, Mamba2 layers, enc/dec blocks)
    each stage owns. Counts may be RAGGED (hybrid stages must take whole
    attention groups; 1F1B still needs one SPMD program), so the generic
    ``partition_params`` zero-pads every stage's stacks to the max count
    and the compute closures mask the dead slices per rank;
  * the **stage-stacked / shared split** (``partition_params`` /
    ``merge_params``) — stacked leaves lead with (S, Lmax, ...) and shard
    over the ``pipe`` mesh axis; everything else (embeddings, heads,
    norms, Zamba's shared attention block) replicates;
  * the **compute closures** (``embed`` / ``blocks_segment`` /
    ``head_loss``) the schedule executes every tick, SPMD-uniform across
    ranks — ``blocks_segment`` runs a static span ``[lo, hi)`` of the
    stage's scan units and returns ``(boundary_out, aux_loss)`` so
    per-stage auxiliary losses (the MoE router balance term) reach the
    total without a second collective; ``blocks`` is the full-stage span;
  * the **stash contract** (``num_units`` / ``stash_spec``) — the
    executor's selective activation stashing cuts the stage at unit
    boundaries: the family says how many segmentable units a rank scans
    (dense/MoE block, xLSTM pair, Zamba group slot, whisper enc/dec
    block — SPMD-uniform, i.e. the WIDEST stage's count) and what one
    stashed inter-unit carry looks like (the boundary pytree, for every
    current family). Chaining ``blocks_segment`` over any partition of
    ``[0, num_units)`` must reproduce ``blocks`` (aux summed);
  * the **boundary-activation spec** (``boundary_spec``) — an arbitrary
    pytree; the enc-dec adapter ships two channels (the frozen encoder
    memory rides along the decoder stages for cross-attention).

All families lay their stage-assignable parameters under
``params['stages'][i]``, so the local<->global leaf-path mapping
(``local_leaf_path`` / ``global_leaf_path``) is one shared regex — which
is also what keeps ``core/compressor.py``'s ``_layer_stage`` and the DAC's
per-stage rank vectors agreeing with the physical layout for every family.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.model import Model, ModelConfig

__all__ = [
    "StageAdapter",
    "register_adapter",
    "adapter_families",
    "supported_reason",
    "make_adapter",
    "global_leaf_path",
    "local_leaf_path",
]

_STAGE_PREFIX = re.compile(r"^\['stages'\]\[(\d+)\]")

F32 = jnp.float32


def global_leaf_path(stage: int, local_path: str) -> str:
    """Stage-local keystr -> the flat-layout keystr the plans use."""
    return f"['stages'][{stage}]{local_path}"


def local_leaf_path(path: str) -> tuple[int, str] | None:
    """Flat-layout keystr -> (stage, stage-local keystr); None if shared."""
    m = _STAGE_PREFIX.match(path)
    if m is None:
        return None
    return int(m.group(1)), path[m.end():]


# -------------------------------------------------------------------- registry
_REGISTRY: dict[str, type["StageAdapter"]] = {}


def register_adapter(*families: str):
    def deco(cls):
        for f in families:
            _REGISTRY[f] = cls
        cls.family = families[0]
        return cls
    return deco


def adapter_families() -> list[str]:
    return sorted(_REGISTRY)


def supported_reason(cfg: ModelConfig, num_stages: int) -> str | None:
    """None if (family, config) can run the pipeline executor, else why not.

    The reason comes from the family's own adapter — not a generic
    "dense only" message — so ``dryrun --pipe`` can say exactly what is
    missing for a given config.
    """
    if num_stages <= 0:
        return f"num_stages={num_stages} must be >= 1"
    cls = _REGISTRY.get(cfg.family)
    if cls is None:
        return (f"family {cfg.family!r} has no stage adapter "
                f"(registered: {adapter_families()})")
    return cls.check(cfg, num_stages)


def make_adapter(model: Model, num_stages: int,
                 remat: bool | None = None) -> "StageAdapter":
    reason = supported_reason(model.config, num_stages)
    if reason is not None:
        raise ValueError(f"pipeline partition unsupported: {reason}")
    return _REGISTRY[model.config.family](model, num_stages, remat)


# ------------------------------------------------------------------ base class
class StageAdapter:
    """Family-agnostic machinery; subclasses fill in the family contract.

    Instances are built per (model, num_stages) by :func:`make_adapter`
    and are what ``pipeline/partition.py``'s ``make_partition`` returns.
    """

    family = ""

    def __init__(self, model: Model, num_stages: int,
                 remat: bool | None = None) -> None:
        self.model = model
        self.cfg = model.config
        self.num_stages = num_stages
        self.remat = self.cfg.remat if remat is None else remat
        self._counts = {k: tuple(v) for k, v in self.unit_counts().items()}
        # (S, Lmax) live-unit masks, None for uniform (non-ragged) stacks
        self._masks: dict[str, np.ndarray | None] = {}
        for key, per in self._counts.items():
            lmax = max(per)
            if all(c == lmax for c in per):
                self._masks[key] = None
            else:
                self._masks[key] = (np.arange(lmax)[None, :]
                                    < np.asarray(per)[:, None])

    # ---- family contract (override) ------------------------------------
    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        raise NotImplementedError

    def unit_counts(self) -> dict[str, list[int]]:
        """stack-key -> stacked units per stage (pure function of cfg)."""
        raise NotImplementedError

    def embed(self, shared: Any, mb: dict) -> Any:
        """Stage-0 boundary input from one microbatch."""
        raise NotImplementedError

    def blocks_segment(self, stage_tree: Any, shared: Any, boundary: Any,
                       s_idx, lo: int, hi: int) -> tuple[Any, jax.Array]:
        """Units ``[lo, hi)`` of one stage: boundary -> (boundary, aux).

        ``lo``/``hi`` are STATIC unit indices (the stash schedule is
        trace-time); chaining segments over a partition of
        ``[0, num_units)`` with the aux contributions summed must equal
        ``blocks`` — that contract is what lets the executor's backward
        replay only the un-stashed spans.
        """
        raise NotImplementedError

    def blocks(self, stage_tree: Any, shared: Any, boundary: Any,
               s_idx) -> tuple[Any, jax.Array]:
        """One stage's full compute: boundary -> (boundary, aux loss)."""
        return self.blocks_segment(stage_tree, shared, boundary, s_idx,
                                   0, self.num_units())

    def num_units(self) -> int:
        """Stash-segmentable scan units per rank (the widest stage's count
        — SPMD uniformity; narrower stages mask their padded tail).

        Default covers the single-stack families (dense/vlm/moe/xlstm);
        zamba (group slots) and whisper (enc + dec halves) override.
        """
        assert len(self._counts) == 1, "multi-stack family must override"
        (per,) = self._counts.values()
        return max(per)

    def stash_spec(self, mb: dict) -> Any:
        """ShapeDtype pytree of ONE stashed inter-unit carry.

        For every current family the scan carry IS the boundary
        activation, so the stash ring reuses ``boundary_spec``; a family
        whose units carry extra state would widen this (and
        ``blocks_segment`` would thread it).
        """
        return self.boundary_spec(mb)

    def head_loss(self, shared: Any, boundary: Any, mb: dict) -> jax.Array:
        """Last-stage loss from the final boundary."""
        raise NotImplementedError

    def boundary_spec(self, mb: dict) -> Any:
        """ShapeDtype pytree of one boundary activation (what ppermute
        moves). Default: one (b, T, d_model) hidden-state array."""
        b, t = mb["tokens"].shape
        return jax.ShapeDtypeStruct((b, t, self.cfg.d_model), self.cfg.jdtype)

    # ---- path mapping (shared ['stages'][i] convention) -----------------
    local_leaf_path = staticmethod(local_leaf_path)
    global_leaf_path = staticmethod(global_leaf_path)

    # ---- generic stage-stacked layout -----------------------------------
    def stage_flags(self, key: str, s_idx) -> jax.Array | None:
        """Per-rank (Lmax,) live mask for a stack, None when uniform."""
        m = self._masks[key]
        if m is None:
            return None
        return jnp.take(jnp.asarray(m), s_idx, axis=0)

    def partition_params(self, params: Any) -> tuple[Any, Any]:
        """Split a flat param tree into (stage_stacked, shared).

        ``stage_stacked`` holds every ``['stages'][i]`` stack with a new
        leading stage dim (S, Lmax, ...), zero-padded where a stage owns
        fewer units than the widest stage; ``shared`` is the remainder
        with its original keys.
        """
        stages = params["stages"]
        if len(stages) != self.num_stages:
            raise ValueError(f"param layout has {len(stages)} stages, "
                             f"expected {self.num_stages}")
        stacked = {}
        for key, per in self._counts.items():
            lmax = max(per)
            ref = next(st[key] for st, c in zip(stages, per) if c)

            def one(st, c):
                if c == 0:
                    return jax.tree_util.tree_map(
                        lambda a: jnp.zeros((lmax,) + a.shape[1:], a.dtype),
                        ref)
                tree = st[key]
                lead = jax.tree_util.tree_leaves(tree)[0].shape[0]
                if lead != c:
                    raise ValueError(
                        f"stack {key!r}: param leading dim {lead} != "
                        f"adapter count {c} (layout/config mismatch)")
                if c == lmax:
                    return tree
                return jax.tree_util.tree_map(
                    lambda a: jnp.pad(
                        a, [(0, lmax - c)] + [(0, 0)] * (a.ndim - 1)), tree)

            stacked[key] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[one(st, c) for st, c in zip(stages, per)])
        shared = {k: v for k, v in params.items() if k != "stages"}
        return stacked, shared

    def merge_params(self, stage_stacked: Any, shared: Any) -> Any:
        """Inverse of :func:`partition_params` — back to the flat layout."""
        stages = []
        for s in range(self.num_stages):
            st = {}
            for key, per in self._counts.items():
                c = per[s]
                if c == 0:
                    continue
                st[key] = jax.tree_util.tree_map(
                    lambda a: a[s, :c], stage_stacked[key])
            stages.append(st)
        params = dict(shared)
        params["stages"] = stages
        return params

    # ---- scan helpers ----------------------------------------------------
    @staticmethod
    def _slice_units(tree: Any, lo: int, hi: int) -> Any:
        """Static unit-span slice of a stage-local stack (leading dim)."""
        return jax.tree_util.tree_map(lambda a: a[lo:hi], tree)

    def _masked_scan(self, body, carry, xs, flags):
        """Scan ``body`` over stacked units; dead (padded) units pass the
        carry through unchanged. ``flags=None`` is the uniform fast path
        (no selects in the loop body)."""
        if flags is None:
            def step(c, x):
                return body(c, x), None
            xs_all = xs
        else:
            def step(c, xf):
                x, ok = xf
                new = body(c, x)
                merged = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(ok, a, b), new, c)
                return merged, None
            xs_all = (xs, flags)
        if self.remat:
            step = jax.checkpoint(step)
        out, _ = lax.scan(step, carry, xs_all)
        return out


def _positions(x: jax.Array) -> jax.Array:
    b, t = x.shape[0], x.shape[1]
    return jnp.broadcast_to(jnp.arange(t), (b, t))


# --------------------------------------------------------------------- dense
@register_adapter("dense")
class DenseAdapter(StageAdapter):
    """Decoder-only transformer: scanned block stacks, token embed + head."""

    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        if cfg.num_stages != num_stages:
            return (f"model was built with num_stages={cfg.num_stages}, "
                    f"pipeline wants {num_stages}; rebuild the model config")
        if cfg.num_layers < num_stages:
            return (f"num_layers={cfg.num_layers} < num_stages={num_stages}:"
                    " at least one block per stage is required")
        return None

    def unit_counts(self):
        return {"blocks": self.cfg.stage_sizes()}

    def embed(self, shared, mb):
        from repro.models import transformer as T
        return T.embed_tokens(shared, mb["tokens"], self.cfg)

    def blocks_segment(self, stage_tree, shared, x, s_idx, lo, hi):
        from repro.models import transformer as T
        cfg = self.cfg
        pos = _positions(x)

        def body(h, bp):
            return T._block_apply(bp, h, cfg, pos, cfg.sliding_window)
        flags = self.stage_flags("blocks", s_idx)
        y = self._masked_scan(body, x,
                              self._slice_units(stage_tree["blocks"], lo, hi),
                              None if flags is None else flags[lo:hi])
        return y, jnp.zeros((), F32)

    def head_loss(self, shared, y, mb):
        from repro.models import layers as L
        from repro.models import transformer as T
        logits = T.final_logits(shared, y, self.cfg)
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))


# ----------------------------------------------------------------------- vlm
@register_adapter("vlm")
class VLMAdapter(DenseAdapter):
    """Dense decoder over a [patches ; tokens] prefix; loss on text only."""

    def boundary_spec(self, mb):
        b, t = mb["tokens"].shape
        p = mb["patches"].shape[1]
        return jax.ShapeDtypeStruct((b, p + t, self.cfg.d_model),
                                    self.cfg.jdtype)

    def embed(self, shared, mb):
        from repro.models import vlm as V
        return V._embed_multimodal(shared, mb["patches"], mb["tokens"],
                                   self.cfg)

    def head_loss(self, shared, y, mb):
        from repro.models import layers as L
        from repro.models import transformer as T
        p = y.shape[1] - mb["tokens"].shape[1]
        logits = T.final_logits(shared, y, self.cfg)[:, p:]
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))


# ----------------------------------------------------------------------- moe
@register_adapter("moe")
class MoEAdapter(StageAdapter):
    """MoE decoder: experts + router live with their block's stage; the
    Switch load-balance aux loss is a per-stage contribution summed over
    the pipe axis (the schedule adds ``aux`` into every rank's local
    loss, so no extra collective is needed)."""

    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        if cfg.num_stages != num_stages:
            return (f"model was built with num_stages={cfg.num_stages}, "
                    f"pipeline wants {num_stages}; rebuild the model config")
        if cfg.num_layers < num_stages:
            return (f"num_layers={cfg.num_layers} < num_stages={num_stages}:"
                    " at least one MoE block per stage is required")
        return None

    def unit_counts(self):
        return {"blocks": self.cfg.stage_sizes()}

    def embed(self, shared, mb):
        return jnp.take(shared["embed"]["tok"], mb["tokens"], axis=0)

    def blocks_segment(self, stage_tree, shared, x, s_idx, lo, hi):
        from repro.models import moe as M
        cfg = self.cfg
        pos = _positions(x)

        def body(carry, bp):
            h, aux = carry
            h, a = M._block_apply(bp, h, cfg, pos, cfg.sliding_window)
            return h, aux + a
        flags = self.stage_flags("blocks", s_idx)
        y, aux = self._masked_scan(body, (x, jnp.zeros((), F32)),
                                   self._slice_units(stage_tree["blocks"],
                                                     lo, hi),
                                   None if flags is None else flags[lo:hi])
        # same normalization as the flat forward: weight * mean-over-layers
        # (applied per segment — contributions stay additive across spans)
        aux = aux * cfg.router_aux_weight / max(1, cfg.num_layers)
        return y, aux

    def head_loss(self, shared, y, mb):
        from repro.models import layers as L
        cfg = self.cfg
        x = L.rms_norm(y, shared["final_norm_scale"], cfg.norm_eps)
        logits = L.lm_logits(x, shared["lm_head"], tie=False)
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))


# --------------------------------------------------------------------- xlstm
@register_adapter("xlstm")
class XLSTMAdapter(StageAdapter):
    """xLSTM: the stage unit is one (mLSTM, sLSTM) pair — splitting a pair
    would separate the matrix-memory block from its recurrent partner."""

    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        if cfg.num_layers % 2:
            return f"num_layers={cfg.num_layers} must be even (pair stacks)"
        if cfg.num_stages != num_stages:
            return (f"model was built with num_stages={cfg.num_stages}, "
                    f"pipeline wants {num_stages}; rebuild the model config")
        n_pairs = cfg.num_layers // 2
        if n_pairs < num_stages:
            return (f"{n_pairs} (mLSTM, sLSTM) pairs < num_stages="
                    f"{num_stages}: at least one pair per stage is required")
        return None

    def unit_counts(self):
        from repro.models.ssm import xlstm_stage_sizes
        return {"pairs": xlstm_stage_sizes(self.cfg)}

    def embed(self, shared, mb):
        return jnp.take(shared["embed"]["tok"], mb["tokens"], axis=0)

    def blocks_segment(self, stage_tree, shared, x, s_idx, lo, hi):
        from repro.models import ssm
        cfg = self.cfg

        def body(h, pair):
            h = ssm.mlstm_apply(pair["mlstm"], h, cfg)
            return ssm.slstm_apply(pair["slstm"], h, cfg)
        flags = self.stage_flags("pairs", s_idx)
        y = self._masked_scan(body, x,
                              self._slice_units(stage_tree["pairs"], lo, hi),
                              None if flags is None else flags[lo:hi])
        return y, jnp.zeros((), F32)

    def head_loss(self, shared, y, mb):
        from repro.models import layers as L
        x = L.rms_norm(y, shared["final_norm_scale"], self.cfg.norm_eps)
        logits = L.lm_logits(x, shared["lm_head"], tie=False)
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))


# --------------------------------------------------------------------- zamba
@register_adapter("zamba")
class ZambaAdapter(StageAdapter):
    """Hybrid Mamba2 + shared attention: stages take WHOLE attention groups
    (a mamba run plus its shared-attn site), so per-stage layer counts are
    ragged whenever ``num_layers`` doesn't tile evenly over groups/stages.
    The shared attention block rides in ``shared`` (replicated over pipe,
    grads pipe-psum'd like embeddings).

    The compute scans GROUP SLOTS, not layers: an outer scan over Gmax
    group slots (inner: the run's mamba layers gathered from the stacked
    stage leaves by a static per-stage index map, padded slots masked)
    applies the shared attention block once per slot — Gmax O(T^2)
    attention applications instead of one per mamba layer with the
    non-site results discarded. Runs shorter than the longest run and
    stages with fewer groups than the widest stage pay only masked mamba
    passes — the cheap side of the SPMD-uniformity trade."""

    def __init__(self, model, num_stages, remat=None):
        super().__init__(model, num_stages, remat)
        from repro.models.hybrid import stage_group_sizes
        plan = stage_group_sizes(self.cfg, num_stages)
        gmax = max(len(sizes) for sizes in plan)
        rmax = max(sz for sizes in plan for sz in sizes)
        # (S, Gmax, Rmax) stage-local layer index per group slot + masks
        idx = np.zeros((num_stages, gmax, rmax), np.int32)
        layer_ok = np.zeros((num_stages, gmax, rmax), bool)
        group_ok = np.zeros((num_stages, gmax), bool)
        for s, sizes in enumerate(plan):
            off = 0
            for g, sz in enumerate(sizes):
                idx[s, g, :sz] = np.arange(off, off + sz)
                layer_ok[s, g, :sz] = True
                group_ok[s, g] = True
                off += sz
        self._group_idx = idx
        self._layer_ok = layer_ok
        self._group_ok = group_ok

    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        from repro.models.hybrid import _num_groups
        if cfg.num_stages != num_stages:
            return (f"model was built with num_stages={cfg.num_stages}, "
                    f"pipeline wants {num_stages}; rebuild the model config")
        g = _num_groups(cfg)
        if g < num_stages:
            return (f"{g} attention groups (attn_every={cfg.attn_every}) < "
                    f"num_stages={num_stages}: whole groups per stage is "
                    "the hybrid pipelining constraint")
        return None

    def unit_counts(self):
        from repro.models.hybrid import stage_group_sizes
        plan = stage_group_sizes(self.cfg, self.num_stages)
        return {"mamba": [sum(sizes) for sizes in plan]}

    def embed(self, shared, mb):
        return jnp.take(shared["embed"]["tok"], mb["tokens"], axis=0)

    def num_units(self):
        # The stash/segment unit is the GROUP SLOT (one mamba run + its
        # shared-attention site), not the mamba layer: a finer cut would
        # split a run from the attention application it masks into.
        return self._group_idx.shape[1]

    def blocks_segment(self, stage_tree, shared, x, s_idx, lo, hi):
        from repro.models import ssm
        from repro.models.hybrid import _shared_apply
        cfg = self.cfg
        pos = _positions(x)
        idx = jnp.take(jnp.asarray(self._group_idx), s_idx, axis=0)[lo:hi]
        layer_ok = jnp.take(jnp.asarray(self._layer_ok), s_idx, axis=0)[lo:hi]
        group_ok = jnp.take(jnp.asarray(self._group_ok), s_idx, axis=0)[lo:hi]
        mamba = stage_tree["mamba"]
        sp = shared["shared"]

        def group_step(h, inp):
            g_idx, g_layer_ok, g_ok = inp          # (Rmax,), (Rmax,), ()

            def layer_step(h2, inp2):
                i, ok = inp2
                mp = jax.tree_util.tree_map(
                    lambda a: jnp.take(a, i, axis=0), mamba)
                h3 = ssm.mamba2_apply(mp, h2, cfg)
                return jnp.where(ok, h3, h2), None
            h, _ = lax.scan(layer_step, h, (g_idx, g_layer_ok))
            h2 = _shared_apply(sp, h, cfg, pos)
            return jnp.where(g_ok, h2, h), None
        if self.remat:
            group_step = jax.checkpoint(group_step)
        y, _ = lax.scan(group_step, x, (idx, layer_ok, group_ok))
        return y, jnp.zeros((), F32)

    def head_loss(self, shared, y, mb):
        from repro.models import layers as L
        x = L.rms_norm(y, shared["final_norm_scale"], self.cfg.norm_eps)
        logits = L.lm_logits(x, shared["lm_head"], tie=False)
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))


# ------------------------------------------------------------------- whisper
@register_adapter("whisper")
class EncDecAdapter(StageAdapter):
    """Encoder-decoder: encoder stages before decoder stages; the boundary
    carries TWO channels — ``mem`` (the running encoder hidden, frozen to
    the encoder output once it crosses into the decoder half, feeding
    every decoder stage's cross-attention) and ``x`` (the decoder hidden,
    carrying the token embeddings through the encoder half untouched).
    Cotangents for ``mem`` accumulate through the pass-through on the way
    back, so encoder stages receive every decoder stage's cross-attention
    gradient without extra collectives."""

    def __init__(self, model, num_stages, remat=None):
        super().__init__(model, num_stages, remat)
        self._num_enc_stages = sum(
            1 for c in self._counts["enc_blocks"] if c > 0)

    @classmethod
    def check(cls, cfg: ModelConfig, num_stages: int) -> str | None:
        from repro.models.encdec import stage_layout
        if cfg.num_stages != num_stages:
            return (f"model was built with num_stages={cfg.num_stages}, "
                    f"pipeline wants {num_stages}; rebuild the model config")
        le = cfg.encoder_layers or cfg.num_layers
        if num_stages > le + cfg.num_layers:
            return (f"num_stages={num_stages} > {le}+{cfg.num_layers} "
                    "enc+dec layers")
        layout = stage_layout(cfg, num_stages)
        if len(layout) != num_stages:
            return (f"enc/dec split yields {len(layout)} stages for "
                    f"num_stages={num_stages}")
        return None

    def unit_counts(self):
        from repro.models.encdec import stage_layout
        layout = stage_layout(self.cfg, self.num_stages)
        return {"enc_blocks": [c["enc"] for c in layout],
                "dec_blocks": [c["dec"] for c in layout]}

    def boundary_spec(self, mb):
        b, t = mb["tokens"].shape
        a = mb["frames"].shape[1]
        d, dt = self.cfg.d_model, self.cfg.jdtype
        return {"mem": jax.ShapeDtypeStruct((b, a, d), dt),
                "x": jax.ShapeDtypeStruct((b, t, d), dt)}

    def embed(self, shared, mb):
        from repro.models import layers as L
        frames, tokens = mb["frames"], mb["tokens"]
        t = tokens.shape[1]
        mem = frames + L.sinusoidal_pos(frames.shape[1], frames.shape[2],
                                        frames.dtype)
        x = jnp.take(shared["embed"]["tok"], tokens, axis=0)
        x = x + lax.dynamic_slice_in_dim(shared["dec_pos"], 0, t, 0)
        return {"mem": mem, "x": x}

    def num_units(self):
        # Units enumerate the enc half first, then the dec half — the same
        # order a rank's compute runs them; the enc output norm rides with
        # the LAST enc unit (applied exactly once, by whichever segment
        # finishes the encoder half).
        return (max(self._counts["enc_blocks"])
                + max(self._counts["dec_blocks"]))

    def blocks_segment(self, stage_tree, shared, bnd, s_idx, lo, hi):
        from repro.models import encdec as E
        from repro.models import layers as L
        cfg = self.cfg
        le = max(self._counts["enc_blocks"])
        mem, x = bnd["mem"], bnd["x"]
        enc_pos = _positions(mem)
        dec_pos = _positions(x)

        def enc_body(h, bp):
            a = E._ln(h, bp, "attn_norm", cfg)
            a = L.attn_apply(bp["attn"], a, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                             causal=False, positions=enc_pos, use_rope=False,
                             norm_eps=cfg.norm_eps, block_q=cfg.block_q)
            h = h + a
            m = E._ln(h, bp, "mlp_norm", cfg)
            return h + L.mlp_apply(bp["mlp"], m, act="gelu")

        # stage_flags is None only at S == 1 (every unit live on the one
        # stage — the unmasked fast path is correct); for S >= 2 the
        # enc/dec counts always contain a 0, so masks always exist.
        elo, ehi = lo, min(hi, le)
        if ehi > elo:
            flags = self.stage_flags("enc_blocks", s_idx)
            mem = self._masked_scan(
                enc_body, mem,
                self._slice_units(stage_tree["enc_blocks"], elo, ehi),
                None if flags is None else flags[elo:ehi])
        # encoder output norm applies exactly once, on the last enc stage,
        # by the segment that runs the final enc unit
        if le and lo <= le - 1 < hi:
            last_enc = s_idx == self._num_enc_stages - 1
            mem = jnp.where(last_enc, E._ln(mem, shared, "enc_norm", cfg),
                            mem)

        def dec_body(h, bp):
            a = E._ln(h, bp, "attn_norm", cfg)
            a = L.attn_apply(bp["attn"], a, num_heads=cfg.num_heads,
                             num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd,
                             causal=True, positions=dec_pos, use_rope=False,
                             norm_eps=cfg.norm_eps, block_q=cfg.block_q)
            h = h + a
            c = E._ln(h, bp, "cross_norm", cfg)
            ek, ev = L.cross_kv(bp["cross"], mem,
                                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.hd)
            c = L.cross_attn_apply(bp["cross"], c, ek, ev,
                                   num_heads=cfg.num_heads,
                                   num_kv_heads=cfg.num_kv_heads,
                                   head_dim=cfg.hd)
            h = h + c
            m = E._ln(h, bp, "mlp_norm", cfg)
            return h + L.mlp_apply(bp["mlp"], m, act="gelu")

        dlo, dhi = max(lo - le, 0), hi - le
        if dhi > dlo:
            flags = self.stage_flags("dec_blocks", s_idx)
            x = self._masked_scan(
                dec_body, x,
                self._slice_units(stage_tree["dec_blocks"], dlo, dhi),
                None if flags is None else flags[dlo:dhi])
        return {"mem": mem, "x": x}, jnp.zeros((), F32)

    def head_loss(self, shared, bnd, mb):
        from repro.models import encdec as E
        from repro.models import layers as L
        x = E._ln(bnd["x"], shared, "final_norm", self.cfg)
        logits = L.lm_logits(x, shared["embed"]["tok"], tie=True)
        return L.cross_entropy(logits, mb["labels"], mb.get("mask"))
