"""PipelineConfig — the one home for pipeline-execution knobs.

Before this module existed, ``schedule`` / ``num_stages`` /
``num_microbatches`` / ``stash_policy`` / ``stash_every`` were re-declared
(and had to be kept in sync by hand) on ``TrainStepConfig``,
``TrainerConfig`` AND ``EDGCConfig``. All three now embed one
:class:`PipelineConfig`; their old flat fields survive as deprecated
init-shim properties (see ``repro.core.config.resolve_embedded``).

Deliberately dependency-free: only ``dataclasses``, so the config can be
imported by ``repro.core`` (controller) and ``repro.train`` without
dragging in the execution modules.
"""
from __future__ import annotations

import dataclasses

__all__ = ["PipelineConfig", "PIPELINE_FIELDS"]


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    """Static pipeline-execution surface (hashable, compile-cache safe).

    ``num_stages > 1`` routes ``make_train_step`` to the pipelined builder;
    the mesh must carry a matching ``pipe`` axis. ``overlap_sync`` turns on
    the schedule-interleaved per-stage DP sync (stages launch their sync
    chunks during their 1F1B/GPipe drain ticks instead of after the loop —
    see ``pipeline/schedule.py::plan_overlap``); ``chunk_bytes`` caps each
    flat-bucket transfer so it fits under one backward tick (0 = natural
    granularity: one chunk per shape group / flat bucket).
    """

    num_stages: int = 1
    schedule: str = "1f1b"         # gpipe | 1f1b
    num_microbatches: int = 0      # 0 -> num_stages
    # Selective activation stashing (pipeline executor only): replay |
    # full | every_k — how much of each stage's forward survives to its
    # backward tick vs being re-derived.
    stash_policy: str = "replay"
    stash_every: int = 2           # k for stash_policy="every_k"
    # Schedule-interleaved per-stage sync (ROADMAP item 1, TAGC-style).
    overlap_sync: bool = False
    chunk_bytes: int = 0           # flat-bucket chunk cap; 0 = per-collective


PIPELINE_FIELDS = tuple(f.name for f in dataclasses.fields(PipelineConfig))
