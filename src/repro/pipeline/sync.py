"""Per-stage DP gradient sync — the first end-to-end run of Algorithm 2.

Each pipeline rank holds one stage's gradients and must sync them over the
(pod, data) axes at the rank the DAC assigned to ITS stage. One SPMD
program cannot give different ranks different collective shapes, so the
executor runs one bucketed schedule (``core/bucketing.py``) per DISTINCT
per-stage plan and each rank keeps the result of the schedule that covers
its stage:

  * ``none`` / ``fixed`` / warm-up — every stage shares one plan: a single
    schedule, zero redundancy (the common case).
  * ``edgc`` / ``optimus`` — D <= S distinct rank assignments (DAC
    quantization keeps D small): D schedules per step, the off-stage
    results masked. The redundant compute/wire work is the price of
    single-program SPMD (Megatron pays with per-stage processes instead);
    the per-stage accounting that the paper's Tables III/VI need is exact
    either way (:func:`stage_wire_bytes`).

Compressor state is keyed ``p{d}:{group}`` per distinct plan, with leading
(stage, dp-replica) dims sharded ``P('pipe', ('pod','data'))``: every rank
carries a shape-correct slice of every schedule's state, but only the
slice of its OWN schedule holds live data (the others evolve masked-off
garbage that is never read back — the host reads the diagonal).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bucketing
from repro.core.bucketing import BucketLayout
from repro.core.compressor import CompressionPlan, LeafInfo, NO_COMPRESSION
from repro.core.powersgd import (
    LowRankState,
    compressed_bytes,
    init_leaf_state,
    resize_rank,
)
from repro.pipeline.partition import global_leaf_path, local_leaf_path

__all__ = [
    "StagePlans",
    "make_stage_plans",
    "stage_sync_grads",
    "stage_sync_chunks",
    "sync_shared_grads",
    "stage_wire_bytes",
    "init_pipeline_comp_state",
    "resize_pipeline_comp_state",
    "replicate_pipeline_comp_state",
]

PsumFn = Callable[[jax.Array], jax.Array]


@dataclasses.dataclass(frozen=True)
class StagePlans:
    """Static per-stage sync schedule: distinct local plans + layouts.

    ``stage_plans[s]`` is stage s's plan over STAGE-LOCAL leaf paths;
    ``distinct`` de-duplicates them (order of first appearance by stage),
    ``d_of_stage[s]`` indexes a stage's schedule, and ``layouts[d]`` is the
    bucketed sync layout each schedule executes.
    """

    num_stages: int
    stage_plans: tuple[CompressionPlan, ...]
    distinct: tuple[tuple[CompressionPlan, tuple[int, ...]], ...]
    d_of_stage: tuple[int, ...]
    layouts: tuple[BucketLayout, ...]

    def state_key(self, d: int, group_key: str) -> str:
        return f"p{d}:{group_key}"

    def predicted_collectives(self) -> tuple[int, ...]:
        """Per-stage collective bill of one full sync pass: stage s runs its
        schedule's ``BucketLayout.num_collectives`` (2 psums per stacked
        group + 1 per flat bucket).  The auditor's psum-budget pass diffs
        traced steps against this."""
        return tuple(self.layouts[self.d_of_stage[s]].num_collectives()
                     for s in range(self.num_stages))


def local_leaves_of(tree: Any) -> list[tuple]:
    """(path, shape, itemsize) triples of a stage-local tree, flatten order."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), tuple(leaf.shape),
             jnp.dtype(leaf.dtype).itemsize) for kp, leaf in flat]


def stage_local_leaves(stacked_tree: Any) -> list[tuple]:
    """Local (path, shape, itemsize) triples of a STAGE-STACKED tree (leading
    S dim stripped) — what one pipe rank's gradient tree looks like."""
    flat = jax.tree_util.tree_flatten_with_path(stacked_tree)[0]
    return [(jax.tree_util.keystr(kp), tuple(leaf.shape)[1:],
             jnp.dtype(leaf.dtype).itemsize) for kp, leaf in flat]


def make_stage_plans(
    plan: CompressionPlan,
    num_stages: int,
    local_leaves: list[tuple[str, tuple[int, ...]]],
    bucket_bytes: int = bucketing.DEFAULT_BUCKET_BYTES,
    chunk_bytes: int = 0,
    local_path: Callable[[str], tuple[int, str] | None] = local_leaf_path,
) -> StagePlans:
    """Split a flat-layout plan into per-stage local plans + layouts.

    Pure function of (plan, leaf shapes): trace-time, host init, and window
    re-plans all derive the identical object, like ``BucketLayout`` itself.
    ``local_leaves`` comes from the family adapter's stage-stacked template
    (``stage_local_leaves``) — for ragged stage plans its shapes are the
    PADDED per-rank shapes, which is exactly what each rank's bucketed
    schedule must pack. ``local_path`` is the adapter's flat->local leaf
    mapping (every registered family uses the shared ``['stages'][i]``
    convention, so the default regex is the common case).
    """
    per_stage: list[list[tuple[str, int]]] = [[] for _ in range(num_stages)]
    for path, rank in plan.ranks:
        loc = local_path(path)
        if loc is None:
            raise ValueError(f"plan compresses non-stage leaf {path!r}; "
                             "shared leaves are excluded from compression")
        s, lp = loc
        if s >= num_stages:
            raise ValueError(f"leaf {path!r} names stage {s} >= {num_stages}")
        per_stage[s].append((lp, rank))
    stage_plans = tuple(CompressionPlan(ranks=tuple(r)) for r in per_stage)

    distinct: list[tuple[CompressionPlan, tuple[int, ...]]] = []
    d_of_stage: list[int] = []
    for s, sp in enumerate(stage_plans):
        for d, (p, stages) in enumerate(distinct):
            if p == sp:
                distinct[d] = (p, stages + (s,))
                d_of_stage.append(d)
                break
        else:
            d_of_stage.append(len(distinct))
            distinct.append((sp, (s,)))

    layouts = tuple(
        bucketing.make_bucket_layout(local_leaves, p, bucket_bytes,
                                     chunk_bytes)
        for p, _ in distinct
    )
    return StagePlans(
        num_stages=num_stages,
        stage_plans=stage_plans,
        distinct=tuple(distinct),
        d_of_stage=tuple(d_of_stage),
        layouts=layouts,
    )


# ------------------------------------------------------------------ executor
def _sub_state(comp: dict, prefix: str) -> dict:
    return {k[len(prefix):]: v for k, v in comp.items() if k.startswith(prefix)}


def stage_sync_grads(
    stage_grads: Any,
    shared_grads: Any,
    comp_state: dict[str, LowRankState],
    splans: StagePlans,
    psum_mean: PsumFn,
    my_stage: jax.Array,
    use_kernels: bool = False,
    codec=None,
) -> tuple[Any, Any, dict[str, LowRankState]]:
    """Sync one rank's stage grads (+ the pipe-summed shared grads) over DP.

    ``my_stage`` is the rank's pipe index (traced inside shard_map, or a
    concrete int in unit tests). Runs every distinct schedule; keeps the one
    covering ``my_stage``. With a ``codec`` every stage collective moves
    entropy-coded (the pipe-shared leaves stay raw — they move once per
    step and carry the boundary-sensitive embedding/head signal). Returns
    (synced_stage, synced_shared, new_state).
    """
    new_state = dict(comp_state)

    out_stage = None
    d_of_stage = jnp.asarray(splans.d_of_stage, jnp.int32)
    my_d = d_of_stage[my_stage]
    for d, (plan_d, _) in enumerate(splans.distinct):
        prefix = f"p{d}:"
        synced_d, st_d = bucketing.bucketed_sync_grads(
            stage_grads, _sub_state(comp_state, prefix), splans.layouts[d],
            psum_mean, use_kernels=use_kernels, codec=codec,
        )
        for k, v in st_d.items():
            new_state[prefix + k] = v
        if out_stage is None:
            out_stage = synced_d
        else:
            mine = my_d == d
            out_stage = jax.tree_util.tree_map(
                lambda a, b: jnp.where(mine, a, b), synced_d, out_stage)

    synced_shared = sync_shared_grads(shared_grads, psum_mean)
    return out_stage, synced_shared, new_state


def sync_shared_grads(shared_grads: Any, psum_mean: PsumFn) -> Any:
    """DP sync of the pipe-replicated shared leaves (embeddings, head,
    norms). Shared leaves are never compressed (DEFAULT_EXCLUDE), so they
    move as one flat-bucket schedule — both the monolithic and the
    overlapped executor finish with exactly this call."""
    shared_layout = bucketing.layout_for_tree(shared_grads, NO_COMPRESSION)
    synced_shared, _ = bucketing.bucketed_sync_grads(
        shared_grads, {}, shared_layout, psum_mean)
    return synced_shared


def stage_sync_chunks(
    grads_by_path: dict[str, jax.Array],
    comp_state: dict[str, LowRankState],
    splans: StagePlans,
    d: int,
    chunk_ids,
    psum_mean: PsumFn,
    use_kernels: bool = False,
    codec=None,
) -> tuple[dict[str, jax.Array], dict[str, LowRankState]]:
    """Run a subset of distinct schedule ``d``'s chunks (overlap primitive).

    The pipelined executor calls this inside a per-stage ``lax.switch``
    branch: every DP peer of a stage shares the same pipe index, hence the
    same branch, so the chunk collectives stay SPMD-consistent across the
    stage's DP group. ``grads_by_path`` holds the rank's stage-local grads
    in wire (param) dtype; only the chunks' members are read. Returns
    (synced leaves by local path, the full comp dict with schedule ``d``'s
    touched ``p{d}:group`` keys replaced).
    """
    prefix = f"p{d}:"
    sub = _sub_state(comp_state, prefix)
    chunks = bucketing.sync_chunks(splans.layouts[d])
    new_state = dict(comp_state)
    updates: dict[str, jax.Array] = {}
    for ci in chunk_ids:
        upd, st = bucketing.sync_chunk_grads(
            grads_by_path, sub, chunks[ci], psum_mean,
            use_kernels=use_kernels, codec=codec)
        updates.update(upd)
        for k, v in st.items():
            new_state[prefix + k] = v
    return updates, new_state


# ----------------------------------------------------------------- accounting
def stage_wire_bytes(
    leaves: list[LeafInfo],
    plan: CompressionPlan,
    num_stages: int,
    bytes_per_elem: int = 2,
    codec=None,
) -> list[tuple[int, int]]:
    """Per-stage (compressed, full) DP-sync bytes — Algorithm 2's ledger.

    Stage s's DP ring moves exactly its own leaves' bytes (stage params are
    disjoint across ranks; shared leaves are charged to their owning
    boundary stage, consistent with ``_layer_stage`` pinning). With a
    ``codec`` the compressed column reports entropy-coded payloads
    (core/wire.py) — full stays the raw baseline, like ``plan_wire_bytes``.
    """
    from repro.core import wire as _wire

    rank_by_path = plan.as_dict()
    out = [[0, 0] for _ in range(num_stages)]
    for info in leaves:
        s = min(info.stage, num_stages - 1)
        nelem = 1
        for d in info.shape:
            nelem *= d
        out[s][1] += nelem * bytes_per_elem
        if info.path in rank_by_path:
            rank = rank_by_path[info.path]
            if codec is not None:
                out[s][0] += _wire.coded_bytes(
                    compressed_bytes(info.shape, rank, 1), codec)
            else:
                out[s][0] += compressed_bytes(info.shape, rank, bytes_per_elem)
        elif codec is not None:
            out[s][0] += _wire.coded_bytes(nelem, codec)
        else:
            out[s][0] += nelem * bytes_per_elem
    return [tuple(x) for x in out]


# ------------------------------------------------------------ state plumbing
def init_pipeline_comp_state(
    params: Any,
    plan: CompressionPlan,
    key: jax.Array,
    splans: StagePlans,
    wire_ef: bool = False,
) -> dict[str, LowRankState]:
    """Host-side compressor state for the pipelined executor.

    Per-leaf warm starts use the SAME key folding as the flat
    ``init_compressor_state`` (fold_in by global plan index), so the
    pipelined and single-program trainers start from bit-identical Q when
    the stage plan is uniform. Leaf SHAPES come from the stage-local
    layouts (not the flat tree): ragged stage plans pad each rank's
    stacks to the widest stage, and the compressor state must match the
    padded gradient a rank actually compresses (padded slices carry zero
    gradients, which PowerSGD maps to zero factors — they never pollute
    the live slices). Leaves: (S, ...) stacked — uncovered (masked-off)
    stage slices are filled with the first covered stage's values, which
    keeps every slice finite and every rank's program shape-uniform.

    ``wire_ef`` (coded wire modes) adds zero flat-bucket EF residuals under
    ``p{d}:ef:{local path}``, stacked (S, ...) like the group state.
    """
    flat_index = {path: i for i, (path, _) in enumerate(plan.ranks)}
    state: dict[str, LowRankState] = {}
    if wire_ef:
        for d in range(len(splans.distinct)):
            for k, zeros in bucketing.init_flat_ef(splans.layouts[d]).items():
                state[splans.state_key(d, k)] = jnp.broadcast_to(
                    zeros, (splans.num_stages,) + zeros.shape)
    for d, (plan_d, stages_d) in enumerate(splans.distinct):
        if not plan_d.ranks:
            continue
        layout = splans.layouts[d]
        local_shapes = {p: shp for g in layout.groups for p, shp in g.members}
        stacks = []
        for s in range(splans.num_stages):
            src = s if s in stages_d else stages_d[0]
            local = {
                lp: init_leaf_state(
                    local_shapes[lp], rank,
                    jax.random.fold_in(
                        key, flat_index[global_leaf_path(src, lp)]),
                    jnp.float32)
                for lp, rank in plan_d.ranks
            }
            stacks.append(bucketing.stack_state(local, layout))
        for gk in stacks[0]:
            state[splans.state_key(d, gk)] = LowRankState(
                q=jnp.stack([st[gk].q for st in stacks]),
                err=jnp.stack([st[gk].err for st in stacks]),
            )
    return state


def replicate_pipeline_comp_state(state: dict, world: int) -> dict:
    """Insert the per-DP-worker replica dim AFTER the stage dim: (S, W, ...)."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[:, None], a.shape[:1] + (world,)
                                   + a.shape[1:]), state)


def resize_pipeline_comp_state(
    state: dict[str, LowRankState],
    old_splans: StagePlans,
    new_splans: StagePlans,
    key: jax.Array,
) -> dict[str, LowRankState]:
    """Migrate warm-start Q / EF across a DAC window re-plan (host-side).

    ``state`` leaves are (S, W, ...); worker 0's diagonal slice (the live
    data for each stage) is resized per the new stage plan — matching the
    flat trainer's plan-change semantics — and restacked WITHOUT the W dim
    (caller re-replicates).
    """
    S = new_splans.num_stages
    per_stage_local: list[dict[str, LowRankState]] = []
    per_stage_ef: list[dict[str, jax.Array]] = []
    for s in range(S):
        d_old = old_splans.d_of_stage[s] if s < old_splans.num_stages else 0
        prefix = f"p{d_old}:"
        ef_prefix = prefix + bucketing.EF_PREFIX
        per_stage_ef.append({
            k[len(ef_prefix):]: v[s, 0]
            for k, v in state.items() if k.startswith(ef_prefix)
        })
        old_sub = {
            k[len(prefix):]: LowRankState(q=v.q[s, 0], err=v.err[s, 0])
            for k, v in state.items()
            if k.startswith(prefix) and not k.startswith(ef_prefix)
        }
        per_leaf = (bucketing.unstack_state(old_sub,
                                            old_splans.layouts[d_old])
                    if old_sub else {})
        new_plan = new_splans.stage_plans[s]
        shapes = {p: shp
                  for g in new_splans.layouts[new_splans.d_of_stage[s]].groups
                  for p, shp in g.members}
        fresh: dict[str, LowRankState] = {}
        for i, (lp, rank) in enumerate(new_plan.ranks):
            sub = jax.random.fold_in(key, s * 100_003 + i)
            if lp in per_leaf:
                fresh[lp] = resize_rank(per_leaf[lp], rank, sub)
            else:
                fresh[lp] = init_leaf_state(shapes[lp], rank, sub, jnp.float32)
        per_stage_local.append(fresh)

    out: dict[str, LowRankState] = {}
    for d, (plan_d, stages_d) in enumerate(new_splans.distinct):
        if not plan_d.ranks:
            continue
        layout = new_splans.layouts[d]
        stacks = []
        for s in range(S):
            src = s if s in stages_d else stages_d[0]
            local = {lp: per_stage_local[src][lp] for lp, _ in plan_d.ranks}
            stacks.append(bucketing.stack_state(local, layout))
        for gk in stacks[0]:
            out[new_splans.state_key(d, gk)] = LowRankState(
                q=jnp.stack([st[gk].q for st in stacks]),
                err=jnp.stack([st[gk].err for st in stacks]),
            )

    # Wire-EF entries migrate self-describingly (cf. resize_stacked_state):
    # preserved where the member stayed in a flat bucket at the same local
    # shape, fresh zeros where it entered/left compression or was resized.
    if any(bucketing.EF_PREFIX in k for k in state):
        for d, (plan_d, stages_d) in enumerate(new_splans.distinct):
            for bucket in new_splans.layouts[d].buckets:
                for lp, shp in bucket.members:
                    slices = []
                    for s in range(S):
                        src = s if s in stages_d else stages_d[0]
                        old = per_stage_ef[src].get(lp)
                        if old is None or tuple(old.shape) != tuple(shp):
                            old = jnp.zeros(shp, jnp.float32)
                        slices.append(old)
                    out[new_splans.state_key(d, bucketing.EF_PREFIX + lp)] = (
                        jnp.stack(slices))
    return out
