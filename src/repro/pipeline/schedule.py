"""GPipe / 1F1B microbatch schedules over the ``pipe`` mesh axis.

Both schedules run as ONE SPMD program inside a shard_map manual over
(pipe, pod, data): every rank executes the same tick sequence against its
own stage's params, boundary activations move forward via ``ppermute``
(+1 ring) and boundary-activation cotangents move backward via the inverse
``ppermute`` — the compat shim in ``dist/collectives.py`` provides the
shard_map surface. Off-schedule ticks are masked per rank (clipped
microbatch indices, zero cotangents) — SPMD uniformity again.

What a stage computes, what the boundary activation looks like (a pytree:
the enc-dec family ships two channels), and whether a stage contributes an
auxiliary loss (the MoE router balance term) all come from the family's
:class:`~repro.pipeline.adapters.StageAdapter` — this module only owns the
tick tables and the collective choreography.

The backward is a hand-rolled VJP (not ``jax.grad`` of the whole chain):
each backward tick re-derives its stage's forward from SAVED activations
and pulls cotangents through ``jax.vjp``. HOW MUCH is saved is the
``stash_policy`` axis (the executor's memory/compute knob):

  replay   only the stage's boundary input survives the forward tick
           (stage-granular rematerialization, Megatron's standard
           recompute) — the backward's VJP replays the WHOLE stage, with
           the adapter's per-unit remat inside when ``cfg.remat``.
  full     every inter-unit carry is stashed into a second activation
           ring; the backward runs one VJP per unit from its stashed
           input — residual live range is one unit, no remat recompute.
  every_k  stash every ``stash_every``-th unit boundary; segment VJPs
           replay at most k units from the nearest stash (segments run
           un-remat'ed — the stash bounds the residual span instead).

Every policy's VJP re-runs the un-stashed segment forwards exactly once
(one stage-forward total): stashing bounds the residual/recompute SPAN
and removes replay's per-unit remat recompute, it does not change the
replay SUM. ``peak_activation_bytes`` is the byte-accurate ledger of what
each policy keeps live per stage; ``policy_tick_cost`` is the matching
backward-tick cost model the calibrated ``simulate_schedule`` (and with
it the Eq. 4 slack the DAC consumes) runs on. That makes the *schedule*
an explicit tick table rather than whatever AD reversal produces:

  tick grids (F = forward of microbatch j at stage s, B = its backward)

    gpipe :  F at  t = j + s            B at  t = 2M + 2S - 3 - j - s
             all forwards, then all backwards in reverse — M in-flight
             boundary activations per rank.
    1f1b  :  F at  t = j + s            B at  t = j + (2S - 1 - s)
             stage S-1 starts draining one tick after its first forward —
             in-flight activations bounded by min(M, 2S) per rank, the
             1F1B memory bound.

Both schedules leave stage s's LAST backward s ticks before stage 0's —
exactly the per-stage slack Algorithm 2 (Eq. 4) converts into larger
ranks: stage s's DP sync may take ``T_com(r_stage1) + s * T_microBack``
and still finish with stage 0 (the paper's 1-indexed stage i has
``(i-1)`` spare microbatch-backwards; here 0-indexed ``s``).
``simulate_schedule`` generalizes the unit-tick analytics to measured
(t_F, t_B) tick costs — B-cost != F-cost shifts both the bubble fraction
and the Eq. 4 slack the DAC consumes (see benchmarks/pipeline_overlap.py
for the CommModel.fit calibration).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import bucketing
from repro.core.comm_model import ring_allreduce_seconds
from repro.core.config import SyncConfig
from repro.core.sync_executor import SyncExecutor
from repro.dist.collectives import make_dp_pmean, shard_map_dp
from repro.dist.sharding import param_pspecs, stage_param_pspecs
from repro.launch.mesh import dp_axes, pipe_size
from repro.models.model import Model
from repro.pipeline import sync as psync
from repro.pipeline.partition import make_partition

from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "SCHEDULES",
    "STASH_POLICIES",
    "slot_table",
    "tick_count",
    "ring_slots",
    "bubble_fraction",
    "peak_inflight",
    "sync_slack_ticks",
    "last_backward_tick",
    "sync_ticks",
    "OverlapPlan",
    "plan_overlap",
    "stash_points",
    "stash_segments",
    "tick_spans",
    "peak_activation_bytes",
    "policy_tick_cost",
    "boundary_nbytes",
    "simulate_schedule",
    "make_pipeline_train_step",
    "pipeline_state_shardings",
]

SCHEDULES = ("gpipe", "1f1b")
STASH_POLICIES = ("replay", "full", "every_k")

tmap = jax.tree_util.tree_map


# ------------------------------------------------------------------ analytics
def tick_count(name: str, S: int, M: int) -> int:
    if name == "gpipe":
        return 2 * (M + S - 1)
    if name == "1f1b":
        return M + 2 * S - 1
    raise ValueError(f"unknown schedule {name!r} (want one of {SCHEDULES})")


def ring_slots(name: str, S: int, M: int) -> int:
    """Boundary-activation ring size: the schedule's in-flight bound."""
    return M if name == "gpipe" else min(M, 2 * S)


def _fwd_mb(t: int, s: int) -> int:
    return t - s


def _bwd_mb(name: str, t: int, s: int, S: int, M: int) -> int:
    if name == "gpipe":
        return (2 * M + 2 * S - 3) - t - s
    return t - (2 * S - 1) + s


def first_bwd_tick(name: str, S: int, M: int) -> int:
    return (M + S - 1) if name == "gpipe" else S


def slot_table(name: str, S: int, M: int,
               sync_plan: "OverlapPlan | None" = None) -> list[list[tuple]]:
    """table[s][t] = tuple of ("F"|"B", microbatch) actions at that tick.

    With a ``sync_plan`` (``plan_overlap``), each stage's tick row also
    carries ("S", chunk_id) entries at the ticks where the overlapped
    executor launches that stage's DP-sync chunks — the schedule-
    interleaved tick table, SYNC ticks included.
    """
    n = tick_count(name, S, M)
    table: list[list[tuple]] = [[() for _ in range(n)] for _ in range(S)]
    for s in range(S):
        for t in range(n):
            acts = []
            if t < M + S - 1:
                j = _fwd_mb(t, s)
                if 0 <= j < M:
                    acts.append(("F", j))
            if t >= first_bwd_tick(name, S, M):
                j = _bwd_mb(name, t, s, S, M)
                if 0 <= j < M:
                    acts.append(("B", j))
            table[s][t] = tuple(acts)
    if sync_plan is not None:
        for s in range(S):
            for t, chunk_ids in sync_plan.launches[s]:
                table[s][t] = table[s][t] + tuple(
                    ("S", ci) for ci in chunk_ids)
    return table


def bubble_fraction(S: int, M: int) -> float:
    """Idle fraction of the classic unit-slot model, (S-1)/(M+S-1).

    GPipe and (non-interleaved) 1F1B share it — the schedules differ in
    peak activation memory and WHEN sync slack opens, not total idle time.
    """
    return (S - 1) / (M + S - 1)


def peak_inflight(name: str, S: int, M: int) -> list[int]:
    """Max simultaneously-saved boundary activations per stage (from the
    tick table: +1 at each F, -1 at each B)."""
    table = slot_table(name, S, M)
    peaks = []
    for s in range(S):
        live = peak = 0
        for acts in table[s]:
            for kind, _ in acts:
                if kind not in ("F", "B"):   # "S" sync entries hold no ring slot
                    continue
                live += 1 if kind == "F" else -1
                peak = max(peak, live)
        peaks.append(peak)
    return peaks


def sync_slack_ticks(name: str, S: int, M: int) -> list[int]:
    """Ticks between stage s's last backward and stage 0's (Alg 2 slack)."""
    last_b = last_backward_tick(name, S, M)
    return [last_b[0] - last_b[s] for s in range(S)]


def last_backward_tick(name: str, S: int, M: int) -> list[int]:
    """Tick of stage s's LAST microbatch backward — after it, the stage's
    gradient accumulator is final (off-schedule VJPs add exact zeros), so
    its DP sync may launch on the very next tick."""
    table = slot_table(name, S, M)
    return [max(t for t, acts in enumerate(table[s])
                if any(k == "B" for k, _ in acts)) for s in range(S)]


def sync_ticks(name: str, S: int, M: int) -> list[tuple[int, ...]]:
    """Per-stage ticks eligible to carry SYNC work: strictly after the
    stage's last backward, within the schedule's tick table. 1F1B drains
    back-to-front, so stage s gets the trailing ``sync_slack_ticks[s]``
    ticks (stage 0 gets none — its sync runs post-loop, as before)."""
    last_b = last_backward_tick(name, S, M)
    n = tick_count(name, S, M)
    return [tuple(range(last_b[s] + 1, n)) for s in range(S)]


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """Schedule-interleaved sync plan emitted by ``plan_overlap``.

    ``launches[s]`` is a tuple of ``(tick, chunk_ids)`` pairs: at global
    ``tick`` the overlapped executor launches those ``sync_chunks`` of
    stage s's bucket layout (psums for a stacked-PowerSGD shape group, or
    one flat-bucket member run). ``residual[s]`` holds the chunk ids that
    did not fit the stage's drain window and run post-loop (stage 0's
    whole schedule is residual — zero slack). ``feasible[s]`` is the
    Eq. 4 signal the DAC consumes: does stage s's estimated sync time fit
    ``est_sync_seconds[0] + slack_seconds[s]``?
    """

    schedule: str
    num_stages: int
    num_microbatches: int
    launches: tuple          # per stage: ((tick, (chunk_id, ...)), ...)
    residual: tuple          # per stage: (chunk_id, ...)
    slack_seconds: tuple     # per stage, from simulate_schedule
    est_sync_seconds: tuple  # per stage, CommModel estimate (or tick units)
    feasible: tuple          # per stage: bool

    def launch_ticks(self, s: int) -> tuple[int, ...]:
        return tuple(t for t, _ in self.launches[s])


def plan_overlap(name: str, S: int, M: int, splans, *,
                 t_f: float = 1.0, t_b: float = 1.0,
                 comm=None, codec=None) -> OverlapPlan:
    """Plan which sync chunks launch at which drain ticks (the planner).

    Greedy per stage: walk the stage's eligible drain ticks front-to-back
    and pack chunks into each tick until the tick's time budget (``t_b``,
    one backward's worth of compute to hide under) is spent; whatever is
    left spills to the post-loop residual. Chunk times come from the
    fitted ``CommModel`` when given (``ring_allreduce_seconds`` of the
    chunk's wire bytes over the model's ICI bandwidth); without one each
    chunk counts a full tick (the unit model — one chunk per drain tick).

    The feasibility signal compares each stage's total estimated sync
    time against stage 0's plus the stage's measured slack — exactly the
    Eq. 4 budget ``DAC._feasible_clamp`` enforces on ranks.
    """
    sim = simulate_schedule(name, S, M, t_f, t_b)
    slack = sim["slack_seconds"]
    ticks = sync_ticks(name, S, M)
    launches, residual, est = [], [], []
    for s in range(S):
        d = splans.d_of_stage[s]
        chunks = bucketing.sync_chunks(splans.layouts[d])
        if comm is not None:
            # wire_bytes: itemsize-aware raw sizes, or the entropy-coded
            # payload when the sync runs under a codec — transfer placement
            # should plan for the bytes that actually move.
            times = [ring_allreduce_seconds(c.wire_bytes(codec=codec),
                                            comm.world,
                                            comm.hw.ici_bw) for c in chunks]
        else:
            times = [t_b] * len(chunks)
        est.append(sum(times))
        per_tick: list[list[int]] = [[] for _ in ticks[s]]
        rest: list[int] = []
        ti, used = 0, 0.0
        for ci, ct in enumerate(times):
            if ti >= len(per_tick):
                rest.append(ci)
                continue
            per_tick[ti].append(ci)
            used += ct
            if used >= t_b - 1e-12:
                ti, used = ti + 1, 0.0
        launches.append(tuple((ticks[s][i], tuple(ids))
                              for i, ids in enumerate(per_tick) if ids))
        residual.append(tuple(rest))
    return OverlapPlan(
        schedule=name, num_stages=S, num_microbatches=M,
        launches=tuple(launches), residual=tuple(residual),
        slack_seconds=tuple(float(t) for t in slack),  # lint: allow(host-call-in-hot-path) host-side planner, never traced
        est_sync_seconds=tuple(est),
        feasible=tuple(est[s] <= est[0] + slack[s] + 1e-9
                       for s in range(S)),
    )


def overlap_branch_psums(oplan: "OverlapPlan", splans
                         ) -> tuple[tuple[tuple[int, tuple[int, ...]], ...],
                                    tuple[int, ...]]:
    """Declared per-switch psum budgets of the overlapped executor.

    The traced step contains one ``lax.switch`` over ``axis_index('pipe')``
    per launch tick (each branch = one stage's chunk launches for that
    tick) plus one residual switch after the flush.  This derives, from
    the SAME plan the executor consumes, the psum count each branch must
    launch: ``SyncChunk.num_collectives`` summed over the tick's chunk
    ids.  Returns ``(in_loop, residual)`` where ``in_loop`` is
    ``((tick, (count_stage0, ..., count_stageS-1)), ...)`` in tick order —
    the ground truth the auditor's psum-budget pass diffs traced switches
    against (a dropped psum in one branch is deadlock-free but silently
    leaves a chunk unsynced; the diff catches it).
    """
    chunks_by_d = tuple(bucketing.sync_chunks(l) for l in splans.layouts)

    def n_of(s: int, ids) -> int:
        d = splans.d_of_stage[s]
        return sum(chunks_by_d[d][ci].num_collectives for ci in ids)

    launch_at: dict[int, dict[int, tuple[int, ...]]] = {}
    for s in range(oplan.num_stages):
        for t, ids in oplan.launches[s]:
            launch_at.setdefault(t, {})[s] = ids
    in_loop = tuple(
        (t, tuple(n_of(s, launch_at[t].get(s, ()))
                  for s in range(oplan.num_stages)))
        for t in sorted(launch_at))
    residual = tuple(n_of(s, oplan.residual[s])
                     for s in range(oplan.num_stages))
    return in_loop, residual


def stash_points(policy: str, n_units: int, stash_every: int = 2
                 ) -> tuple[int, ...]:
    """Interior unit boundaries the forward tick stashes (static).

    ``replay`` stashes nothing (the backward re-derives the stage from its
    boundary input); ``full`` stashes every inter-unit carry; ``every_k``
    stashes multiples of ``stash_every`` strictly inside ``(0, n_units)``.
    """
    if policy == "replay":
        return ()
    if policy == "full":
        return tuple(range(1, n_units))
    if policy == "every_k":
        return tuple(range(max(1, stash_every), n_units,
                           max(1, stash_every)))
    raise ValueError(
        f"unknown stash policy {policy!r} (want one of {STASH_POLICIES})")


def stash_segments(policy: str, n_units: int, stash_every: int = 2
                   ) -> tuple[tuple[int, int], ...]:
    """Consecutive unit spans between stash points — what the backward
    replays per VJP. ``replay`` degenerates to one whole-stage span."""
    bounds = (0,) + stash_points(policy, n_units, stash_every) + (n_units,)
    return tuple(zip(bounds[:-1], bounds[1:]))


def peak_activation_bytes(name: str, S: int, M: int, policy: str, *,
                          boundary_bytes: int, n_units: int,
                          stash_every: int = 2) -> list[int]:
    """Per-stage peak bytes of the saved-activation rings — the ledger.

    Tick-table derived: each F tick saves one boundary-ring entry plus
    ``len(stash_points)`` stash-ring entries for its microbatch and the
    matching B tick frees them, so the peak live entry count per stage is
    exactly ``peak_inflight``. Every entry is one boundary-spec'd pytree
    (``boundary_bytes``; the stashed inter-unit carry IS the boundary for
    every current family — see ``StageAdapter.stash_spec``), hence
    ``full >= every_k >= replay`` per stage, always.
    """
    n_stash = len(stash_points(policy, n_units, stash_every))
    per_mb = boundary_bytes * (1 + n_stash)
    return [p * per_mb for p in peak_inflight(name, S, M)]


def policy_tick_cost(t_f: float, t_b: float, policy: str,
                     remat: bool = False) -> float:
    """Backward-tick cost model per stash policy (feeds the calibrated
    ``simulate_schedule`` and the Eq. 4 slack the DAC consumes).

    Every policy's hand-rolled VJP re-runs the un-stashed segment
    forwards once — one stage-forward (``t_f``) on top of the pure
    backward ``t_b`` — because stashing bounds the recompute SPAN, not
    the replay SUM. ``replay`` with per-unit remat inside the stage pays
    that forward a second time (the scan bodies recompute under
    ``jax.checkpoint``); the stashed policies run their segments
    un-remat'ed, so they never do.
    """
    if policy not in STASH_POLICIES:
        raise ValueError(
            f"unknown stash policy {policy!r} (want one of {STASH_POLICIES})")
    replay_cost = t_f * (2.0 if (policy == "replay" and remat) else 1.0)
    return t_b + replay_cost


def boundary_nbytes(part, mb: dict) -> int:
    """Bytes of one boundary-activation pytree for one microbatch.

    ``mb`` maps batch keys to per-microbatch ShapeDtypeStructs (or
    arrays); ``part`` is the family's stage adapter.
    """
    import math
    spec = part.boundary_spec(mb)
    return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(spec))


def tick_spans(name: str, S: int, M: int,
               t_f: float = 1.0, t_b: float = 1.0) -> list[dict]:
    """Per-action spans of the dependency-driven event simulation.

    One dict per tick-table F/B entry::

        {"stage": s, "tick": t, "kind": "F"|"B", "mb": j,
         "start": seconds, "end": seconds}

    This is the timing engine ``simulate_schedule`` aggregates over and
    the obs tick tracer (``repro.obs.trace``) renders as Chrome
    trace-event spans: each F(s, j) waits for F(s-1, j) and the rank's
    previous op; each B(s, j) waits for B(s+1, j) (or its own F on the
    last stage).
    """
    table = slot_table(name, S, M)
    end_f: dict[tuple[int, int], float] = {}
    end_b: dict[tuple[int, int], float] = {}
    free = [0.0] * S
    spans: list[dict] = []
    for t in range(tick_count(name, S, M)):
        for s in range(S):
            for kind, j in table[s][t]:
                if kind == "F":
                    dep = end_f.get((s - 1, j), 0.0) if s > 0 else 0.0
                    start = max(free[s], dep)
                    end_f[(s, j)] = free[s] = start + t_f
                else:
                    dep = (end_b.get((s + 1, j), 0.0) if s < S - 1
                           else end_f[(s, j)])
                    dep = max(dep, end_f[(s, j)])
                    start = max(free[s], dep)
                    end_b[(s, j)] = free[s] = start + t_b
                spans.append({"stage": s, "tick": t, "kind": kind,
                              "mb": j, "start": start, "end": free[s]})
    return spans


def simulate_schedule(name: str, S: int, M: int,
                      t_f: float = 1.0, t_b: float = 1.0,
                      splans=None, comm=None) -> dict:
    """Dependency-driven timing of a schedule with measured tick costs.

    The unit-tick analytics above assume B-cost == F-cost; real backwards
    run ~2x the forward (plus the stage-replay recompute here), which
    changes both the bubble fraction and the per-stage Eq. 4 slack.
    ``t_b`` is per STASH POLICY: pass ``policy_tick_cost(t_f, t_b_pure,
    policy, remat)`` so the slack the DAC consumes reflects what the
    backward tick actually replays under that policy. This
    replays the slot table as an event simulation: each F(s, j) waits for
    F(s-1, j) and the rank's previous op; each B(s, j) waits for B(s+1, j)
    (or its own F on the last stage). Returns::

        {"makespan": seconds, "bubble_fraction": scalar,
         "slack_seconds": [per stage]}       # Eq. 4 slack in seconds

    The bubble is one number: every stage is busy for exactly
    M * (t_f + t_b) seconds of the same makespan. With t_f == t_b == 1
    it matches ``bubble_fraction`` and the slack equals
    ``sync_slack_ticks`` (the calibration degenerates to the unit model).

    With ``splans`` (per-stage bucket layouts from ``make_stage_plans``)
    the simulation is also the OVERLAP PLANNER: the returned dict gains
    ``out["overlap"]``, the :class:`OverlapPlan` from ``plan_overlap``
    driven by this run's measured (t_f, t_b) — which tick each stage's
    sync chunks launch at, what spills to the residual, and the per-stage
    Eq. 4 feasibility signal (chunk times from the fitted ``comm`` model
    when given).
    """
    spans = tick_spans(name, S, M, t_f, t_b)
    makespan = max(sp["end"] for sp in spans)
    busy = M * (t_f + t_b)
    last_b = [max(sp["end"] for sp in spans
                  if sp["stage"] == s and sp["kind"] == "B")
              for s in range(S)]
    out = {
        "makespan": makespan,
        "bubble_fraction": 1.0 - busy / makespan,
        "slack_seconds": [last_b[0] - last_b[s] for s in range(S)],
    }
    if splans is not None:
        out["overlap"] = plan_overlap(name, S, M, splans,
                                      t_f=t_f, t_b=t_b, comm=comm)
    return out


# ------------------------------------------------------------- step builder
def make_pipeline_train_step(model: Model, mesh, cfg):
    """Pipelined train step: (state, batch) -> (state, metrics).

    ``cfg`` is a ``repro.train.step.TrainStepConfig`` with
    ``num_stages > 1``; the mesh must carry a ``pipe`` axis of that size.
    State layout (see the family's ``StageAdapter`` /
    ``init_pipeline_comp_state``):

      stage_params  stage-stacked stacks, leaves (S, Lmax, ...) over 'pipe'
      shared_params embeddings/head/norms/shared blocks, replicated
      opt_m/opt_v   {"stage": ..., "shared": ...} mirrors of the above
      opt_step      scalar
      comp          per-distinct-plan stacked compressor state,
                    leaves (S, dp_world, ...) over ('pipe', dp axes)
    """
    S = cfg.num_stages
    M = cfg.num_microbatches or S
    name = cfg.schedule
    if name not in SCHEDULES:
        raise ValueError(f"unknown schedule {name!r} (want one of {SCHEDULES})")
    if cfg.measure_entropy and cfg.gds.estimator != "gaussian":
        # The pipelined entropy is reassembled from psum'd sufficient
        # statistics, which only the Gaussian (Lemma 2) estimator admits —
        # refuse loudly rather than silently diverge from the flat step.
        raise ValueError(
            f"pipelined step supports the gaussian entropy estimator only, "
            f"got {cfg.gds.estimator!r}")
    if pipe_size(mesh) != S:
        raise ValueError(f"mesh pipe axis has size {pipe_size(mesh)}, "
                         f"step wants num_stages={S}")
    stash = getattr(cfg, "stash_policy", "replay")
    if stash not in STASH_POLICIES:
        raise ValueError(f"unknown stash policy {stash!r} "
                         f"(want one of {STASH_POLICIES})")
    axes_dp = dp_axes(mesh)
    manual = ("pipe",) + tuple(axes_dp)
    # Stashed policies bound the backward's residual span by the segment
    # width, so per-unit remat inside the stage would only re-add the
    # recompute the stash exists to remove — replay keeps cfg.remat.
    part = make_partition(model, S, remat=cfg.remat and stash == "replay")
    segs = stash_segments(stash, part.num_units(),
                          getattr(cfg, "stash_every", 2))
    n_stash = len(segs) - 1
    adam_cfg = cfg.adam

    sync_cfg = getattr(cfg, "sync", None) or SyncConfig(
        use_kernels=getattr(cfg, "use_kernels", False))
    overlap = bool(getattr(cfg, "overlap_sync", False))

    # Static stage-plan schedule from the flat plan + the local leaf shapes.
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    stage_shapes = jax.eval_shape(
        lambda p: part.partition_params(p)[0], params_shapes)
    splans = psync.make_stage_plans(
        cfg.policy_plan, S, psync.stage_local_leaves(stage_shapes),
        bucket_bytes=sync_cfg.bucket_bytes,
        chunk_bytes=int(getattr(cfg, "chunk_bytes", 0) or 0),
        local_path=part.local_leaf_path)
    sync_exec = SyncExecutor(
        sync_cfg, mode="per-stage-overlapped" if overlap else "per-stage",
        splans=splans)
    if overlap:
        # The planner: which drain tick launches which sync chunks. The
        # tick table is static, so the launch plan specializes the traced
        # loop at build time — SYNC ticks become real per-rank branches
        # (one lax.switch on the pipe index per launching tick) instead of
        # every rank running every distinct schedule where-masked.
        oplan = plan_overlap(name, S, M, splans)
        chunks_by_d = tuple(bucketing.sync_chunks(l) for l in splans.layouts)
        launch_at: dict[int, dict[int, tuple[int, ...]]] = {}
        for s_ in range(S):
            for t_, ids_ in oplan.launches[s_]:
                launch_at.setdefault(t_, {})[s_] = ids_
    else:
        oplan, launch_at = None, {}

    R = ring_slots(name, S, M)
    n_ticks = tick_count(name, S, M)
    fbt = first_bwd_tick(name, S, M)
    fwd_perm = [(i, (i + 1) % S) for i in range(S)]
    bwd_perm = [(i, (i - 1) % S) for i in range(S)]
    inv_M = 1.0 / M

    def local_step(state, batch):
        from repro.optim import adam

        s_idx = lax.axis_index("pipe")
        is_first = s_idx == 0
        is_last = s_idx == S - 1
        squeeze = lambda t: tmap(lambda a: a[0], t)
        stage_p = squeeze(state["stage_params"])
        shared_p = state["shared_params"]
        comp = tmap(lambda a: a[0, 0], state["comp"])

        def to_mb(a):
            if a.shape[0] % M:
                raise ValueError(f"local batch {a.shape[0]} not divisible by "
                                 f"num_microbatches={M}")
            return a.reshape((M, a.shape[0] // M) + a.shape[1:])

        mb = {k: to_mb(v) for k, v in batch.items()}
        take_mb = lambda j: {k: jnp.take(v, j, axis=0) for k, v in mb.items()}
        bspec = part.boundary_spec(
            {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
             for k, v in mb.items()})
        zeros_bnd = lambda: tmap(lambda s: jnp.zeros(s.shape, s.dtype), bspec)

        def seg_fwd(sp, sh, xin, mbj, i):
            # One stash segment's compute, SPMD-uniform across ranks: the
            # first segment owns embed (+ the is_first boundary select),
            # the last owns the head CE (masked by is_last), and every
            # segment contributes its own aux loss (MoE router balance) —
            # the pipe psum of loss_acc totals both. The masked paths get
            # zero cotangents in the backward, so their params see zero
            # gradient without explicit bookkeeping.
            lo, hi = segs[i]
            if i == 0:
                x0 = part.embed(sh, mbj)
                xin = tmap(lambda a, b: jnp.where(is_first, a, b), x0, xin)
            y, aux = part.blocks_segment(sp, sh, xin, s_idx, lo, hi)
            contrib = aux
            if i == len(segs) - 1:
                head = part.head_loss(sh, y, mbj)
                contrib = contrib + jnp.where(is_last, head, 0.0)
            return y, contrib

        def rank_fwd(sp, sh, mbj, x_recv):
            # Full forward chain; with stash_policy="replay" (one segment)
            # this is byte-identical to the pre-stash executor. The
            # interior segment inputs are what the stash ring saves.
            y = x_recv
            local_loss = jnp.zeros((), jnp.float32)
            interior = []
            for i in range(len(segs)):
                if i:
                    interior.append(y)
                y, contrib = seg_fwd(sp, sh, y, mbj, i)
                local_loss = local_loss + contrib
            return y, local_loss, interior

        fwd_recv = zeros_bnd()
        bwd_recv = zeros_bnd()
        ring = tmap(lambda s: jnp.zeros((R,) + s.shape, s.dtype), bspec)
        sspec = part.stash_spec(
            {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype)
             for k, v in mb.items()})
        stash_ring = (tmap(lambda s: jnp.zeros((R, n_stash) + s.shape,
                                               s.dtype), sspec)
                      if n_stash else None)
        loss_acc = jnp.zeros((), jnp.float32)
        f32z = lambda t: tmap(lambda a: jnp.zeros(a.shape, jnp.float32), t)
        gacc_s = f32z(stage_p)
        gacc_sh = f32z(shared_p)

        pmean_dp = make_dp_pmean(axes_dp)
        kps, stage_def = jax.tree_util.tree_flatten_with_path(stage_p)
        spaths = tuple(jax.tree_util.keystr(kp) for kp, _ in kps)
        pdt = {p: l.dtype for p, (_, l) in zip(spaths, kps)}
        sync_carry = None
        if overlap:
            # In-loop sync carry: synced stage leaves (wire dtype, zeros
            # until their chunk runs) + the compressor state. Every
            # lax.switch branch returns this exact pytree structure.
            sync_carry = (
                {p: jnp.zeros(l.shape, l.dtype)
                 for p, (_, l) in zip(spaths, kps)},
                comp,
            )

        def launch_sync(t, carry, gacc):
            """Launch tick t's planned chunks: one lax.switch on the pipe
            index. All DP peers of a stage share the index, hence the
            branch, so the chunk psums stay collective-consistent inside
            the stage's DP group while other stages run real F/B work.
            A stage's gacc is final here — its last backward already
            retired (plan invariant; off-schedule VJPs add exact zeros)."""
            here = launch_at[t]
            gvals = jax.tree_util.tree_leaves(gacc)
            g_by_path = {p: g.astype(pdt[p]) for p, g in zip(spaths, gvals)}

            def mk(s):
                ids = here.get(s, ())
                if not ids:
                    return lambda c: c
                d = splans.d_of_stage[s]
                need = sorted({p for ci in ids
                               for p in chunks_by_d[d][ci].member_paths})

                def run(c, ids=ids, d=d, need=need):
                    parts, comp_c = c
                    gb = {p: g_by_path[p] for p in need}
                    upd, comp_c = sync_exec.run_chunks(
                        d, ids, gb, comp_c, pmean_dp)
                    parts = {p: upd.get(p, parts[p]) for p in spaths}
                    return parts, comp_c

                return run

            return lax.switch(s_idx, [mk(s) for s in range(S)], carry)

        for t in range(n_ticks):
            if t < M + S - 1:
                off = t - s_idx
                valid_f = (off >= 0) & (off < M)
                jf = jnp.clip(off, 0, M - 1)
                y, loss_mb, interior = rank_fwd(stage_p, shared_p,
                                                take_mb(jf), fwd_recv)
                loss_acc = loss_acc + jnp.where(valid_f, loss_mb, 0.0)
                upd = lambda r, v: jnp.where(
                    valid_f,
                    lax.dynamic_update_index_in_dim(r, v, jf % R, 0), r)
                ring = tmap(upd, ring, fwd_recv)
                if n_stash:
                    stash_ring = tmap(
                        upd, stash_ring,
                        tmap(lambda *xs: jnp.stack(xs), *interior))
                fwd_recv = tmap(lambda a: lax.ppermute(a, "pipe", fwd_perm), y)
            if t >= fbt:
                # same arithmetic the slot_table analytics use (on traced s)
                offb = _bwd_mb(name, t, s_idx, S, M)
                valid_b = (offb >= 0) & (offb < M)
                jb = jnp.clip(offb, 0, M - 1)
                mbj = take_mb(jb)
                x_saved = tmap(lambda r: jnp.take(r, jb % R, axis=0), ring)
                stash_saved = (tmap(lambda r: jnp.take(r, jb % R, axis=0),
                                    stash_ring) if n_stash else None)

                # vjp is linear in the cotangents: masking them masks the
                # whole backward (param grads AND the outgoing boundary
                # cotangent) — off-schedule ranks contribute exact zeros.
                # seg_fwd internally masks the head by is_last, so the
                # uniform inv_M loss cotangent is correct on every rank
                # (it also pulls the per-stage aux-loss gradients).
                # Segments chain back to front: each VJP re-runs only its
                # own span's forward from the stashed input (replay's
                # single segment re-runs the whole stage) and hands its
                # input cotangent to the upstream segment.
                ct_carry = tmap(
                    lambda a: jnp.where(valid_b & ~is_last, a,
                                        jnp.zeros_like(a)), bwd_recv)
                ct_loss = jnp.where(valid_b, inv_M, 0.0)
                add32 = lambda a, g: a + g.astype(jnp.float32)
                for i in range(len(segs) - 1, -1, -1):
                    xin = (x_saved if i == 0 else
                           tmap(lambda a, i=i: a[i - 1], stash_saved))

                    def seg(sp, sh, xr, mbj=mbj, i=i):
                        return seg_fwd(sp, sh, xr, mbj, i)

                    _, vjp = jax.vjp(seg, stage_p, shared_p, xin)
                    gs, gsh, ct_carry = vjp((ct_carry, ct_loss))
                    gacc_s = tmap(add32, gacc_s, gs)
                    gacc_sh = tmap(add32, gacc_sh, gsh)
                bwd_recv = tmap(lambda a: lax.ppermute(a, "pipe", bwd_perm),
                                ct_carry)
            if overlap and t in launch_at:
                sync_carry = launch_sync(t, sync_carry, gacc_s)

        psum_pipe = lambda x: lax.psum(x, "pipe")
        loss = pmean_dp(psum_pipe(loss_acc) * inv_M)

        cast_like = lambda g, p: g.astype(p.dtype)
        gacc_s = tmap(cast_like, gacc_s, stage_p)
        # Shared-param grads: boundary ranks (and, for Zamba's shared attn
        # block, every rank) computed partial contributions; the pipe psum
        # gives every rank the total.
        gacc_sh = tmap(lambda g, p: psum_pipe(g).astype(p.dtype),
                       gacc_sh, shared_p)

        if overlap:
            # Residual chunks (whatever the drain window couldn't hide —
            # all of stage 0's, whose slack is zero) run post-loop in the
            # same per-stage switch; then the synced leaves reassemble in
            # flatten order and the shared leaves finish exactly as the
            # monolithic path does.
            g_by_path = dict(zip(spaths, jax.tree_util.tree_leaves(gacc_s)))

            def fin(s):
                ids = oplan.residual[s]
                d = splans.d_of_stage[s]
                need = sorted({p for ci in ids
                               for p in chunks_by_d[d][ci].member_paths})

                def run(c, ids=ids, d=d, need=need):
                    parts, comp_c = c
                    if ids:
                        gb = {p: g_by_path[p] for p in need}
                        upd, comp_c = sync_exec.run_chunks(
                            d, ids, gb, comp_c, pmean_dp)
                        parts = {p: upd.get(p, parts[p]) for p in spaths}
                    return parts, comp_c

                return run

            parts_f, comp2 = lax.switch(
                s_idx, [fin(s) for s in range(S)], sync_carry)
            synced_s = jax.tree_util.tree_unflatten(
                stage_def, [parts_f[p] for p in spaths])
            synced_sh = sync_exec.sync_shared(gacc_sh, pmean_dp)
        else:
            synced_s, synced_sh, comp2 = sync_exec.sync(
                gacc_s, comp, pmean_dp, shared_grads=gacc_sh,
                my_stage=s_idx)

        if cfg.measure_entropy:
            from repro.core.entropy import entropy_from_moments, sample_moments
            # Ragged stage plans zero-pad each rank's stacks to the widest
            # stage; pooling the PADDED leaves would count the exact-zero
            # pad slots in n and bias sigma (and the Lemma-2 entropy) low.
            # Each top-level key of the stage tree is one adapter stack —
            # its live-unit mask drops pad samples so the pipelined pooled
            # moments match the flat step's exactly.
            z = jnp.zeros((), jnp.float32)
            n1 = a1 = a2 = z
            for key in sorted(synced_s):
                kn, k1, k2 = sample_moments(
                    synced_s[key], cfg.gds,
                    lead_mask=part.stage_flags(key, s_idx))
                n1, a1, a2 = n1 + kn, a1 + k1, a2 + k2
            n2, c1, c2 = sample_moments(synced_sh, cfg.gds)
            w = jnp.where(is_first, 1.0, 0.0)  # count shared leaves once
            # Each rank scatters its pooled moments into its stage's slot
            # and the (S,)-vectors psum over pipe: the SAME three Lemma-2
            # collectives as the scalar pooling (the ISR-gate invariant —
            # the off variant lowers exactly 3 fewer psums), but the slots
            # now also yield the per-stage entropy series for free. Slot
            # sums recover the pooled moments exactly: every other rank
            # contributes zeros to a slot.
            scatter = lambda v: jnp.zeros((S,), jnp.float32).at[s_idx].set(v)
            n_vec = psum_pipe(scatter(n1 + w * n2))
            s1_vec = psum_pipe(scatter(a1 + w * c1))
            s2_vec = psum_pipe(scatter(a2 + w * c2))
            entropy = entropy_from_moments(n_vec.sum(), s1_vec.sum(),
                                           s2_vec.sum())
            stage_entropy = entropy_from_moments(n_vec, s1_vec, s2_vec)
        else:
            entropy = jnp.zeros((), jnp.float32)
            stage_entropy = jnp.zeros((S,), jnp.float32)

        sumsq = lambda t: sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in jax.tree_util.tree_leaves(t))
        gnorm = jnp.sqrt(psum_pipe(sumsq(synced_s)) + sumsq(synced_sh))

        params_local = {"stage": stage_p, "shared": shared_p}
        grads_local = {"stage": synced_s, "shared": synced_sh}
        ost = adam.AdamState(
            step=state["opt_step"],
            m={"stage": squeeze(state["opt_m"]["stage"]),
               "shared": state["opt_m"]["shared"]},
            v={"stage": squeeze(state["opt_v"]["stage"]),
               "shared": state["opt_v"]["shared"]},
        )
        new_p, ost, opt_mets = adam.update(params_local, grads_local, ost,
                                           adam_cfg, gnorm=gnorm)

        unsq = lambda t: tmap(lambda a: a[None], t)
        new_state = {
            "stage_params": unsq(new_p["stage"]),
            "shared_params": new_p["shared"],
            "opt_m": {"stage": unsq(ost.m["stage"]), "shared": ost.m["shared"]},
            "opt_v": {"stage": unsq(ost.v["stage"]), "shared": ost.v["shared"]},
            "opt_step": ost.step,
            "comp": tmap(lambda a: a[None, None], comp2),
        }
        from repro.core.powersgd import ef_norm_sq
        ef_norm = jnp.sqrt(pmean_dp(psum_pipe(ef_norm_sq(comp2))))
        metrics = {"loss": loss, "entropy": entropy,
                   "stage_entropy": stage_entropy, "ef_norm": ef_norm,
                   **opt_mets}
        return new_state, metrics

    dp = tuple(axes_dp)
    sspecs = {
        "stage_params": P("pipe"),
        "shared_params": P(),
        "opt_m": {"stage": P("pipe"), "shared": P()},
        "opt_v": {"stage": P("pipe"), "shared": P()},
        "opt_step": P(),
        "comp": P("pipe", dp),
    }
    step = shard_map_dp(
        local_step, mesh,
        in_specs=(sspecs, P(dp)),
        out_specs=({**sspecs}, P()),
        manual_axes=manual,
    )
    return step


def pipeline_state_shardings(state, model: Model, mesh):
    """NamedShardings for the pipelined TrainState.

    Stage-stacked leaves: 'pipe' on the stage dim + Megatron TP on the
    rest; shared leaves follow the flat TP rules; compressor state leads
    with ('pipe', dp) and keeps its (rank-thin or group-mixed) trailing
    dims replicated, mirroring the flat trainer's bucketed layout choice.
    """
    stage_specs = stage_param_pspecs(state["stage_params"], mesh)
    shared_specs = param_pspecs(state["shared_params"], mesh)
    dp = dp_axes(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    comp_shard = tmap(lambda a: ns(P("pipe", tuple(dp))), state["comp"])
    return {
        "stage_params": tmap(ns, stage_specs),
        "shared_params": tmap(ns, shared_specs),
        "opt_m": {"stage": tmap(ns, stage_specs),
                  "shared": tmap(ns, shared_specs)},
        "opt_v": {"stage": tmap(ns, stage_specs),
                  "shared": tmap(ns, shared_specs)},
        "opt_step": ns(P()),
        "comp": comp_shard,
    }
