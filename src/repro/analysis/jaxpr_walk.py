"""Closed-jaxpr traversal + replica-uniformity dataflow for the auditor.

The collective-safety passes (``parity``, ``budget``, ``hostcalls``) all
need the same three primitives, which live here:

  * :func:`walk` — depth-first traversal of a jaxpr INCLUDING every
    sub-jaxpr reachable through equation params (``cond`` branches,
    ``scan``/``while`` bodies, ``pjit``/``remat2``/``custom_*`` calls,
    ``shard_map`` bodies), yielding ``(eqn, path)`` pairs where ``path``
    is a stable, human-readable position string — the path-qualified
    part of every auditor diagnostic.
  * :func:`collective_signature` — the ORDERED sequence of
    :class:`CollectiveCall` records (primitive, named axes, operand
    shapes/dtypes, comm-relevant params) a jaxpr would issue. Two
    program fragments with equal signatures launch identical collective
    sequences — the SPMD deadlock-freedom currency.
  * :func:`uniform_env` — a forward dataflow pass computing, for every
    variable, the set of mesh axes across which its value is provably
    IDENTICAL on all ranks.  ``lax.switch`` on such a variable is safe
    for any collective over axes inside that set: every rank of the
    collective's group takes the same branch.  Sources of uniformity:
    literals/consts (uniform everywhere), ``axis_index`` (uniform
    everywhere EXCEPT its axis), collectives (their result is uniform
    over the reduced axes), shard_map inputs (uniform over every manual
    axis their ``in_names`` do NOT shard).  Everything else propagates
    the intersection of its operands — deterministic ops preserve
    uniformity.  The analysis is conservative: "not provably uniform"
    never means "safe".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

from jax.extend import core as jex_core

__all__ = [
    "COLLECTIVE_PRIMS",
    "HOST_CALLBACK_PRIMS",
    "CollectiveCall",
    "as_jaxpr",
    "subjaxprs",
    "walk",
    "collective_signature",
    "count_collectives",
    "uniform_env",
    "shard_map_contexts",
]

# Named-axis communication primitives (jax.lax.* parallel operators as
# they appear in jaxprs).  ``axis_index`` reads the mesh coordinate but
# moves no data — it is a uniformity SOURCE, not a collective.
COLLECTIVE_PRIMS = frozenset({
    "psum", "pmax", "pmin", "ppermute", "pbroadcast", "all_gather",
    "all_to_all", "psum_scatter", "pgather", "reduce_scatter",
})

# Host round-trips that must never appear inside a compiled train step
# (each one is a device->host sync under jit).
HOST_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "python_callback",
    "callback", "host_callback", "outside_call", "debug_print",
})


@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One collective launch: everything that must match across ranks."""

    primitive: str
    axes: tuple[str, ...]              # named mesh axes (sorted)
    operands: tuple[tuple[tuple[int, ...], str], ...]   # ((shape, dtype), ...)
    params: tuple[tuple[str, str], ...] = ()            # perm / groups / ...
    path: str = ""                     # jaxpr position (diagnostics only)

    def matches(self, other: "CollectiveCall") -> bool:
        """Signature equality — everything except the jaxpr position."""
        return (self.primitive == other.primitive and self.axes == other.axes
                and self.operands == other.operands
                and self.params == other.params)

    def describe(self) -> str:
        ops = ", ".join(f"{dt}{list(shp)}" for shp, dt in self.operands)
        return f"{self.primitive}[{','.join(self.axes)}]({ops})"


def as_jaxpr(obj: Any) -> jex_core.Jaxpr:
    """Accept a Jaxpr, ClosedJaxpr, or anything with a ``.jaxpr`` chain."""
    seen = set()
    while not isinstance(obj, jex_core.Jaxpr):
        if id(obj) in seen or not hasattr(obj, "jaxpr"):
            raise TypeError(f"not a jaxpr: {type(obj).__name__}")
        seen.add(id(obj))
        obj = obj.jaxpr
    return obj


def eqn_axes(eqn) -> tuple[str, ...]:
    """Named mesh axes a primitive communicates over (sorted, str only)."""
    axes: list[str] = []
    for key in ("axes", "axis_name", "axis_index_groups_axes"):
        v = eqn.params.get(key)
        if v is None:
            continue
        for a in v if isinstance(v, (tuple, list)) else (v,):
            if isinstance(a, str):
                axes.append(a)
    return tuple(sorted(set(axes)))


def subjaxprs(eqn) -> list[tuple[str, jex_core.Jaxpr]]:
    """(label, sub-jaxpr) for every jaxpr stored in an equation's params.

    ``cond`` branches get ``branch=i`` labels (the parity checker keys on
    them); everything else is labelled by its param name.  The scan is
    generic — any future primitive carrying jaxprs in params is walked.
    """
    out: list[tuple[str, jex_core.Jaxpr]] = []
    for key, val in eqn.params.items():
        items = val if isinstance(val, (tuple, list)) else (val,)
        multi = isinstance(val, (tuple, list))
        for i, item in enumerate(items):
            if isinstance(item, jex_core.ClosedJaxpr):
                item = item.jaxpr
            if not isinstance(item, jex_core.Jaxpr):
                continue
            if eqn.primitive.name == "cond" and key == "branches":
                out.append((f"branch={i}", item))
            else:
                out.append((f"{key}[{i}]" if multi else key, item))
    return out


def walk(jaxpr: Any, path: str = "") -> Iterator[tuple[Any, str]]:
    """Depth-first (eqn, path) over a jaxpr and all nested sub-jaxprs."""
    j = as_jaxpr(jaxpr)
    for n, eqn in enumerate(j.eqns):
        here = f"{path}/{eqn.primitive.name}#{n}"
        yield eqn, here
        for label, sub in subjaxprs(eqn):
            yield from walk(sub, f"{here}.{label}")


def _comm_params(eqn) -> tuple[tuple[str, str], ...]:
    """Comm-relevant non-axis params (permutation, explicit groups)."""
    out = []
    for key in ("perm", "axis_index_groups", "split_axis", "concat_axis",
                "all_gather_dimension", "tiled"):
        if eqn.params.get(key) is not None:
            out.append((key, repr(eqn.params[key])))
    return tuple(out)


def collective_signature(jaxpr: Any, path: str = "",
                         prims: frozenset[str] = COLLECTIVE_PRIMS,
                         ) -> tuple[CollectiveCall, ...]:
    """Ordered collective sequence of a jaxpr, nested control flow included.

    Note on loops: a ``scan``/``while`` body is included ONCE — the
    signature is the per-iteration sequence.  Branch parity of a switch
    nested in a loop still holds iff the per-iteration signatures match,
    so this is exactly what the parity checker needs (trip counts are
    rank-invariant under SPMD).
    """
    sig: list[CollectiveCall] = []
    for eqn, here in walk(jaxpr, path):
        if eqn.primitive.name not in prims:
            continue
        operands = tuple(
            (tuple(v.aval.shape), str(v.aval.dtype))
            for v in eqn.invars if hasattr(v, "aval"))
        sig.append(CollectiveCall(
            primitive=eqn.primitive.name, axes=eqn_axes(eqn),
            operands=operands, params=_comm_params(eqn), path=here))
    return tuple(sig)


def count_collectives(jaxpr: Any, primitive: str | None = None) -> int:
    """Number of collective eqns traced anywhere in a (closed) jaxpr.

    ``primitive="psum"`` counts just that primitive — the reusable form
    of the ad-hoc ``str(jaxpr).count("psum")`` spy the pipeline tests
    used to hand-roll (string counting also matched e.g. variable names;
    this counts equations).
    """
    want = frozenset({primitive}) if primitive else COLLECTIVE_PRIMS
    return sum(1 for eqn, _ in walk(jaxpr) if eqn.primitive.name in want)


# ------------------------------------------------------------- uniformity
def _inner_axis_index_axes(eqn) -> set[str]:
    """Axes any nested axis_index reads — conservative de-uniformizer."""
    axes: set[str] = set()
    for _, sub in subjaxprs(eqn):
        for inner, _ in walk(sub):
            if inner.primitive.name == "axis_index":
                a = inner.params.get("axis_name")
                for x in a if isinstance(a, (tuple, list)) else (a,):
                    if isinstance(x, str):
                        axes.add(x)
    return axes


def uniform_env(jaxpr: Any, in_uniform: list[frozenset[str]],
                all_axes: frozenset[str]) -> dict:
    """Forward pass: var -> axes over which its value is rank-uniform.

    ``in_uniform`` parallels the jaxpr's invars; constvars are treated as
    uniform over ``all_axes`` (closed-over constants are replicated).
    ``pjit``/``remat2``-style inline calls recurse with their operands'
    sets; opaque control flow (scan/while/cond) falls back to the
    intersection of its inputs minus any axis an inner ``axis_index``
    reads — sound, never more uniform than reality.
    """
    j = as_jaxpr(jaxpr)
    env: dict = {}
    for v, u in zip(j.invars, in_uniform):
        env[v] = frozenset(u)
    for v in j.constvars:
        env[v] = all_axes

    def read(x) -> frozenset[str]:
        if isinstance(x, jex_core.Literal):
            return all_axes
        return env.get(x, frozenset())

    for eqn in j.eqns:
        name = eqn.primitive.name
        ins = [read(x) for x in eqn.invars]
        base = frozenset(all_axes)
        for u in ins:
            base &= u
        if name == "axis_index":
            a = eqn.params.get("axis_name")
            drop = {x for x in (a if isinstance(a, (tuple, list)) else (a,))
                    if isinstance(x, str)}
            out = all_axes - drop
        elif name in COLLECTIVE_PRIMS and name not in ("ppermute", "pgather",
                                                       "all_to_all"):
            # reductions/gathers produce the same value on every member
            # rank; a ppermute/all_to_all result still varies per rank
            out = base | frozenset(eqn_axes(eqn))
        elif name in ("pjit", "closed_call", "core_call", "remat2",
                      "custom_jvp_call", "custom_vjp_call"):
            subs = subjaxprs(eqn)
            if len(subs) == 1:
                sub = subs[0][1]
                if len(sub.invars) == len(ins):
                    sub_env = uniform_env(sub, ins, all_axes)
                    outs = [sub_env.get(v, frozenset())
                            if not isinstance(v, jex_core.Literal)
                            else all_axes
                            for v in sub.outvars]
                    for ov, u in zip(eqn.outvars, outs):
                        env[ov] = u
                    continue
            out = base - _inner_axis_index_axes(eqn)
        elif subjaxprs(eqn):
            out = base - _inner_axis_index_axes(eqn)
        else:
            out = base
        for ov in eqn.outvars:
            env[ov] = out
    return env


def shard_map_contexts(jaxpr: Any) -> list[tuple[Any, str, frozenset[str],
                                                 list[frozenset[str]]]]:
    """Every shard_map body with its manual axes and per-input uniformity.

    Returns ``(body_jaxpr, path, manual_axes, in_uniform)`` tuples: an
    input is uniform over each manual axis its ``in_names`` entry does
    not shard (replicated params -> uniform over all manual axes; the
    batch -> varying over the DP axes).  This is the precise entry point
    the parity checker seeds :func:`uniform_env` with.
    """
    out = []
    for eqn, path in walk(jaxpr):
        if eqn.primitive.name != "shard_map":
            continue
        mesh = eqn.params.get("mesh")
        auto = eqn.params.get("auto") or frozenset()
        names = [str(a) for a in getattr(mesh, "axis_names", ())]
        manual = frozenset(n for n in names if n not in auto)
        in_names = eqn.params.get("in_names") or ()
        body = subjaxprs(eqn)[0][1]
        in_uniform = []
        for spec in in_names:
            sharded: set[str] = set()
            for ax_list in dict(spec).values():
                sharded.update(a for a in ax_list if isinstance(a, str))
            in_uniform.append(manual - sharded)
        out.append((body, f"{path}.jaxpr", manual, in_uniform))
    return out
