"""Psum-budget checker: predicted vs traced collective counts.

The bucketed sync executor has an exact, statically-derivable collective
bill — 2 factor psums per stacked PowerSGD shape group, 1 psum per flat
bucket chunk (``BucketLayout.num_collectives`` / ``SyncChunk.
num_collectives``), summed over a pipeline's distinct stage schedules
(``StagePlans.predicted_collectives``), plus EXACTLY the three Lemma-2
moment psums (n, s1, s2) that the GDS ISR alpha gate removes wholesale
on entropy-off steps.  This module turns those predictions into checks:

  * :class:`CollectiveSpy` — the one reusable psum-hook spy the test
    suite's ad-hoc ``calls = []`` closures grew into: pass it wherever a
    ``psum_mean`` hook goes, then assert against the layout.
  * :func:`check_sync_spy` — spy vs ``BucketLayout`` (count, factor/flat
    split, per-group ranks, wire dtypes).
  * :func:`check_entropy_gate` — entropy-on minus entropy-off traced
    psums == 3 for the pipelined step (the ISR invariant; the flat step
    measures entropy on already-synced grads, so its delta is 0).
  * :func:`check_overlap_branches` — the overlapped executor's switch
    branches vs the declared ``overlap_branch_psums`` launch metadata
    (delegates to ``parity.check_switch_budgets``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax

from .jaxpr_walk import count_collectives
from .parity import Violation, check_switch_budgets

__all__ = [
    "ENTROPY_PSUMS",
    "CollectiveSpy",
    "spy_sync",
    "check_sync_spy",
    "check_entropy_gate",
    "check_overlap_branches",
]

# The Lemma-2 sufficient-statistic psums (n, s1, s2) the ISR gate elides.
ENTROPY_PSUMS = 3


class CollectiveSpy:
    """Recording stand-in for the executors' ``psum_mean`` hook.

    Passes values through unchanged while recording (shape, dtype) of
    every launch — works under tracing (``jax.eval_shape``) and eager
    alike.  Factor psums are the 3-D stacked PowerSGD launches; flat
    psums are the 1-D packed buckets/chunks.
    """

    def __init__(self) -> None:
        self.calls: list[tuple[tuple[int, ...], Any]] = []

    def __call__(self, x):
        self.calls.append((tuple(x.shape), x.dtype))
        return x

    def __len__(self) -> int:
        return len(self.calls)

    @property
    def factor_calls(self) -> list[tuple[tuple[int, ...], Any]]:
        return [c for c in self.calls if len(c[0]) == 3]

    @property
    def flat_calls(self) -> list[tuple[tuple[int, ...], Any]]:
        return [c for c in self.calls if len(c[0]) == 1]

    def factor_ranks(self) -> list[int]:
        """Distinct trailing dims of the stacked factor psums — the DAC
        ranks the executor actually applied on the wire."""
        return sorted({shape[-1] for shape, _ in self.factor_calls})


def spy_sync(fn, *args) -> CollectiveSpy:
    """Run ``fn(*args, spy)`` under abstract evaluation, return the spy.

    ``fn`` takes the psum hook as its last argument (the executors'
    convention).  ``jax.eval_shape`` keeps this shape-only — no FLOPs,
    works on ShapeDtypeStruct trees at any model scale.
    """
    spy = CollectiveSpy()
    jax.eval_shape(lambda *a: fn(*a, spy), *args)
    return spy


def check_sync_spy(spy: CollectiveSpy, layout, where: str = "sync",
                   ) -> list[Violation]:
    """Spy record vs a ``BucketLayout``'s predicted collective bill."""
    out: list[Violation] = []
    want = layout.num_collectives()
    if len(spy) != want:
        out.append(Violation(
            rule="psum-budget", path=where,
            message=(f"executor launched {len(spy)} collectives, layout "
                     f"predicts {want} (2 per group x {len(layout.groups)} "
                     f"+ 1 per bucket x {len(layout.buckets)})")))
    nf = len(spy.factor_calls)
    if nf != 2 * len(layout.groups):
        out.append(Violation(
            rule="psum-budget", path=where,
            message=(f"{nf} stacked-factor psums, expected "
                     f"{2 * len(layout.groups)} (2 per shape group)")))
    want_ranks = sorted({g.rank for g in layout.groups})
    got_ranks = spy.factor_ranks()
    if got_ranks != want_ranks:
        out.append(Violation(
            rule="psum-budget", path=where,
            message=(f"factor psums carry ranks {got_ranks}, plan ranks "
                     f"are {want_ranks} — DAC ranks not applied on the "
                     f"wire")))
    return out


def check_entropy_gate(traced_on: Any, traced_off: Any,
                       expected_delta: int = ENTROPY_PSUMS,
                       where: str = "step") -> list[Violation]:
    """ISR invariant: the entropy-off variant traces exactly
    ``expected_delta`` fewer psums (3 moment psums for the pipelined
    step, 0 for the flat step) and never MORE work than entropy-on."""
    on = count_collectives(traced_on, "psum")
    off = count_collectives(traced_off, "psum")
    if on - off != expected_delta:
        return [Violation(
            rule="entropy-gate", path=where,
            message=(f"entropy-on traces {on} psums, entropy-off {off}: "
                     f"delta {on - off}, ISR invariant requires exactly "
                     f"{expected_delta}"))]
    return []


def check_overlap_branches(traced: Any, oplan, splans) -> list[Violation]:
    """Overlapped-step switches vs the planner's declared launch schedule.

    ``oplan``/``splans`` are the step's ``OverlapPlan``/``StagePlans``;
    the declared per-switch budgets come from
    ``pipeline.schedule.overlap_branch_psums`` (in-loop launch ticks in
    order, then the post-flush residual switch).
    """
    from repro.pipeline.schedule import overlap_branch_psums

    in_loop, residual = overlap_branch_psums(oplan, splans)
    expected: list[tuple[int, ...]] = [c for _, c in in_loop]
    expected.append(residual)
    return check_switch_budgets(traced, expected, "psum")
