"""Host-sync & recompile audit.

Two ways a "compiled" train step silently stops being compiled:

  * **Host round-trips inside the step.**  A ``pure_callback`` /
    ``io_callback`` / ``debug_callback`` traced into the jaxpr forces a
    device->host sync every step (the PR 8 telemetry work exists
    precisely to batch those at flush boundaries OUTSIDE the step).
    :func:`check_host_transfers` walks the traced step and flags every
    callback primitive, path-qualified.

  * **Unbounded recompilation.**  The trainer's ``_get_step`` cache is
    keyed ``(CompressionPlan, measure_entropy, SyncConfig)``; plans only
    change at DAC window boundaries and codecs only at window
    boundaries, so after N steps the cache must hold at most
    ``(N // window + 1)`` plans x 2 entropy variants x the codecs seen.
    :func:`check_step_cache` proves the enumerated keys are hashable and
    inside that bound; :func:`audit_recompiles` derives the bound from a
    live trainer (``Trainer.step_cache_keys``).
"""
from __future__ import annotations

from typing import Any, Iterable

from .jaxpr_walk import HOST_CALLBACK_PRIMS, walk
from .parity import Violation

__all__ = [
    "check_host_transfers",
    "check_step_cache",
    "audit_recompiles",
]


def check_host_transfers(traced: Any, allow: Iterable[str] = (),
                         ) -> list[Violation]:
    """Flag device->host callbacks traced into a compiled step.

    ``allow`` lists primitive names that are intentionally present (e.g.
    a debugging build); anything else in
    :data:`~repro.analysis.jaxpr_walk.HOST_CALLBACK_PRIMS` is a
    violation with the jaxpr path of the offending equation.
    """
    allowed = frozenset(allow)
    out: list[Violation] = []
    for eqn, path in walk(traced):
        name = eqn.primitive.name
        if name in HOST_CALLBACK_PRIMS and name not in allowed:
            cb = eqn.params.get("callback")
            what = getattr(cb, "__name__", None) or repr(cb) if cb else name
            out.append(Violation(
                rule="host-sync", path=path,
                message=(f"{name} ({what}) inside a compiled step — every "
                         f"invocation is a device->host round-trip")))
    return out


def check_step_cache(keys: Iterable[Any], steps: int, window: int,
                     entropy_variants: int = 2,
                     codecs_seen: int | None = None) -> list[Violation]:
    """Prove the step-cache keys are hashable and window-bounded.

    ``keys`` are the trainer's ``_get_step`` cache keys (tuples of
    ``(plan, measure_entropy, sync_cfg)``); ``steps``/``window`` bound
    the number of distinct plans at ``steps // window + 1`` (the DAC
    re-plans only at window boundaries).
    """
    out: list[Violation] = []
    keys = list(keys)
    for k in keys:
        try:
            hash(k)
        except TypeError:
            out.append(Violation(
                rule="recompile", path=repr(k),
                message="unhashable step-cache key — every lookup would "
                        "miss and recompile"))
            return out
    plans = {k[0] for k in keys if isinstance(k, tuple) and k}
    if codecs_seen is None:
        codecs_seen = len({k[2] for k in keys
                           if isinstance(k, tuple) and len(k) > 2}) or 1
    plan_bound = max(1, steps) // max(1, window) + 1
    if len(plans) > plan_bound:
        out.append(Violation(
            rule="recompile", path="_step_cache",
            message=(f"{len(plans)} distinct plans after {steps} steps "
                     f"with window={window}: plans must only change at "
                     f"window boundaries (bound {plan_bound})")))
    key_bound = plan_bound * entropy_variants * max(1, codecs_seen)
    if len(keys) > key_bound:
        out.append(Violation(
            rule="recompile", path="_step_cache",
            message=(f"{len(keys)} compiled step variants after {steps} "
                     f"steps (bound {key_bound} = {plan_bound} plans x "
                     f"{entropy_variants} entropy variants x "
                     f"{codecs_seen} codecs) — recompiles are not "
                     f"window-bounded")))
    return out


def audit_recompiles(trainer) -> list[Violation]:
    """Window-bounded-recompile audit of a live Trainer."""
    steps = len(trainer.history)
    window = int(trainer.edgc_cfg.dac.window)
    return check_step_cache(trainer.step_cache_keys(), steps, window)
