"""Collective-parity checker: SPMD deadlock freedom for branchy steps.

On multi-host SPMD hardware every rank executes the same program; a
``lax.switch``/``lax.cond`` whose branches launch DIFFERENT collective
sequences deadlocks the moment two ranks of one collective's group take
different branches — rank A blocks in a psum rank B never enters.  The
overlapped pipeline executor launches compressed per-stage sync inside
exactly such switches (`pipeline/schedule.py`), so the invariant this
module machine-checks is the one the whole sync-overlap design stands on.

A branch divergence is safe in precisely one case: the predicate is
provably UNIFORM across every mesh axis any branch collective runs over
(then all ranks of each collective group take the same branch).  The
pipelined launch switch is the canonical instance — predicate =
``axis_index('pipe')``, collectives over the DP axes only.  Provenance
comes from :func:`~repro.analysis.jaxpr_walk.uniform_env`, seeded at
each ``shard_map`` boundary from its ``in_names`` (replicated operands
are uniform everywhere, the batch varies over the DP axes, ...).

For switches with an intentionally divergent launch schedule the checker
additionally diffs per-branch collective counts against the DECLARED
launch metadata (``schedule.overlap_branch_psums``) — a dropped psum in
one branch is not a deadlock there (the DP group still agrees), but it
is a silently-unsynced gradient chunk; the budget diff catches it with
the same path-qualified diagnostics.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from jax.extend import core as jex_core

from .jaxpr_walk import (
    COLLECTIVE_PRIMS,
    as_jaxpr,
    collective_signature,
    count_collectives,
    eqn_axes,
    subjaxprs,
    uniform_env,
    walk,
)

__all__ = [
    "Violation",
    "check_collective_parity",
    "switch_collective_counts",
    "check_switch_budgets",
]


@dataclasses.dataclass(frozen=True)
class Violation:
    """One auditor finding, path-qualified into the traced jaxpr."""

    rule: str
    path: str
    message: str

    def __str__(self) -> str:
        return f"[{self.rule}] {self.path}: {self.message}"


def _named_axes(jaxpr: Any) -> frozenset[str]:
    """Every mesh axis name mentioned anywhere in the program."""
    axes: set[str] = set()
    for eqn, _ in walk(jaxpr):
        axes.update(eqn_axes(eqn))
        if eqn.primitive.name == "axis_index":
            a = eqn.params.get("axis_name")
            axes.update(x for x in (a if isinstance(a, (tuple, list))
                                    else (a,)) if isinstance(x, str))
        mesh = eqn.params.get("mesh")
        if mesh is not None:
            axes.update(str(n) for n in getattr(mesh, "axis_names", ()))
    return frozenset(axes)


def _sub_in_uniform(eqn, sub, ins: list[frozenset[str]]
                    ) -> list[frozenset[str]]:
    """Map an eqn's operand-uniformity onto a sub-jaxpr's invars.

    cond branches drop the predicate; while bodies drop the cond-fn
    consts; everything whose invars align 1:1 (scan, pjit, remat2, ...)
    maps directly.  Any mismatch falls back to "nothing provable" —
    conservative, never unsound.
    """
    name = eqn.primitive.name
    if name == "cond":
        mapped = ins[1:]
    elif name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        mapped = ins[cn:]
    else:
        mapped = ins
    if len(sub.invars) != len(mapped):
        return [frozenset()] * len(sub.invars)
    return mapped


def _shard_map_seed(eqn) -> tuple[frozenset[str], list[frozenset[str]]]:
    """(manual axes, per-invar uniformity) at a shard_map boundary."""
    mesh = eqn.params.get("mesh")
    auto = eqn.params.get("auto") or frozenset()
    names = [str(a) for a in getattr(mesh, "axis_names", ())]
    manual = frozenset(n for n in names if n not in auto)
    in_uniform = []
    for spec in (eqn.params.get("in_names") or ()):
        sharded: set[str] = set()
        for ax_list in dict(spec).values():
            sharded.update(a for a in ax_list if isinstance(a, str))
        in_uniform.append(manual - sharded)
    return manual, in_uniform


def _check_cond(eqn, path: str, pred_uniform: frozenset[str],
                out: list[Violation]) -> None:
    branches = eqn.params["branches"]
    sigs = [collective_signature(b, f"{path}.branch={i}")
            for i, b in enumerate(branches)]
    if _all_match(sigs):
        return
    # Divergent branches: every collective's axes must sit inside the
    # predicate's uniform set, else two group members can disagree.
    unsafe = [c for s in sigs for c in s
              if not frozenset(c.axes) <= pred_uniform]
    if not unsafe:
        return
    ref, other = sigs[0], None
    bi = 0
    for i, s in enumerate(sigs[1:], start=1):
        if len(s) != len(ref) or not all(a.matches(b)
                                         for a, b in zip(ref, s)):
            other, bi = s, i
            break
    detail = _diff_detail(ref, other, bi) if other is not None else ""
    axes_txt = sorted({a for c in unsafe for a in c.axes
                       if a not in pred_uniform})
    out.append(Violation(
        rule="collective-parity", path=path,
        message=(f"switch branches launch different collective sequences "
                 f"and the predicate is not uniform over {axes_txt} "
                 f"(uniform over {sorted(pred_uniform) or '[]'}) — "
                 f"SPMD deadlock on a real mesh. {detail}")))


def _all_match(sigs) -> bool:
    ref = sigs[0]
    for s in sigs[1:]:
        if len(s) != len(ref) or not all(a.matches(b)
                                         for a, b in zip(ref, s)):
            return False
    return True


def _diff_detail(ref, other, bi: int) -> str:
    n = min(len(ref), len(other))
    for k in range(n):
        if not ref[k].matches(other[k]):
            return (f"first divergence at collective #{k}: branch 0 issues "
                    f"{ref[k].describe()}, branch {bi} issues "
                    f"{other[k].describe()}.")
    longer, which = (ref, 0) if len(ref) > len(other) else (other, bi)
    return (f"branch {which} issues {abs(len(ref) - len(other))} extra "
            f"collective(s) starting with {longer[n].describe()} "
            f"(branch 0: {len(ref)}, branch {bi}: {len(other)}).")


def _check_jaxpr(j, in_uniform: list[frozenset[str]],
                 all_axes: frozenset[str], path: str,
                 out: list[Violation]) -> None:
    env = uniform_env(j, in_uniform, all_axes)

    def read(x) -> frozenset[str]:
        if isinstance(x, jex_core.Literal):
            return all_axes
        return env.get(x, frozenset())

    for n, eqn in enumerate(j.eqns):
        here = f"{path}/{eqn.primitive.name}#{n}"
        ins = [read(x) for x in eqn.invars]
        if eqn.primitive.name == "cond":
            _check_cond(eqn, here, ins[0], out)
        if eqn.primitive.name == "shard_map":
            manual, seed = _shard_map_seed(eqn)
            body = subjaxprs(eqn)[0][1]
            if len(seed) != len(body.invars):
                seed = [frozenset()] * len(body.invars)
            _check_jaxpr(body, seed, all_axes | manual, f"{here}.jaxpr", out)
            continue
        for label, sub in subjaxprs(eqn):
            _check_jaxpr(sub, _sub_in_uniform(eqn, sub, ins), all_axes,
                         f"{here}.{label}", out)


def check_collective_parity(traced: Any) -> list[Violation]:
    """Audit every switch/cond in a traced step for SPMD collective parity.

    ``traced`` is anything :func:`jax.make_jaxpr` returns (or a raw
    Jaxpr).  Returns [] when every branchy collective launch is provably
    deadlock-free; otherwise one path-qualified :class:`Violation` per
    offending switch.
    """
    jaxpr = as_jaxpr(traced)
    out: list[Violation] = []
    _check_jaxpr(jaxpr, [frozenset()] * len(jaxpr.invars),
                 _named_axes(jaxpr), "", out)
    return out


# ----------------------------------------------------- declared-budget diff
def switch_collective_counts(traced: Any, primitive: str = "psum",
                             ) -> list[tuple[str, tuple[int, ...]]]:
    """(path, per-branch collective counts) of every collective-carrying
    switch, in program order — the traced side of the launch-metadata
    diff.  Nested sub-switches are reported separately (walk order)."""
    out = []
    for eqn, path in walk(traced):
        if eqn.primitive.name != "cond":
            continue
        counts = tuple(count_collectives(b, primitive)
                       for b in eqn.params["branches"])
        if any(counts):
            out.append((path, counts))
    return out


def check_switch_budgets(traced: Any,
                         expected: Sequence[tuple[int, ...]],
                         primitive: str = "psum") -> list[Violation]:
    """Diff traced switch branches against the declared launch schedule.

    ``expected`` is the per-switch, per-branch collective budget in
    program order — for the overlapped pipelined step that is
    ``overlap_branch_psums(...)``: the in-loop launch switches in tick
    order, then the post-flush residual switch.  A branch whose traced
    count disagrees (e.g. a seeded mutation dropping one factor psum)
    yields a path-qualified violation naming branch and delta.
    """
    got = switch_collective_counts(traced, primitive)
    out: list[Violation] = []
    if len(got) != len(expected):
        out.append(Violation(
            rule="psum-budget", path="",
            message=(f"traced {len(got)} collective-carrying switches, "
                     f"launch metadata declares {len(expected)} "
                     f"(traced paths: {[p for p, _ in got]})")))
        return out
    for (path, counts), want in zip(got, expected):
        want = tuple(want)
        if counts == want:
            continue
        for b, (c, w) in enumerate(zip(counts, want)):
            if c != w:
                out.append(Violation(
                    rule="psum-budget", path=f"{path}.branch={b}",
                    message=(f"branch launches {c} {primitive} collectives, "
                             f"declared schedule expects {w} "
                             f"(full switch: traced={counts}, "
                             f"declared={want})")))
    return out
