"""Repo-specific AST lint rules for the collective-safety auditor.

These are invariants ruff cannot express — they encode how THIS codebase
keeps its compiled step compiled and its collectives well-formed:

  ``dup-dict-key``           duplicate literal keys in a dict display:
                             the later entry silently wins (the
                             ``DTYPE_BYTES`` ``"s64"`` bug this rule was
                             born from).  Checked repo-wide.
  ``host-call-in-hot-path``  ``float()`` / ``np.*`` / ``.block_until_
                             ready()`` in modules that run inside jit —
                             on a traced value each is a trace error or
                             a silent host sync.  Checked in the
                             HOT_PATH module list only; host-side
                             planner code inside those modules carries
                             an inline allow.
  ``collective-axis-name``   ``lax.psum(x)``-style collective calls
                             without an explicit axis name: under
                             shard_map the axis context is ambient and a
                             missing name reduces over nothing (or
                             raises late); every call must say which
                             mesh axis it reduces over.
  ``unhashable-cache-key``   a list/dict/set display used directly as a
                             ``*_cache`` subscript: unhashable keys turn
                             a compile cache into a per-step recompile.

Allowlist format: an inline ``# lint: allow(<rule-id>)`` comment on the
offending line suppresses that rule there (add a reason after the
closing paren); ``run_lint(..., allow={rule: [path-substring, ...]})``
suppresses a rule for whole files.
"""
from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Iterable

__all__ = ["LintFinding", "RULES", "HOT_PATH_SUFFIXES", "lint_source",
           "run_lint", "iter_py_files"]

RULES = {
    "dup-dict-key": "duplicate literal key in a dict display",
    "host-call-in-hot-path": "float()/np.*/.block_until_ready() in a "
                             "jit hot-path module",
    "collective-axis-name": "collective call without an explicit axis name",
    "unhashable-cache-key": "unhashable literal used as a cache key",
}

# Modules whose function bodies run inside jit (traced): host-call
# patterns there operate on tracers.  Mixed modules that also hold
# host-side planners (schedule.py) use inline allows for those lines.
HOT_PATH_SUFFIXES = (
    "core/powersgd.py", "core/bucketing.py", "core/wire.py",
    "core/entropy.py", "pipeline/sync.py", "pipeline/schedule.py",
    "dist/collectives.py", "train/step.py", "optim/adam.py",
    "kernels/", "models/",
)

# lax.* collectives that take the axis name as 2nd positional / kwarg.
_COLLECTIVE_FNS = frozenset({
    "psum", "pmean", "pmax", "pmin", "ppermute", "pbroadcast",
    "all_gather", "all_to_all", "psum_scatter",
})
_AXIS_KWARGS = frozenset({"axis_name", "axes"})

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([\w\-, ]+)\)")


@dataclasses.dataclass(frozen=True)
class LintFinding:
    file: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}"


def _allowed_rules(line_text: str) -> frozenset[str]:
    m = _ALLOW_RE.search(line_text)
    if not m:
        return frozenset()
    return frozenset(x.strip() for x in m.group(1).split(","))


def is_hot_path(filename: str) -> bool:
    norm = filename.replace(os.sep, "/")
    return any(suffix in norm for suffix in HOT_PATH_SUFFIXES)


class _Visitor(ast.NodeVisitor):
    def __init__(self, filename: str, lines: list[str], hot: bool) -> None:
        self.filename = filename
        self.lines = lines
        self.hot = hot
        self.findings: list[LintFinding] = []

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        line = getattr(node, "lineno", 1)
        text = self.lines[line - 1] if line <= len(self.lines) else ""
        if rule in _allowed_rules(text):
            return
        self.findings.append(LintFinding(self.filename, line, rule, message))

    # -------------------------------------------------------- dup-dict-key
    def visit_Dict(self, node: ast.Dict) -> None:
        seen: dict[object, int] = {}
        for key in node.keys:
            if key is None or not isinstance(key, ast.Constant):
                continue
            try:
                marker = (type(key.value).__name__, key.value)
            except TypeError:
                continue
            if marker in seen:
                self._emit(key, "dup-dict-key",
                           f"duplicate key {key.value!r} (first at line "
                           f"{seen[marker]}) — the earlier entry is "
                           f"silently overwritten")
            else:
                seen[marker] = key.lineno
        self.generic_visit(node)

    # ------------------------------------------------------------- calls
    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        # host-call-in-hot-path: float(...)
        if (self.hot and isinstance(func, ast.Name) and func.id == "float"):
            self._emit(node, "host-call-in-hot-path",
                       "float() on a traced value forces a host sync "
                       "(ConcretizationError under jit)")
        # host-call-in-hot-path: x.block_until_ready()
        if isinstance(func, ast.Attribute) and \
                func.attr == "block_until_ready" and self.hot:
            self._emit(node, "host-call-in-hot-path",
                       ".block_until_ready() inside a hot path is a "
                       "device sync")
        # collective-axis-name: lax.psum(x) with no axis argument
        if isinstance(func, ast.Attribute) and \
                func.attr in _COLLECTIVE_FNS and _is_lax(func.value):
            has_axis = (len(node.args) >= 2
                        or any(kw.arg in _AXIS_KWARGS
                               for kw in node.keywords))
            if not has_axis:
                self._emit(node, "collective-axis-name",
                           f"lax.{func.attr}() without an explicit axis "
                           f"name — collectives must say which mesh axis "
                           f"they communicate over")
        self.generic_visit(node)

    # ------------------------------------------------ np.* in hot paths
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self.hot and isinstance(node.value, ast.Name) and \
                node.value.id in ("np", "numpy"):
            self._emit(node, "host-call-in-hot-path",
                       f"np.{node.attr} in a jit hot path — numpy "
                       f"concretizes traced values (use jnp)")
        self.generic_visit(node)

    # ------------------------------------------------ unhashable keys
    def visit_Subscript(self, node: ast.Subscript) -> None:
        target = node.value
        name = (target.attr if isinstance(target, ast.Attribute)
                else target.id if isinstance(target, ast.Name) else "")
        if "cache" in name:
            for sub in ast.walk(node.slice):
                if isinstance(sub, (ast.List, ast.Dict, ast.Set,
                                    ast.ListComp, ast.SetComp,
                                    ast.DictComp)):
                    self._emit(node, "unhashable-cache-key",
                               f"{name}[...] indexed with an unhashable "
                               f"{type(sub).__name__.lower()} literal — "
                               f"every lookup misses and recompiles")
                    break
        self.generic_visit(node)


def _is_lax(node: ast.expr) -> bool:
    return (isinstance(node, ast.Name) and node.id == "lax") or \
           (isinstance(node, ast.Attribute) and node.attr == "lax")


def lint_source(source: str, filename: str = "<string>",
                hot: bool | None = None) -> list[LintFinding]:
    """Lint one module's source; ``hot`` overrides HOT_PATH detection."""
    if hot is None:
        hot = is_hot_path(filename)
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [LintFinding(filename, e.lineno or 1, "dup-dict-key",
                            f"unparseable: {e.msg}")]
    v = _Visitor(filename, source.splitlines(), hot)
    v.visit(tree)
    return v.findings


def iter_py_files(roots: Iterable[str]) -> list[str]:
    out = []
    for root in roots:
        if os.path.isfile(root):
            out.append(root)
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f) for f in sorted(filenames)
                       if f.endswith(".py"))
    return sorted(out)


def run_lint(roots: Iterable[str], select: Iterable[str] | None = None,
             allow: dict[str, list[str]] | None = None,
             ) -> list[LintFinding]:
    """Lint every ``.py`` under ``roots``.

    ``select`` restricts to a rule subset (e.g. only ``dup-dict-key``
    repo-wide); ``allow`` maps rule id -> path substrings to skip.
    """
    selected = frozenset(select) if select is not None else None
    allow = allow or {}
    findings: list[LintFinding] = []
    for path in iter_py_files(roots):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        for f in lint_source(src, path):
            if selected is not None and f.rule not in selected:
                continue
            if any(sub in path for sub in allow.get(f.rule, ())):
                continue
            findings.append(f)
    return findings
