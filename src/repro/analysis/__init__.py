"""Collective-safety auditor: static analysis over traced train steps.

Four passes, all operating on ``jax.make_jaxpr`` output (pure abstract
tracing — no FLOPs, works on ShapeDtypeStruct trees at any model scale)
or on Python source (the AST lint):

  * collective parity (`parity`) — SPMD deadlock freedom for every
    ``lax.switch``/``cond`` in a compiled step,
  * psum budgets (`budget`) — predicted vs traced collective counts,
  * host-sync & recompile audit (`hostcalls`),
  * repo-specific AST lint rules (`lint`).

CLI entry point: ``python -m repro.launch.audit``.
"""
from .jaxpr_walk import (
    COLLECTIVE_PRIMS,
    HOST_CALLBACK_PRIMS,
    CollectiveCall,
    as_jaxpr,
    collective_signature,
    count_collectives,
    shard_map_contexts,
    subjaxprs,
    uniform_env,
    walk,
)
from .parity import (
    Violation,
    check_collective_parity,
    check_switch_budgets,
    switch_collective_counts,
)
from .budget import (
    ENTROPY_PSUMS,
    CollectiveSpy,
    check_entropy_gate,
    check_overlap_branches,
    check_sync_spy,
    spy_sync,
)
from .hostcalls import (
    audit_recompiles,
    check_host_transfers,
    check_step_cache,
)
from .lint import (
    HOT_PATH_SUFFIXES,
    LintFinding,
    RULES,
    lint_source,
    run_lint,
)

__all__ = [
    "COLLECTIVE_PRIMS",
    "HOST_CALLBACK_PRIMS",
    "CollectiveCall",
    "as_jaxpr",
    "collective_signature",
    "count_collectives",
    "shard_map_contexts",
    "subjaxprs",
    "uniform_env",
    "walk",
    "Violation",
    "check_collective_parity",
    "check_switch_budgets",
    "switch_collective_counts",
    "ENTROPY_PSUMS",
    "CollectiveSpy",
    "check_entropy_gate",
    "check_overlap_branches",
    "check_sync_spy",
    "spy_sync",
    "audit_recompiles",
    "check_host_transfers",
    "check_step_cache",
    "HOT_PATH_SUFFIXES",
    "LintFinding",
    "RULES",
    "lint_source",
    "run_lint",
]
