"""Public jit'd wrappers for the Pallas kernels, with shape-aware fallbacks.

Callers use these; the wrappers pick interpret mode off the backend (CPU ->
interpret=True so the identical kernel bodies execute in Python), route
shapes the kernels can't tile (non-divisible, too large for a VMEM panel)
to the ref.py oracles, and handle dtype promotion.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import entropy_hist as _hist
from . import lowrank as _lr
from . import ref

F32 = jnp.float32


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _tileable(m: int, n: int) -> bool:
    return m % 128 == 0 and n % 128 == 0


@partial(jax.jit, static_argnames=())
def lowrank_p(grad, err, q):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.ef_lowrank_p(grad, err, q)
    return _lr.ef_lowrank_p(grad, err, q, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def lowrank_q(grad, err, p_hat):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.ef_lowrank_q(grad, err, p_hat)
    return _lr.ef_lowrank_q(grad, err, p_hat, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def decompress_residual(p_hat, q, grad, err):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.decompress_residual(p_hat, q, grad, err)
    return _lr.decompress_residual(p_hat, q, grad, err, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def orthonormalize(p):
    """Gram-Schmidt panel kernel under ~4 MB VMEM, else jnp QR."""
    m, r = p.shape
    if m * r * 4 > (4 << 20) or m % 8 != 0:
        return jnp.linalg.qr(p.astype(F32))[0]
    return _lr.gram_schmidt_panel(p, interpret=_interpret())


# ------------------------------------------------ batched (E, m, n) stacks
# Wrappers for the bucketed executor's shape groups; same routing rules as
# the 2-D wrappers (interpret on CPU, ref/jnp fallback for untileable shapes)
# applied per stack.

@partial(jax.jit, static_argnames=())
def lowrank_p3(grad, err, q):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.ef_lowrank_p)(grad, err, q)
    return _lr.ef_lowrank_p_batched(grad, err, q, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def lowrank_q3(grad, err, p_hat):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.ef_lowrank_q)(grad, err, p_hat)
    return _lr.ef_lowrank_q_batched(grad, err, p_hat, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def decompress_residual3(p_hat, q, grad, err):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.decompress_residual)(p_hat, q, grad, err)
    return _lr.decompress_residual_batched(p_hat, q, grad, err,
                                           interpret=_interpret())


@partial(jax.jit, static_argnames=())
def orthonormalize3(p):
    """Per-slice Gram-Schmidt panels under ~4 MB VMEM each, else jnp QR."""
    _, m, r = p.shape
    if m * r * 4 > (4 << 20) or m % 8 != 0:
        return jax.vmap(lambda x: jnp.linalg.qr(x.astype(F32))[0])(p)
    return _lr.gram_schmidt_panel_batched(p, interpret=_interpret())


# legacy alias used by core.powersgd's use_kernels path
def lowrank_matmul(m_mat, q):
    """M @ Q with the P-kernel (EF already folded into m_mat by the caller)."""
    zeros = jnp.zeros_like(m_mat)
    mm, nn = m_mat.shape
    if not _tileable(mm, nn):
        return m_mat.astype(F32) @ q.astype(F32)
    return _lr.ef_lowrank_p(m_mat, zeros, q, interpret=_interpret())


@partial(jax.jit, static_argnames=("num_bins", "range_sigmas"))
def sampled_entropy_hist(x, num_bins: int = 256, range_sigmas: float = 8.0):
    """Histogram differential entropy via the Pallas binning kernel."""
    eps = 1e-12
    x = x.astype(F32).reshape(-1)
    mu = jnp.mean(x)
    sigma = jnp.std(x) + eps
    lo = mu - range_sigmas * sigma
    width = (2.0 * range_sigmas * sigma) / num_bins
    counts = _hist.hist_counts(x, lo, 1.0 / width, num_bins=num_bins,
                               interpret=_interpret())
    p = counts / x.shape[0]
    plogp = jnp.where(p > 0, p * jnp.log(p + eps), 0.0)
    return -jnp.sum(plogp) + jnp.log(width + eps)
