"""Public jit'd wrappers for the Pallas kernels, with shape-aware fallbacks.

Callers use these; the wrappers pick interpret mode off the backend (CPU ->
interpret=True so the identical kernel bodies execute in Python), route
shapes the kernels can't tile (non-divisible, too large for a VMEM panel)
to the ref.py oracles, and handle dtype promotion.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import entropy_hist as _hist
from . import lowrank as _lr
from . import pack as _pack
from . import ref

F32 = jnp.float32


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


def _tileable(m: int, n: int) -> bool:
    return m % 128 == 0 and n % 128 == 0


@partial(jax.jit, static_argnames=())
def lowrank_p(grad, err, q):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.ef_lowrank_p(grad, err, q)
    return _lr.ef_lowrank_p(grad, err, q, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def lowrank_q(grad, err, p_hat):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.ef_lowrank_q(grad, err, p_hat)
    return _lr.ef_lowrank_q(grad, err, p_hat, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def decompress_residual(p_hat, q, grad, err):
    m, n = grad.shape
    if not _tileable(m, n):
        return ref.decompress_residual(p_hat, q, grad, err)
    return _lr.decompress_residual(p_hat, q, grad, err, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def orthonormalize(p):
    """Gram-Schmidt panel kernel under ~4 MB VMEM, else jnp QR."""
    m, r = p.shape
    if m * r * 4 > (4 << 20) or m % 8 != 0:
        return jnp.linalg.qr(p.astype(F32))[0]
    return _lr.gram_schmidt_panel(p, interpret=_interpret())


# ------------------------------------------------ batched (E, m, n) stacks
# Wrappers for the bucketed executor's shape groups; same routing rules as
# the 2-D wrappers (interpret on CPU, ref/jnp fallback for untileable shapes)
# applied per stack.

@partial(jax.jit, static_argnames=())
def lowrank_p3(grad, err, q):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.ef_lowrank_p)(grad, err, q)
    return _lr.ef_lowrank_p_batched(grad, err, q, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def lowrank_q3(grad, err, p_hat):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.ef_lowrank_q)(grad, err, p_hat)
    return _lr.ef_lowrank_q_batched(grad, err, p_hat, interpret=_interpret())


@partial(jax.jit, static_argnames=())
def decompress_residual3(p_hat, q, grad, err):
    _, m, n = grad.shape
    if not _tileable(m, n):
        return jax.vmap(ref.decompress_residual)(p_hat, q, grad, err)
    return _lr.decompress_residual_batched(p_hat, q, grad, err,
                                           interpret=_interpret())


@partial(jax.jit, static_argnames=())
def orthonormalize3(p):
    """Per-slice Gram-Schmidt panels under ~4 MB VMEM each, else jnp QR."""
    _, m, r = p.shape
    if m * r * 4 > (4 << 20) or m % 8 != 0:
        return jax.vmap(lambda x: jnp.linalg.qr(x.astype(F32))[0])(p)
    return _lr.gram_schmidt_panel_batched(p, interpret=_interpret())


# legacy alias used by core.powersgd's use_kernels path
def lowrank_matmul(m_mat, q):
    """M @ Q with the P-kernel (EF already folded into m_mat by the caller)."""
    zeros = jnp.zeros_like(m_mat)
    mm, nn = m_mat.shape
    if not _tileable(mm, nn):
        return m_mat.astype(F32) @ q.astype(F32)
    return _lr.ef_lowrank_p(m_mat, zeros, q, interpret=_interpret())


# ------------------------------------------------ wire-format bit packing
# b-bit code <-> uint32 word packing for core/wire.py. Small payloads (under
# one 512-word panel) route to the ref oracle — the padding would dominate —
# larger ones run the Pallas kernels (interpret on CPU, as above).

_PACK_BW = 512


@partial(jax.jit, static_argnames=("bits",))
def pack_bits(codes, bits: int):
    """Flat unsigned codes (n,) -> uint32 words (ceil(n / (32 // bits)),)."""
    epw = 32 // bits
    n = codes.shape[0]
    nwords = -(-n // epw)
    if nwords < _PACK_BW:
        return ref.pack_bits(codes, bits)
    nw_p = -(-nwords // _PACK_BW) * _PACK_BW
    c = jnp.pad(codes.astype(jnp.uint32), (0, nw_p * epw - n))
    slots = c.reshape(nw_p, epw).T            # row j = bit-slot j of each word
    words = _pack.pack_words(slots, bits=bits, bw=_PACK_BW,
                             interpret=_interpret())
    return words[:nwords]


@partial(jax.jit, static_argnames=("bits", "n"))
def unpack_bits(words, bits: int, n: int):
    """Inverse of pack_bits: uint32 words -> first n int32 codes."""
    epw = 32 // bits
    nwords = words.shape[0]
    if nwords < _PACK_BW:
        return ref.unpack_bits(words, bits, n)
    nw_p = -(-nwords // _PACK_BW) * _PACK_BW
    w = jnp.pad(words, (0, nw_p - nwords))
    slots = _pack.unpack_words(w, bits=bits, bw=_PACK_BW,
                               interpret=_interpret())
    return slots.T.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("num_bins", "range_sigmas"))
def sampled_entropy_hist(x, num_bins: int = 256, range_sigmas: float = 8.0):
    """Histogram differential entropy via the Pallas binning kernel."""
    eps = 1e-12
    x = x.astype(F32).reshape(-1)
    mu = jnp.mean(x)
    sigma = jnp.std(x) + eps
    lo = mu - range_sigmas * sigma
    width = (2.0 * range_sigmas * sigma) / num_bins
    counts = _hist.hist_counts(x, lo, 1.0 / width, num_bins=num_bins,
                               interpret=_interpret())
    p = counts / x.shape[0]
    plogp = jnp.where(p > 0, p * jnp.log(p + eps), 0.0)
    return -jnp.sum(plogp) + jnp.log(width + eps)
