"""Pallas kernels: bit-pack/unpack int codes for the entropy-coded wire.

The wire codec (core/wire.py) quantizes sync payloads to b-bit unsigned
codes (b in {4, 8}); these kernels pack 32//b codes into each uint32 word
and back. The pack -> unpack round trip is bit-exact, which is what lets
the coded sync path keep PR 6's chunked-vs-monolithic equality at the
coded-payload level.

Layout: the ops wrapper reshapes the flat code vector to (epw, nwords) --
row j holds bit-slot j of every word -- so the kernel only does contiguous
row slices (no in-kernel reshapes or strided loads). Grid is over word
blocks; each program ORs epw shifted rows into its (1, bw) word block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

U32 = jnp.uint32


def _pack_kernel(c_ref, w_ref, *, bits: int):
    epw = 32 // bits
    word = c_ref[0:1, :].astype(U32)
    for j in range(1, epw):
        word = word | (c_ref[j:j + 1, :].astype(U32) << U32(j * bits))
    w_ref[...] = word


def _unpack_kernel(w_ref, c_ref, *, bits: int):
    epw = 32 // bits
    mask = U32((1 << bits) - 1)
    w = w_ref[...]                                   # (1, bw) uint32
    rows = [((w >> U32(j * bits)) & mask).astype(jnp.int32)
            for j in range(epw)]
    c_ref[...] = jnp.concatenate(rows, axis=0)       # (epw, bw)


def pack_words(slots: jax.Array, *, bits: int, bw: int = 512,
               interpret: bool = True) -> jax.Array:
    """Pack slot-major codes (epw, nwords) -> uint32 words (nwords,).

    nwords must be a multiple of bw (the ops wrapper pads).
    """
    epw, nwords = slots.shape
    assert epw == 32 // bits and nwords % bw == 0
    words = pl.pallas_call(
        functools.partial(_pack_kernel, bits=bits),
        grid=(nwords // bw,),
        in_specs=[pl.BlockSpec((epw, bw), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, bw), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nwords), U32),
        interpret=interpret,
    )(slots)
    return words[0]


def unpack_words(words: jax.Array, *, bits: int, bw: int = 512,
                 interpret: bool = True) -> jax.Array:
    """Unpack uint32 words (nwords,) -> slot-major int32 codes (epw, nwords)."""
    epw = 32 // bits
    nwords = words.shape[0]
    assert nwords % bw == 0
    return pl.pallas_call(
        functools.partial(_unpack_kernel, bits=bits),
        grid=(nwords // bw,),
        in_specs=[pl.BlockSpec((1, bw), lambda i: (0, i))],
        out_specs=pl.BlockSpec((epw, bw), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((epw, nwords), jnp.int32),
        interpret=interpret,
    )(words.reshape(1, -1))
