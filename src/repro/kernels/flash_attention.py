"""Pallas flash-attention (forward) — kills the score-materialization traffic.

The roofline (EXPERIMENTS §Roofline) shows the memory term dominating every
train/prefill row, and the HLO walk attributes most of it to materialized
(block_q x Tk) attention scores: the pure-jnp blockwise attention still
writes/reads every score block through HBM (~2 * B*H*T*Tk*4 bytes per
layer). The fix is the classic flash schedule: tile Q in VMEM, stream K/V
tiles, keep the softmax running statistics (m, l) and the output accumulator
in VMEM scratch — scores never leave VMEM.

Layout: grid (B*Hkv*rep, Tq/bq, Tk/bk); the K-tile axis is the innermost
(sequential) grid dim, accumulating into VMEM scratch. Causal masking skips
fully-masked tiles via ``pl.when``. GQA is handled by indexing the kv head
as (head // rep).

Forward-only: training integration would pair it with a custom_vjp backward
kernel (the standard recompute form); serving prefill uses it as-is. The
oracle is ref.flash_reference == blockwise_attention semantics.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, bq: int, bk: int, rep: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def body():
        q = q_ref[0].astype(F32)                    # (bq, d)
        k = k_ref[0].astype(F32)                    # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=F32) * scale  # (bq, bk)
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                       # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)              # (bq, 1)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        v = v_ref[0].astype(F32)                     # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v, preferred_element_type=F32)
        m_ref[...] = m_new

    if causal:
        # skip K tiles strictly above the diagonal of this Q tile
        pl.when((kb * bk) <= (qb * bq + bq - 1))(body)
    else:
        body()

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: bool = True):
    """q: (B, Tq, H, Dh); k, v: (B, Tk, Hkv, Dh). Returns (B, Tq, H, Dh).

    VMEM working set per program: q/k/v tiles + (bq, Dh) accumulator +
    (bq, bk) scores ≈ (2*bq + 2*bk) * Dh * 4 + bq*bk*4 bytes — with the
    defaults and Dh=128, ~0.75 MB, comfortably inside a v5e core's VMEM.
    """
    B, Tq, H, Dh = q.shape
    _, Tk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    bq = min(bq, Tq)
    bk = min(bk, Tk)
    assert Tq % bq == 0 and Tk % bk == 0, (Tq, bq, Tk, bk)

    # flatten heads into the leading grid dim: (B*H, T, Dh)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)

    grid = (B * H, Tq // bq, Tk // bk)

    def kv_index(h, i, j):
        # map flattened q-head index -> kv-head index (GQA)
        return (h // rep, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          bq=bq, bk=bk, rep=rep),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, Dh), F32),   # output accumulator
            pltpu.VMEM((bq, 1), F32),    # running max m
            pltpu.VMEM((bq, 1), F32),    # running sum l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)
