"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def ef_lowrank_p(grad: jax.Array, err: jax.Array, q: jax.Array) -> jax.Array:
    """Fused error-feedback + P factor: P = (grad + err) @ q, fp32 accum.

    grad, err: (m, n); q: (n, r) -> (m, r).
    """
    m_mat = grad.astype(F32) + err.astype(F32)
    return m_mat @ q.astype(F32)


def ef_lowrank_q(grad: jax.Array, err: jax.Array, p_hat: jax.Array) -> jax.Array:
    """Fused error-feedback + Q factor: Q = (grad + err)^T @ p_hat.

    grad, err: (m, n); p_hat: (m, r) -> (n, r).
    """
    m_mat = grad.astype(F32) + err.astype(F32)
    return m_mat.T @ p_hat.astype(F32)


def decompress_residual(p_hat: jax.Array, q: jax.Array, grad: jax.Array,
                        err: jax.Array) -> tuple[jax.Array, jax.Array]:
    """g_hat = p_hat @ q^T and the new EF residual (grad + err) - g_hat."""
    g_hat = p_hat.astype(F32) @ q.astype(F32).T
    new_err = grad.astype(F32) + err.astype(F32) - g_hat
    return g_hat, new_err


def gram_schmidt(p: jax.Array, eps: float = 1e-8) -> jax.Array:
    """Column-wise modified Gram-Schmidt (m, r) -> orthonormal (m, r)."""
    m, r = p.shape
    p = p.astype(F32)
    cols = []
    for i in range(r):
        v = p[:, i]
        for u in cols:
            v = v - jnp.dot(u, v) * u
        v = v / (jnp.linalg.norm(v) + eps)
        cols.append(v)
    return jnp.stack(cols, axis=1)


def sampled_entropy_hist(x: jax.Array, num_bins: int = 256,
                         range_sigmas: float = 8.0, eps: float = 1e-12
                         ) -> jax.Array:
    """Histogram differential entropy of a flat sample (nats).

    Matches repro.core.entropy.histogram_entropy exactly (same binning).
    """
    x = x.astype(F32).reshape(-1)
    mu = jnp.mean(x)
    sigma = jnp.std(x) + eps
    lo = mu - range_sigmas * sigma
    width = (2.0 * range_sigmas * sigma) / num_bins
    idx = jnp.clip(((x - lo) / width).astype(jnp.int32), 0, num_bins - 1)
    counts = jnp.zeros((num_bins,), F32).at[idx].add(1.0)
    p = counts / x.shape[0]
    plogp = jnp.where(p > 0, p * jnp.log(p + eps), 0.0)
    return -jnp.sum(plogp) + jnp.log(width + eps)


def pack_bits(codes: jax.Array, bits: int) -> jax.Array:
    """Bit-pack unsigned int codes in [0, 2**bits) into uint32 words.

    codes: flat (n,) integer array; bits must divide 32 (4 or 8 in
    practice). Returns (ceil(n / (32 // bits)),) uint32 where word w holds
    codes[w*epw : (w+1)*epw] in its low-to-high bit fields. The tail word
    is zero-padded, so pack -> unpack is a bit-exact identity on the first
    n elements.
    """
    epw = 32 // bits
    n = codes.shape[0]
    pad = (-n) % epw
    c = jnp.pad(codes.astype(jnp.uint32), (0, pad)).reshape(-1, epw)
    word = c[:, 0]
    for j in range(1, epw):
        word = word | (c[:, j] << jnp.uint32(j * bits))
    return word


def unpack_bits(words: jax.Array, bits: int, n: int) -> jax.Array:
    """Inverse of pack_bits: uint32 words -> first n int32 codes."""
    epw = 32 // bits
    mask = jnp.uint32((1 << bits) - 1)
    cols = [(words >> jnp.uint32(j * bits)) & mask for j in range(epw)]
    codes = jnp.stack(cols, axis=1).reshape(-1)
    return codes[:n].astype(jnp.int32)


def flash_reference(q, k, v, causal: bool = True):
    """Plain full-materialization GQA attention (flash kernel's oracle)."""
    import math
    B, Tq, H, Dh = q.shape
    _, Tk, Hkv, _ = k.shape
    rep = H // Hkv
    qh = q.reshape(B, Tq, Hkv, rep, Dh).astype(F32)
    s = jnp.einsum("bqgrd,bkgd->bgrqk", qh, k.astype(F32)) / math.sqrt(Dh)
    if causal:
        mask = jnp.arange(Tq)[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqk,bkgd->bqgrd", p, v.astype(F32))
    return o.reshape(B, Tq, H, Dh).astype(q.dtype)
