"""Pallas TPU kernels for EDGC's compression hot-spots (+ jnp oracles)."""
from . import ops, ref

__all__ = ["ops", "ref"]
