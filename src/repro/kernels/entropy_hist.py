"""Pallas kernel: histogram counts for the GDS entropy estimator.

GDS needs a 256-bin histogram of a beta-sampled gradient slice every 1/alpha
iterations. On GPU the reference implementation copies the sample to host;
on TPU that transfer stalls the step, so we bin on-device: one pass over the
sample in VMEM-sized tiles, each tile scattering into a per-program partial
histogram that the grid accumulates (revisiting output blocks is free —
the (1, bins) histogram block stays resident).

mu/sigma (for the bin range) are cheap jnp reductions computed by the ops
wrapper; the kernel gets (lo, inv_width) as scalar prefetch-style operands
(a (1, 1) block in SMEM-compatible layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

F32 = jnp.float32


def _hist_kernel(scal_ref, x_ref, o_ref, *, num_bins: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    lo = scal_ref[0, 0]
    inv_w = scal_ref[0, 1]
    x = x_ref[...].astype(F32)                     # (1, bx)
    idx = jnp.clip(((x - lo) * inv_w).astype(jnp.int32), 0, num_bins - 1)
    onehot = (idx[0, :, None] == jnp.arange(num_bins)[None, :]).astype(F32)
    o_ref[...] += jnp.sum(onehot, axis=0, keepdims=True)  # (1, bins)


def hist_counts(x, lo, inv_width, *, num_bins: int = 256, bx: int = 2048,
                interpret: bool = True):
    """Histogram counts of flat x (N,) given precomputed (lo, 1/bin_width)."""
    n = x.shape[0]
    bx = min(bx, n)
    pad = (-n) % bx
    if pad:
        # pad with a sentinel far below lo: every padded element clips into
        # bin 0, and the pad count is subtracted back out of bin 0 below
        sentinel = jnp.full((pad,), lo - 1e6, x.dtype)
        x = jnp.concatenate([x, sentinel], 0)
    scal = jnp.stack([lo, inv_width]).reshape(1, 2).astype(F32)
    counts = pl.pallas_call(
        functools.partial(_hist_kernel, num_bins=num_bins),
        grid=(x.shape[0] // bx,),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
            pl.BlockSpec((1, bx), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_bins), F32),
        interpret=interpret,
    )(scal, x.reshape(1, -1))
    counts = counts[0]
    if pad:
        counts = counts.at[0].add(-float(pad))  # lint: allow(host-call-in-hot-path) pad is a static Python int
    return counts
