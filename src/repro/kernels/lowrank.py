"""Pallas TPU kernels for the PowerSGD compression hot-spots.

The compression pipeline touches the full (m, n) gradient three times per
step: P = (G + E) @ Q, Q = (G + E)^T @ P_hat, and the decompress+residual
G_hat = P_hat Q^T / E' = (G + E) - G_hat. Uncompressed these are four HBM
sweeps of the gradient (EF add, two factor matmuls, residual); the kernels
fuse the EF add into each consumer so every sweep reads G and E exactly once
— the arithmetic intensity of the factor matmuls is ~r FLOPs/byte, so they
are HBM-bound and the fusion is worth exactly one sweep (~25%).

Tiling: (bm, bn) VMEM tiles of the gradient, MXU-aligned (multiples of 128
on the contracting dims); the thin factor (n x r or m x r panel, r <= 256)
stays resident across the accumulation grid axis. fp32 accumulation.

All kernels run under ``interpret=True`` on CPU (how tests validate them
against ref.py) and compile for TPU with the same BlockSpecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

F32 = jnp.float32


def _tile(dim: int, pref: int) -> int:
    """Largest MXU-friendly tile <= pref that divides dim (fallback: dim)."""
    for t in (pref, pref // 2, pref // 4, 256, 128):
        if t and t <= dim and dim % t == 0:
            return t
    return dim


# --------------------------------------------------------------- P = (G+E)@Q
def _p_kernel(g_ref, e_ref, q_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    m_blk = g_ref[...].astype(F32) + e_ref[...].astype(F32)   # fused EF add
    o_ref[...] += jnp.dot(m_blk, q_ref[...].astype(F32),
                          preferred_element_type=F32)


def ef_lowrank_p(grad, err, q, *, bm: int = 256, bn: int = 512,
                 interpret: bool = True):
    """P = (grad + err) @ q.  grad/err (m, n), q (n, r) -> (m, r) fp32."""
    m, n = grad.shape
    r = q.shape[1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _p_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), F32),
        interpret=interpret,
    )(grad, err, q)


# ------------------------------------------------------------ Q = (G+E)^T@P
def _q_kernel(g_ref, e_ref, p_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    m_blk = g_ref[...].astype(F32) + e_ref[...].astype(F32)
    o_ref[...] += jnp.dot(m_blk.T, p_ref[...].astype(F32),
                          preferred_element_type=F32)


def ef_lowrank_q(grad, err, p_hat, *, bm: int = 512, bn: int = 256,
                 interpret: bool = True):
    """Q = (grad + err)^T @ p_hat.  grad/err (m, n), p_hat (m, r) -> (n, r)."""
    m, n = grad.shape
    r = p_hat.shape[1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (n // bn, m // bm)   # accumulate over m
    return pl.pallas_call(
        _q_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm, bn), lambda j, i: (i, j)),
            pl.BlockSpec((bm, r), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bn, r), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), F32),
        interpret=interpret,
    )(grad, err, p_hat)


# --------------------------------------- G_hat = P Q^T ; E' = (G+E) - G_hat
def _dec_kernel(p_ref, q_ref, g_ref, e_ref, ghat_ref, newerr_ref):
    g_hat = jnp.dot(p_ref[...].astype(F32), q_ref[...].astype(F32).T,
                    preferred_element_type=F32)
    ghat_ref[...] = g_hat.astype(ghat_ref.dtype)
    m_blk = g_ref[...].astype(F32) + e_ref[...].astype(F32)
    newerr_ref[...] = (m_blk - g_hat).astype(newerr_ref.dtype)


def decompress_residual(p_hat, q, grad, err, *, bm: int = 256, bn: int = 512,
                        interpret: bool = True):
    """(g_hat, new_err) both (m, n), one pass, no accumulation grid axis."""
    m, n = grad.shape
    r = q.shape[1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _dec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, r), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), grad.dtype),
            jax.ShapeDtypeStruct((m, n), grad.dtype),
        ],
        interpret=interpret,
    )(p_hat, q, grad, err)


# ------------------------------------------------------- Gram-Schmidt panel
def _gs_kernel(p_ref, o_ref, *, r: int, eps: float):
    """Single-block modified Gram-Schmidt; the (m, r) panel lives in VMEM.

    r is static and small (<= 256): the column loop unrolls; each step is a
    VPU dot + rank-1 update on the resident panel.
    """
    p = p_ref[...].astype(F32)
    for i in range(r):
        v = p[:, i]
        if i > 0:
            u = p[:, :i]                          # already orthonormal
            coef = jnp.einsum("mk,m->k", u, v)    # (i,)
            v = v - u @ coef
        v = v / (jnp.sqrt(jnp.sum(v * v)) + eps)
        p = p.at[:, i].set(v)
    o_ref[...] = p


def gram_schmidt_panel(p, *, eps: float = 1e-8, interpret: bool = True):
    """Orthonormalize an (m, r) panel in one VMEM-resident kernel call.

    VMEM budget: m * r * 4 bytes (<= ~4 MB for m=16384, r=64). ops.py falls
    back to jnp QR above that.
    """
    m, r = p.shape
    return pl.pallas_call(
        functools.partial(_gs_kernel, r=r, eps=eps),
        grid=(1,),
        in_specs=[pl.BlockSpec((m, r), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((m, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, r), F32),
        interpret=interpret,
    )(p)


# -------------------------------------------------- batched (E, m, n) stacks
# Entry points for the bucketed sync executor (core/bucketing.py): a shape
# group stacks E same-shaped gradients, and the grid grows a leading E axis
# so one kernel launch sweeps the whole stack. Block shapes keep a leading 1
# on the stack axis; the VMEM working set per program is identical to the
# 2-D kernels'.

def _p3_kernel(g_ref, e_ref, q_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    m_blk = g_ref[0].astype(F32) + e_ref[0].astype(F32)   # fused EF add
    o_ref[0] += jnp.dot(m_blk, q_ref[0].astype(F32),
                        preferred_element_type=F32)


def ef_lowrank_p_batched(grad, err, q, *, bm: int = 256, bn: int = 512,
                         interpret: bool = True):
    """P[e] = (grad[e] + err[e]) @ q[e].  (E, m, n) x (E, n, r) -> (E, m, r)."""
    num_e, m, n = grad.shape
    r = q.shape[-1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (num_e, m // bm, n // bn)    # accumulate over j (fastest axis)
    return pl.pallas_call(
        _p3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bn, r), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, r), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_e, m, r), F32),
        interpret=interpret,
    )(grad, err, q)


def _q3_kernel(g_ref, e_ref, p_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    m_blk = g_ref[0].astype(F32) + e_ref[0].astype(F32)
    o_ref[0] += jnp.dot(m_blk.T, p_ref[0].astype(F32),
                        preferred_element_type=F32)


def ef_lowrank_q_batched(grad, err, p_hat, *, bm: int = 512, bn: int = 256,
                         interpret: bool = True):
    """Q[e] = (grad[e] + err[e])^T @ p_hat[e].  -> (E, n, r)."""
    num_e, m, n = grad.shape
    r = p_hat.shape[-1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (num_e, n // bn, m // bm)    # accumulate over m (fastest axis)
    return pl.pallas_call(
        _q3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, bm, bn), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, bm, r), lambda b, j, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, r), lambda b, j, i: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((num_e, n, r), F32),
        interpret=interpret,
    )(grad, err, p_hat)


def _dec3_kernel(p_ref, q_ref, g_ref, e_ref, ghat_ref, newerr_ref):
    g_hat = jnp.dot(p_ref[0].astype(F32), q_ref[0].astype(F32).T,
                    preferred_element_type=F32)
    ghat_ref[0] = g_hat.astype(ghat_ref.dtype)
    m_blk = g_ref[0].astype(F32) + e_ref[0].astype(F32)
    newerr_ref[0] = (m_blk - g_hat).astype(newerr_ref.dtype)


def decompress_residual_batched(p_hat, q, grad, err, *, bm: int = 256,
                                bn: int = 512, interpret: bool = True):
    """(g_hat, new_err) both (E, m, n); one pass, no accumulation axis."""
    num_e, m, n = grad.shape
    r = q.shape[-1]
    bm, bn = _tile(m, bm), _tile(n, bn)
    grid = (num_e, m // bm, n // bn)
    return pl.pallas_call(
        _dec3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, r), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bn, r), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
            pl.BlockSpec((1, bm, bn), lambda b, i, j: (b, i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((num_e, m, n), grad.dtype),
            jax.ShapeDtypeStruct((num_e, m, n), grad.dtype),
        ],
        interpret=interpret,
    )(p_hat, q, grad, err)


def _gs3_kernel(p_ref, o_ref, *, r: int, eps: float):
    p = p_ref[0].astype(F32)
    for i in range(r):
        v = p[:, i]
        if i > 0:
            u = p[:, :i]
            coef = jnp.einsum("mk,m->k", u, v)
            v = v - u @ coef
        v = v / (jnp.sqrt(jnp.sum(v * v)) + eps)
        p = p.at[:, i].set(v)
    o_ref[0] = p


def gram_schmidt_panel_batched(p, *, eps: float = 1e-8,
                               interpret: bool = True):
    """Per-slice Gram-Schmidt over an (E, m, r) stack; grid over E, one
    VMEM-resident (m, r) panel per program (same budget as the 2-D panel)."""
    num_e, m, r = p.shape
    return pl.pallas_call(
        functools.partial(_gs3_kernel, r=r, eps=eps),
        grid=(num_e,),
        in_specs=[pl.BlockSpec((1, m, r), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, m, r), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_e, m, r), F32),
        interpret=interpret,
    )(p)
