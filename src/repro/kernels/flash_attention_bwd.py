"""Pallas flash-attention backward — completes the training-path kernel.

Standard recompute-form backward (no materialized scores in HBM):

  D  = rowsum(dO ∘ O)                      (per query row)
  P  = exp(S - L)     with L = m + log(l)  (recomputed per tile)
  dV = Σ_q  Pᵀ dO
  dP = dO Vᵀ
  dS = P ∘ (dP - D)
  dQ = Σ_k  dS K · scale
  dK = Σ_q  dSᵀ Q · scale

Two kernels with transposed grids (the classic split):
  * dq kernel : grid (BH, n_q, n_k) — dQ tile accumulates across k tiles;
  * dkv kernel: grid (BH, n_k, n_q) — dK/dV tiles accumulate across q tiles.

``flash_attention_train`` wires fwd+bwd through jax.custom_vjp; the fwd
saves (O, LSE) — the standard memory footprint (2 extra rows per query).
Oracle: jax.grad of ref.flash_reference (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu


F32 = jnp.float32
NEG_INF = -1e30


# ----------------------------------------------------------- fwd (with LSE)
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                *, scale: float, causal: bool, bq: int, bk: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        s = jnp.dot(q, k.T, preferred_element_type=F32) * scale
        if causal:
            q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v_ref[0].astype(F32), preferred_element_type=F32)
        m_ref[...] = m_new

    if causal:
        pl.when((kb * bk) <= (qb * bq + bq - 1))(body)
    else:
        body()

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)
        lse_ref[0] = (m_ref[...] + jnp.log(l))[:, 0]


def _recompute_p(q, k, lse_rows, *, scale, causal, qb, kb, bq, bk):
    """P tile from saved LSE: exp(S - L)."""
    s = jnp.dot(q, k.T, preferred_element_type=F32) * scale
    if causal:
        q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    return jnp.exp(s - lse_rows[:, None])


# ----------------------------------------------------------------- dq kernel
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale: float, causal: bool, bq: int, bk: int):
    kb = pl.program_id(2)
    qb = pl.program_id(1)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        p = _recompute_p(q, k, lse_ref[0], scale=scale, causal=causal,
                         qb=qb, kb=kb, bq=bq, bk=bk)
        dp = jnp.dot(do_ref[0].astype(F32), v_ref[0].astype(F32).T,
                     preferred_element_type=F32)
        ds = p * (dp - delta_ref[0][:, None])
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=F32) * scale

    if causal:
        pl.when((kb * bk) <= (qb * bq + bq - 1))(body)
    else:
        body()

    @pl.when(kb == pl.num_programs(2) - 1)
    def _finish():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------- dkv kernel
def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale: float, causal: bool, bq: int, bk: int):
    qb = pl.program_id(2)          # inner (accumulation) axis = q tiles
    kb = pl.program_id(1)

    @pl.when(qb == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def body():
        q = q_ref[0].astype(F32)
        k = k_ref[0].astype(F32)
        p = _recompute_p(q, k, lse_ref[0], scale=scale, causal=causal,
                         qb=qb, kb=kb, bq=bq, bk=bk)
        do = do_ref[0].astype(F32)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=F32)
        dp = jnp.dot(do, v_ref[0].astype(F32).T, preferred_element_type=F32)
        ds = p * (dp - delta_ref[0][:, None])
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=F32) * scale

    if causal:
        # q tiles strictly above this k tile's diagonal contribute nothing
        pl.when((qb * bq + bq - 1) >= (kb * bk))(body)
    else:
        body()

    @pl.when(qb == pl.num_programs(2) - 1)
    def _finish():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


# ------------------------------------------------------------------ plumbing
def _fwd_with_stats(q, k, v, *, causal, bq, bk, interpret):
    B, Tq, H, Dh = q.shape
    _, Tk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    grid = (B * H, Tq // bq, Tk // bk)
    kv_index = lambda h, i, j: (h // rep, j, 0)
    o, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=scale, causal=causal, bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dh), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tq, Dh), q.dtype),
            jax.ShapeDtypeStruct((B * H, Tq), F32),
        ],
        scratch_shapes=[pltpu.VMEM((bq, Dh), F32), pltpu.VMEM((bq, 1), F32),
                        pltpu.VMEM((bq, 1), F32)],
        interpret=interpret,
    )(qf, kf, vf)
    return o, lse


def _bwd(q, k, v, o, lse, do, *, causal, bq, bk, interpret):
    B, Tq, H, Dh = q.shape
    _, Tk, Hkv, _ = k.shape
    rep = H // Hkv
    scale = 1.0 / math.sqrt(Dh)
    BH = B * H
    qf = q.transpose(0, 2, 1, 3).reshape(BH, Tq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Tk, Dh)
    dof = do.transpose(0, 2, 1, 3).reshape(BH, Tq, Dh)
    of = o.transpose(0, 2, 1, 3).reshape(BH, Tq, Dh)
    delta = jnp.sum(dof.astype(F32) * of.astype(F32), axis=-1)  # (BH, Tq)

    kv_index = lambda h, i, j: (h // rep, j, 0)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal, bq=bq, bk=bk),
        grid=(BH, Tq // bq, Tk // bk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tq, Dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, Dh), F32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    # dK/dV accumulate over q tiles PER Q-HEAD; sum GQA groups afterwards.
    kv_q_index = lambda h, i, j: (h // rep, i, 0)
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal, bq=bq, bk=bk),
        grid=(BH, Tk // bk, Tq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, Dh), kv_q_index),
            pl.BlockSpec((1, bk, Dh), kv_q_index),
            pl.BlockSpec((1, bq, Dh), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, j)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, Dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, Dh), lambda h, i, j: (h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tk, Dh), F32),
            jax.ShapeDtypeStruct((BH, Tk, Dh), F32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, Dh), F32), pltpu.VMEM((bk, Dh), F32)],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)

    dq = dq.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)
    # GQA: sum the rep query heads sharing each kv head
    dk = dk.reshape(B, Hkv, rep, Tk, Dh).sum(axis=2).transpose(0, 2, 1, 3)
    dv = dv.reshape(B, Hkv, rep, Tk, Dh).sum(axis=2).transpose(0, 2, 1, 3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ------------------------------------------------------------- custom_vjp op
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_train(q, k, v, causal: bool = True, bq: int = 256,
                          bk: int = 256, interpret: bool = True):
    o, _ = _fwd_with_stats(q, k, v, causal=causal, bq=min(bq, q.shape[1]),
                           bk=min(bk, k.shape[1]), interpret=interpret)
    B, Tq, H, Dh = q.shape
    return o.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)


def _vjp_fwd(q, k, v, causal, bq, bk, interpret):
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    o, lse = _fwd_with_stats(q, k, v, causal=causal, bq=bq, bk=bk,
                             interpret=interpret)
    B, Tq, H, Dh = q.shape
    o_out = o.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)
    return o_out, (q, k, v, o_out, lse)


def _vjp_bwd(causal, bq, bk, interpret, res, do):
    q, k, v, o, lse = res
    bq = min(bq, q.shape[1])
    bk = min(bk, k.shape[1])
    dq, dk, dv = _bwd(q, k, v, o, lse, do, causal=causal, bq=bq, bk=bk,
                      interpret=interpret)
    return dq.astype(q.dtype), dk, dv


flash_attention_train.defvjp(_vjp_fwd, _vjp_bwd)
