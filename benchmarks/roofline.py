"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads dryrun JSON records (launch/dryrun.py --out ...) and derives, per
(arch x shape):

  compute    = FLOPs_per_chip / peak_FLOPs
  memory     = bytes_per_chip / HBM_bw
  collective = collective_bytes_per_chip / link_bw
  dominant   = argmax of the three
  MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE), D = tokens
  usefulness  = MODEL_FLOPS / (FLOPs_per_chip * chips)

Hardware constants per the brief: 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import sys

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.core.comm_model import TPU_V5E
from repro.models.model import active_param_count, build_model, param_count

from .common import csv_row

HW = TPU_V5E


def _model_params(arch: str) -> tuple[int, int]:
    cfg = get_config(arch, "full")
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    total = param_count(shapes)
    active = active_param_count(cfg, shapes)
    return total, active


def analyze(records: list[dict], chips: int = 256) -> list[dict]:
    out = []
    pcache: dict[str, tuple[int, int]] = {}
    for rec in records:
        if "flops_per_chip" not in rec:
            out.append(rec)
            continue
        arch, shape = rec["arch"], rec["shape"]
        if arch not in pcache:
            pcache[arch] = _model_params(arch)
        total_p, active_p = pcache[arch]
        spec = INPUT_SHAPES[shape]
        tokens = spec["global_batch"] * (spec["seq_len"] if spec["kind"] != "decode" else 1)

        compute_s = rec["flops_per_chip"] / HW.peak_flops
        memory_s = rec["bytes_per_chip"] / HW.hbm_bw
        coll_s = rec["collective_total"] / HW.ici_bw
        terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
        dominant = max(terms, key=terms.get)

        factor = 6 if spec["kind"] == "train" else 2
        model_flops = factor * active_p * tokens
        hlo_total = rec["flops_per_chip"] * chips
        useful = model_flops / hlo_total if hlo_total else 0.0

        out.append({
            **rec,
            "roofline": {
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dominant,
                "model_flops": model_flops,
                "useful_fraction": useful,
                "step_lower_bound_s": max(terms.values()),
            },
            "params_total": total_p, "params_active": active_p,
        })
    return out


def to_markdown(records: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful FLOP frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if "roofline" not in r:
            tag = r.get("reason", r.get("error", ""))[:60]
            lines.append(f"| {r.get('arch')} | {r.get('shape')} | — | — | — | "
                         f"{'SKIP' if r.get('skipped') else 'FAIL'} | {tag} |")
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3e} | "
            f"{rf['memory_s']:.3e} | {rf['collective_s']:.3e} | "
            f"**{rf['dominant']}** | {rf['useful_fraction']:.2f} |")
    return "\n".join(lines)


def run(path: str = "dryrun_single_pod.json") -> list[str]:
    try:
        with open(path) as f:
            records = json.load(f)
    except FileNotFoundError:
        return [csv_row("roofline_missing_dryrun_json", 0.0, path)]
    analyzed = analyze(records)
    rows = []
    for r in analyzed:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        rows.append(csv_row(
            f"roofline_{r['arch']}_{r['shape']}", 0.0,
            f"dom={rf['dominant']};comp={rf['compute_s']:.3e};"
            f"mem={rf['memory_s']:.3e};coll={rf['collective_s']:.3e};"
            f"useful={rf['useful_fraction']:.2f}"))
    with open(path.replace(".json", "_roofline.json"), "w") as f:
        json.dump(analyzed, f, indent=1)
    return rows


if __name__ == "__main__":
    for row in run(sys.argv[1] if len(sys.argv) > 1 else "dryrun_single_pod.json"):
        print(row)
