"""Observation 1 + 2 reproduction (paper Fig. 2, Fig. 3).

Claims validated:
  * gradient entropy starts unstable/high and DECREASES toward a stable band
    as the loss converges (Fig. 2);
  * the gradient std (spread) narrows over training — zero-centralization
    (Fig. 3).
"""
from __future__ import annotations

import numpy as np

from .common import csv_row, run_policy


def run(steps: int = 700) -> list[str]:
    res = run_policy("edgc", steps, window=50)
    us = res["wall_s"] * 1e6 / steps

    # Thin sink consumer: the trajectories come from the trainer's own
    # telemetry stream (MemorySink), not from poking trainer internals.
    ent = np.array([v for _, v in res["metrics"].scalars("entropy")])
    losses = [v for _, v in res["metrics"].scalars("loss")]
    n = len(ent)
    # Paper Fig. 2: an initial UNSTABLE phase (entropy rises from the random
    # init as LR warms up) followed by a steady decline. EDGC's own warm-up
    # mechanism exists precisely to sit out the unstable phase, so the
    # Observation-1 claim is about the post-peak trajectory.
    k = max(1, n // 8)
    smooth = np.convolve(ent, np.ones(k) / k, mode="valid")
    peak = int(np.argmax(smooth))
    post = smooth[peak:]
    early_post = float(np.mean(post[: max(1, len(post) // 4)]))
    late_post = float(np.mean(post[-max(1, len(post) // 4):]))
    sig_early, sig_late = np.exp(early_post), np.exp(late_post)

    rows = [
        csv_row("obs1_peak_entropy_nats", us, f"{float(smooth[peak]):.4f}"),
        csv_row("obs1_postpeak_early_nats", us, f"{early_post:.4f}"),
        csv_row("obs1_postpeak_late_nats", us, f"{late_post:.4f}"),
        csv_row("obs1_entropy_decreased_postpeak", us,
                str(bool(late_post < early_post))),
        csv_row("obs2_grad_sigma_postpeak_early", us, f"{sig_early:.3e}"),
        csv_row("obs2_grad_sigma_postpeak_late", us, f"{sig_late:.3e}"),
        csv_row("obs2_centralized_postpeak", us,
                str(bool(sig_late < sig_early))),
        csv_row("obs1_loss_first", us, f"{losses[0]:.4f}"),
        csv_row("obs1_loss_last", us, f"{losses[-1]:.4f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
