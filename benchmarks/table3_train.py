"""Table III reproduction: the four policies head-to-head.

Megatron-LM (none) / PowerSGD (fixed) / Optimus-CC (selective fixed) / EDGC
share every line of the stack except the sync rule. Reported per policy:
final loss (paper: PPL parity), exact DP-sync bytes, modeled comm time on
the TPU ring (CPU container — see DESIGN §6), and wall seconds.

Paper claims mapped here:
  * EDGC comm bytes  << none (paper: -45.8%/-46.45% comm time);
  * EDGC final loss ~= none (paper: equal PPL at 17.95);
  * aggressive fixed low rank hurts loss (paper: PowerSGD PPL 22.37).
"""
from __future__ import annotations

import time


from .common import csv_row, run_policy


def run(steps: int = 300) -> list[str]:
    rows = []
    results = {}
    for policy, kw in [
        ("none", {}),
        ("fixed", {"rank": 8}),        # aggressive fixed rank (PowerSGD row)
        ("optimus", {"rank": 16}),
        ("edgc", {"window": 50}),
    ]:
        t0 = time.time()
        res = run_policy(policy, steps, **kw)
        us = (time.time() - t0) * 1e6 / steps
        results[policy] = res
        comm = res["trainer"].controller.comm
        t_comm_model = comm.eta and res["bytes_synced"] / max(res["bytes_full"], 1)
        rows.append(csv_row(f"table3_{policy}_final_loss", us,
                            f"{res['final_loss']:.4f}"))
        rows.append(csv_row(f"table3_{policy}_sync_GB", us,
                            f"{res['bytes_synced']/2**30:.3f}"))
        rows.append(csv_row(f"table3_{policy}_comm_saved", us,
                            f"{res['comm_savings']:.2%}"))
        rows.append(csv_row(f"table3_{policy}_wall_s", us,
                            f"{res['wall_s']:.1f}"))

    none_loss = results["none"]["final_loss"]
    edgc_loss = results["edgc"]["final_loss"]
    rows.append(csv_row("table3_edgc_loss_gap_vs_none", 0.0,
                        f"{edgc_loss - none_loss:+.4f}"))
    rows.append(csv_row("table3_edgc_comm_reduction", 0.0,
                        f"{results['edgc']['comm_savings']:.2%}"))
    rows.append(csv_row("table3_fixed_worse_than_edgc", 0.0,
                        str(bool(results['fixed']['final_loss'] > edgc_loss))))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
