"""Table VII reproduction: window-size fidelity of the entropy trajectory.

Paper: window-averaged entropy at w=1000 keeps CC >= 0.94 / MSE <= 0.28 vs
the w=1 trajectory; w=2500 distorts. Scaled to the fidelity run (shorter
training), we compare windowed means against the per-step baseline at
proportional window sizes and report the same CC/MSE metrics.
"""
from __future__ import annotations

import time

import numpy as np

from .common import csv_row, fidelity_data, fidelity_trainer


def _windowed(traj: np.ndarray, w: int) -> np.ndarray:
    """Per-step trajectory where each window's mean replaces its members."""
    out = np.empty_like(traj)
    for s in range(0, len(traj), w):
        out[s: s + w] = traj[s: s + w].mean()
    return out


def run(steps: int = 400) -> list[str]:
    t0 = time.time()
    # measure entropy EVERY step (alpha=1) to get the w=1 baseline
    tr = fidelity_trainer("none", steps, alpha=1.0)
    tr.tcfg.log_every = 1
    tr.edgc_cfg = tr.edgc_cfg  # (entropy measured in-step regardless of policy)
    data = fidelity_data()
    hist = tr.run(data.batches())
    traj = np.array([h["entropy"] for h in hist])
    us = (time.time() - t0) * 1e6 / steps

    rows = []
    for w in (10, 50, 100, 250):
        wt = _windowed(traj, w)
        cc = float(np.corrcoef(traj, wt)[0, 1])
        mse = float(np.mean((traj - wt) ** 2))
        rows.append(csv_row(f"table7_w{w}_cc", us, f"{cc:.4f}"))
        rows.append(csv_row(f"table7_w{w}_mse", us, f"{mse:.5f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
