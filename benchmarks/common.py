"""Shared benchmark harness utilities (fidelity-scale training runs)."""
from __future__ import annotations

import time


from repro.configs.gpt2 import GPT2_FIDELITY
from repro.core import EDGCConfig, GDSConfig
from repro.core.dac import DACConfig
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.obs import MemorySink, MetricsRegistry
from repro.optim.adam import AdamConfig
from repro.train.trainer import Trainer, TrainerConfig

FIDELITY_SEQ = 128
FIDELITY_BATCH = 8


def fidelity_trainer(policy: str, steps: int, *, rank: int = 32,
                     window: int = 50, num_stages: int = 4, seed: int = 0,
                     cfg=None, alpha: float = 0.5, beta: float = 0.25,
                     lr: float = 1e-3, metrics=None) -> Trainer:
    cfg = cfg or GPT2_FIDELITY
    model = build_model(cfg)
    mesh = make_host_mesh(data=1, model=1)
    edgc = EDGCConfig(
        policy=policy, fixed_rank=rank, num_stages=num_stages,
        total_iterations=steps,
        gds=GDSConfig(alpha=alpha, beta=beta),
        dac=DACConfig(window=window, adjust_limit=4),
    )
    tcfg = TrainerConfig(
        total_steps=steps, log_every=max(1, steps // 40),
        adam=AdamConfig(lr=lr, warmup_steps=max(10, steps // 10),
                        total_steps=steps),
        metrics=metrics,
    )
    return Trainer(model, mesh, edgc, tcfg, seed=seed)


def fidelity_data(cfg=None, seed: int = 0) -> SyntheticLM:
    cfg = cfg or GPT2_FIDELITY
    return SyntheticLM(vocab_size=cfg.vocab_size, seq_len=FIDELITY_SEQ,
                       batch_size=FIDELITY_BATCH, seed=seed)


def run_policy(policy: str, steps: int, **kw):
    # Benchmarks consume the trainer's own telemetry stream: an in-memory
    # sink captures the structured records every run already emits, so the
    # harness reads series (entropy, ranks, wire bytes) instead of poking
    # trainer internals.
    sink = MemorySink()
    kw.setdefault("metrics", MetricsRegistry([sink]))
    tr = fidelity_trainer(policy, steps, **kw)
    data = fidelity_data(kw.get("cfg"), kw.get("seed", 0))
    t0 = time.time()
    hist = tr.run(data.batches())
    wall = time.time() - t0
    return {
        "policy": policy,
        "history": hist,
        "final_loss": hist[-1]["loss"],
        "bytes_synced": tr.bytes_synced,
        "bytes_full": tr.bytes_full,
        "comm_savings": tr.comm_savings(),
        "wall_s": wall,
        "trainer": tr,
        "metrics": sink,
    }


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
