"""Table VI reproduction: cumulative comm cost, fixed ranks vs CQM-dynamic.

Paper (30k steps, GPT2-345M population): no-compression 3.04 h, rank 64
3.02 h, rank 32 1.48 h, rank 16 0.74 h, CQM 1.88 h — CQM sits between the
aggressive fixed ranks and rank 64 while tracking accuracy. We reproduce the
*structure* of that table: exact cumulative DP-sync bytes per policy over
the same trained run, converted to ring-time on the TPU model.
"""
from __future__ import annotations

import time


from repro.core import CommModel
from repro.core.compressor import make_plan, plan_wire_bytes

from .common import csv_row, run_policy


def run(steps: int = 300) -> list[str]:
    rows = []
    t0 = time.time()

    # CQM/EDGC dynamic run (gives the rank trajectory + its byte stream)
    res = run_policy("edgc", steps, window=50)
    tr = res["trainer"]
    leaves = tr.leaves
    world = 16
    comm = CommModel.from_shapes(
        [l.shape[-2:] for l in leaves if l.eligible], world=world)

    def ring_seconds(nbytes: float) -> float:
        from repro.core.comm_model import ring_allreduce_seconds
        return ring_allreduce_seconds(nbytes, world, comm.hw.ici_bw)

    # fixed-rank policies: bytes are static per step
    _, full_bytes_step = plan_wire_bytes(leaves, make_plan("fixed", leaves, fixed_rank=1))
    for rank in (64, 32, 16):
        plan = make_plan("fixed", leaves, fixed_rank=rank)
        comp_b, full_b = plan_wire_bytes(leaves, plan)
        rows.append(csv_row(f"table6_rank{rank}_total_ring_s", 0.0,
                            f"{ring_seconds(comp_b) * steps:.3f}"))
    rows.append(csv_row("table6_none_total_ring_s", 0.0,
                        f"{ring_seconds(full_b) * steps:.3f}"))
    rows.append(csv_row("table6_cqm_total_ring_s", 0.0,
                        f"{ring_seconds(res['bytes_synced'] / steps) * steps:.3f}"))
    rows.append(csv_row("table6_cqm_final_loss", (time.time()-t0)*1e6/steps,
                        f"{res['final_loss']:.4f}"))
    rows.append(csv_row("table6_rank_trajectory", 0.0,
                        ";".join(str(r[1][0]) for r in tr.controller.rank_history[-5:])))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
