"""Pipeline schedules vs flat DP: bubble fraction + per-stage sync bytes.

Two layers, matching how the subsystem splits:

  * **Analytics** (``run()``, registered in ``benchmarks.run``; no devices):
    tick-table bubble fractions and peak in-flight activations for GPipe vs
    1F1B, the Algorithm-2 rank vector from the analytic comm model, and the
    per-stage DP sync bytes it implies vs the flat-DP baseline — including
    the Eq. 4 overlap check (every stage's sync fits stage 1's sync time
    plus its backprop head start).
  * **Execution** (``main()``, standalone — forces 4 fake CPU devices
    before jax init): runs the pipelined Trainer (1F1B, pipe=4) and the
    flat single-stage Trainer on the gpt2 fidelity config, asserts loss
    parity, counts lowered collective ops, and (full mode) times both,
    writing ``BENCH_pipeline.json``.

  PYTHONPATH=src python benchmarks/pipeline_overlap.py           # full+JSON
  PYTHONPATH=src python benchmarks/pipeline_overlap.py --smoke   # CI gate
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags +
                               " --xla_force_host_platform_device_count=4")

import argparse
import json
import time

S, M = 4, 16


# ----------------------------------------------------------------- analytics
def _analytics(num_stages: int = S, num_micro: int = M) -> dict:
    import jax

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import CommModel, classify_leaves, make_plan, \
        plan_wire_bytes, stage_aligned_ranks
    from repro.models.model import build_model
    from repro.pipeline.schedule import (
        bubble_fraction, peak_inflight, ring_slots, slot_table,
        sync_slack_ticks, tick_count,
    )
    from repro.pipeline.sync import stage_wire_bytes

    model = build_model(GPT2_FIDELITY)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params_shapes, GPT2_FIDELITY.num_layers,
                             num_stages, min_dim=64)
    shapes = [l.shape[-2:] for l in leaves if l.eligible]
    comm = CommModel.from_shapes(shapes, world=4)

    r_min, r_max = 8, 64
    r1 = 24
    t_micro = comm.t_com(8)
    ranks = stage_aligned_ranks(r1, num_stages, comm, t_micro, r_min, r_max)
    plan = make_plan("edgc", leaves, stage_ranks=ranks,
                     num_stages=num_stages)
    per_stage = stage_wire_bytes(leaves, plan, num_stages)
    comp_total, full_total = plan_wire_bytes(leaves, plan)

    sched = {}
    for name in ("gpipe", "1f1b"):
        table = slot_table(name, num_stages, num_micro)
        busy = [sum(len(a) for a in table[s]) for s in range(num_stages)]
        assert all(b == 2 * num_micro for b in busy), busy
        sched[name] = {
            "ticks": tick_count(name, num_stages, num_micro),
            "peak_inflight": peak_inflight(name, num_stages, num_micro),
            "ring_slots": ring_slots(name, num_stages, num_micro),
            "sync_slack_ticks": sync_slack_ticks(name, num_stages, num_micro),
        }

    # Eq. 4 feasibility: stage s's sync fits inside stage 1's sync time
    # plus its (s-microbatch-backward) head start.
    t1 = comm.t_com(ranks[0])
    overlap_ok = all(
        comm.t_com(ranks[s]) <= t1 + s * t_micro + 1e-12
        for s in range(num_stages)
    )
    return {
        "num_stages": num_stages,
        "num_microbatches": num_micro,
        "bubble_fraction": bubble_fraction(num_stages, num_micro),
        "schedules": sched,
        "dac_ranks": ranks,
        "stage_bytes": per_stage,
        "plan_bytes": {"compressed": comp_total, "full": full_total},
        "overlap_feasible": overlap_ok,
    }


def _check_analytics(a: dict) -> None:
    ranks = a["dac_ranks"]
    assert all(r2 >= r1 for r1, r2 in zip(ranks, ranks[1:])), \
        f"Alg 2 ranks must be non-decreasing over stages: {ranks}"
    assert a["overlap_feasible"], "Eq. 4 overlap must hold by construction"
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    assert max(f["peak_inflight"]) <= max(g["peak_inflight"]), (f, g)
    assert f["ring_slots"] <= g["ring_slots"]
    assert f["sync_slack_ticks"] == g["sync_slack_ticks"] == list(
        range(a["num_stages"]))
    per_stage = a["stage_bytes"]
    assert sum(c for c, _ in per_stage) == a["plan_bytes"]["compressed"]
    assert sum(fu for _, fu in per_stage) == a["plan_bytes"]["full"]
    assert all(c <= fu for c, fu in per_stage)


def _csv_row(name: str, us_per_call: float, derived: str) -> str:
    # benchmarks.common.csv_row, inlined: this module must also run as a
    # plain script (it forces the fake device count before jax init, so it
    # cannot ride `python -m benchmarks.run` for its execution half).
    return f"{name},{us_per_call:.3f},{derived}"


def _rows(a: dict, us: float) -> list[str]:
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    return [
        _csv_row("pipeline_bubble_fraction", us,
                 f"{a['bubble_fraction']:.4f}"),
        _csv_row("pipeline_peak_acts_gpipe", 0.0, str(max(g["peak_inflight"]))),
        _csv_row("pipeline_peak_acts_1f1b", 0.0, str(max(f["peak_inflight"]))),
        _csv_row("pipeline_dac_ranks", 0.0, ";".join(map(str, a["dac_ranks"]))),
        _csv_row("pipeline_stage_sync_bytes", 0.0,
                 ";".join(str(c) for c, _ in a["stage_bytes"])),
        _csv_row("pipeline_overlap_feasible", 0.0, str(a["overlap_feasible"])),
    ]


def run(steps: int | None = None) -> list[str]:
    """Device-independent analytics rows (the benchmarks.run entry)."""
    t0 = time.time()
    a = _analytics()
    _check_analytics(a)
    return _rows(a, (time.time() - t0) * 1e6)


# ----------------------------------------------------------------- execution
def _trainers(steps: int):
    import jax  # noqa: F401  (device count must already be forced)

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    def mk(mesh, schedule="1f1b"):
        model = build_model(GPT2_FIDELITY)
        edgc = EDGCConfig(policy="fixed", fixed_rank=8, num_stages=4,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=0.5, beta=0.25),
                          dac=DACConfig(window=max(2, steps // 2)))
        tcfg = TrainerConfig(total_steps=steps, log_every=1,
                             schedule=schedule,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    data = lambda: SyntheticLM(GPT2_FIDELITY.vocab_size, 32, 8,
                               seed=3).batches()
    pipe = mk(make_host_mesh(pipe=4, data=1, model=1))
    flat = mk(make_host_mesh(data=1, model=1))
    return pipe, flat, data


def execute(smoke: bool) -> dict:
    import re

    import jax
    import jax.numpy as jnp
    import numpy as np

    steps = 3 if smoke else 10
    pipe, flat, data = _trainers(steps)
    hp = pipe.run(data())
    hf = flat.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    print(f"pipeline_loss_gap,0.000,{gap:.2e}")
    assert gap < 5e-3, f"1F1B loss must match flat DP (gap {gap})"
    assert all(np.isfinite(lp)), lp

    # lowered-op census of the pipelined step: boundary ppermutes present
    step = pipe._get_step()
    batch = {k: jnp.asarray(v) for k, v in next(data()).items()}
    text = step.lower(jax.device_get(pipe.state), batch).as_text()
    n_permute = len(re.findall(r"collective.permute|ppermute", text))
    n_allreduce = len(re.findall(r"all.reduce", text))
    print(f"pipeline_ppermutes,0.000,{n_permute}")
    print(f"pipeline_allreduces,0.000,{n_allreduce}")
    assert n_permute > 0, "pipelined step must move boundaries via ppermute"

    rec = {"loss_gap": float(gap), "ppermutes": n_permute,
           "allreduces": n_allreduce,
           "stage_bytes": pipe.stage_bytes()}
    if not smoke:
        def time_steps(tr, n=5):
            it = data()
            tr.run(it, num_steps=1)          # warm
            t0 = time.perf_counter()
            tr.run(it, num_steps=n)
            return (time.perf_counter() - t0) / n

        p2, f2, data = _trainers(20)
        rec["s_per_step_pipelined"] = time_steps(p2)
        rec["s_per_step_flat"] = time_steps(f2)
        print(f"pipeline_step_s,{rec['s_per_step_pipelined']*1e6:.1f},pipelined")
        print(f"flat_step_s,{rec['s_per_step_flat']*1e6:.1f},flat")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast run: analytics asserts + 3-step loss parity")
    ap.add_argument("--out", default="BENCH_pipeline.json")
    args = ap.parse_args()

    t0 = time.time()
    a = _analytics()
    _check_analytics(a)
    for row in _rows(a, (time.time() - t0) * 1e6):
        print(row)
    rec = execute(args.smoke)
    if not args.smoke:
        payload = {"analytics": a, "execution": rec}
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
