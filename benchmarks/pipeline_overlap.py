"""Pipeline schedules vs flat DP: bubble fraction + per-stage sync bytes.

Two layers, matching how the subsystem splits:

  * **Analytics** (``run()``, registered in ``benchmarks.run``; no devices):
    tick-table bubble fractions and peak in-flight activations for GPipe vs
    1F1B, the Algorithm-2 rank vector from the analytic comm model, and the
    per-stage DP sync bytes it implies vs the flat-DP baseline — including
    the Eq. 4 overlap check (every stage's sync fits stage 1's sync time
    plus its backprop head start). The unit-tick numbers are then
    CALIBRATED: per-microbatch forward and forward+backward wall times are
    measured on the fidelity config, per-call costs recovered with
    ``CommModel.fit`` (least squares through the origin over microbatch
    counts — the same fit that reproduces Fig. 9's T = eta*r), and the
    weighted schedule simulation (``simulate_schedule``) reports the
    bubble fraction and Eq. 4 slack in SECONDS with B-cost != F-cost.
  * **Execution** (``main()``, standalone — forces 4 fake CPU devices
    before jax init): runs the pipelined Trainer (1F1B, pipe=4) and the
    flat single-stage Trainer on the chosen family (``--family gpt2`` =
    the dense fidelity config, ``--family moe`` = a 4-stage MoE smoke
    config exercising the MoE stage adapter), asserts loss parity
    (an envelope for MoE: per-microbatch router-aux means flip discrete
    top-1 assignments), counts lowered collective ops, and (full mode)
    times both, writing ``BENCH_pipeline.json``.

  PYTHONPATH=src python benchmarks/pipeline_overlap.py           # full+JSON
  PYTHONPATH=src python benchmarks/pipeline_overlap.py --smoke   # CI gate
  PYTHONPATH=src python benchmarks/pipeline_overlap.py --smoke --family moe
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags +
                               " --xla_force_host_platform_device_count=4")

import argparse
import json
import time

S, M = 4, 16


def _moe_smoke_cfg(num_stages: int = S):
    from repro.models.model import ModelConfig
    return ModelConfig(
        name="moe-pipe-smoke", family="moe", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        num_experts=4, experts_per_token=2, capacity_factor=4.0,
        num_stages=num_stages)


def _exec_cfg(family: str, num_stages: int = S):
    if family == "moe":
        return _moe_smoke_cfg(num_stages)
    import dataclasses

    from repro.configs.gpt2 import GPT2_FIDELITY
    return dataclasses.replace(GPT2_FIDELITY, num_stages=num_stages)


# ----------------------------------------------------------------- analytics
def _measure_tick_costs(num_stages: int = S, reps: int = 2) -> dict:
    """Measured per-microbatch F and B costs via CommModel.fit.

    Times k in {1, 2, 4} consecutive jitted calls of (a) the forward loss
    and (b) value_and_grad on one microbatch of the fidelity config. A
    through-origin fit of the RAW series would fold the fixed dispatch
    overhead into the slope (t = c + eta*k fitted as eta'*k biases eta'
    by c*sum(k)/sum(k^2)), so the k=1 measurement is subtracted first:
    t(k) - t(1) = eta * (k - 1) passes exactly through the origin, and
    ``CommModel.fit`` over (k-1, t(k)-t(1)) recovers an overhead-free
    per-microbatch cost (MAPE reports the residual nonlinearity). The
    backward-only cost is the difference of the two fits; both are
    divided by S for the per-stage tick (the schedule's unit of work).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import CommModel
    from repro.models.model import build_model

    model = build_model(GPT2_FIDELITY)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, GPT2_FIDELITY.vocab_size, (2, 64)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    fwd = jax.jit(lambda p, b: model.loss_fn(p, b)[0])
    fb = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))

    def time_calls(fn, k: int) -> float:
        fn(params, batch)        # warm (compile)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(k):
                jax.block_until_ready(fn(params, batch))
            best = min(best, time.perf_counter() - t0)
        return best

    ks = np.asarray([1, 2, 4], np.float64)
    fwd_s = np.asarray([time_calls(fwd, int(k)) for k in ks])
    fb_s = np.asarray([time_calls(fb, int(k)) for k in ks])
    m_f, mape_f = CommModel.fit(ks[1:] - ks[0], fwd_s[1:] - fwd_s[0])
    m_fb, mape_fb = CommModel.fit(ks[1:] - ks[0], fb_s[1:] - fb_s[0])
    t_f = m_f.eta / num_stages
    t_b = max(m_fb.eta - m_f.eta, 1e-9) / num_stages
    return {
        "t_f_stage_s": t_f,
        "t_b_stage_s": t_b,
        "b_over_f": t_b / max(t_f, 1e-12),
        "fit_mape_f": mape_f,
        "fit_mape_fb": mape_fb,
    }


def _analytics(num_stages: int = S, num_micro: int = M,
               measure: bool = True) -> dict:
    import jax

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import CommModel, classify_leaves, make_plan, \
        plan_wire_bytes, stage_aligned_ranks
    from repro.models.model import build_model
    from repro.pipeline.schedule import (
        bubble_fraction, peak_inflight, ring_slots, simulate_schedule,
        slot_table, sync_slack_ticks, tick_count,
    )
    from repro.pipeline.sync import stage_wire_bytes

    import dataclasses

    import jax.numpy as jnp

    from repro.pipeline.partition import make_partition
    from repro.pipeline.schedule import (
        STASH_POLICIES, boundary_nbytes, peak_activation_bytes,
        policy_tick_cost,
    )

    model = build_model(GPT2_FIDELITY)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params_shapes, GPT2_FIDELITY.num_layers,
                             num_stages, min_dim=64)
    shapes = [l.shape[-2:] for l in leaves if l.eligible]
    comm = CommModel.from_shapes(shapes, world=4)

    r_min, r_max = 8, 64
    r1 = 24
    t_micro = comm.t_com(8)
    ranks = stage_aligned_ranks(r1, num_stages, comm, t_micro, r_min, r_max)
    plan = make_plan("edgc", leaves, stage_ranks=ranks,
                     num_stages=num_stages)
    per_stage = stage_wire_bytes(leaves, plan, num_stages)
    comp_total, full_total = plan_wire_bytes(leaves, plan)

    sched = {}
    for name in ("gpipe", "1f1b"):
        table = slot_table(name, num_stages, num_micro)
        busy = [sum(len(a) for a in table[s]) for s in range(num_stages)]
        assert all(b == 2 * num_micro for b in busy), busy
        sched[name] = {
            "ticks": tick_count(name, num_stages, num_micro),
            "peak_inflight": peak_inflight(name, num_stages, num_micro),
            "ring_slots": ring_slots(name, num_stages, num_micro),
            "sync_slack_ticks": sync_slack_ticks(name, num_stages, num_micro),
        }

    # Eq. 4 feasibility: stage s's sync fits inside stage 1's sync time
    # plus its (s-microbatch-backward) head start.
    t1 = comm.t_com(ranks[0])
    overlap_ok = all(
        comm.t_com(ranks[s]) <= t1 + s * t_micro + 1e-12
        for s in range(num_stages)
    )
    rec = {
        "num_stages": num_stages,
        "num_microbatches": num_micro,
        "bubble_fraction": bubble_fraction(num_stages, num_micro),
        "schedules": sched,
        "dac_ranks": ranks,
        "stage_bytes": per_stage,
        "plan_bytes": {"compressed": comp_total, "full": full_total},
        "overlap_feasible": overlap_ok,
    }

    # Activation-memory ledger per stash policy (byte-accurate, from the
    # tick table). The fidelity config has one block per stage at S=4 —
    # every policy would degenerate — so the ledger runs on a 16-layer
    # variant (4 segmentable units per stage: full stashes 3 carries,
    # every_k=2 one, replay none). Boundary bytes use the execution
    # harness's microbatch shape (batch 8 / M=4 microbatches, T=32).
    stash_cfg = dataclasses.replace(GPT2_FIDELITY, num_layers=16,
                                    num_stages=num_stages)
    part = make_partition(build_model(stash_cfg), num_stages)
    n_units = part.num_units()
    mb = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32)}
    bbytes = boundary_nbytes(part, mb)
    rec["stash"] = {
        "n_units": n_units,
        "boundary_bytes": bbytes,
        "peak_activation_bytes": {
            pol: {name: peak_activation_bytes(
                      name, num_stages, num_micro, pol,
                      boundary_bytes=bbytes, n_units=n_units)
                  for name in ("gpipe", "1f1b")}
            for pol in STASH_POLICIES
        },
    }

    if measure:
        # Calibrated tick costs (satellite): measured F/B per-microbatch
        # times instead of B-cost == F-cost, simulated through the real
        # dependency structure. The DAC slack the paper's Eq. 4 consumes
        # is the BACKWARD tick length, so the calibrated rank vector uses
        # the measured t_b (the analytic one above uses a comm-model
        # stand-in).
        costs = _measure_tick_costs(num_stages)
        # The executor's backward tick is NOT the flat model's pure
        # backward: every stash policy's hand-rolled VJP re-runs the
        # un-stashed segment forwards once (one extra t_f), and the
        # replay policy with per-unit remat inside (the memory-floor
        # configuration stashing exists to relax) pays that forward a
        # second time. policy_tick_cost models exactly that for the
        # FIDELITY config's remat setting — the same flag the executor
        # would run — so the Eq. 4 slack and the DAC rank vector are
        # calibrated per policy instead of from the understated t_b.
        per_policy = {}
        for pol in STASH_POLICIES:
            t_b_pol = policy_tick_cost(costs["t_f_stage_s"],
                                       costs["t_b_stage_s"], pol,
                                       remat=GPT2_FIDELITY.remat)
            sims = {}
            for name in ("gpipe", "1f1b"):
                sim = simulate_schedule(name, num_stages, num_micro,
                                        costs["t_f_stage_s"], t_b_pol)
                sims[name] = {
                    "bubble_fraction": sim["bubble_fraction"],
                    "slack_seconds": sim["slack_seconds"],
                    "makespan_s": sim["makespan"],
                }
            per_policy[pol] = {
                "t_b_tick_s": t_b_pol,
                "schedules": sims,
                "dac_ranks": stage_aligned_ranks(r1, num_stages, comm,
                                                 t_b_pol, r_min, r_max),
            }
        replay = per_policy["replay"]
        rec["calibrated"] = {**costs, "schedules": replay["schedules"],
                             "dac_ranks": replay["dac_ranks"],
                             "per_policy": per_policy}
    return rec


def _check_analytics(a: dict) -> None:
    ranks = a["dac_ranks"]
    assert all(r2 >= r1 for r1, r2 in zip(ranks, ranks[1:])), \
        f"Alg 2 ranks must be non-decreasing over stages: {ranks}"
    assert a["overlap_feasible"], "Eq. 4 overlap must hold by construction"
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    assert max(f["peak_inflight"]) <= max(g["peak_inflight"]), (f, g)
    assert f["ring_slots"] <= g["ring_slots"]
    assert f["sync_slack_ticks"] == g["sync_slack_ticks"] == list(
        range(a["num_stages"]))
    per_stage = a["stage_bytes"]
    assert sum(c for c, _ in per_stage) == a["plan_bytes"]["compressed"]
    assert sum(fu for _, fu in per_stage) == a["plan_bytes"]["full"]
    assert all(c <= fu for c, fu in per_stage)
    # Activation ledger: stashing can only ADD ring bytes, per stage and
    # schedule — full >= every_k >= replay, strictly when units allow it.
    led = a["stash"]["peak_activation_bytes"]
    for name in ("gpipe", "1f1b"):
        for s in range(a["num_stages"]):
            assert (led["full"][name][s] >= led["every_k"][name][s]
                    >= led["replay"][name][s]), (name, s, led)
    assert a["stash"]["n_units"] >= 3   # the 16-layer variant is non-trivial
    assert max(led["full"]["1f1b"]) > max(led["every_k"]["1f1b"]) \
        > max(led["replay"]["1f1b"]), led
    if "calibrated" in a:
        cal = a["calibrated"]
        assert cal["t_f_stage_s"] > 0 and cal["t_b_stage_s"] > 0
        for name in ("gpipe", "1f1b"):
            slack = cal["schedules"][name]["slack_seconds"]
            # Eq. 4 slack opens monotonically with the stage index and is
            # (to scheduling jitter) s backward ticks
            assert slack[0] == 0.0
            assert all(b >= a2 - 1e-12 for a2, b in zip(slack, slack[1:])), \
                slack
        ranks_cal = cal["dac_ranks"]
        assert all(r2 >= r1 for r1, r2 in zip(ranks_cal, ranks_cal[1:]))
        pp = cal["per_policy"]
        # replay's backward tick is never shorter than a stashed one
        # (equal at remat=False — the fidelity default — strictly longer
        # when the config remats inside the stage), so its Eq. 4 slack
        # and late-stage ranks dominate or match the stashed policies'
        assert pp["replay"]["t_b_tick_s"] >= pp["full"]["t_b_tick_s"]
        assert pp["full"]["t_b_tick_s"] == pp["every_k"]["t_b_tick_s"]
        for pol in pp:
            rks = pp[pol]["dac_ranks"]
            assert all(b >= a2 for a2, b in zip(rks, rks[1:])), (pol, rks)
        assert all(r >= f for r, f in zip(pp["replay"]["dac_ranks"],
                                          pp["full"]["dac_ranks"]))


def _csv_row(name: str, us_per_call: float, derived: str) -> str:
    # benchmarks.common.csv_row, inlined: this module must also run as a
    # plain script (it forces the fake device count before jax init, so it
    # cannot ride `python -m benchmarks.run` for its execution half).
    return f"{name},{us_per_call:.3f},{derived}"


def _rows(a: dict, us: float) -> list[str]:
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    led = a["stash"]["peak_activation_bytes"]
    rows = [
        _csv_row("pipeline_bubble_fraction", us,
                 f"{a['bubble_fraction']:.4f}"),
        _csv_row("pipeline_peak_acts_gpipe", 0.0, str(max(g["peak_inflight"]))),
        _csv_row("pipeline_peak_acts_1f1b", 0.0, str(max(f["peak_inflight"]))),
        _csv_row("pipeline_dac_ranks", 0.0, ";".join(map(str, a["dac_ranks"]))),
        _csv_row("pipeline_stage_sync_bytes", 0.0,
                 ";".join(str(c) for c, _ in a["stage_bytes"])),
        _csv_row("pipeline_overlap_feasible", 0.0, str(a["overlap_feasible"])),
    ] + [
        _csv_row(f"pipeline_peak_act_bytes_{pol}_1f1b", 0.0,
                 ";".join(str(b) for b in led[pol]["1f1b"]))
        for pol in ("replay", "every_k", "full")
    ]
    if "calibrated" in a:
        cal = a["calibrated"]
        rows += [
            _csv_row(f"pipeline_tick_b_{pol}",
                     cal["per_policy"][pol]["t_b_tick_s"] * 1e6,
                     ";".join(map(str, cal["per_policy"][pol]["dac_ranks"])))
            for pol in ("replay", "every_k", "full")
        ] + [
            _csv_row("pipeline_tick_b_over_f",
                     cal["t_b_stage_s"] * 1e6, f"{cal['b_over_f']:.2f}"),
            _csv_row("pipeline_bubble_calibrated_1f1b", 0.0,
                     f"{cal['schedules']['1f1b']['bubble_fraction']:.4f}"),
            _csv_row("pipeline_slack_s_calibrated_1f1b", 0.0,
                     ";".join(f"{s:.2e}"
                              for s in cal["schedules"]["1f1b"]
                              ["slack_seconds"])),
            _csv_row("pipeline_dac_ranks_calibrated", 0.0,
                     ";".join(map(str, cal["dac_ranks"]))),
        ]
    return rows


def run(steps: int | None = None) -> list[str]:
    """Device-independent analytics rows (the benchmarks.run entry).

    Skips the wall-clock calibration (registered benchmarks must stay
    deterministic/cheap); the standalone main() measures it.
    """
    t0 = time.time()
    a = _analytics(measure=False)
    _check_analytics(a)
    return _rows(a, (time.time() - t0) * 1e6)


# ----------------------------------------------------------------- execution
def _trainers(steps: int, family: str = "gpt2", stash: str = "replay",
              num_layers: int | None = None):
    import dataclasses

    import jax  # noqa: F401  (device count must already be forced)

    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    def mk(mesh, schedule="1f1b"):
        # Both trainers share one config (num_stages=4): the flat baseline
        # keeps the "virtual stages" semantics, so param layouts — and with
        # them the PowerSGD warm-start keys — are identical and the loss
        # trajectories are comparable down to fp tolerance. alpha=1 keeps
        # the ISR gate always-on: one compiled step variant per plan.
        cfg = _exec_cfg(family, S)
        if num_layers is not None:
            cfg = dataclasses.replace(cfg, num_layers=num_layers)
        model = build_model(cfg)
        edgc = EDGCConfig(policy="fixed", fixed_rank=8, num_stages=S,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=1.0, beta=0.25),
                          dac=DACConfig(window=max(2, steps // 2)))
        tcfg = TrainerConfig(total_steps=steps, log_every=1,
                             schedule=schedule, stash_policy=stash,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    vocab = _exec_cfg(family).vocab_size
    data = lambda: SyntheticLM(vocab, 32, 8, seed=3).batches()
    pipe = mk(make_host_mesh(pipe=4, data=1, model=1))
    flat = mk(make_host_mesh(data=1, model=1))
    return pipe, flat, data


def _overlap_pair(total_steps: int):
    """Monolithic vs overlapped pipelined trainers (policy=optimus, D=2).

    optimus gives the boundary stages a different rank from the interior
    ones, so the monolithic per-stage sync runs TWO masked compression
    schedules on every device while the overlapped executor's lax.switch
    runs exactly one — the structural win the step-time comparison below
    measures — and the drain ticks additionally hide the late stages'
    chunked transfers. The config is tuned so compression compute is a
    visible step fraction on the fake pod: rank 64 against 8-token
    microbatches makes one PowerSGD schedule cost several microbatch
    ticks, where the fidelity config's sync would vanish under the
    23-tick dispatch overhead. M=16 microbatches (the module's analytic
    M) opens the full 2(S-1)-tick drain.
    """
    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import ModelConfig, build_model
    from repro.optim.adam import AdamConfig
    from repro.pipeline import PipelineConfig
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="overlap-bench", family="dense", num_layers=8,
                      d_model=256, num_heads=4, num_kv_heads=2, d_ff=1024,
                      vocab_size=512, num_stages=S)

    def mk(overlap: bool):
        model = build_model(cfg)
        pcfg = PipelineConfig(num_stages=S, schedule="1f1b",
                              num_microbatches=M, overlap_sync=overlap,
                              chunk_bytes=1 << 20)
        edgc = EDGCConfig(policy="optimus", fixed_rank=64,
                          total_iterations=total_steps,
                          gds=GDSConfig(alpha=1.0, beta=0.25),
                          dac=DACConfig(window=total_steps),
                          pipeline=pcfg)
        tcfg = TrainerConfig(total_steps=total_steps, log_every=1,
                             pipeline=pcfg,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=total_steps))
        return Trainer(model, make_host_mesh(pipe=S, data=1, model=1),
                       edgc, tcfg, seed=0)

    data = lambda: SyntheticLM(cfg.vocab_size, 8, M, seed=5).batches()
    return mk(False), mk(True), data


def execute(smoke: bool, family: str = "gpt2") -> dict:
    import re

    import jax
    import jax.numpy as jnp
    import numpy as np

    steps = 3 if smoke else 10
    pipe, flat, data = _trainers(steps, family)
    hp = pipe.run(data())
    hf = flat.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    print(f"pipeline_loss_gap,0.000,{gap:.2e}")
    # MoE: the pipelined run microbatches (M=S) while the flat baseline
    # cannot, and per-microbatch router-aux means flip discrete top-k
    # assignments — an envelope, not strict parity, is the correct check.
    tol = 0.25 if family == "moe" else 5e-3
    assert gap < tol, f"1F1B must track flat DP for {family} (gap {gap})"
    assert all(np.isfinite(lp)), lp

    # lowered-op census of the pipelined step: boundary ppermutes present
    step = pipe._get_step()
    batch = {k: jnp.asarray(v) for k, v in next(data()).items()}
    text = step.lower(jax.device_get(pipe.state), batch).as_text()
    n_permute = len(re.findall(r"collective.permute|ppermute", text))
    n_allreduce = len(re.findall(r"all.reduce", text))
    print(f"pipeline_ppermutes,0.000,{n_permute}")
    print(f"pipeline_allreduces,0.000,{n_allreduce}")
    assert n_permute > 0, "pipelined step must move boundaries via ppermute"

    rec = {"family": family, "loss_gap": float(gap), "ppermutes": n_permute,
           "allreduces": n_allreduce,
           "stash_policy": pipe.tcfg.stash_policy,
           "stage_bytes": pipe.stage_bytes()}

    if family == "gpt2":
        # Selective stashing through the REAL executor: a 12-layer variant
        # (3 segmentable units per stage at S=4, so every_k=2 actually
        # stashes a carry) must hold the same loss parity as replay.
        pk, fk, datak = _trainers(steps, family, stash="every_k",
                                  num_layers=12)
        lpk = [h["loss"] for h in pk.run(datak())]
        lfk = [h["loss"] for h in fk.run(datak())]
        gap_k = max(abs(a - b) for a, b in zip(lpk, lfk))
        print(f"pipeline_loss_gap_every_k,0.000,{gap_k:.2e}")
        assert gap_k < 5e-3, f"every_k stashing must track flat DP ({gap_k})"
        rec["every_k_loss_gap"] = float(gap_k)

    def time_steps(tr, it, n=5):
        tr.run(it, num_steps=1)              # warm
        t0 = time.perf_counter()
        tr.run(it, num_steps=n)
        return (time.perf_counter() - t0) / n

    if family == "gpt2":
        # Overlapped drain-phase sync vs monolithic post-loop sync through
        # the REAL executor. The chunked in-drain psums are slice-exact
        # reorderings of the bucket psums, so the losses must be
        # bit-identical — far inside the flat-parity tolerance — and the
        # overlapped step must not be slower: its lax.switch runs one
        # stage's sync schedule per device where the monolithic path runs
        # every distinct one under masks. Timing is interleaved
        # best-of-k: the two trainers alternate so machine-load drift
        # hits both, and the minima compare steady-state steps.
        n_t, reps = (2, 2) if smoke else (3, 4)
        par = 3 if smoke else 10
        mono, over, datao = _overlap_pair(par + reps * (n_t + 1) + 1)
        lm2 = [h["loss"] for h in mono.run(datao(), num_steps=par)]
        lo2 = [h["loss"] for h in over.run(datao(), num_steps=par)]
        gap_o = max(abs(a - b) for a, b in zip(lm2, lo2))
        print(f"pipeline_loss_gap_overlap,0.000,{gap_o:.2e}")
        assert gap_o < 1e-6, \
            f"overlapped sync must be loss-identical to monolithic ({gap_o})"
        oplan = over.overlap_plan
        assert oplan is not None and all(oplan.feasible), oplan
        in_loop = sum(len(ids) for s in range(S)
                      for _, ids in oplan.launches[s])
        resid = sum(len(r) for r in oplan.residual)
        assert in_loop > 0, "S=4/M=16 drain must host in-loop sync chunks"
        print(f"pipeline_overlap_chunks,0.000,{in_loop};{resid}")
        itm, ito = datao(), datao()
        tms, tos = [], []
        for _ in range(reps):
            tms.append(time_steps(mono, itm, n_t))
            tos.append(time_steps(over, ito, n_t))
        t_mono, t_over = min(tms), min(tos)
        print(f"pipeline_step_s_monolithic,{t_mono*1e6:.1f},per-stage")
        print(f"pipeline_step_s_overlapped,{t_over*1e6:.1f},"
              "per-stage-overlapped")
        rec["overlap"] = {
            "loss_gap_vs_monolithic": float(gap_o),
            "in_loop_chunks": in_loop, "residual_chunks": resid,
            "s_per_step_monolithic": t_mono,
            "s_per_step_overlapped": t_over,
            "speedup": t_mono / t_over,
        }
        if smoke:
            # CI gate: generous jitter margin on shared runners; the full
            # benchmark asserts strictly faster.
            assert t_over <= t_mono * 1.10, (t_over, t_mono)
        else:
            assert t_over < t_mono, \
                f"overlapped must beat monolithic ({t_over} vs {t_mono})"

    if not smoke:
        p2, f2, data = _trainers(20, family)
        rec["s_per_step_pipelined"] = time_steps(p2, data())
        rec["s_per_step_flat"] = time_steps(f2, data())
        print(f"pipeline_step_s,{rec['s_per_step_pipelined']*1e6:.1f},pipelined")
        print(f"flat_step_s,{rec['s_per_step_flat']*1e6:.1f},flat")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast run: analytics asserts + 3-step loss parity")
    ap.add_argument("--family", default="gpt2", choices=["gpt2", "moe"],
                    help="execution config: dense fidelity or the MoE "
                         "stage-adapter smoke config")
    ap.add_argument("--out", default=None,
                    help="JSON path (default: BENCH_pipeline.json for gpt2, "
                         "BENCH_pipeline_<family>.json otherwise — the "
                         "dense baseline is never silently clobbered)")
    args = ap.parse_args()
    if args.out is None:
        args.out = ("BENCH_pipeline.json" if args.family == "gpt2"
                    else f"BENCH_pipeline_{args.family}.json")

    t0 = time.time()
    # The analytics (and their wall-clock calibration) are defined on the
    # dense fidelity config; only the gpt2 artifact records them so a
    # family baseline never carries mislabeled dense numbers.
    a = _analytics(measure=not args.smoke and args.family == "gpt2")
    _check_analytics(a)
    for row in _rows(a, (time.time() - t0) * 1e6):
        print(row)
    rec = execute(args.smoke, args.family)
    if not args.smoke:
        payload = ({"analytics": a, "execution": rec}
                   if args.family == "gpt2" else {"execution": rec})
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
