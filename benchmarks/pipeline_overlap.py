"""Pipeline schedules vs flat DP: bubble fraction + per-stage sync bytes.

Two layers, matching how the subsystem splits:

  * **Analytics** (``run()``, registered in ``benchmarks.run``; no devices):
    tick-table bubble fractions and peak in-flight activations for GPipe vs
    1F1B, the Algorithm-2 rank vector from the analytic comm model, and the
    per-stage DP sync bytes it implies vs the flat-DP baseline — including
    the Eq. 4 overlap check (every stage's sync fits stage 1's sync time
    plus its backprop head start). The unit-tick numbers are then
    CALIBRATED: per-microbatch forward and forward+backward wall times are
    measured on the fidelity config, per-call costs recovered with
    ``CommModel.fit`` (least squares through the origin over microbatch
    counts — the same fit that reproduces Fig. 9's T = eta*r), and the
    weighted schedule simulation (``simulate_schedule``) reports the
    bubble fraction and Eq. 4 slack in SECONDS with B-cost != F-cost.
  * **Execution** (``main()``, standalone — forces 4 fake CPU devices
    before jax init): runs the pipelined Trainer (1F1B, pipe=4) and the
    flat single-stage Trainer on the chosen family (``--family gpt2`` =
    the dense fidelity config, ``--family moe`` = a 4-stage MoE smoke
    config exercising the MoE stage adapter), asserts loss parity
    (an envelope for MoE: per-microbatch router-aux means flip discrete
    top-1 assignments), counts lowered collective ops, and (full mode)
    times both, writing ``BENCH_pipeline.json``.

  PYTHONPATH=src python benchmarks/pipeline_overlap.py           # full+JSON
  PYTHONPATH=src python benchmarks/pipeline_overlap.py --smoke   # CI gate
  PYTHONPATH=src python benchmarks/pipeline_overlap.py --smoke --family moe
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags +
                               " --xla_force_host_platform_device_count=4")

import argparse
import json
import time

S, M = 4, 16


def _moe_smoke_cfg(num_stages: int = S):
    from repro.models.model import ModelConfig
    return ModelConfig(
        name="moe-pipe-smoke", family="moe", num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=512,
        num_experts=4, experts_per_token=2, capacity_factor=4.0,
        num_stages=num_stages)


def _exec_cfg(family: str, num_stages: int = S):
    if family == "moe":
        return _moe_smoke_cfg(num_stages)
    import dataclasses

    from repro.configs.gpt2 import GPT2_FIDELITY
    return dataclasses.replace(GPT2_FIDELITY, num_stages=num_stages)


# ----------------------------------------------------------------- analytics
def _measure_tick_costs(num_stages: int = S, reps: int = 2) -> dict:
    """Measured per-microbatch F and B costs via CommModel.fit.

    Times k in {1, 2, 4} consecutive jitted calls of (a) the forward loss
    and (b) value_and_grad on one microbatch of the fidelity config. A
    through-origin fit of the RAW series would fold the fixed dispatch
    overhead into the slope (t = c + eta*k fitted as eta'*k biases eta'
    by c*sum(k)/sum(k^2)), so the k=1 measurement is subtracted first:
    t(k) - t(1) = eta * (k - 1) passes exactly through the origin, and
    ``CommModel.fit`` over (k-1, t(k)-t(1)) recovers an overhead-free
    per-microbatch cost (MAPE reports the residual nonlinearity). The
    backward-only cost is the difference of the two fits; both are
    divided by S for the per-stage tick (the schedule's unit of work).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import CommModel
    from repro.models.model import build_model

    model = build_model(GPT2_FIDELITY)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, GPT2_FIDELITY.vocab_size, (2, 64)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    fwd = jax.jit(lambda p, b: model.loss_fn(p, b)[0])
    fb = jax.jit(jax.grad(lambda p, b: model.loss_fn(p, b)[0]))

    def time_calls(fn, k: int) -> float:
        fn(params, batch)        # warm (compile)
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            for _ in range(k):
                jax.block_until_ready(fn(params, batch))
            best = min(best, time.perf_counter() - t0)
        return best

    ks = np.asarray([1, 2, 4], np.float64)
    fwd_s = np.asarray([time_calls(fwd, int(k)) for k in ks])
    fb_s = np.asarray([time_calls(fb, int(k)) for k in ks])
    m_f, mape_f = CommModel.fit(ks[1:] - ks[0], fwd_s[1:] - fwd_s[0])
    m_fb, mape_fb = CommModel.fit(ks[1:] - ks[0], fb_s[1:] - fb_s[0])
    t_f = m_f.eta / num_stages
    t_b = max(m_fb.eta - m_f.eta, 1e-9) / num_stages
    return {
        "t_f_stage_s": t_f,
        "t_b_stage_s": t_b,
        "b_over_f": t_b / max(t_f, 1e-12),
        "fit_mape_f": mape_f,
        "fit_mape_fb": mape_fb,
    }


def _analytics(num_stages: int = S, num_micro: int = M,
               measure: bool = True) -> dict:
    import jax

    from repro.configs.gpt2 import GPT2_FIDELITY
    from repro.core import CommModel, classify_leaves, make_plan, \
        plan_wire_bytes, stage_aligned_ranks
    from repro.models.model import build_model
    from repro.pipeline.schedule import (
        bubble_fraction, peak_inflight, ring_slots, simulate_schedule,
        slot_table, sync_slack_ticks, tick_count,
    )
    from repro.pipeline.sync import stage_wire_bytes

    model = build_model(GPT2_FIDELITY)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params_shapes, GPT2_FIDELITY.num_layers,
                             num_stages, min_dim=64)
    shapes = [l.shape[-2:] for l in leaves if l.eligible]
    comm = CommModel.from_shapes(shapes, world=4)

    r_min, r_max = 8, 64
    r1 = 24
    t_micro = comm.t_com(8)
    ranks = stage_aligned_ranks(r1, num_stages, comm, t_micro, r_min, r_max)
    plan = make_plan("edgc", leaves, stage_ranks=ranks,
                     num_stages=num_stages)
    per_stage = stage_wire_bytes(leaves, plan, num_stages)
    comp_total, full_total = plan_wire_bytes(leaves, plan)

    sched = {}
    for name in ("gpipe", "1f1b"):
        table = slot_table(name, num_stages, num_micro)
        busy = [sum(len(a) for a in table[s]) for s in range(num_stages)]
        assert all(b == 2 * num_micro for b in busy), busy
        sched[name] = {
            "ticks": tick_count(name, num_stages, num_micro),
            "peak_inflight": peak_inflight(name, num_stages, num_micro),
            "ring_slots": ring_slots(name, num_stages, num_micro),
            "sync_slack_ticks": sync_slack_ticks(name, num_stages, num_micro),
        }

    # Eq. 4 feasibility: stage s's sync fits inside stage 1's sync time
    # plus its (s-microbatch-backward) head start.
    t1 = comm.t_com(ranks[0])
    overlap_ok = all(
        comm.t_com(ranks[s]) <= t1 + s * t_micro + 1e-12
        for s in range(num_stages)
    )
    rec = {
        "num_stages": num_stages,
        "num_microbatches": num_micro,
        "bubble_fraction": bubble_fraction(num_stages, num_micro),
        "schedules": sched,
        "dac_ranks": ranks,
        "stage_bytes": per_stage,
        "plan_bytes": {"compressed": comp_total, "full": full_total},
        "overlap_feasible": overlap_ok,
    }

    if measure:
        # Calibrated tick costs (satellite): measured F/B per-microbatch
        # times instead of B-cost == F-cost, simulated through the real
        # dependency structure. The DAC slack the paper's Eq. 4 consumes
        # is the BACKWARD tick length, so the calibrated rank vector uses
        # the measured t_b (the analytic one above uses a comm-model
        # stand-in).
        costs = _measure_tick_costs(num_stages)
        cal = {}
        for name in ("gpipe", "1f1b"):
            sim = simulate_schedule(name, num_stages, num_micro,
                                    costs["t_f_stage_s"],
                                    costs["t_b_stage_s"])
            cal[name] = {
                "bubble_fraction": sim["bubble_fraction"],
                "slack_seconds": sim["slack_seconds"],
                "makespan_s": sim["makespan"],
            }
        ranks_cal = stage_aligned_ranks(r1, num_stages, comm,
                                        costs["t_b_stage_s"], r_min, r_max)
        rec["calibrated"] = {**costs, "schedules": cal,
                             "dac_ranks": ranks_cal}
    return rec


def _check_analytics(a: dict) -> None:
    ranks = a["dac_ranks"]
    assert all(r2 >= r1 for r1, r2 in zip(ranks, ranks[1:])), \
        f"Alg 2 ranks must be non-decreasing over stages: {ranks}"
    assert a["overlap_feasible"], "Eq. 4 overlap must hold by construction"
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    assert max(f["peak_inflight"]) <= max(g["peak_inflight"]), (f, g)
    assert f["ring_slots"] <= g["ring_slots"]
    assert f["sync_slack_ticks"] == g["sync_slack_ticks"] == list(
        range(a["num_stages"]))
    per_stage = a["stage_bytes"]
    assert sum(c for c, _ in per_stage) == a["plan_bytes"]["compressed"]
    assert sum(fu for _, fu in per_stage) == a["plan_bytes"]["full"]
    assert all(c <= fu for c, fu in per_stage)
    if "calibrated" in a:
        cal = a["calibrated"]
        assert cal["t_f_stage_s"] > 0 and cal["t_b_stage_s"] > 0
        for name in ("gpipe", "1f1b"):
            slack = cal["schedules"][name]["slack_seconds"]
            # Eq. 4 slack opens monotonically with the stage index and is
            # (to scheduling jitter) s backward ticks
            assert slack[0] == 0.0
            assert all(b >= a2 - 1e-12 for a2, b in zip(slack, slack[1:])), \
                slack
        ranks_cal = cal["dac_ranks"]
        assert all(r2 >= r1 for r1, r2 in zip(ranks_cal, ranks_cal[1:]))


def _csv_row(name: str, us_per_call: float, derived: str) -> str:
    # benchmarks.common.csv_row, inlined: this module must also run as a
    # plain script (it forces the fake device count before jax init, so it
    # cannot ride `python -m benchmarks.run` for its execution half).
    return f"{name},{us_per_call:.3f},{derived}"


def _rows(a: dict, us: float) -> list[str]:
    g, f = a["schedules"]["gpipe"], a["schedules"]["1f1b"]
    rows = [
        _csv_row("pipeline_bubble_fraction", us,
                 f"{a['bubble_fraction']:.4f}"),
        _csv_row("pipeline_peak_acts_gpipe", 0.0, str(max(g["peak_inflight"]))),
        _csv_row("pipeline_peak_acts_1f1b", 0.0, str(max(f["peak_inflight"]))),
        _csv_row("pipeline_dac_ranks", 0.0, ";".join(map(str, a["dac_ranks"]))),
        _csv_row("pipeline_stage_sync_bytes", 0.0,
                 ";".join(str(c) for c, _ in a["stage_bytes"])),
        _csv_row("pipeline_overlap_feasible", 0.0, str(a["overlap_feasible"])),
    ]
    if "calibrated" in a:
        cal = a["calibrated"]
        rows += [
            _csv_row("pipeline_tick_b_over_f",
                     cal["t_b_stage_s"] * 1e6, f"{cal['b_over_f']:.2f}"),
            _csv_row("pipeline_bubble_calibrated_1f1b", 0.0,
                     f"{cal['schedules']['1f1b']['bubble_fraction']:.4f}"),
            _csv_row("pipeline_slack_s_calibrated_1f1b", 0.0,
                     ";".join(f"{s:.2e}"
                              for s in cal["schedules"]["1f1b"]
                              ["slack_seconds"])),
            _csv_row("pipeline_dac_ranks_calibrated", 0.0,
                     ";".join(map(str, cal["dac_ranks"]))),
        ]
    return rows


def run(steps: int | None = None) -> list[str]:
    """Device-independent analytics rows (the benchmarks.run entry).

    Skips the wall-clock calibration (registered benchmarks must stay
    deterministic/cheap); the standalone main() measures it.
    """
    t0 = time.time()
    a = _analytics(measure=False)
    _check_analytics(a)
    return _rows(a, (time.time() - t0) * 1e6)


# ----------------------------------------------------------------- execution
def _trainers(steps: int, family: str = "gpt2"):
    import jax  # noqa: F401  (device count must already be forced)

    from repro.core import EDGCConfig, GDSConfig
    from repro.core.dac import DACConfig
    from repro.data.pipeline import SyntheticLM
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.optim.adam import AdamConfig
    from repro.train.trainer import Trainer, TrainerConfig

    def mk(mesh, schedule="1f1b"):
        # Both trainers share one config (num_stages=4): the flat baseline
        # keeps the "virtual stages" semantics, so param layouts — and with
        # them the PowerSGD warm-start keys — are identical and the loss
        # trajectories are comparable down to fp tolerance.
        cfg = _exec_cfg(family, S)
        model = build_model(cfg)
        edgc = EDGCConfig(policy="fixed", fixed_rank=8, num_stages=S,
                          total_iterations=steps,
                          gds=GDSConfig(alpha=0.5, beta=0.25),
                          dac=DACConfig(window=max(2, steps // 2)))
        tcfg = TrainerConfig(total_steps=steps, log_every=1,
                             schedule=schedule,
                             adam=AdamConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=steps))
        return Trainer(model, mesh, edgc, tcfg, seed=0)

    vocab = _exec_cfg(family).vocab_size
    data = lambda: SyntheticLM(vocab, 32, 8, seed=3).batches()
    pipe = mk(make_host_mesh(pipe=4, data=1, model=1))
    flat = mk(make_host_mesh(data=1, model=1))
    return pipe, flat, data


def execute(smoke: bool, family: str = "gpt2") -> dict:
    import re

    import jax
    import jax.numpy as jnp
    import numpy as np

    steps = 3 if smoke else 10
    pipe, flat, data = _trainers(steps, family)
    hp = pipe.run(data())
    hf = flat.run(data())
    lp, lf = [h["loss"] for h in hp], [h["loss"] for h in hf]
    gap = max(abs(a - b) for a, b in zip(lp, lf))
    print(f"pipeline_loss_gap,0.000,{gap:.2e}")
    # MoE: the pipelined run microbatches (M=S) while the flat baseline
    # cannot, and per-microbatch router-aux means flip discrete top-k
    # assignments — an envelope, not strict parity, is the correct check.
    tol = 0.25 if family == "moe" else 5e-3
    assert gap < tol, f"1F1B must track flat DP for {family} (gap {gap})"
    assert all(np.isfinite(lp)), lp

    # lowered-op census of the pipelined step: boundary ppermutes present
    step = pipe._get_step()
    batch = {k: jnp.asarray(v) for k, v in next(data()).items()}
    text = step.lower(jax.device_get(pipe.state), batch).as_text()
    n_permute = len(re.findall(r"collective.permute|ppermute", text))
    n_allreduce = len(re.findall(r"all.reduce", text))
    print(f"pipeline_ppermutes,0.000,{n_permute}")
    print(f"pipeline_allreduces,0.000,{n_allreduce}")
    assert n_permute > 0, "pipelined step must move boundaries via ppermute"

    rec = {"family": family, "loss_gap": float(gap), "ppermutes": n_permute,
           "allreduces": n_allreduce,
           "stage_bytes": pipe.stage_bytes()}
    if not smoke:
        def time_steps(tr, n=5):
            it = data()
            tr.run(it, num_steps=1)          # warm
            t0 = time.perf_counter()
            tr.run(it, num_steps=n)
            return (time.perf_counter() - t0) / n

        p2, f2, data = _trainers(20, family)
        rec["s_per_step_pipelined"] = time_steps(p2)
        rec["s_per_step_flat"] = time_steps(f2)
        print(f"pipeline_step_s,{rec['s_per_step_pipelined']*1e6:.1f},pipelined")
        print(f"flat_step_s,{rec['s_per_step_flat']*1e6:.1f},flat")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast run: analytics asserts + 3-step loss parity")
    ap.add_argument("--family", default="gpt2", choices=["gpt2", "moe"],
                    help="execution config: dense fidelity or the MoE "
                         "stage-adapter smoke config")
    ap.add_argument("--out", default=None,
                    help="JSON path (default: BENCH_pipeline.json for gpt2, "
                         "BENCH_pipeline_<family>.json otherwise — the "
                         "dense baseline is never silently clobbered)")
    args = ap.parse_args()
    if args.out is None:
        args.out = ("BENCH_pipeline.json" if args.family == "gpt2"
                    else f"BENCH_pipeline_{args.family}.json")

    t0 = time.time()
    # The analytics (and their wall-clock calibration) are defined on the
    # dense fidelity config; only the gpt2 artifact records them so a
    # family baseline never carries mislabeled dense numbers.
    a = _analytics(measure=not args.smoke and args.family == "gpt2")
    _check_analytics(a)
    for row in _rows(a, (time.time() - t0) * 1e6):
        print(row)
    rec = execute(args.smoke, args.family)
    if not args.smoke:
        payload = ({"analytics": a, "execution": rec}
                   if args.family == "gpt2" else {"execution": rec})
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
