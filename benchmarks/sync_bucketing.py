"""Per-leaf vs bucketed DP gradient sync: collective counts + wall time.

Lowered-HLO collective-op counts (the latency term the bucketing subsystem
attacks) and steady-state sync wall time on a fake 4-device CPU DP mesh,
for the gpt2 fidelity config (52 leaves, 24 compressed at rank 8).

  PYTHONPATH=src python benchmarks/sync_bucketing.py            # full + JSON
  PYTHONPATH=src python benchmarks/sync_bucketing.py --smoke    # CI gate

``--smoke`` asserts the bucketed path lowers to <= 25% of the per-leaf
path's collective ops, that the wire pack/unpack kernels round-trip
bit-exactly, and that the quant8 coded payload is <= 0.5x the raw fp32
payload — exiting nonzero otherwise (wired into CI). The full run also
times both executors plus each wire mode and writes ``BENCH_sync.json``
(including the ``wire`` section: coded bytes + sync time per mode).

Standalone only (not part of benchmarks.run): it must force the fake
device count before jax initializes.
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (_flags +
                               " --xla_force_host_platform_device_count=4")

import argparse
import json
import re
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.gpt2 import GPT2_FIDELITY
from repro.core import classify_leaves, init_compressor_state, make_plan
from repro.core import bucketing
from repro.core.compressor import sync_grads
from repro.dist.collectives import make_dp_pmean, shard_map_dp
from repro.launch.hlo_cost import analyze_hlo, xla_cost_analysis
from repro.launch.mesh import dp_axes, make_host_mesh
from repro.models.model import build_model
from repro.train.step import replicate_comp_state

WORLD = 4


def _setup():
    model = build_model(GPT2_FIDELITY)
    params = model.init(jax.random.PRNGKey(0))
    leaves = classify_leaves(params, GPT2_FIDELITY.num_layers, 4, min_dim=64)
    assert len(leaves) >= 32, len(leaves)
    plan = make_plan("fixed", leaves, fixed_rank=8)
    mesh = make_host_mesh(data=WORLD, model=1)
    rng = np.random.default_rng(0)
    gstack = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.standard_normal((WORLD,) + p.shape),
                              jnp.float32), params)
    return params, leaves, plan, mesh, gstack


def _build_sync(params, leaves, plan, mesh, bucketed, codec=None):
    axes = dp_axes(mesh)
    layout = bucketing.make_bucket_layout(leaves, plan)
    comp = init_compressor_state(params, plan, jax.random.PRNGKey(1),
                                 layout=layout if bucketed else None,
                                 wire_ef=codec is not None)
    comp = replicate_comp_state(comp, WORLD)

    def local(gs, cs):
        squeeze = lambda t: jax.tree_util.tree_map(lambda a: a[0], t)
        synced, c2 = sync_grads(squeeze(gs), squeeze(cs), plan,
                                make_dp_pmean(axes), bucketed=bucketed,
                                codec=codec)
        return synced, jax.tree_util.tree_map(lambda a: a[None], c2)

    fn = shard_map_dp(local, mesh, in_specs=(P(("data",)), P(("data",))),
                      out_specs=(P(), P(("data",))), manual_axes=axes)
    return jax.jit(fn), comp, layout


def _count_collectives(lowered_text: str) -> int:
    return len(re.findall(r"all_reduce|all-reduce", lowered_text))


def _analyze(tag, jfn, gstack, comp):
    lowered = jfn.lower(gstack, comp)
    n_coll = _count_collectives(lowered.as_text())
    compiled = lowered.compile()
    hlo = analyze_hlo(compiled.as_text())
    xla = xla_cost_analysis(compiled)
    return compiled, {
        "tag": tag,
        "collective_ops": n_coll,
        "collective_bytes": hlo["collective_bytes"],
        "xla_flops": xla.get("flops", 0.0),
    }


def _time_round(compiled, gstack, st, iters):
    t0 = time.perf_counter()
    for _ in range(iters):
        synced, st = compiled(gstack, st)
    jax.block_until_ready(st)
    return (time.perf_counter() - t0) / iters, st


def _wire_section(params, leaves, plan, mesh, gstack, smoke: bool) -> dict:
    """Coded bytes (+ sync wall time, full runs) per wire mode.

    The byte numbers are the exact planned payload (packed words + scales)
    vs the same sync priced at raw fp32; the smoke path additionally
    asserts the pack/unpack kernels round-trip bit-exactly.
    """
    from repro.core import plan_wire_bytes, wire
    from repro.kernels import ops as kops

    rng = np.random.default_rng(1)
    for bits in (4, 8):
        codes = jnp.asarray(
            rng.integers(0, 1 << bits, size=20000), jnp.int32)
        back = kops.unpack_bits(kops.pack_bits(codes, bits), bits,
                                codes.shape[0])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))
    print("wire_pack_roundtrip,0.000,bits=4/8 bit-exact")

    raw_fp32 = plan_wire_bytes(leaves, plan, 4)[0]
    section = {"raw_fp32_bytes": raw_fp32}
    for mode in ("raw", "quant8", "quant4"):
        codec = wire.resolve_codec(mode)
        coded = plan_wire_bytes(leaves, plan, 4, codec=codec)[0]
        entry = {"coded_bytes": coded,
                 "reduction_vs_raw_fp32": coded / raw_fp32}
        if not smoke and codec is not None:
            jfn, comp, _ = _build_sync(params, leaves, plan, mesh, True,
                                       codec=codec)
            compiled = jfn.lower(gstack, comp).compile()
            _, st = compiled(gstack, comp)          # warm-up
            best = float("inf")
            for _ in range(3):
                dt, st = _time_round(compiled, gstack, st, iters=6)
                best = min(best, dt)
            entry["us_per_sync"] = best * 1e6
        section[mode] = entry
        us = f"{entry.get('us_per_sync', 0.0):.3f}"
        print(f"wire_{mode},{us},coded_bytes={coded} "
              f"({entry['reduction_vs_raw_fp32']:.3f}x raw fp32)")

    assert section["quant8"]["coded_bytes"] < raw_fp32, "coded must beat raw"
    assert section["quant8"]["coded_bytes"] <= 0.5 * raw_fp32, (
        "quant8 payload must be <= 0.5x the raw fp32 payload")
    assert (section["quant4"]["coded_bytes"]
            < section["quant8"]["coded_bytes"])
    return section


def run(smoke: bool = False, out: str = "BENCH_sync.json"):
    params, leaves, plan, mesh, gstack = _setup()
    results, compiled, states = {}, {}, {}
    for bucketed in (False, True):
        tag = "bucketed" if bucketed else "per_leaf"
        jfn, comp, layout = _build_sync(params, leaves, plan, mesh, bucketed)
        compiled[tag], results[tag] = _analyze(tag, jfn, gstack, comp)
        if bucketed:
            results[tag]["layout"] = {
                "groups": len(layout.groups),
                "buckets": len(layout.buckets),
                "planned_collectives": layout.num_collectives(),
            }
        if not smoke:
            _, states[tag] = compiled[tag](gstack, comp)     # warm-up
    if not smoke:
        # interleave timing rounds so background-load drift hits both
        # executors equally; keep each executor's best round (min is the
        # standard noise-robust statistic for wall-clock microbenchmarks)
        best = {tag: float("inf") for tag in results}
        for _ in range(5):
            for tag in results:
                dt, states[tag] = _time_round(compiled[tag], gstack,
                                              states[tag], iters=6)
                best[tag] = min(best[tag], dt)
        for tag in results:
            results[tag]["us_per_sync"] = best[tag] * 1e6

    ratio = results["bucketed"]["collective_ops"] / results["per_leaf"]["collective_ops"]
    for tag in ("per_leaf", "bucketed"):
        r = results[tag]
        # smoke asserts the (deterministic) op-count collapse only; a
        # 3-iter timing sample is noise and would read as a perf claim
        us = f"{r['us_per_sync']:.3f}" if "us_per_sync" in r else "0.000"
        print(f"sync_{tag},{us},collectives={r['collective_ops']}")
    print(f"sync_collective_ratio,{ratio:.4f},bucketed/per_leaf")
    if not smoke:
        speedup = (results["per_leaf"]["us_per_sync"]
                   / results["bucketed"]["us_per_sync"])
        print(f"sync_speedup,{speedup:.3f},per_leaf_us/bucketed_us")

    assert ratio <= 0.25, (
        f"bucketed sync lowers to {ratio:.0%} of per-leaf collectives; "
        f"must be <= 25%")

    wire_section = _wire_section(params, leaves, plan, mesh, gstack, smoke)

    if not smoke:
        payload = {
            "config": GPT2_FIDELITY.name,
            "world": WORLD,
            "num_leaves": len(leaves),
            "num_compressed": len(plan.ranks),
            "results": results,
            "collective_ratio": ratio,
            "sync_speedup": speedup,
            "wire": wire_section,
        }
        with open(out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {out}")
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast run; assert the collective-count drop only")
    ap.add_argument("--out", default="BENCH_sync.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
