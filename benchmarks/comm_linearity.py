"""Fig. 9 / Eq. 3 reproduction: T_com(r) is linear in rank, MAPE small.

The paper measures wall-clock all-reduce time on its V100 cluster and fits
T = eta*r with MAPE 2.85%. Here the byte counts are EXACT (PowerSGD moves
(m+n)*r per leaf) and the wire model is the analytic TPU ICI ring; we
additionally inject multiplicative measurement noise to show the fit's MAPE
at paper-like noise levels, and verify Eq. 2's rank bound logic.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CommModel, rank_bounds
from repro.core.compressor import classify_leaves
from repro.configs.gpt2 import GPT2_2_5B
from repro.models.model import build_model

import jax

from .common import csv_row


def run() -> list[str]:
    rows = []
    t0 = time.time()

    # shapes of the real GPT2-2.5B compressed population (paper's model)
    cfg = GPT2_2_5B
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params_shapes, cfg.num_layers, cfg.num_stages,
                             min_dim=128)
    shapes = []
    for l in leaves:
        if l.eligible:
            m, n = l.shape[-2:]
            reps = int(np.prod(l.shape[:-2])) if len(l.shape) > 2 else 1
            shapes.extend([(m, n)] * reps)

    comm = CommModel.from_shapes(shapes, world=16)
    ranks = np.arange(4, 132, 8)
    t_exact = np.array([comm.t_com(r) for r in ranks])

    # exact linearity (structural claim)
    fit, mape0 = CommModel.fit(ranks, t_exact)
    rows.append(csv_row("fig9_eta_s_per_rank", (time.time()-t0)*1e6,
                        f"{fit.eta:.3e}"))
    rows.append(csv_row("fig9_mape_noiseless", 0.0, f"{mape0:.4%}"))

    # with paper-like measurement noise (3% multiplicative)
    rng = np.random.default_rng(0)
    noisy = t_exact * (1 + 0.03 * rng.standard_normal(len(ranks)))
    _, mape = CommModel.fit(ranks, noisy)
    rows.append(csv_row("fig9_mape_noisy3pct", 0.0, f"{mape:.4%}"))

    # Eq. 2 rank bounds on this population
    r_min, r_max = rank_bounds(comm, max_possible=min(min(s) for s in shapes) // 2)
    rows.append(csv_row("eq2_r_max", 0.0, str(r_max)))
    rows.append(csv_row("eq2_r_min", 0.0, str(r_min)))
    rows.append(csv_row("eq2_compression_pays_at_rmax", 0.0,
                        str(bool(comm.t_total(r_max) <= comm.t_uncompressed()))))
    rows.append(csv_row("eq2_t_uncompressed_s", 0.0,
                        f"{comm.t_uncompressed():.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
