"""Table V reproduction: entropy-estimation cost under GSR beta (+ ISR alpha).

Paper: beta=0.25 cuts per-iteration entropy time ~40% vs full data; combined
with alpha=0.1 the per-window total drops ~94%. We time the on-device
estimator at the paper's betas on a real gradient-sized tensor and derive
the same two ratios, plus validate that sampled entropy tracks full entropy.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entropy import histogram_entropy, strided_sample

from .common import csv_row


def _time_entropy(x, beta: float, iters: int = 20) -> float:
    @jax.jit
    def f(x):
        return histogram_entropy(strided_sample(x, beta))
    f(x).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        f(x).block_until_ready()
    return (time.time() - t0) / iters


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    # gradient-sized tensor (~13M entries, GPT2-345M layer scale)
    x = jnp.asarray(rng.standard_normal(13_000_000).astype(np.float32))

    times = {}
    h_full = float(histogram_entropy(x))
    for beta in (1.0, 0.5, 0.25, 0.05):
        s = _time_entropy(x, beta)
        times[beta] = s
        h_b = float(histogram_entropy(strided_sample(x, beta)))
        rows.append(csv_row(f"table5_beta{beta}_ms", s * 1e6, f"{s*1e3:.2f}"))
        rows.append(csv_row(f"table5_beta{beta}_entropy_abs_err", 0.0,
                            f"{abs(h_b - h_full):.4f}"))

    saving_b = 1 - times[0.25] / times[1.0]
    rows.append(csv_row("table5_beta0.25_time_saving", 0.0, f"{saving_b:.1%}"))
    # alpha=0.1: measure 1 iteration in 10 -> per-window cost scales by alpha
    alpha = 0.1
    combined = 1 - alpha * times[0.25] / times[1.0]
    rows.append(csv_row("table5_alpha0.1_beta0.25_window_saving", 0.0,
                        f"{combined:.1%}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
