"""CQM validation (Theorem 1 / Observation 3 / Fig. 10).

Claims validated:
  * the MP-law estimate g(r; m, n) matches the ACTUAL SVD truncation error
    of i.i.d. matrices to <1% (Theorem 1 soundness);
  * REAL gradient matrices compress with LOWER error than the i.i.d. theory
    predicts (Observation 3's correlation margin — the paper's safety
    argument for Constraint 1);
  * at fixed rank, compression error decays over training (Fig. 10 trend).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import theoretical_error

from .common import csv_row, fidelity_data, fidelity_trainer


def _actual_error(mat: np.ndarray, r: int) -> float:
    s = np.linalg.svd(mat, compute_uv=False)
    return float(np.sqrt((s[r:] ** 2).sum()))


def run(steps: int = 200) -> list[str]:
    rows = []
    rng = np.random.default_rng(0)

    # --- Theorem 1: MP estimate vs actual, i.i.d. matrices -----------------
    t0 = time.time()
    rel_errs = []
    for (m, n) in [(128, 512), (256, 1024), (512, 512)]:
        A = rng.standard_normal((m, n))
        for r in (8, 32, m // 4):
            pred = theoretical_error(r, m, n)
            act = _actual_error(A, r)
            rel_errs.append(abs(pred - act) / act)
    us = (time.time() - t0) * 1e6 / len(rel_errs)
    rows.append(csv_row("thm1_mp_vs_svd_max_rel_err", us,
                        f"{max(rel_errs):.4f}"))

    # --- Obs 3: real gradients beat the i.i.d. bound ------------------------
    t0 = time.time()
    tr = fidelity_trainer("none", steps)
    data = fidelity_data()
    batches = data.batches()
    # capture a real gradient mid-training
    tr.run(iter([next(batches) for _ in range(steps)]))
    import jax.numpy as jnp
    model = tr.model
    params = tr.state["params"]
    batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
    grads = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    margins = []
    for kp, g in flat:
        g = np.asarray(g, np.float64)
        if g.ndim == 3:          # stacked per-layer leaves: take each layer
            mats = [g[i] for i in range(g.shape[0])]
        elif g.ndim == 2:
            mats = [g]
        else:
            continue
        if "embed" in str(kp):
            continue
        for gm in mats:
            if min(gm.shape) < 64:
                continue
            m, n = sorted(gm.shape)
            sigma = gm.std()
            r = m // 8
            theory = theoretical_error(r, m, n, sigma)
            actual = _actual_error(gm if gm.shape[0] <= gm.shape[1] else gm.T, r)
            margins.append(actual / theory)
    us = (time.time() - t0) * 1e6 / max(1, len(margins))
    rows.append(csv_row("obs3_actual_over_theory_mean", us,
                        f"{np.mean(margins):.4f}"))
    rows.append(csv_row("obs3_grad_beats_iid_bound", us,
                        str(bool(np.mean(margins) < 1.0))))

    # --- Fig 10: fixed-rank error decays over training ----------------------
    t0 = time.time()
    tr2 = fidelity_trainer("fixed", 2 * steps, rank=16)
    data2 = fidelity_data(seed=1)
    b_iter = data2.batches()

    def err_at(trainer):
        params = trainer.state["params"]
        batch = {k: jnp.asarray(v) for k, v in next(b_iter).items()}
        grads = jax.grad(lambda p: trainer.model.loss_fn(p, batch)[0])(params)
        errs, abs_errs = [], []
        for kp, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            g = np.asarray(g, np.float64)
            if "embed" in str(kp):
                continue
            mats = [g[i] for i in range(g.shape[0])] if g.ndim == 3 \
                else ([g] if g.ndim == 2 else [])
            for gm in mats:
                if min(gm.shape) < 64:
                    continue
                gm = gm if gm.shape[0] <= gm.shape[1] else gm.T
                ae = _actual_error(gm, 16)
                abs_errs.append(ae)
                errs.append(ae / (np.linalg.norm(gm) + 1e-12))
        return float(np.mean(errs)), float(np.mean(abs_errs))

    tr2.run(b_iter, num_steps=steps // 2)
    rel_early, abs_early = err_at(tr2)
    tr2.run(b_iter, num_steps=3 * steps // 2)
    rel_late, abs_late = err_at(tr2)
    us = (time.time() - t0) * 1e6 / (2 * steps)
    # paper Fig. 10 plots ABSOLUTE error at fixed rank: it decays because
    # sigma decays (Obs 2); the norm-relative error stays roughly flat
    # (correlations weaken over training, Obs 3's own caveat).
    rows.append(csv_row("fig10_abs_err_early", us, f"{abs_early:.5f}"))
    rows.append(csv_row("fig10_abs_err_late", us, f"{abs_late:.5f}"))
    rows.append(csv_row("fig10_abs_err_decays", us,
                        str(bool(abs_late < abs_early))))
    rows.append(csv_row("fig10_rel_err_early", us, f"{rel_early:.4f}"))
    rows.append(csv_row("fig10_rel_err_late", us, f"{rel_late:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
