"""Fig. 14 / Alg. 2 reproduction: stage-aligned vs globally-synchronized rank.

The ablated baseline gives every pipeline stage the same rank (stage 1's);
stage alignment lets later stages run LARGER ranks inside their timing slack
(Eq. 4), so their reconstruction error is strictly lower at zero added
critical-path time. We compute both rank vectors from the same comm model
and compare the per-stage theoretical reconstruction error + the timing
balance claim.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import CommModel, stage_aligned_ranks, theoretical_error
from repro.core.compressor import classify_leaves
from repro.configs.gpt2 import GPT2_2_5B
from repro.models.model import build_model

import jax

from .common import csv_row


def run() -> list[str]:
    t0 = time.time()
    cfg = GPT2_2_5B
    model = build_model(cfg)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    leaves = classify_leaves(params_shapes, cfg.num_layers, 4, min_dim=128)
    shapes = [l.shape[-2:] for l in leaves if l.eligible]
    comm = CommModel.from_shapes(shapes, world=16)

    num_stages = 4
    r1 = 32
    # per-stage backprop slack: one micro-batch backward, analytic
    t_micro = comm.t_com(8)
    aligned = stage_aligned_ranks(r1, num_stages, comm, t_micro, 8, 128)
    ablated = [r1] * num_stages

    m, n = max(shapes, key=lambda s: s[0] * s[1])
    m, n = sorted((m, n))
    err_aligned = [theoretical_error(r, m, n) for r in aligned]
    err_ablated = [theoretical_error(r, m, n) for r in ablated]
    rel_impr = 1 - np.sum(err_aligned) / np.sum(err_ablated)

    # timing balance: stage i finishes comm at t_com(r_i) - (i-1)*t_micro skew
    finish = [comm.t_com(r) - i * t_micro for i, r in enumerate(aligned)]
    spread = (max(finish) - min(finish)) / max(finish)

    us = (time.time() - t0) * 1e6
    return [
        csv_row("fig14_aligned_ranks", us, ";".join(map(str, aligned))),
        csv_row("fig14_ablated_ranks", 0.0, ";".join(map(str, ablated))),
        csv_row("fig14_error_improvement", 0.0, f"{rel_impr:.2%}"),
        csv_row("fig14_aligned_error_lower", 0.0,
                str(bool(np.sum(err_aligned) <= np.sum(err_ablated)))),
        csv_row("fig14_comm_finish_spread", 0.0, f"{spread:.2%}"),
    ]


if __name__ == "__main__":
    for r in run():
        print(r)
