"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (per the scaffold contract).

  PYTHONPATH=src python -m benchmarks.run              # everything
  PYTHONPATH=src python -m benchmarks.run --only table3,fig9
  PYTHONPATH=src python -m benchmarks.run --fast       # shorter runs
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("obs_entropy", "benchmarks.obs_entropy"),      # Fig. 2 / Fig. 3
    ("cqm_error", "benchmarks.cqm_error"),          # Thm. 1 / Obs. 3 / Fig. 10
    ("comm_linearity", "benchmarks.comm_linearity"),  # Fig. 9 / Eq. 2-3
    ("table3", "benchmarks.table3_train"),          # Table III
    ("table5", "benchmarks.table5_gsr"),            # Table V
    ("table6", "benchmarks.table6_comm"),           # Table VI
    ("table7", "benchmarks.table7_window"),         # Table VII
    ("fig14", "benchmarks.fig14_stage"),            # Fig. 14 / Alg. 2
    ("pipeline", "benchmarks.pipeline_overlap"),    # §IV-D schedules / Eq. 4
    ("roofline", "benchmarks.roofline"),            # §Roofline (from dry-run)
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    import importlib
    print("name,us_per_call,derived")
    failed = []
    for name, modpath in SUITES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = importlib.import_module(modpath)
            kwargs = {}
            if args.fast:
                import inspect
                if "steps" in inspect.signature(mod.run).parameters:
                    kwargs["steps"] = 100
            for row in mod.run(**kwargs):
                print(row, flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            traceback.print_exc()
            print(f"{name}_FAILED,0,{e}", flush=True)
            failed.append(name)
    if failed:
        sys.exit(f"benchmark suites failed: {failed}")


if __name__ == "__main__":
    main()
